"""Observability BENCH artifact CLI (thin adapter).

Benchmarks the tracing layer (:mod:`repro.obs`) across its three gate
axes — enabled-tracing overhead on the heavy-tail sim at 1024 workers
(<= 5 % wall-clock, identical virtual schedule), byte-identical
``repro.obs/v1`` summaries across same-seed reruns, and straggler
attribution (the 0.25x-speed workers of ``stragglers_10pct`` must rank
slowest by measured ``speed_est``) — and writes a schema-validated
``BENCH_obs.json`` (``repro.bench.obs/v1``).  Exits non-zero if any
scenario misses its check (CI gates on the quick tier).

    PYTHONPATH=src python benchmarks/obs_bench.py --quick
    PYTHONPATH=src python benchmarks/obs_bench.py \\
        --quick --trace-out trace.json --summary-out TRACE_summary.json

``--summary-out`` reproduces the committed reference summary
(``benchmarks/refs/TRACE_heavy_tail_quick.json``) byte-for-byte at the
default seed.  The scenario declarations and record layout live in
:mod:`repro.bench.obs` (``python -m repro.bench.obs`` is the same
entry point).
"""

from __future__ import annotations

import sys

from repro.bench.obs import main

if __name__ == "__main__":
    sys.exit(main())
