"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * paper_tables: Tables I/II + Figs 4-9 + §IV.A/B/C + §V headline
    numbers, reproduced by the calibrated full-scale simulator;
  * beyond_paper: beyond-paper scenarios (stragglers, speculation, ...);
  * kernels_bench: Pallas kernel micro-benchmarks vs jnp oracles;
  * dispatch_bench: protocol-core dispatch throughput (deque vs the old
    O(n^2) list.pop(0) manager);
  * roofline_table: per-(arch x shape x mesh) roofline terms from the
    multi-pod dry-run records (skipped if dryrun hasn't run).

``--backend {threads,processes,sim}`` instead runs one fixed-seed
self-scheduled smoke workload through the unified runtime entry point
(``repro.runtime.run_job``) and exits non-zero unless every task
completes — the CI smoke job is ``benchmarks/run.py --backend sim``.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _smoke_fn(task):
    time.sleep(task.size_bytes * 2e-5)   # pretend to parse a file
    return task.size_bytes


def run_backend_smoke(backend: str) -> int:
    from repro.core.messages import Task
    from repro.core.triples import TriplesConfig
    from repro.runtime import run_job

    tasks = [Task(task_id=f"t{i:04d}", size_bytes=(i * 37) % 23 + 1,
                  timestamp=i) for i in range(200)]
    triple = TriplesConfig(nodes=1, nppn=8)     # 8 processes, 7 workers
    r = run_job(tasks, _smoke_fn, backend=backend, triple=triple,
                tasks_per_message=5, poll_interval=0.002)
    print("name,us_per_call,derived")
    print(f"run_job_{backend},{r.job_seconds * 1e6 / len(tasks):.1f},"
          f"tasks={len(r.completed_ids)}_msgs={r.messages_sent}"
          f"_workers={len(r.worker_stats)}", flush=True)
    ok = r.completed_ids == {t.task_id for t in tasks}
    if not ok:
        print(f"run_job_{backend},0,ERROR_incomplete", flush=True)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=["threads", "processes", "sim"],
                    help="run a fixed-seed run_job smoke workload on one "
                         "execution backend instead of the full suite")
    args = ap.parse_args()
    if args.backend:
        sys.exit(run_backend_smoke(args.backend))

    from benchmarks import (beyond_paper, dispatch_bench, kernels_bench,
                            paper_tables, roofline_table)

    print("name,us_per_call,derived")
    groups = [("paper", paper_tables.ALL),
              ("beyond", beyond_paper.ALL),
              ("kernels", kernels_bench.ALL),
              ("dispatch", dispatch_bench.ALL),
              ("roofline", roofline_table.ALL)]
    failures = 0
    for _gname, fns in groups:
        for fn in fns:
            try:
                for row in fn():
                    print(row, flush=True)
            except Exception as e:     # keep the harness going
                failures += 1
                print(f"{fn.__name__},0,ERROR_{type(e).__name__}",
                      flush=True)
                traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
