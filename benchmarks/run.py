"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * paper_tables: Tables I/II + Figs 4-9 + §IV.A/B/C + §V headline
    numbers, reproduced by the calibrated full-scale simulator (scenario
    declarations live in repro.bench.paper);
  * beyond_paper: beyond-paper scenarios (stragglers, speculation, ...);
  * kernel_bench: Pallas kernel micro-benchmarks vs jnp oracles;
  * dispatch_bench: protocol-core dispatch throughput (deque vs the old
    O(n^2) list.pop(0) manager);
  * roofline_table: per-(arch x shape x mesh) roofline terms from the
    multi-pod dry-run records (skipped if dryrun hasn't run).

``--backend {threads,processes,sim}`` instead runs one fixed-seed
self-scheduled smoke workload through the unified runtime entry point
(``repro.runtime.run_job``) and writes a structured ``BENCH_smoke.json``
record; it exits non-zero if the record is schema-invalid or any
completion check fails — the CI smoke job is
``benchmarks/run.py --backend sim``.

For the full structured campaign artifact (per-scenario reference deltas,
regression gates), use ``python -m repro.bench.campaign``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

SMOKE_OUT = "BENCH_smoke.json"


def run_backend_smoke(backend: str, out: str = SMOKE_OUT) -> int:
    from repro.bench import (
        Check, RunSpec, Scenario, csv_rows, run_scenario)
    from repro.bench.schema import (
        SCHEMA_VERSION, SMOKE_SCHEMA, validate_smoke)

    sc = Scenario(
        name=f"run_job_{backend}", group="smoke", tier="quick",
        run=RunSpec(dataset="smoke", phase="organize", backend=backend,
                    n_workers=7, nodes=1, nppn=8, tasks_per_message=5),
        checks=(Check("tasks_completed", "within_abs", 200.0, 0.0,
                      "smoke invariant (exactly-once completion)"),
                Check("messages_sent", "within_abs", 40.0, 0.0,
                      "smoke invariant (200 tasks / 5 per message)")))
    record = run_scenario(sc)
    doc = {"schema": SMOKE_SCHEMA, "schema_version": SCHEMA_VERSION,
           "created_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
           "scenario": record}
    problems = validate_smoke(doc)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print("name,us_per_call,derived")
    print(csv_rows([record])[0], flush=True)
    if problems:
        print(f"{out} is SCHEMA-INVALID: " + "; ".join(problems),
              file=sys.stderr)
        return 2
    print(f"wrote {out}")
    if record["status"] != "pass":
        print(f"smoke {record['status']}: {record.get('error') or record['checks']}",
              file=sys.stderr)
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=["threads", "processes", "sim"],
                    help="run a fixed-seed run_job smoke workload on one "
                         "execution backend instead of the full suite")
    ap.add_argument("--smoke-out", default=SMOKE_OUT,
                    help=f"smoke artifact path (default {SMOKE_OUT})")
    args = ap.parse_args()
    if args.backend:
        sys.exit(run_backend_smoke(args.backend, args.smoke_out))

    from benchmarks import (beyond_paper, dispatch_bench, kernel_bench,
                            paper_tables, roofline_table)

    print("name,us_per_call,derived")
    groups = [("paper", paper_tables.ALL),
              ("beyond", beyond_paper.ALL),
              ("kernels", kernel_bench.ALL),
              ("dispatch", dispatch_bench.ALL),
              ("roofline", roofline_table.ALL)]
    failures = 0
    for _gname, fns in groups:
        for fn in fns:
            try:
                for row in fn():
                    print(row, flush=True)
            except Exception as e:     # keep the harness going
                failures += 1
                print(f"{fn.__name__},0,ERROR_{type(e).__name__}",
                      flush=True)
                traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
