"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * paper_tables: Tables I/II + Figs 4-9 + §IV.A/B/C + §V headline
    numbers, reproduced by the calibrated full-scale simulator;
  * kernels_bench: Pallas kernel micro-benchmarks vs jnp oracles;
  * roofline_table: per-(arch x shape x mesh) roofline terms from the
    multi-pod dry-run records (skipped if dryrun hasn't run).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (beyond_paper, kernels_bench, paper_tables,
                            roofline_table)

    print("name,us_per_call,derived")
    groups = [("paper", paper_tables.ALL),
              ("beyond", beyond_paper.ALL),
              ("kernels", kernels_bench.ALL),
              ("roofline", roofline_table.ALL)]
    failures = 0
    for _gname, fns in groups:
        for fn in fns:
            try:
                for row in fn():
                    print(row, flush=True)
            except Exception as e:     # keep the harness going
                failures += 1
                print(f"{fn.__name__},0,ERROR_{type(e).__name__}",
                      flush=True)
                traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
