"""One benchmark per paper table/figure (simulator at full LLSC scale).

Each function returns a list of CSV rows ``name,us_per_call,derived``:
  * us_per_call — wall-clock microseconds to produce the benchmark
    (i.e. simulator cost on this container);
  * derived — the headline figure-of-merit the paper reports
    (job seconds, reduction %, span hours, ...).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ARCHIVE_PHASE, ORGANIZE_PHASE, PROCESS_PHASE, RADAR_PHASE,
    feasible_table_cells, simulate_self_scheduling, simulate_static)
from repro.core.cost_model import LEGACY_LAUNCH_PENALTY
from repro.tracks.datasets import (
    aircraft_archive_manifest, monday_manifest, processing_manifest,
    radar_message_manifest)

PAPER_TABLE1 = {(2048, 32): 5640, (1024, 32): 5944, (512, 32): 7493,
                (256, 32): 11944, (1024, 16): 5963, (512, 16): 7157,
                (256, 16): 11860, (512, 8): 6989, (256, 8): 11860}
PAPER_TABLE2 = {(2048, 32): 5456, (1024, 32): 5704, (512, 32): 6608,
                (256, 32): 11015, (1024, 16): 5568, (512, 16): 6330,
                (256, 16): 10428, (512, 8): 6171, (256, 8): 10428}


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def table1_organize_chrono() -> list[str]:
    """TABLE I: organize dataset #1, chronological + self-scheduling."""
    tasks = monday_manifest()
    rows = []
    for cores, nppn in feasible_table_cells():
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=cores - 1, nodes=cores // nppn, nppn=nppn,
            model=ORGANIZE_PHASE, organization="chronological"))
        paper = PAPER_TABLE1[(cores, nppn)]
        rows.append(f"table1_c{cores}_n{nppn},{us:.0f},"
                    f"{r.job_seconds:.0f}s_sim_vs_{paper}s_paper")
    return rows


def table2_organize_size() -> list[str]:
    """TABLE II: organize dataset #1, largest-first + self-scheduling."""
    tasks = monday_manifest()
    rows = []
    for cores, nppn in feasible_table_cells():
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=cores - 1, nodes=cores // nppn, nppn=nppn,
            model=ORGANIZE_PHASE, organization="largest_first"))
        paper = PAPER_TABLE2[(cores, nppn)]
        rows.append(f"table2_c{cores}_n{nppn},{us:.0f},"
                    f"{r.job_seconds:.0f}s_sim_vs_{paper}s_paper")
    return rows


def fig4_jobtime() -> list[str]:
    """Fig 4: job time vs cores; the 50%-fewer-nodes headline."""
    tasks = monday_manifest()
    (better, worse), us = _timed(lambda: (
        simulate_self_scheduling(tasks, n_workers=1023, nodes=64, nppn=16,
                                 model=ORGANIZE_PHASE,
                                 organization="largest_first"),
        simulate_self_scheduling(tasks, n_workers=2047, nodes=64, nppn=32,
                                 model=ORGANIZE_PHASE,
                                 organization="chronological")))
    return [f"fig4_1024c16_size_beats_2048c32_chrono,{us:.0f},"
            f"{better.job_seconds:.0f}s<{worse.job_seconds:.0f}s"]


def fig56_worker_dists() -> list[str]:
    """Figs 5-6: worker-time distribution shift/shape."""
    tasks = monday_manifest()
    rows = []
    for org in ("chronological", "largest_first"):
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=255, nodes=32, nppn=8, model=ORGANIZE_PHASE,
            organization=org))
        busy = np.array([b for b in r.worker_busy if b > 0])
        rows.append(
            f"fig56_{org},{us:.0f},"
            f"median={np.median(busy):.0f}s_span={r.worker_time_span:.0f}s")
    return rows


def fig7_tasks_per_message() -> list[str]:
    """Fig 7: performance decrease as tasks/message increases."""
    tasks = monday_manifest()
    rows = []
    for k in (1, 2, 4, 8, 16):
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=511, nodes=64, nppn=8, model=ORGANIZE_PHASE,
            organization="largest_first", tasks_per_message=k))
        rows.append(f"fig7_k{k},{us:.0f},{r.job_seconds:.0f}s")
    return rows


def sec4b_archive_cyclic() -> list[str]:
    """§IV.B: block -> cyclic archive job time reduction (>90%)."""
    arch = aircraft_archive_manifest()
    (rb, rc), us = _timed(lambda: (
        simulate_static(arch, n_workers=1023, nodes=64, nppn=16,
                        model=ARCHIVE_PHASE, policy="block"),
        simulate_static(arch, n_workers=1023, nodes=64, nppn=16,
                        model=ARCHIVE_PHASE, policy="cyclic")))
    red = (1 - rc.job_seconds / rb.job_seconds) * 100
    return [f"sec4b_block_to_cyclic,{us:.0f},"
            f"{red:.1f}pct_reduction_paper_gt90"]


def sec4a_median_worker() -> list[str]:
    """§IV.A: median worker time -14% vs legacy batch/block."""
    tasks = monday_manifest()
    (rs, rb), us = _timed(lambda: (
        simulate_self_scheduling(tasks, n_workers=255, nodes=32, nppn=8,
                                 model=ORGANIZE_PHASE,
                                 organization="largest_first"),
        simulate_static(tasks, n_workers=255, nodes=32, nppn=8,
                        model=ORGANIZE_PHASE, policy="block",
                        organization="chronological",
                        legacy_launch_penalty=LEGACY_LAUNCH_PENALTY)))
    delta = (rs.median_worker_busy / rb.median_worker_busy - 1) * 100
    return [f"sec4a_median_worker_delta,{us:.0f},"
            f"{delta:.1f}pct_paper_minus14"]


def fig8_processing() -> list[str]:
    """§IV.C / Fig 8: processing worker-time distribution."""
    proc = processing_manifest()
    r, us = _timed(lambda: simulate_self_scheduling(
        proc, n_workers=1023, nodes=64, nppn=16, model=PROCESS_PHASE,
        organization="random"))
    busy = np.array([b for b in r.worker_busy if b > 0])
    return [f"fig8_processing,{us:.0f},"
            f"median={np.median(busy)/3600:.1f}h_paper13.1"
            f"_max={busy.max()/3600:.1f}h_paper29.6"]


def fig8_legacy_batch() -> list[str]:
    """§IV.C: legacy batch/block needs >7 days."""
    proc = processing_manifest()
    r, us = _timed(lambda: simulate_static(
        proc, n_workers=1023, nodes=32, nppn=32, model=PROCESS_PHASE,
        policy="block", organization="filename",
        legacy_launch_penalty=LEGACY_LAUNCH_PENALTY))
    return [f"fig8_legacy_batch_block,{us:.0f},"
            f"{r.job_seconds/86400:.1f}days_paper_gt7"]


def fig9_radar() -> list[str]:
    """§V / Fig 9: radar dataset, 300 tasks/message, tight span."""
    rad = radar_message_manifest()
    r, us = _timed(lambda: simulate_self_scheduling(
        rad, n_workers=1023, nodes=128, nppn=8, model=RADAR_PHASE,
        organization="random"))
    busy = np.array([b for b in r.worker_busy if b > 0])
    return [f"fig9_radar,{us:.0f},"
            f"median={np.median(busy)/3600:.2f}h_paper24.34"
            f"_span={(busy.max()-busy.min())/3600:.2f}h_paper1.12"]


ALL = [table1_organize_chrono, table2_organize_size, fig4_jobtime,
       fig56_worker_dists, fig7_tasks_per_message, sec4b_archive_cyclic,
       sec4a_median_worker, fig8_processing, fig8_legacy_batch, fig9_radar]
