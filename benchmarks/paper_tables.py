"""One benchmark per paper table/figure — thin adapter over repro.bench.

The scenario *declarations* (datasets, triples, organizations, reference
cells, tolerances) live in :mod:`repro.bench.paper`; this module only
groups them for the historical ``name,us_per_call,derived`` CSV harness
(benchmarks/run.py).  For the structured artifact with per-cell deltas
and pass/fail checks, run ``python -m repro.bench.campaign`` instead.
"""

from __future__ import annotations

from repro.bench import csv_rows, paper_scenarios, run_scenario
from repro.bench.paper import (          # noqa: F401  (back-compat re-export)
    PAPER_TABLE1, PAPER_TABLE2, TABLE_TOLERANCE)


def _rows(*groups: str) -> list[str]:
    return csv_rows([run_scenario(sc) for sc in paper_scenarios()
                     if sc.group in groups])


def table1_organize_chrono() -> list[str]:
    """TABLE I: organize dataset #1, chronological + self-scheduling."""
    return _rows("table1")


def table2_organize_size() -> list[str]:
    """TABLE II: organize dataset #1, largest-first + self-scheduling."""
    return _rows("table2")


def fig4_jobtime() -> list[str]:
    """Fig 4: job time vs cores; the 50%-fewer-nodes headline."""
    return _rows("fig4")


def fig56_worker_dists() -> list[str]:
    """Figs 5-6: worker-time distribution shift/shape."""
    return _rows("fig56")


def fig7_tasks_per_message() -> list[str]:
    """Fig 7: performance decrease as tasks/message increases."""
    return _rows("fig7")


def sec4a_median_worker() -> list[str]:
    """§IV.A: median worker time -14% vs legacy batch/block."""
    return _rows("sec4a")


def sec4b_archive_cyclic() -> list[str]:
    """§IV.B: block -> cyclic archive job time reduction (>90%)."""
    return _rows("sec4b")


def fig89_processing_radar() -> list[str]:
    """§IV.C / Fig 8 + §V / Fig 9: processing + radar distributions."""
    return _rows("fig8", "fig9")


ALL = [table1_organize_chrono, table2_organize_size, fig4_jobtime,
       fig56_worker_dists, fig7_tasks_per_message, sec4a_median_worker,
       sec4b_archive_cyclic, fig89_processing_radar]
