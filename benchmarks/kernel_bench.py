"""Kernel-level BENCH artifact CLI (thin adapter).

Runs the fused segment pipeline against the unfused three-launch
baseline over synthetic segment-length workloads and writes a
schema-validated ``BENCH_kernels.json`` (``repro.bench.kernels/v1``)
with throughput, padded-element fraction, intermediate host<->device
transfer counts, and per-bucket compile cache hits.  Exits non-zero if
any scenario misses its check (CI gates on the quick tier).

    PYTHONPATH=src python benchmarks/kernel_bench.py --quick
    PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_kernels.json

The scenario declarations and record layout live in
:mod:`repro.bench.kernels` (``python -m repro.bench.kernels`` is the
same entry point).
"""

from __future__ import annotations

import sys

from repro.bench.kernels import main

if __name__ == "__main__":
    sys.exit(main())
