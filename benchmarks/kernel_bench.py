"""Canonical kernel benchmark entry point.

Two roles in one module:

* **CLI** — runs the fused segment pipeline against the unfused
  three-launch baseline over synthetic segment-length workloads and
  writes a schema-validated ``BENCH_kernels.json``
  (``repro.bench.kernels/v1``) with throughput, padded-element
  fraction, intermediate host<->device transfer counts, and per-bucket
  compile cache hits.  Exits non-zero if any scenario misses its check
  (CI gates on the quick tier).

      PYTHONPATH=src python benchmarks/kernel_bench.py --quick
      PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_kernels.json

  The scenario declarations and record layout live in
  :mod:`repro.bench.kernels` (``python -m repro.bench.kernels`` is the
  same entry point).

* **CSV micro-benchmarks** (``ALL``, consumed by ``benchmarks/run.py``)
  — Pallas (interpret) kernels vs their jnp oracles plus real
  workflow-throughput figures.  On TPU the same harness times the
  compiled kernels; here the derived column reports tracks/second of
  the oracle path (the honest CPU number) plus the Pallas-vs-ref
  agreement.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.kernels import ops, ref


def _time_call(fn, *args, iters=3, **kw):
    fn(*args, **kw)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6, out


def bench_track_interp() -> list[str]:
    rng = np.random.default_rng(0)
    B, N, C, M = 8, 512, 3, 1024
    t_in = np.sort(rng.uniform(0, 900, (B, N)), axis=1).astype(np.float32)
    v_in = rng.normal(size=(B, C, N)).astype(np.float32)
    count = np.full((B,), N, np.int32)
    t_out = np.sort(rng.uniform(0, 900, (B, M)), axis=1).astype(np.float32)
    us_ref, out_ref = _time_call(ref.track_interp_ref, t_in, v_in,
                                 count, t_out)
    us_pal, out_pal = _time_call(ops.track_interp, t_in, v_in, count,
                                 t_out)
    err = float(np.abs(np.asarray(out_ref) - np.asarray(out_pal)).max())
    return [
        f"kernel_track_interp_ref_B{B}xN{N}xM{M},{us_ref:.0f},"
        f"{B / (us_ref/1e6):.0f}tracks_per_s",
        f"kernel_track_interp_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_dynamic_rates() -> list[str]:
    rng = np.random.default_rng(1)
    B, M = 16, 1024
    v = rng.normal(size=(B, 3, M)).astype(np.float32)
    count = np.full((B,), M, np.int32)
    us_ref, o1 = _time_call(ref.dynamic_rates_ref, v, count, 1.0)
    us_pal, o2 = _time_call(ops.dynamic_rates, v, count, 1.0)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_dynamic_rates_ref_B{B}xM{M},{us_ref:.0f},"
        f"{B*M/(us_ref/1e6)/1e6:.1f}Mpts_per_s",
        f"kernel_dynamic_rates_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_agl_lookup() -> list[str]:
    rng = np.random.default_rng(2)
    B, M, H, W = 8, 1024, 256, 512
    dem = rng.uniform(0, 3000, (H, W)).astype(np.float32)
    fi = rng.uniform(4, 100, (B, M)).astype(np.float32)
    fj = rng.uniform(4, 200, (B, M)).astype(np.float32)
    alt = rng.uniform(0, 4000, (B, M)).astype(np.float32)
    us_ref, o1 = _time_call(ref.agl_lookup_ref, dem, fi, fj, alt)
    us_pal, o2 = _time_call(ops.agl_lookup, dem, fi, fj, alt)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_agl_lookup_ref_B{B}xM{M},{us_ref:.0f},"
        f"{B*M/(us_ref/1e6)/1e6:.1f}Mlookups_per_s",
        f"kernel_agl_lookup_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_flash_attention() -> list[str]:
    rng = np.random.default_rng(3)
    B, H, KV, T, hd = 1, 4, 2, 512, 64
    q = rng.normal(size=(B, H, T, hd)).astype(np.float32)
    k = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
    v = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
    us_ref, o1 = _time_call(ref.flash_attention_ref, q, k, v)
    us_pal, o2 = _time_call(ops.flash_attention, q, k, v, iters=1)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_flash_attn_ref_B{B}H{H}T{T},{us_ref:.0f},"
        f"{B*H*T*T*hd*4/(us_ref/1e6)/1e9:.1f}GFLOP_s",
        f"kernel_flash_attn_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_fused_segment_pipeline() -> list[str]:
    """One fused ops.process_segments bucket vs the three separate ops
    (the full fused-vs-unfused comparison is the CLI's BENCH artifact)."""
    rng = np.random.default_rng(4)
    B, N, K = 16, 128, 256
    H, W = 209, 473
    dem = rng.uniform(0, 2500, (H, W)).astype(np.float32)
    grid = (24.0, 50.0, -125.0, -66.0, 8.0)
    t_in = np.sort(rng.uniform(0, 250, (B, N)), axis=1).astype(np.float32)
    v_in = np.stack([40 + rng.normal(0, .01, (B, N)),
                     -100 + rng.normal(0, .01, (B, N)),
                     1500 + rng.normal(0, 5, (B, N))],
                    axis=1).astype(np.float32)
    count_in = np.full((B,), N, np.int32)
    t_out = np.tile(np.arange(K, dtype=np.float32), (B, 1))
    count_out = np.full((B,), K, np.int32)

    def unfused():
        interp = np.asarray(ops.track_interp(t_in, v_in, count_in, t_out))
        lat, lon, alt = interp[..., 0], interp[..., 1], interp[..., 2]
        fi = (np.clip(lat, grid[0], grid[1]) - grid[0]) * grid[4]
        fj = (np.clip(lon, grid[2], grid[3]) - grid[2]) * grid[4]
        agl = np.asarray(ops.agl_lookup(dem, fi, fj, alt))
        v_grid = np.stack([lat, lon, alt], axis=1).astype(np.float32)
        return agl, np.asarray(ops.dynamic_rates(v_grid, count_out, 1.0))

    def fused():
        out = ops.process_segments(dem, t_in, v_in, count_in, t_out,
                                   count_out, grid=grid)
        # fetch once so the timing covers the device work (the unfused
        # closure blocks on its np.asarray hops)
        return {k: np.asarray(v) for k, v in out.items()}

    us_unf, _ = _time_call(lambda: unfused())
    us_fus, out = _time_call(lambda: fused())
    return [
        f"segment_pipeline_unfused_B{B}xK{K},{us_unf:.0f},"
        f"{B / (us_unf/1e6):.0f}segs_per_s",
        f"segment_pipeline_fused_B{B}xK{K},{us_fus:.0f},"
        f"speedup={us_unf/us_fus:.2f}x",
    ]


ALL = [bench_track_interp, bench_dynamic_rates, bench_agl_lookup,
       bench_flash_attention, bench_fused_segment_pipeline]


if __name__ == "__main__":
    from repro.bench.kernels import main

    sys.exit(main())
