"""Encounter-screening BENCH artifact CLI (thin adapter).

Benchmarks the spatial-hash + fused-kernel encounter screen
(:mod:`repro.geometry.gridhash`, :mod:`repro.kernels.encounter_screen`)
across density x backend x policy cells — candidate-set exactness
against the numpy brute-force all-pairs reference, live fused-kernel
speedup at aerodrome density, and simulated policy makespan on the
quadratic per-cell cost skew — and writes a schema-validated
``BENCH_encounters.json`` (``repro.bench.encounters/v1``).  Exits
non-zero if any scenario misses its check (CI gates on the quick tier:
exact candidates on dense jit AND pallas cells, kernel >= 5x brute at
aerodrome density, sized_lpt/adaptive_chunk >= 1.3x static makespan).

    PYTHONPATH=src python benchmarks/encounters_bench.py --quick
    PYTHONPATH=src python benchmarks/encounters_bench.py --out BENCH_encounters.json

The scenario declarations and record layout live in
:mod:`repro.bench.encounters` (``python -m repro.bench.encounters`` is
the same entry point).
"""

from __future__ import annotations

import sys

from repro.bench.encounters import main

if __name__ == "__main__":
    sys.exit(main())
