"""Storage-layer BENCH artifact CLI (thin adapter).

Benchmarks the columnar track store (:mod:`repro.store`) against the
paper's CSV-zip stopgap — batch-feed throughput into the fused segment
pipeline across cold/warm x sync/prefetch cells — and writes a
schema-validated ``BENCH_storage.json`` (``repro.bench.storage/v1``)
with bytes-per-point, prefetch wait fraction, bitwise feed-equality and
rebuild-determinism metrics.  Exits non-zero if any scenario misses its
check (CI gates on the quick tier: store+prefetch >= 2x the zip path).

    PYTHONPATH=src python benchmarks/storage_bench.py --quick
    PYTHONPATH=src python benchmarks/storage_bench.py --out BENCH_storage.json

The scenario declarations and record layout live in
:mod:`repro.bench.storage` (``python -m repro.bench.storage`` is the
same entry point).
"""

from __future__ import annotations

import sys

from repro.bench.storage import main

if __name__ == "__main__":
    sys.exit(main())
