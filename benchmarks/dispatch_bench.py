"""Manager dispatch micro-benchmark (satellite of the runtime refactor).

The old Manager popped its queue with ``list.pop(0)`` and pruned
in-flight ids with ``list.remove`` — O(n²) across a job.  The unified
protocol core uses ``collections.deque`` + per-worker ``set``s.  These
rows measure a full dispatch->done cycle per task through
``SchedulerCore`` against the old list-based pattern, at queue depths
where the difference matters (the radar workload of §V dispatches 43,969
message units).
"""

from __future__ import annotations

import time

from repro.core.messages import Task
from repro.runtime.protocol import SchedulerCore

N_WORKERS = 64
SIZES = (10_000, 50_000)


def _tasks(n: int) -> list[Task]:
    return [Task(task_id=f"t{i:06d}", size_bytes=(i * 37) % 9973 + 1)
            for i in range(n)]


def bench_dispatch_core():
    """deque/set protocol core: full assign+done cycle per task."""
    rows = []
    for n in SIZES:
        tasks = _tasks(n)
        core = SchedulerCore(tasks, organization="largest_first",
                             tasks_per_message=1)
        t0 = time.perf_counter()
        i = 0
        while core.pending:
            wid = f"w{i % N_WORKERS}"
            batch = core.next_batch(wid)
            core.on_done(wid, [t.task_id for t in batch])
            i += 1
        dt = time.perf_counter() - t0
        rows.append(f"dispatch_core_n{n},{dt / n * 1e6:.3f},"
                    f"dispatches_per_s={n / dt:,.0f}")
    return rows


def bench_dispatch_list_pop0():
    """The old Manager's pattern: ``list.pop(0)`` queue pops (the dominant
    O(n²) term) plus per-worker in-flight lists pruned with
    ``list.remove`` on each simulated DONE."""
    rows = []
    for n in SIZES:
        pending = sorted(_tasks(n), key=lambda t: -t.size_bytes)
        in_flight: dict[str, list[str]] = {
            f"w{w}": [] for w in range(N_WORKERS)}
        t0 = time.perf_counter()
        i = 0
        while pending:
            wid = f"w{i % N_WORKERS}"
            t = pending.pop(0)
            fl = in_flight[wid]
            fl.append(t.task_id)
            if len(fl) > 1:          # DONE for this worker's previous task
                fl.remove(fl[0])
            i += 1
        dt = time.perf_counter() - t0
        rows.append(f"dispatch_list_pop0_n{n},{dt / n * 1e6:.3f},"
                    f"dispatches_per_s={n / dt:,.0f}")
    return rows


ALL = [bench_dispatch_core, bench_dispatch_list_pop0]
