"""Scheduling-policy BENCH artifact CLI (thin adapter).

Benchmarks the pluggable scheduling policies
(:mod:`repro.runtime.policies`) across policy x dataset x fault-profile
x backend cells — simulated makespan + worker-busy quantiles on the
heavy-tailed manifests, and live store-backed prefetch-wait attribution
for shard_affinity — and writes a schema-validated
``BENCH_scheduling.json`` (``repro.bench.scheduling/v1``).  Exits
non-zero if any scenario misses its check (CI gates on the quick tier:
adaptive_chunk and sized_lpt >= 1.3x static makespan on the heavy-tail
dataset with 20 % worker deaths, shard_affinity cutting measured
prefetch wait vs fifo_selfsched).

    PYTHONPATH=src python benchmarks/scheduling_bench.py --quick
    PYTHONPATH=src python benchmarks/scheduling_bench.py --out BENCH_scheduling.json

The scenario declarations and record layout live in
:mod:`repro.bench.scheduling` (``python -m repro.bench.scheduling`` is
the same entry point).
"""

from __future__ import annotations

import sys

from repro.bench.scheduling import main

if __name__ == "__main__":
    sys.exit(main())
