"""DEPRECATED alias of :mod:`benchmarks.kernel_bench`.

The two modules drifted apart (``kernel_bench.py`` grew the structured
``BENCH_kernels.json`` CLI while this one held the CSV micro-benchmark
functions); they are now merged in ``kernel_bench.py``.  This shim
re-exports the public surface and will be removed — update imports to
``from benchmarks import kernel_bench``.
"""

from __future__ import annotations

import warnings

from benchmarks.kernel_bench import (  # noqa: F401
    ALL,
    bench_agl_lookup,
    bench_dynamic_rates,
    bench_flash_attention,
    bench_fused_segment_pipeline,
    bench_track_interp,
)

warnings.warn(
    "benchmarks.kernels_bench is deprecated; use benchmarks.kernel_bench",
    DeprecationWarning,
    stacklevel=2,
)
