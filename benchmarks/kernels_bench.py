"""Kernel micro-benchmarks: Pallas (interpret) + jnp oracle + real
workflow-throughput figures. On TPU the same harness times the compiled
kernels; here the derived column reports tracks/second of the oracle
path (the honest CPU number) plus the Pallas-vs-ref agreement."""

from __future__ import annotations

import time

import numpy as np

from repro.kernels import ops, ref


def _time_call(fn, *args, iters=3, **kw):
    fn(*args, **kw)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6, out


def bench_track_interp() -> list[str]:
    rng = np.random.default_rng(0)
    B, N, C, M = 8, 512, 3, 1024
    t_in = np.sort(rng.uniform(0, 900, (B, N)), axis=1).astype(np.float32)
    v_in = rng.normal(size=(B, C, N)).astype(np.float32)
    count = np.full((B,), N, np.int32)
    t_out = np.sort(rng.uniform(0, 900, (B, M)), axis=1).astype(np.float32)
    us_ref, out_ref = _time_call(ref.track_interp_ref, t_in, v_in,
                                 count, t_out)
    us_pal, out_pal = _time_call(ops.track_interp, t_in, v_in, count,
                                 t_out)
    err = float(np.abs(np.asarray(out_ref) - np.asarray(out_pal)).max())
    return [
        f"kernel_track_interp_ref_B{B}xN{N}xM{M},{us_ref:.0f},"
        f"{B / (us_ref/1e6):.0f}tracks_per_s",
        f"kernel_track_interp_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_dynamic_rates() -> list[str]:
    rng = np.random.default_rng(1)
    B, M = 16, 1024
    v = rng.normal(size=(B, 3, M)).astype(np.float32)
    count = np.full((B,), M, np.int32)
    us_ref, o1 = _time_call(ref.dynamic_rates_ref, v, count, 1.0)
    us_pal, o2 = _time_call(ops.dynamic_rates, v, count, 1.0)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_dynamic_rates_ref_B{B}xM{M},{us_ref:.0f},"
        f"{B*M/(us_ref/1e6)/1e6:.1f}Mpts_per_s",
        f"kernel_dynamic_rates_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_agl_lookup() -> list[str]:
    rng = np.random.default_rng(2)
    B, M, H, W = 8, 1024, 256, 512
    dem = rng.uniform(0, 3000, (H, W)).astype(np.float32)
    fi = rng.uniform(4, 100, (B, M)).astype(np.float32)
    fj = rng.uniform(4, 200, (B, M)).astype(np.float32)
    alt = rng.uniform(0, 4000, (B, M)).astype(np.float32)
    us_ref, o1 = _time_call(ref.agl_lookup_ref, dem, fi, fj, alt)
    us_pal, o2 = _time_call(ops.agl_lookup, dem, fi, fj, alt)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_agl_lookup_ref_B{B}xM{M},{us_ref:.0f},"
        f"{B*M/(us_ref/1e6)/1e6:.1f}Mlookups_per_s",
        f"kernel_agl_lookup_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


def bench_flash_attention() -> list[str]:
    rng = np.random.default_rng(3)
    B, H, KV, T, hd = 1, 4, 2, 512, 64
    q = rng.normal(size=(B, H, T, hd)).astype(np.float32)
    k = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
    v = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
    us_ref, o1 = _time_call(ref.flash_attention_ref, q, k, v)
    us_pal, o2 = _time_call(ops.flash_attention, q, k, v, iters=1)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    return [
        f"kernel_flash_attn_ref_B{B}H{H}T{T},{us_ref:.0f},"
        f"{B*H*T*T*hd*4/(us_ref/1e6)/1e9:.1f}GFLOP_s",
        f"kernel_flash_attn_pallas_interpret,{us_pal:.0f},maxerr={err:.1e}",
    ]


ALL = [bench_track_interp, bench_dynamic_rates, bench_agl_lookup,
       bench_flash_attention]
