"""§Roofline: render the per-(arch x shape x mesh) table from the
dry-run JSON records (run launch/dryrun.py first)."""

from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import compute_terms

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_terms(dryrun_dir: str = DRYRUN_DIR):
    out = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(p))
        if rec.get("ok"):
            out.append((compute_terms(rec), rec))
    return out


OPT_DIR = os.path.join(os.path.dirname(__file__), "..",
                       "experiments", "dryrun_opt")


def roofline_rows() -> list[str]:
    """Baseline (paper-faithful) rows + optimized (§Perf) rows."""
    rows = []
    for tag, d in (("", DRYRUN_DIR), ("opt_", OPT_DIR)):
        for t, rec in load_terms(d):
            rows.append(
                f"roofline_{tag}{t.arch}_{t.shape}_{t.mesh},"
                f"{rec.get('compile_s', 0) * 1e6:.0f},"
                f"bound={t.bottleneck}"
                f"_comp={t.compute_s:.3f}s_mem={t.memory_s:.3f}s"
                f"_coll={t.collective_s:.3f}s"
                f"_useful={t.useful_ratio:.2f}"
                f"_roofline={t.roofline_fraction * 100:.1f}pct")
    return rows


def markdown_table(dryrun_dir: str = DRYRUN_DIR) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s "
        "| bottleneck | useful | roofline % |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for t, _rec in load_terms(dryrun_dir):
        lines.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {t.compute_s:.4f} "
            f"| {t.memory_s:.4f} | {t.collective_s:.4f} "
            f"| {t.bottleneck} | {t.useful_ratio:.2f} "
            f"| {t.roofline_fraction * 100:.1f} |")
    return "\n".join(lines)


ALL = [roofline_rows]

if __name__ == "__main__":
    print(markdown_table())
