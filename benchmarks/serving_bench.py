"""Continuous-ingest serving BENCH artifact CLI (thin adapter).

Benchmarks the serving mode (:mod:`repro.serving.ingest` /
:mod:`repro.serving.service`): an :class:`~repro.serving.IngestService`
tails a synthetic feed into the columnar store while a
:class:`~repro.serving.StoreFrontEnd` answers tiny ``latest``/``nearest``
lookups and generation-pinned snapshot reads, and writes a
schema-validated ``BENCH_serving.json`` (``repro.bench.serving/v1``).
Exits non-zero if any scenario misses its check (CI gates on the quick
tier: live-ingested store byte-identical to a batch build of the same
observations, tiny-query p99 under concurrent ingest <= 3x idle p99,
ingest backlog bounded by the shard target).

    PYTHONPATH=src python benchmarks/serving_bench.py --quick
    PYTHONPATH=src python benchmarks/serving_bench.py --out BENCH_serving.json

The scenario declarations and record layout live in
:mod:`repro.bench.serving` (``python -m repro.bench.serving`` is the
same entry point).
"""

from __future__ import annotations

import sys

from repro.bench.serving import main

if __name__ == "__main__":
    sys.exit(main())
