"""Beyond-paper benchmarks — the paper's own declared future work.

§VI: "Additional benchmarking is possible future work, as we did not
vary the number of threads" — plus two knobs the paper fixed on LLSC
advice (0.3 s poll) or abandoned after one data point (tasks/message).

  * threads_sweep    — vary threads-per-process at fixed cores
  * poll_sweep       — vary the 0.3 s poll interval
  * batching_regimes — tasks/message across task-size regimes: shows WHY
                       k>1 hurt dataset #1 (2425 big tasks) but k=300
                       was required for radar (13.2 M tiny tasks)
  * failure_sweep    — makespan vs worker-failure rate (self-scheduling's
                       re-queue keeps the job alive; the paper has no
                       failure story at all)
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (
    ORGANIZE_PHASE, RADAR_PHASE, simulate_self_scheduling)
from repro.core.cost_model import PhaseCostModel
from repro.tracks.datasets import monday_manifest, radar_message_manifest


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def threads_sweep() -> list[str]:
    """Threads-per-process: more threads/process at fixed total cores
    means fewer processes sharing the node's I/O path (lower effective
    NPPN) but also fewer concurrent workers. Model: nppn' = nppn/threads,
    workers' = workers/threads, per-task CPU / threads**0.7 (imperfect
    intra-task scaling)."""
    tasks = monday_manifest()
    rows = []
    for threads in (1, 2, 4):
        m = dataclasses.replace(
            ORGANIZE_PHASE,
            cpu_rate=ORGANIZE_PHASE.cpu_rate * threads ** 0.7)
        workers = 1024 // threads - 1
        nppn = max(16 // threads, 1)
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=workers, nodes=64, nppn=nppn, model=m,
            organization="largest_first"))
        rows.append(f"beyond_threads_{threads},{us:.0f},"
                    f"{r.job_seconds:.0f}s_{workers}workers")
    return rows


def poll_sweep() -> list[str]:
    """The 0.3 s poll was an LLSC recommendation, never benchmarked.
    For dataset #1's ~600 s tasks it is irrelevant; it only matters when
    tasks are near the poll scale."""
    tasks = monday_manifest()
    rows = []
    for poll in (0.05, 0.3, 2.0, 10.0):
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=511, nodes=64, nppn=8, model=ORGANIZE_PHASE,
            organization="largest_first", poll_interval=poll))
        rows.append(f"beyond_poll_{poll},{us:.0f},{r.job_seconds:.0f}s")
    return rows


def batching_regimes() -> list[str]:
    """tasks/message interacts with the task-size regime: batching is a
    load-balancing tax on big-task jobs and a manager-serialization
    rescue on tiny-task jobs."""
    rows = []
    # Regime 1: dataset #1 (2425 tasks, ~600 s each) — batching hurts.
    big = monday_manifest()
    for k in (1, 8):
        r, us = _timed(lambda: simulate_self_scheduling(
            big, n_workers=511, nodes=64, nppn=8, model=ORGANIZE_PHASE,
            organization="largest_first", tasks_per_message=k))
        rows.append(f"beyond_batch_bigtasks_k{k},{us:.0f},"
                    f"{r.job_seconds:.0f}s")
    # Regime 2: radar-like tiny tasks where the MANAGER's serial send
    # loop is the constraint (the reason §V used 300 tasks/message):
    # 131,400 x ~0.25 s tasks on 1023 workers — work/worker ~= 85 s while
    # unbatched messaging costs 131,400 x 2 ms = 263 s of pure manager
    # serialization. k=1 is manager-bound, k=300 is granularity-bound at
    # this task count, k=30 balances both.
    from repro.core.messages import Task
    rng = np.random.default_rng(0)
    tiny = [Task(task_id=f"t{i:06d}", size_bytes=400_000,
                 cpu_cost_hint=float(rng.gamma(8.0, 0.25 / 8)))
            for i in range(131_400)]
    for k in (1, 30, 300):
        r, us = _timed(lambda kk=k: simulate_self_scheduling(
            tiny, n_workers=1023, nodes=128, nppn=8, model=RADAR_PHASE,
            organization="random", tasks_per_message=kk))
        rows.append(
            f"beyond_batch_tinytasks_k{k},{us:.0f},"
            f"{r.job_seconds:.0f}s_msgs{r.messages_sent}")
    return rows


def failure_sweep() -> list[str]:
    """Worker deaths at increasing rates: self-scheduling re-queues the
    lost work; makespan grows ~linearly with lost capacity, no cliff."""
    tasks = monday_manifest()
    rows = []
    for frac in (0.0, 0.05, 0.2):
        n_workers = 511
        deaths = {i: 1000.0 + 7.0 * i
                  for i in range(int(n_workers * frac))}
        r, us = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=n_workers, nodes=64, nppn=8,
            model=ORGANIZE_PHASE, organization="largest_first",
            worker_death=deaths, failure_timeout=30.0))
        rows.append(
            f"beyond_failures_{int(frac*100)}pct,{us:.0f},"
            f"{r.job_seconds:.0f}s_reassigned{r.reassigned_tasks}")
    return rows


def straggler_sweep() -> list[str]:
    """Persistent SLOW workers (not dead — 4x slower): the quantitative
    version of the paper's central qualitative claim. Static distribution
    is hostage to its slowest assignee; self-scheduling routes work away
    from stragglers automatically."""
    from repro.core import simulate_static
    tasks = monday_manifest()
    n_workers = 511
    rows = []
    rng = np.random.default_rng(0)
    for frac in (0.0, 0.1):
        speed = np.ones(n_workers)
        slow = rng.choice(n_workers, int(n_workers * frac), replace=False)
        speed[slow] = 0.25
        rs, us1 = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=n_workers, nodes=64, nppn=8,
            model=ORGANIZE_PHASE, organization="largest_first",
            worker_speed=speed))
        rb, us2 = _timed(lambda: simulate_static(
            tasks, n_workers=n_workers, nodes=64, nppn=8,
            model=ORGANIZE_PHASE, policy="cyclic",
            organization="chronological", worker_speed=speed))
        rsp, us3 = _timed(lambda: simulate_self_scheduling(
            tasks, n_workers=n_workers, nodes=64, nppn=8,
            model=ORGANIZE_PHASE, organization="largest_first",
            worker_speed=speed, speculative=True))
        rows.append(
            f"beyond_stragglers_{int(frac*100)}pct,{us1+us2+us3:.0f},"
            f"selfsched={rs.job_seconds:.0f}s_static={rb.job_seconds:.0f}s"
            f"_speculative={rsp.job_seconds:.0f}s")
    return rows


ALL = [threads_sweep, poll_sweep, batching_regimes, failure_sweep,
       straggler_sweep]
