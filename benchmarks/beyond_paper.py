"""Beyond-paper benchmarks — thin adapter over repro.bench.beyond.

The sweep declarations (threads-per-process, poll interval, batching
regimes, failures, stragglers) live in :mod:`repro.bench.beyond` as
scenario-matrix cells; this module only groups them for the historical
CSV harness (benchmarks/run.py).
"""

from __future__ import annotations

from repro.bench import beyond_scenarios, csv_rows, run_scenario


def _rows(*groups: str) -> list[str]:
    return csv_rows([run_scenario(sc) for sc in beyond_scenarios()
                     if sc.group in groups])


def threads_sweep() -> list[str]:
    """Vary threads-per-process at fixed total cores (§VI future work)."""
    return _rows("beyond_threads")


def poll_sweep() -> list[str]:
    """Vary the 0.3 s poll interval (an LLSC recommendation, never
    benchmarked)."""
    return _rows("beyond_poll")


def batching_regimes() -> list[str]:
    """tasks/message across task-size regimes: a load-balancing tax on
    big-task jobs, a manager-serialization rescue on tiny-task jobs."""
    return _rows("beyond_batch_bigtasks", "beyond_batch_tinytasks")


def failure_sweep() -> list[str]:
    """Worker deaths at increasing rates: re-queue keeps the job alive."""
    return _rows("beyond_failures")


def straggler_sweep() -> list[str]:
    """Persistent 4x-slow workers: self-scheduling vs static vs
    speculative backup tasks."""
    return _rows("beyond_stragglers")


ALL = [threads_sweep, poll_sweep, batching_regimes, failure_sweep,
       straggler_sweep]
