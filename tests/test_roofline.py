"""HLO collective parsing + roofline term math."""

import glob
import json
import os

import pytest

from repro.roofline.analysis import RooflineTerms, compute_terms
from repro.roofline.hlo_parse import collective_bytes, parse_hlo_shapes

FAKE_HLO = """
HloModule jit_f, num_partitions=8

ENTRY %main_spmd (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %dot = f32[64,64]{1,0} dot(%p0, %p0)
  %all-reduce = f32[64,64]{1,0} all-reduce(%dot), channel_id=1, replica_groups={{0,2,4,6},{1,3,5,7}}, use_global_device_ids=true
  %ag = bf16[128,64]{1,0} all-gather(%small), dimensions={0}, replica_groups=[2,4]<=[8]
  %small = bf16[32,64]{1,0} copy(%p0)
  %rs = f32[8,64]{1,0} reduce-scatter(%all-reduce), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[64,64]{1,0} collective-permute(%dot), source_target_pairs={{0,1}}
  ROOT %out = f32[64,64]{1,0} add(%cp, %cp)
}
"""


def test_parse_hlo_shapes():
    sizes = parse_hlo_shapes(FAKE_HLO)
    assert sizes["p0"] == 64 * 64 * 4
    assert sizes["ag"] == 128 * 64 * 2
    assert sizes["small"] == 32 * 64 * 2
    assert sizes["rs"] == 8 * 64 * 4


def test_collective_bytes_categories():
    st = collective_bytes(FAKE_HLO, n_devices=8)
    f64 = 64 * 64 * 4
    # all-reduce over group of 4: operand f32[64,64]
    assert st.operand_bytes["all-reduce"] == f64
    assert abs(st.wire_bytes["all-reduce"] - 2 * 3 / 4 * f64) < 1e-6
    # all-gather: wire ~ (g-1)/g * output, group 4
    assert abs(st.wire_bytes["all-gather"] - 3 / 4 * 128 * 64 * 2) < 1e-6
    # reduce-scatter over 8: operand = f64
    assert abs(st.wire_bytes["reduce-scatter"] - 7 / 8 * f64) < 1e-6
    # collective-permute: operand bytes
    assert st.wire_bytes["collective-permute"] == f64


def test_roofline_terms_math():
    t = RooflineTerms(
        arch="a", shape="train_4k", mesh="16x16", chips=256,
        flops=197e12 * 0.5,            # 0.5 s of per-chip compute
        hbm_bytes=819e9 * 0.25,        # 0.25 s of HBM
        collective_bytes=50e9 * 1.0,   # 1.0 s of ICI
        model_flops=197e12 * 256 * 0.4).finalize()
    assert abs(t.compute_s - 0.5) < 1e-9
    assert abs(t.memory_s - 0.25) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.bottleneck == "collective"
    assert abs(t.useful_ratio - 0.8) < 1e-9
    assert abs(t.roofline_fraction - 0.4) < 1e-9


def test_compute_terms_composition():
    rec = {
        "arch": "a", "shape": "train_4k", "mesh": "16x16", "chips": 256,
        "n_superblocks": 10,
        "cost": {"flops": 100.0, "bytes accessed": 10.0},
        "block_cost": {"flops": 7.0, "bytes accessed": 1.0},
        "collectives": {"wire_bytes_total": 20.0},
        "block_collectives": {"wire_bytes_total": 2.0},
        "model_flops": 1e6,
    }
    t = compute_terms(rec)
    assert t.flops == 100.0 + 9 * 7.0
    assert t.hbm_bytes == 10.0 + 9 * 1.0
    assert t.collective_bytes == 20.0 + 9 * 2.0


DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
                    reason="dry-run records not generated yet")
def test_dryrun_records_all_ok_and_terms_positive():
    """Deliverable (e): every (arch x shape x mesh) cell compiled."""
    recs = [json.load(open(p))
            for p in glob.glob(os.path.join(DRYRUN_DIR, "*.json"))]
    assert len(recs) >= 60            # 32 cells x 2 meshes
    meshes = {r["mesh"] for r in recs}
    assert meshes == {"16x16", "2x16x16"}
    for r in recs:
        assert r["ok"], (r["arch"], r["shape"], r["mesh"], r.get("error"))
        t = compute_terms(r)
        assert t.compute_s > 0 and t.memory_s > 0
        assert t.collective_s >= 0
        assert r["memory"].get("temp_size_in_bytes", 1) >= 0
