"""Tests for the observability benchmark matrix + committed reference.

The deterministic cells (determinism, straggler ranking) run at their
real quick-tier size and must PASS; the overhead cell's executor is
exercised on its virtual-schedule invariant (``makespan_identical``)
without gating the wall-clock ratio here — pytest runs under arbitrary
load, so the ≤1.05 wall-clock gate belongs to the dedicated CI
obs-smoke job (and to ``benchmarks/obs_bench.py --quick`` locally).
The committed reference summary
(``benchmarks/refs/TRACE_heavy_tail_quick.json``) is regenerated
in-process and must match byte-for-byte — the test that keeps the CI
diff honest.
"""

import dataclasses
import json
import os

import pytest

from repro.bench import obs as obsbench
from repro.bench.compare import compare_docs, default_metric
from repro.bench.compare import main as compare_main
from repro.bench.schema import (
    OBS_BENCH_SCHEMA, canonical_bytes, validate_obs, validate_obs_summary)

_REF = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                    "refs", "TRACE_heavy_tail_quick.json")

#: Shrunk spec for executor-level tests (the real quick cells run the
#: full 12k-task workload; these keep unit runtime low).
_TINY = dataclasses.replace(obsbench._BASE, dataset_limit=1500,
                            n_workers=32, repeats=1)


def test_quick_tier_is_the_acceptance_cells():
    names = {sc.name for sc in obsbench.obs_scenarios()
             if sc.tier == "quick"}
    assert names == {"obs_overhead_heavy_tail_w1024",
                     "obs_determinism_heavy_tail",
                     "obs_straggler_ranking"}


def test_spec_validation():
    with pytest.raises(ValueError):
        obsbench.ObsSpec(kind="nope")
    with pytest.raises(ValueError):
        obsbench.ObsSpec(backend="threads")
    with pytest.raises(ValueError):
        obsbench.ObsSpec(fault_profile="nope")
    with pytest.raises(ValueError):
        obsbench.ObsSpec(repeats=0)


def test_overhead_executor_schedule_invariant():
    out = obsbench._execute_overhead(
        dataclasses.replace(_TINY, kind="overhead"))
    m = out["metrics"]
    # Tracing must not change a single virtual decision, at any scale.
    assert m["makespan_identical"] == 1
    assert m["tasks_completed"] == 1500
    assert m["n_events"] > 4 * 1500 * 0.9
    assert m["events_dropped"] == 0
    assert out["measured"]["overhead_ratio"] > 0.0


def test_determinism_and_straggler_cells_pass_at_quick_size():
    doc = obsbench.run_obs_campaign(
        quick=True, filters=["determinism", "straggler"])
    assert validate_obs(doc) == []
    assert doc["summary"]["fail"] == 0 and doc["summary"]["error"] == 0
    by_name = {r["name"]: r for r in doc["scenarios"]}
    det = by_name["obs_determinism_heavy_tail"]
    assert det["metrics"]["summary_identical"] == 1
    assert det["metrics"]["n_events_identical"] == 1
    strag = by_name["obs_straggler_ranking"]
    assert strag["metrics"]["straggler_rank_correct"] == 1
    assert strag["metrics"]["bottom_k_hits"] \
        == strag["metrics"]["n_slow_workers"] > 0
    assert strag["metrics"]["straggler_count"] >= 1


def test_straggler_executor_requires_straggler_profile():
    rec = obsbench.run_obs_scenario(obsbench.ObsScenario(
        name="bad", group="obs_straggler",
        run=dataclasses.replace(_TINY, kind="straggler",
                                fault_profile="none")))
    assert rec["status"] == "error"
    assert "straggler" in rec["error"]


def test_campaign_doc_is_deterministic_modulo_wall_clock():
    kw = dict(quick=True, filters=["determinism"])
    a = obsbench.run_obs_campaign(**kw)
    b = obsbench.run_obs_campaign(**kw)
    assert canonical_bytes(a) == canonical_bytes(b)


def test_committed_reference_summary_is_current():
    """benchmarks/refs/TRACE_heavy_tail_quick.json == a fresh run."""
    _tracer, summary = obsbench.reference_run()
    assert validate_obs_summary(summary) == []
    with open(_REF, "rb") as f:
        assert f.read() == canonical_bytes(summary), \
            "committed reference trace summary is stale — regenerate " \
            "with: python benchmarks/obs_bench.py --quick " \
            "--summary-out benchmarks/refs/TRACE_heavy_tail_quick.json"


def test_compare_dispatch_for_obs_schemas(tmp_path, capsys):
    with open(_REF) as f:
        ref = json.load(f)
    assert default_metric(ref) == "critical_path_s"
    assert default_metric({"schema": OBS_BENCH_SCHEMA}) \
        == "makespan_seconds"
    rows, regressions = compare_docs(ref, ref)
    assert [r["name"] for r in rows] == ["heavy_tail_quick"]
    assert not regressions
    # The CLI path CI uses: ref vs fresh copy -> exit 0, info rows shown.
    dup = tmp_path / "fresh.json"
    dup.write_bytes(canonical_bytes(ref))
    assert compare_main([_REF, str(dup), "--threshold", "0.10"]) == 0
    out = capsys.readouterr().out
    assert "exec_p99_over_p50" in out
    assert "no regressions" in out
