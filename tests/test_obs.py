"""End-to-end tracing layer: span-ledger invariants + exporters.

The tracer is only trustworthy if its event stream is *exactly* the
run's history, so the core assertions here are ledger invariants over
real runs (property-tested via hypothesis / the tests/_compat shim):

  * exactly ONE ``exec`` span per completed task — per backend, under
    worker deaths and speculation;
  * every ``requeued`` task that later completed was re-``assigned``
    after the requeue;
  * a worker's ``exec`` spans never overlap on its own timeline (the
    live ``drive`` reconstruction clamps; the sim emits real windows);
  * same-seed sim traces are bitwise repeatable and their canonical
    summaries byte-identical.

Timing-sensitive span tests (store decode, ingest lifecycle) inject the
``_TickClock`` fake monotonic clock from ``test_store`` into the
*tracer* — zero sleeps, exact span arithmetic.  Exporters are checked by
round-trip (Perfetto) and by rendering (report CLI).
"""

import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.bench.schema import canonical_bytes, validate_obs_summary
from repro.core.cost_model import PHASES
from repro.core.messages import Task
from repro.obs import (
    INSTANT, Tracer, build_summary, from_chrome_trace, phase_of,
    summary_from_tracer, to_chrome_trace, write_trace_files)
from repro.obs.report import load_summary
from repro.obs.report import main as report_main
from repro.obs.report import render_report
from repro.runtime import run_job


class _TickClock:
    """Fake monotonic clock: advances one unit per reading."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def _tasks(n, *, mb=4):
    return [Task(task_id=f"t{i:04d}", size_bytes=(i % 5 + 1) * mb * 100_000,
                 timestamp=i) for i in range(n)]


def _sizeof(task):               # module-level: picklable
    return task.size_bytes


# ---------------------------------------------------------------------------
# Tracer mechanics.
# ---------------------------------------------------------------------------

def test_ring_eviction_and_dropped_accounting():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit(float(i), INSTANT, "e", "task", 0, f"t{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    # Oldest evicted first: the ring retains the newest four.
    assert [e[0] for e in tr.events] == [6.0, 7.0, 8.0, 9.0]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_raw_fast_path_accounts_like_emit():
    a, b = Tracer(capacity=3), Tracer(capacity=3)
    for i in range(5):
        a.emit(float(i), INSTANT, "e", "task", 0)
    raw = b.raw
    for i in range(5):
        raw((float(i), INSTANT, "e", "task", 0, None, None))
    b.emitted += 5
    assert b.events == a.events
    assert b.dropped == a.dropped == 2


def test_clock_injection_and_rebind():
    clock = _TickClock()
    tr = Tracer(clock=clock)
    assert tr.now() == 1.0 and tr.now() == 2.0
    tr.instant("i", "sched", "m")          # reads the injected clock
    assert tr.events[-1][0] == 3.0
    tr.set_clock(lambda: 42.0)
    tr.span("s", "sched", "m", tr.now(), tr.now() + 1.0)
    assert tr.events[-1][:2] == (42.0, 1.0)


def test_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_phase_of_buckets():
    assert phase_of("radar:t0042") == "radar"
    assert phase_of("t0042") == "all"
    assert phase_of(None) == "all"


# ---------------------------------------------------------------------------
# Span-ledger invariants over real runs.
# ---------------------------------------------------------------------------

def _ledger_invariants(events, completed_ids):
    """The invariants every traced run must satisfy (see module doc)."""
    completed = set(completed_ids)
    execs = [e for e in events if e[2] == "exec"]
    # Exactly one exec span per completed task, none for anything else.
    assert sorted(e[5] for e in execs) == sorted(completed)
    dones = [e[5] for e in events if e[2] == "done"]
    assert sorted(dones) == sorted(completed)
    # requeued -> later assigned for every task that finished.
    last_ass, last_req = {}, {}
    for i, e in enumerate(events):
        if e[2] == "assigned":
            last_ass[e[5]] = i
        elif e[2] == "requeued":
            last_req[e[5]] = i
    for tid, i in last_req.items():
        if tid in completed:
            assert last_ass.get(tid, -1) > i, \
                f"{tid} completed but never re-assigned after requeue"
    # Per-worker exec spans never overlap.
    by_worker = {}
    for e in execs:
        by_worker.setdefault(str(e[4]), []).append(e)
    for spans in by_worker.values():
        spans.sort(key=lambda e: e[0])
        for prev, nxt in zip(spans, spans[1:]):
            assert nxt[0] >= prev[0] + prev[1] - 1e-9


@st.composite
def _shapes(draw):
    n = draw(st.integers(4, 30))
    k = draw(st.integers(1, 3))
    org = draw(st.sampled_from(["largest_first", "chronological"]))
    seed = draw(st.integers(0, 4))
    return n, k, org, seed


@given(_shapes())
@settings(max_examples=8, deadline=None)
def test_sim_ledger_invariants_and_bitwise_repeatability(shape):
    n, k, org, seed = shape

    def run():
        tr = Tracer()
        res = run_job(_tasks(n), None, backend="sim", n_workers=3,
                      organization=org, tasks_per_message=k,
                      organize_seed=seed, cost_model=PHASES["process"],
                      worker_death={0: 2.0}, raise_on_failure=False,
                      tracer=tr)
        return tr, res

    tr, res = run()
    assert len(res.completed_ids) == n        # exactly-once under death
    _ledger_invariants(tr.events, res.completed_ids)
    tr2, _ = run()
    # Virtual-clock traces are bitwise repeatable...
    assert tr.events == tr2.events
    # ...and so are their canonical summary bytes.
    assert canonical_bytes(summary_from_tracer(tr, label="x")) \
        == canonical_bytes(summary_from_tracer(tr2, label="x"))


def test_sim_requeues_are_traced():
    tr = Tracer()
    run_job(_tasks(20), None, backend="sim", n_workers=3,
            cost_model=PHASES["process"], worker_death={0: 2.0},
            raise_on_failure=False, tracer=tr)
    names = {e[2] for e in tr.events}
    assert {"queued", "assigned", "exec", "done"} <= names
    assert "requeued" in names          # worker 0 died holding work
    assert any(e[2] == "worker_dead" and e[3] == "sched"
               for e in tr.events)


def test_live_threads_ledger_invariants():
    tr = Tracer()
    res = run_job(_tasks(12), _sizeof, backend="threads", n_workers=3,
                  tasks_per_message=2, tracer=tr)
    assert len(res.completed_ids) == 12
    _ledger_invariants(tr.events, res.completed_ids)
    # Live exec spans are drive-side reconstructions on the wall clock.
    assert all(e[1] >= 0.0 for e in tr.events if e[2] == "exec")


# ---------------------------------------------------------------------------
# Store + serving spans on an injected clock (zero sleeps).
# ---------------------------------------------------------------------------

@pytest.fixture()
def served_store(tmp_path):
    """A small committed store built through the serving ingest path."""
    import os

    from repro.serving import FeedSpec, IngestService, SyntheticFeed
    feed_dir = str(tmp_path / "feed")
    store_dir = str(tmp_path / "store")
    os.makedirs(feed_dir)
    feed = SyntheticFeed(feed_dir, FeedSpec(n_files=8, obs_per_file=48,
                                            seed=3))
    tr = Tracer(clock=_TickClock())
    svc = IngestService(feed_dir, store_dir, target_points=96, tracer=tr)
    feed.emit_all()
    svc.poll_once()
    manifest = svc.seal()
    return {"svc": svc, "tracer": tr, "store": store_dir,
            "manifest": manifest}


def test_ingest_lifecycle_spans_zero_sleep(served_store):
    tr = served_store["tracer"]
    serving = [e for e in tr.events if e[3] == "serving"]
    names = {e[2] for e in serving}
    assert {"ingest_scan", "ingest_cut", "ingest_build",
            "ingest_commit", "ingest_seal"} <= names
    builds = [e for e in serving if e[2] == "ingest_build"]
    commits = [e for e in serving if e[2] == "ingest_commit"]
    # One build + one commit span per committed shard, real durations
    # (the tick clock advances between the span's two readings).
    assert len(builds) == len(commits) \
        == len(served_store["manifest"].shards)
    assert all(e[1] > 0.0 for e in builds + commits)
    # Every serving event sits on the injected clock's timeline.
    assert all(0.0 < e[0] <= tr.clock.t for e in serving)


def test_store_reader_spans_zero_sleep(served_store):
    from repro.store.reader import TrackStore
    tr = Tracer(clock=_TickClock())
    store = TrackStore(served_store["store"], tracer=tr)
    n = len(list(store.iter_batches(prefetch=2)))
    assert n == len(served_store["manifest"].shards) > 1
    decodes = [e for e in tr.events if e[2] == "store_decode"]
    assert len(decodes) == n
    assert {e[4] for e in decodes} \
        == {s.shard_id for s in served_store["manifest"].shards}
    # extra carries the shard payload size for cost attribution.
    assert all(isinstance(e[6], int) and e[6] > 0 for e in decodes)
    assert all(e[1] > 0.0 for e in decodes)
    # The prefetch thread emitted handoff instants through the same
    # ring (GIL-atomic appends), and the consumer measured its waits.
    assert sum(1 for e in tr.events if e[2] == "store_prefetch") == n
    assert all(e[1] >= 0.0 for e in tr.events if e[2] == "store_wait")


def test_frontend_query_spans(served_store):
    from repro.serving import Query, StoreFrontEnd
    svc, tr = served_store["svc"], served_store["tracer"]
    front = StoreFrontEnd(svc, tiny_slots=1)   # inherits svc's tracer
    assert front.tracer is tr
    q1 = Query(1, "latest", {"track_id": sorted(svc.retained)[0]})
    q2 = Query(2, "latest", {"track_id": sorted(svc.retained)[0]})
    assert front.admit(q1)
    assert not front.admit(q2)                 # one tiny slot -> reject
    front.step()
    names = [(e[2], e[5]) for e in tr.events if e[4] == "frontend"]
    assert ("query_admit", "latest:1") in names
    assert ("query_reject", "latest:2") in names
    spans = [e for e in tr.events
             if e[2] == "query" and e[5] == "latest:1"]
    assert len(spans) == 1 and spans[0][1] > 0.0


# ---------------------------------------------------------------------------
# Straggler attribution.
# ---------------------------------------------------------------------------

def test_summary_speed_estimates_rank_slowed_worker_last():
    tr = Tracer()
    speed = [1.0] * 8
    speed[5] = 0.25
    run_job(_tasks(200), None, backend="sim", n_workers=8,
            cost_model=PHASES["process"], worker_speed=speed,
            raise_on_failure=False, tracer=tr)
    doc = summary_from_tracer(tr, label="stragglers")
    workers = {w: d for w, d in doc["workers"].items()
               if isinstance(d, dict)}
    ranked = sorted(workers, key=lambda w: workers[w]["speed_est"])
    assert ranked[0] == "5"
    assert workers["5"]["speed_est"] < 0.5
    # Healthy workers estimate near nominal speed.
    assert all(workers[w]["speed_est"] > 0.7 for w in ranked[1:])
    # The 4x-slowed worker's tasks blow past the 2x straggler line.
    assert doc["scenario"]["metrics"]["straggler_count"] > 0
    assert any(s["worker"] == "5" for s in doc["stragglers"])


def test_summary_is_schema_valid_and_normalized():
    tr = Tracer()
    run_job(_tasks(20), None, backend="sim", n_workers=4,
            cost_model=PHASES["process"], tracer=tr)
    doc = summary_from_tracer(tr, label="norm")
    assert validate_obs_summary(doc) == []
    # Canonical bytes round-trip through JSON unchanged.
    assert canonical_bytes(json.loads(canonical_bytes(doc))) \
        == canonical_bytes(doc)


def test_summary_worker_table_is_capped():
    events = [(float(i), 1.0, "exec", "task", i, f"t{i}", 100)
              for i in range(10)]
    doc = build_summary(events, max_workers=4)
    workers = doc["workers"]
    assert workers["_dropped_workers"] == 6
    assert len(workers) == 5               # 4 kept + the drop marker
    assert doc["scenario"]["metrics"]["n_workers_seen"] == 10


# ---------------------------------------------------------------------------
# Exporters: Perfetto round-trip + report rendering.
# ---------------------------------------------------------------------------

def test_perfetto_round_trip_preserves_structure():
    tr = Tracer(clock=_TickClock())
    tr.instant("queued", "task", 0, task_id="a:t1")
    tr.span("exec", "task", 3, 10.0, 12.5, task_id="a:t1", extra=4096)
    tr.instant("admit", "dag", "radar", extra=7)
    doc = to_chrome_trace(tr.events, label="rt")
    doc = json.loads(json.dumps(doc))          # must be JSON-clean
    back = from_chrome_trace(doc)
    t0 = min(e[0] for e in tr.events)

    def norm(events, rel):
        return [(round(e[0] - (t0 if rel else 0.0), 6), round(e[1], 6),
                 e[2], e[3], str(e[4]), e[5], e[6]) for e in events]

    assert norm(back, rel=False) == norm(tr.events, rel=True)
    # Instants survive as instants (INSTANT sentinel restored).
    assert sum(1 for e in back if e[1] == INSTANT) == 2


def test_write_trace_files_and_report(tmp_path, capsys):
    tr = Tracer()
    run_job(_tasks(30), None, backend="sim", n_workers=4,
            cost_model=PHASES["process"], tracer=tr)
    paths = write_trace_files(tr, str(tmp_path), label="smoke")
    # The report CLI reads both artifacts and tells the same story.
    for path in (paths["trace"], paths["summary"]):
        assert report_main([path]) == 0
        out = capsys.readouterr().out
        assert "critical path" in out
        assert "slowest workers" in out
    # trace.json reduces to the same headline metrics as the canonical
    # summary (timestamps go through the us scaling, hence approx).
    via_trace = load_summary(paths["trace"])
    with open(paths["summary"]) as f:
        direct = json.load(f)
    for key in ("n_exec_spans", "straggler_count", "n_workers_seen"):
        assert via_trace["scenario"]["metrics"][key] \
            == direct["scenario"]["metrics"][key]
    assert via_trace["scenario"]["metrics"]["critical_path_s"] \
        == pytest.approx(direct["scenario"]["metrics"]["critical_path_s"],
                         rel=1e-6)


def test_report_summary_out_rebuilds_canonical_bytes(tmp_path):
    tr = Tracer()
    run_job(_tasks(10), None, backend="sim", n_workers=2,
            cost_model=PHASES["process"], tracer=tr)
    direct = summary_from_tracer(tr, label="rebuild")
    trace = tmp_path / "trace.json"
    with open(trace, "w") as f:
        json.dump(to_chrome_trace(tr.events, label="rebuild"), f)
    out = tmp_path / "TRACE_summary.json"
    assert report_main([str(trace), "--summary-out", str(out)]) == 0
    rebuilt = json.loads(out.read_bytes())
    assert validate_obs_summary(rebuilt) == []
    assert rebuilt["scenario"]["metrics"]["n_exec_spans"] \
        == direct["scenario"]["metrics"]["n_exec_spans"]


def test_report_rejects_unknown_documents(tmp_path):
    bogus = tmp_path / "nope.json"
    bogus.write_text('{"schema": "other/v1"}')
    assert report_main([str(bogus)]) == 1


def test_render_report_lines_cover_every_section():
    tr = Tracer()
    run_job(_tasks(40), None, backend="sim", n_workers=4,
            cost_model=PHASES["process"],
            worker_speed=[1.0, 1.0, 0.25, 1.0], tracer=tr)
    lines = render_report(summary_from_tracer(tr, label="full"))
    text = "\n".join(lines)
    for needle in ("makespan", "lifecycle:", "per-phase critical path:",
                   "slowest workers", "dispatch timeline"):
        assert needle in text
