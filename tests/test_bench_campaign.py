"""The scenario-matrix campaign engine: schema validity, reproducibility,
reference checks, and the regression-compare tool.

Heavy paper scenarios are covered by tests/test_simulator_paper.py; here
the engine runs cheap smoke-dataset scenarios so the whole module stays
in the seconds range.
"""

import copy
import json
import subprocess
import sys
import os

import pytest

from repro.bench import (
    Check, RunSpec, Scenario, canonical_bytes, csv_rows, expand,
    paper_scenarios, run_campaign, run_scenario, smoke_scenarios,
    validate_campaign, validate_record)
from repro.bench.campaign import all_scenarios
from repro.bench.paper import PAPER_TABLE1, PAPER_TABLE2, TABLE_TOLERANCE

REPO = os.path.join(os.path.dirname(__file__), "..")


def _sim_scenario(name="mini_sim", checks=(), **over):
    kw = dict(dataset="smoke", phase="organize", backend="sim",
              n_workers=4, nodes=1, nppn=4, tasks_per_message=5)
    kw.update(over)
    return Scenario(name=name, group="mini", tier="quick",
                    run=RunSpec(**kw), checks=tuple(checks))


MINI = [
    _sim_scenario(),
    _sim_scenario(name="mini_threads", backend="threads",
                  checks=[Check("tasks_completed", "within_abs", 200, 0)]),
    _sim_scenario(name="mini_static", mode="static", policy="cyclic"),
]


@pytest.fixture(scope="module")
def campaign():
    return run_campaign(MINI)


def test_campaign_is_schema_valid(campaign):
    assert validate_campaign(campaign) == []


def test_campaign_statuses_and_summary(campaign):
    by_name = {r["name"]: r for r in campaign["scenarios"]}
    assert by_name["mini_sim"]["status"] == "ran"       # no checks
    assert by_name["mini_threads"]["status"] == "pass"
    assert campaign["summary"]["total"] == 3
    assert campaign["summary"]["pass"] == 1
    assert campaign["summary"]["fail"] == 0


def test_campaign_byte_identical_on_rerun(campaign):
    again = run_campaign(MINI)
    assert canonical_bytes(campaign) == canonical_bytes(again)


def test_canonical_excludes_wall_clock(campaign):
    doctored = copy.deepcopy(campaign)
    doctored["created_at"] = "1970-01-01T00:00:00+0000"
    doctored["timing"]["wall_s"] = 999.0
    for rec in doctored["scenarios"]:
        rec["timing"]["wall_s"] = 123.0
        rec["measured"]["job_seconds"] = 42.0 if rec["measured"] else None
    assert canonical_bytes(doctored) == canonical_bytes(campaign)


def test_live_record_splits_wall_clock_out_of_metrics(campaign):
    rec = {r["name"]: r for r in campaign["scenarios"]}["mini_threads"]
    # Deterministic protocol decisions stay in metrics...
    assert rec["metrics"]["messages_sent"] == 40
    assert rec["metrics"]["dispatch_digest"]
    # ...wall-clock measurements do not.
    assert "job_seconds" not in rec["metrics"]
    assert rec["measured"]["job_seconds"] > 0


def test_sim_and_live_share_dispatch_digest(campaign):
    by_name = {r["name"]: r for r in campaign["scenarios"]}
    assert (by_name["mini_sim"]["metrics"]["dispatch_digest"]
            == by_name["mini_threads"]["metrics"]["dispatch_digest"])


def test_failing_check_fails_scenario():
    sc = _sim_scenario(checks=[Check("job_seconds", "max", 0.0,
                                     source="impossible")])
    rec = run_scenario(sc)
    assert rec["status"] == "fail"
    assert rec["checks"][0]["passed"] is False
    assert validate_record(rec) == []


def test_error_scenario_recorded_not_raised():
    sc = Scenario(name="boom", group="mini",
                  run=RunSpec(dataset="does_not_exist"))
    rec = run_scenario(sc)
    assert rec["status"] == "error"
    assert "does_not_exist" in rec["error"]
    assert validate_record(rec) == []


def test_check_kinds():
    m = {"x": 110.0}
    assert Check("x", "within_rel", 100.0, 0.15).evaluate(m)["passed"]
    assert not Check("x", "within_rel", 100.0, 0.05).evaluate(m)["passed"]
    assert Check("x", "within_abs", 100.0, 10.0).evaluate(m)["passed"]
    assert Check("x", "min", 100.0).evaluate(m)["passed"]
    assert not Check("x", "max", 100.0).evaluate(m)["passed"]
    assert not Check("missing", "min", 0.0).evaluate(m)["passed"]
    with pytest.raises(ValueError):
        Check("x", "approximately", 1.0)


def test_baseline_scenario_derives_comparison_metrics():
    sc = Scenario(
        name="mini_vs_static", group="mini",
        run=RunSpec(dataset="smoke", backend="sim", n_workers=4,
                    nodes=1, nppn=4),
        baseline=RunSpec(dataset="smoke", backend="sim", mode="static",
                         policy="block", n_workers=4, nodes=1, nppn=4,
                         organization="filename"))
    rec = run_scenario(sc)
    assert rec["status"] == "ran"
    assert "job_seconds_reduction_pct" in rec["metrics"]
    assert rec["metrics"]["baseline_job_seconds"] > 0


def test_expand_matrix_product_and_names():
    scens = expand("g", dataset="smoke", n_workers=4,
                   tasks_per_message=[1, 2], organization=["random",
                                                           "largest_first"])
    assert len(scens) == 4
    names = {sc.name for sc in scens}
    assert "g_k1_orgrandom" in names
    assert len(names) == 4
    assert all(sc.group == "g" for sc in scens)


def test_declared_matrix_is_well_formed():
    scens = all_scenarios()
    names = [sc.name for sc in scens]
    assert len(names) == len(set(names)), "duplicate scenario names"
    quick = [sc for sc in scens if sc.tier == "quick"]
    # The quick tier carries every Table I/II reference cell.
    table_cells = [sc for sc in quick if sc.group in ("table1", "table2")]
    assert len(table_cells) == len(PAPER_TABLE1) + len(PAPER_TABLE2) == 18
    for sc in table_cells:
        assert sc.checks[0].tol == TABLE_TOLERANCE
        assert sc.checks[0].metric == "job_seconds"
    # Live smokes exist on both backends.
    assert {sc.run.backend for sc in smoke_scenarios()} >= {"threads",
                                                            "processes"}


def test_fault_profile_backend_mismatch_rejected():
    """A profile whose knobs the backend can't honor must fail loudly,
    not run fault-free while claiming to measure fault recovery."""
    with pytest.raises(ValueError, match="sim backend"):
        RunSpec(dataset="smoke", backend="threads",
                fault_profile="deaths_5pct")
    with pytest.raises(ValueError, match="live backend"):
        RunSpec(dataset="smoke", backend="sim",
                fault_profile="live_one_death")


def test_fault_profile_axis_materializes():
    from repro.bench.scenarios import FAULT_PROFILES
    deaths, speed, fail_after, slow = \
        FAULT_PROFILES["deaths_5pct"].materialize(100, seed=0)
    assert len(deaths) == 5 and speed is None and fail_after is None \
        and slow is None
    d2, s2, f2, sl2 = FAULT_PROFILES["stragglers_10pct"].materialize(
        100, seed=0)
    assert d2 is None and len(s2) == 100 and s2.count(0.25) == 10 \
        and sl2 is None
    _, _, _, sl3 = FAULT_PROFILES["live_slow4"].materialize(100, seed=0)
    assert sl3 == {"w0": 4.0}
    # Seeded: same straggler choice every time.
    assert s2 == FAULT_PROFILES["stragglers_10pct"].materialize(100, 0)[1]


def test_csv_rows_have_no_stray_commas(campaign):
    for row in csv_rows(campaign["scenarios"]):
        assert row.count(",") == 2, row


def test_compare_docs_flags_regressions(campaign):
    from repro.bench.compare import compare_docs
    slower = copy.deepcopy(campaign)
    for rec in slower["scenarios"]:
        if "job_seconds" in rec["metrics"]:
            rec["metrics"]["job_seconds"] *= 1.5
    rows, regs = compare_docs(campaign, slower, threshold=0.10)
    assert regs and all(r["delta_pct"] > 10 for r in regs)
    rows2, regs2 = compare_docs(campaign, campaign, threshold=0.10)
    assert not regs2
    # Live wall-clock job times must NOT be regression-gated.
    gated = {r["name"] for r in rows}
    assert "mini_threads" not in gated


@pytest.mark.slow
def test_campaign_cli_writes_valid_artifact(tmp_path):
    """End-to-end: the ``python -m repro.bench.campaign`` entry point."""
    out = tmp_path / "BENCH_campaign.json"
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.bench.campaign",
         "--filter", "smoke_threads", "--filter", "fig4",
         "--out", str(out)],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert validate_campaign(doc) == []
    assert {r["name"] for r in doc["scenarios"]} >= {
        "smoke_threads", "fig4_1024c16_size_beats_2048c32_chrono"}


@pytest.mark.slow
def test_benchmarks_smoke_writes_bench_smoke_json(tmp_path):
    from repro.bench.schema import validate_smoke
    out = tmp_path / "BENCH_smoke.json"
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--backend", "sim", "--smoke-out", str(out)],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert validate_smoke(doc) == []
    assert doc["scenario"]["status"] == "pass"
