"""End-to-end --screen workflow: barrier vs DAG byte-identity + exactness.

One scaled raw feed (single hourly file so the synthetic aircraft share
an hour and actually co-bin), pushed through the full store-input
pipeline twice — barrier mode and streaming-DAG mode — with screening
enabled.  The candidates.json artifacts must be byte-identical, and
their pair set must equal the brute-force all-pairs screen over the
same store-derived rows.
"""

import json
import os

import pytest

from repro.kernels.encounter_screen import brute_force_screen
from repro.tracks.segments import SegmentProcessor, segment_tasks_from_store
from repro.tracks.workflow import TrackWorkflow, _screen_rows_for_uri

# Calibrated so the ~60 co-located synthetic aircraft yield a small,
# non-empty candidate set (3 pairs) in a few screening cells.
SCREEN_KW = dict(
    input="store", store_target_points=2048, screen=True,
    screen_h_m=50_000.0, screen_v_m=1000.0, screen_cell_deg=1.0,
    n_workers=4, poll_interval=0.003)


def _run(root, mode):
    wf = TrackWorkflow(str(root), mode=mode, **SCREEN_KW)
    wf.generate_raw(n_files=1, scale=1e3)
    wf.run()
    return wf


@pytest.fixture(scope="module")
def barrier_wf(tmp_path_factory):
    return _run(tmp_path_factory.mktemp("screen_barrier"), "barrier")


@pytest.fixture(scope="module")
def dag_wf(tmp_path_factory):
    return _run(tmp_path_factory.mktemp("screen_dag"), "dag")


def test_barrier_candidates_artifact(barrier_wf):
    with open(barrier_wf.candidates_path) as f:
        doc = json.load(f)
    assert doc["schema"] == "repro.encounters/v1"
    assert doc["thresholds"] == {"h_m": 50_000.0, "v_m": 1000.0}
    assert doc["grid"]["cell_deg"] == 1.0
    cands = doc["candidates"]
    assert len(cands) >= 1
    # Canonical: a < b, sorted by (a, b), unique pairs.
    pairs = [(c["a"], c["b"]) for c in cands]
    assert all(a < b for a, b in pairs)
    assert pairs == sorted(set(pairs))


def test_dag_byte_identical_to_barrier(barrier_wf, dag_wf):
    with open(barrier_wf.candidates_path, "rb") as f:
        barrier = f.read()
    with open(dag_wf.candidates_path, "rb") as f:
        dag = f.read()
    assert barrier == dag


def test_candidates_equal_brute_force(barrier_wf):
    """The workflow's grid-screened candidates are exactly the brute
    force all-pairs set over the same store-derived rows."""
    proc = SegmentProcessor(backend=barrier_wf.backend,
                            pipeline=barrier_wf.pipeline)
    rows = []
    for t in segment_tasks_from_store(barrier_wf.store_dir,
                                      granularity="shard"):
        rows.extend(_screen_rows_for_uri(proc, t.payload))
    want = brute_force_screen(rows, config=barrier_wf.screen_config)
    with open(barrier_wf.candidates_path) as f:
        got = json.load(f)["candidates"]
    assert [(c["a"], c["b"]) for c in got] == \
        [(c["a"], c["b"]) for c in want]


def test_screen_resumes_when_artifact_missing(barrier_wf):
    """Deleting candidates.json and re-running only redoes screening
    (phases_done guard drops 'screen' when the artifact is gone)."""
    os.remove(barrier_wf.candidates_path)
    wf = TrackWorkflow(barrier_wf.root, mode="barrier", **SCREEN_KW)
    wf.run()
    assert os.path.exists(barrier_wf.candidates_path)
    test_barrier_candidates_artifact(barrier_wf)


def test_screen_requires_store_input(tmp_path):
    with pytest.raises(ValueError, match="store"):
        TrackWorkflow(str(tmp_path), screen=True, input="zip")
