"""Golden statistics for the synthetic dataset manifests.

The paper's Tables I/II and §IV/§V results are functions of the dataset
*statistics* (file counts, byte totals, size-distribution shape); these
tests pin the synthetic manifests to the published constants so a seed or
generator change can't silently move every downstream benchmark.
"""

import math

import numpy as np
import pytest

from repro.tracks import datasets as ds
from repro.tracks.datasets import get_manifest, manifest_stats

GB = 1_000_000_000


@pytest.fixture(scope="module")
def monday():
    return get_manifest("monday")


@pytest.fixture(scope="module")
def aerodrome():
    return get_manifest("aerodrome")


@pytest.fixture(scope="module")
def radar():
    return get_manifest("radar_messages")


# -- exact paper constants ------------------------------------------------


def test_monday_paper_constants(monday):
    """§III.B: 104 Mondays => 2425 hourly files, 714 GB."""
    s = manifest_stats(monday)
    assert s["count"] == ds.MONDAY_FILE_COUNT == 2425
    assert abs(s["total_bytes"] / (714 * GB) - 1) < 1e-4
    assert len({t.task_id for t in monday}) == 2425


def test_aerodrome_paper_constants(aerodrome):
    """§III.C: 695 bounding boxes x 196 days => 136,884 files, 847 GB."""
    s = manifest_stats(aerodrome)
    assert s["count"] == ds.AERODROME_FILE_COUNT == 136_884
    assert abs(s["total_bytes"] / (847 * GB) - 1) < 1e-4


def test_radar_paper_constants(radar):
    """§V: 13,190,700 ids / 300 per message => 43,969 messages."""
    assert len(radar) == ds.RADAR_MESSAGE_COUNT == 43_969
    assert ds.RADAR_MESSAGE_COUNT == math.ceil(
        ds.RADAR_ID_COUNT / ds.RADAR_TASKS_PER_MESSAGE)


# -- distribution shape (Fig 3) -------------------------------------------


def test_monday_sizes_are_diurnal_not_heavy_tailed(monday):
    """Fig 3 dataset #1: 'roughly Gaussian' per-hour mix with a diurnal
    cycle (files are per-UTC-hour; volume peaks ~14:00 UTC)."""
    s = manifest_stats(monday)
    assert s["cv"] < 1.0                       # no heavy tail
    assert s["median_over_mean"] > 0.85        # symmetric-ish
    assert s["top1pct_share"] < 0.05
    sizes = np.array([t.size_bytes for t in monday], float)
    hours = np.array([int(t.task_id.split("/h")[1][:2]) for t in monday])
    mean_by_hour = np.array([sizes[hours == h].mean() for h in range(24)])
    peak, trough = mean_by_hour.argmax(), mean_by_hour.argmin()
    assert 11 <= peak <= 17                    # peaks around 14:00 UTC
    assert mean_by_hour[peak] > 2.0 * mean_by_hour[trough]


def test_aerodrome_sizes_are_heavy_tailed(aerodrome):
    """Fig 3 dataset #2: 'sloping' — activity is not uniform across
    locations; many small files, a few huge ones."""
    s = manifest_stats(aerodrome)
    assert s["cv"] > 2.0
    assert s["median_over_mean"] < 0.4         # mass lives in the tail
    assert s["top1pct_share"] > 0.20


def test_radar_messages_are_tiny_and_uniform(radar):
    """§V: per-message cost spread ~2 % — the precondition for the
    paper's 1.12 h worker span over a 24.34 h median."""
    cpu = np.array([t.cpu_cost_hint for t in radar], float)
    assert (cpu > 0).all()
    assert cpu.std() / cpu.mean() < 0.05


def test_processing_has_ferry_flight_outliers():
    """§IV.C/§V: a handful of continental ferry flights stretch the max
    worker toward 29.6 h without moving the 99.1 % quantile."""
    proc = get_manifest("processing")
    cpu = np.array([t.cpu_cost_hint for t in proc], float)
    assert cpu.max() > 5 * np.percentile(cpu, 99.1)


# -- encounter-screening cell manifests (ISSUE 8) -------------------------


@pytest.fixture(scope="module")
def aerodrome_dense():
    return get_manifest("aerodrome_dense")


@pytest.fixture(scope="module")
def enroute_sparse():
    return get_manifest("enroute_sparse")


def _occs(tasks):
    return np.array([t.size_bytes // ds.SCREEN_ROW_BYTES for t in tasks])


def test_aerodrome_dense_goldens(aerodrome_dense):
    """Terminal-area density: 3000 aircraft binned into screen cells
    with a hotspot whose occupancy dominates the quadratic cost."""
    occ = _occs(aerodrome_dense)
    assert len(aerodrome_dense) == 585
    assert occ.max() == 237
    assert (occ >= 2).all()                    # singleton cells pre-pruned
    cpu = np.array([t.cpu_cost_hint for t in aerodrome_dense], float)
    assert cpu.sum() == pytest.approx(91.5, rel=0.01)
    assert cpu.max() == pytest.approx(6.99, rel=0.01)


def test_enroute_sparse_goldens(enroute_sparse):
    occ = _occs(enroute_sparse)
    assert len(enroute_sparse) == 23
    assert occ.max() == 3


def test_dense_occupancy_dwarfs_sparse(aerodrome_dense, enroute_sparse):
    """The acceptance skew: aerodrome-dense max cell occupancy is at
    least 10x the en-route-sparse one."""
    assert _occs(aerodrome_dense).max() >= 10 * _occs(enroute_sparse).max()


def test_screen_manifest_cost_hints_are_quadratic(aerodrome_dense):
    """cpu_cost_hint tracks occupancy^2 (pair count), not size_bytes —
    the skew the scheduling policies are benchmarked on."""
    from repro.geometry.gridhash import cell_cost
    occ = _occs(aerodrome_dense)
    cpu = np.array([t.cpu_cost_hint for t in aerodrome_dense], float)
    want = np.array([cell_cost(int(k)) for k in occ])
    np.testing.assert_allclose(cpu, want, rtol=1e-12)


def test_screen_manifests_seed_stable():
    a = get_manifest("aerodrome_dense")
    b = get_manifest("aerodrome_dense")
    assert [t.task_id for t in a] == [t.task_id for t in b]
    assert [t.cpu_cost_hint for t in a] == [t.cpu_cost_hint for t in b]


# -- registry plumbing ----------------------------------------------------


def test_registry_covers_all_manifests():
    assert set(ds.MANIFESTS) >= {"monday", "aerodrome", "radar_messages",
                                 "archive", "processing", "smoke", "tiny",
                                 "aerodrome_dense", "enroute_sparse"}


def test_get_manifest_limit_and_isolation(monday):
    head = get_manifest("monday", limit=10)
    assert [t.task_id for t in head] == [t.task_id for t in monday[:10]]
    # Mutating a returned list must not poison the cache.
    head.clear()
    assert len(get_manifest("monday", limit=10)) == 10


def test_get_manifest_unknown_name():
    with pytest.raises(KeyError, match="unknown manifest"):
        get_manifest("nope")


def test_smoke_manifest_is_seed_stable():
    a = get_manifest("smoke")
    b = get_manifest("smoke")
    assert [t.task_id for t in a] == [t.task_id for t in b]
    assert [t.size_bytes for t in a] == [t.size_bytes for t in b]
    assert len(a) == 200
