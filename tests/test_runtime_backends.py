"""Backend equivalence: one protocol core over threads/processes/sim.

The acceptance bar of the runtime refactor: the same fixed-seed workload
must complete the identical task-id set with identical message-batching
behavior on every backend, and worker death must re-queue on (at least)
two backends.
"""

import json
import os
import time

import pytest

from repro.core.cost_model import PhaseCostModel
from repro.core.messages import Task
from repro.core.triples import TriplesConfig
from repro.runtime import ManagerCheckpoint, SchedulerCore, run_job
from repro.tracks.workflow import TrackWorkflow

BACKENDS = ["threads", "processes", "sim"]
FAST = dict(poll_interval=0.002)

SIM_MODEL = PhaseCostModel(
    name="t", r_process=1e6, b_node=8e6, b_global=64e6,
    cpu_rate=50e6, contention_alpha=0.001, task_overhead_s=0.01,
    msg_overhead_s=0.001)


def _tasks(n, size_fn=lambda i: (i * 37) % 23 + 1):
    return [Task(task_id=f"t{i:04d}", size_bytes=size_fn(i), timestamp=i)
            for i in range(n)]


def _double(task):            # module-level: picklable for processes
    return task.size_bytes * 2


def _slow(task):
    time.sleep(0.001)
    return 1


# -- completion + batching equivalence ----------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_completes_all(backend):
    r = run_job(_tasks(30), _double, backend=backend, n_workers=4,
                tasks_per_message=3, **FAST)
    assert r.completed_ids == {t.task_id for t in _tasks(30)}
    assert r.messages_sent == 10
    assert r.backend == backend


def test_backends_identical_completion_and_batching():
    runs = {b: run_job(_tasks(40), _double, backend=b, n_workers=5,
                       tasks_per_message=4, organization="largest_first",
                       **FAST)
            for b in BACKENDS}
    ids = {b: r.completed_ids for b, r in runs.items()}
    assert ids["threads"] == ids["processes"] == ids["sim"]
    # The dispatch log (sequence of ASSIGN batches) is decided by the
    # shared SchedulerCore, so it is bit-identical across backends.
    assert runs["threads"].batches == runs["processes"].batches \
        == runs["sim"].batches
    # Results travel in DONE messages on both live backends.
    assert runs["threads"].results == runs["processes"].results
    assert len(runs["threads"].results) == 40


def test_random_organization_seed_consistent_across_backends():
    runs = [run_job(_tasks(25), _double, backend=b, n_workers=3,
                    organization="random", organize_seed=7,
                    tasks_per_message=2, **FAST)
            for b in BACKENDS]
    assert runs[0].batches == runs[1].batches == runs[2].batches


def test_triple_selects_worker_count_uniformly():
    triple = TriplesConfig(nodes=1, nppn=8)     # 8 processes -> 7 workers
    for backend in ("threads", "sim"):
        r = run_job(_tasks(10), _double, backend=backend, triple=triple,
                    **FAST)
        assert len(r.worker_stats) == triple.worker_processes == 7


# -- fault injection on two live backends + sim --------------------------


def _slow20(task):
    time.sleep(0.02)
    return 1


@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_worker_death_requeues(backend):
    # Enough aggregate work (60 x 20ms) that w0 is guaranteed to receive
    # its fatal 4th task even when spawn-based workers boot staggered.
    r = run_job(_tasks(60), _slow20, backend=backend, n_workers=4,
                failure_timeout=0.5, worker_fail_after={"w0": 3}, **FAST)
    assert r.completed_ids == {t.task_id for t in _tasks(60)}
    assert r.failed_workers == ["w0"]
    assert r.reassigned_tasks >= 1


def test_long_task_does_not_trip_failure_detection():
    """Heartbeats beat THROUGH task execution: a healthy worker busy far
    longer than failure_timeout must not be condemned."""
    def long_task(task):
        time.sleep(0.4)
        return 1

    r = run_job(_tasks(4), long_task, backend="threads", n_workers=2,
                failure_timeout=0.1, **FAST)
    assert r.completed_ids == {t.task_id for t in _tasks(4)}
    assert r.failed_workers == []
    assert r.reassigned_tasks == 0


def test_hard_thread_death_detected_without_timeout():
    """A worker whose thread dies hard is detected even with no
    failure_timeout configured (no silent hang)."""
    r = run_job(_tasks(20), _slow, backend="threads", n_workers=3,
                worker_fail_after={"w1": 2}, **FAST)
    assert r.completed_ids == {t.task_id for t in _tasks(20)}
    assert r.failed_workers == ["w1"]
    assert r.reassigned_tasks >= 1


def _poison(task):
    # First worker to see t0003 dies hard (os._exit: no DONE, no FAILED);
    # the file flag makes the re-queued copy succeed on the next worker.
    flag = task.payload
    if task.task_id == "t0003" and flag and not os.path.exists(flag):
        open(flag, "w").close()
        os._exit(1)
    time.sleep(0.001)
    return 1


def test_hard_process_death_detected_without_timeout(tmp_path):
    """An OOM-kill-style process death (no DONE, no FAILED, process gone)
    is detected without failure_timeout — the job must not hang."""
    flag = str(tmp_path / "died_once")
    tasks = [Task(task_id=f"t{i:04d}", size_bytes=(i * 37) % 23 + 1,
                  payload=flag) for i in range(20)]
    r = run_job(tasks, _poison, backend="processes", n_workers=3, **FAST)
    assert r.completed_ids == {t.task_id for t in tasks}
    assert len(r.failed_workers) == 1
    assert r.reassigned_tasks >= 1


def test_sim_worker_death_requeues():
    tasks = _tasks(40, size_fn=lambda i: 10_000_000)
    r = run_job(tasks, backend="sim", n_workers=8, nodes=1, nppn=8,
                cost_model=SIM_MODEL, worker_death={0: 5.0},
                failure_timeout=2.0)
    assert r.completed_ids == {t.task_id for t in tasks}
    assert r.dead_workers == [0]
    assert r.reassigned_tasks >= 1


def test_sim_all_workers_dead_raises():
    """Same contract as live backends: an unfinishable job raises rather
    than returning a silently partial result."""
    tasks = _tasks(40, size_fn=lambda i: 10_000_000)
    with pytest.raises(RuntimeError, match="incomplete"):
        run_job(tasks, backend="sim", n_workers=4, nodes=1, nppn=4,
                cost_model=SIM_MODEL,
                worker_death={i: 1.0 for i in range(4)},
                failure_timeout=2.0)


def test_batch_fn_runs_whole_assign_message():
    calls = []

    class BatchedFn:
        def __call__(self, task):
            return task.size_bytes

        def process_batch(self, tasks):
            calls.append(len(tasks))
            return {t.task_id: t.size_bytes for t in tasks}

    r = run_job(_tasks(24), BatchedFn(), backend="threads", n_workers=2,
                tasks_per_message=6, **FAST)
    assert len(r.completed_ids) == 24
    assert calls and all(c == 6 for c in calls)   # one call per message


# -- mid-phase manager checkpointing -------------------------------------


def test_on_checkpoint_called_mid_job():
    seen = []
    run_job(_tasks(40), _slow, backend="threads", n_workers=2,
            on_checkpoint=lambda ck: seen.append(ck),
            checkpoint_interval_s=0.005, **FAST)
    assert seen, "expected at least one mid-job checkpoint"
    assert all(isinstance(c, ManagerCheckpoint) for c in seen)
    # A mid-job checkpoint is a partial ledger.
    assert 0 < len(seen[0].completed) <= 40


def test_workflow_saves_mid_phase_checkpoints(tmp_path, monkeypatch):
    wf = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.002,
                       checkpoint_interval_s=0.005)
    saved = []
    orig = wf._save_ckpt

    def spy(state):
        saved.append(json.loads(json.dumps(state)))
        orig(state)

    monkeypatch.setattr(wf, "_save_ckpt", spy)
    wf._run_phase("organize", _tasks(40), _slow)
    mid = [s for s in saved if s.get("manager")]
    assert mid, "no mid-phase manager checkpoint was persisted"
    assert mid[0]["manager_phase"] == "organize"
    ck = ManagerCheckpoint.loads(mid[0]["manager"])
    assert 0 < len(ck.completed) <= 40
    # After the phase completes the manager slot is cleared.
    final = saved[-1]
    assert final["manager"] is None
    assert "organize" in final["phases_done"]


def test_workflow_resumes_from_mid_phase_checkpoint(tmp_path):
    tasks = _tasks(20)
    done_before = {f"t{i:04d}" for i in range(12)}
    ck = ManagerCheckpoint(done_before, [])
    state = {"phases_done": [], "manager": ck.dumps(),
             "manager_phase": "organize"}
    os.makedirs(tmp_path, exist_ok=True)
    with open(os.path.join(tmp_path, "workflow_ckpt.json"), "w") as f:
        json.dump(state, f)

    ran = []
    wf = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.002)
    wf._run_phase("organize", tasks, lambda t: ran.append(t.task_id))
    assert sorted(ran) == sorted(
        t.task_id for t in tasks if t.task_id not in done_before)


# -- protocol-core unit behavior -----------------------------------------


def test_core_exactly_once_on_late_done():
    core = SchedulerCore(_tasks(4), tasks_per_message=2)
    b1 = core.next_batch("w0")
    assert [t.task_id for t in b1] == ["t0003", "t0001"]  # largest first
    core.mark_dead("w0")       # requeues the in-flight pair
    assert core.reassigned == 2
    # Late DONE from the "dead" worker: exactly-once, no double count.
    assert core.on_done("w0", ["t0001"]) == ["t0001"]
    assert core.on_done("w0", ["t0001"]) == []
    # The stale requeued copy is skipped at dispatch time.
    b2 = core.next_batch("w1")
    assert "t0001" not in {t.task_id for t in b2}


def test_core_rejects_duplicate_task_ids():
    with pytest.raises(ValueError, match="unique"):
        SchedulerCore([Task(task_id="a"), Task(task_id="a")])
