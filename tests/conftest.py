import os
import sys

# Tests run against the real single CPU device (the 512-device flag is
# set ONLY inside launch/dryrun.py, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
