import os
import sys

# Tests run against the real single CPU device (the 512-device flag is
# set ONLY inside launch/dryrun.py, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Graceful degradation: if the real hypothesis package is missing, fall
# back to the deterministic shim in tests/_compat so the whole suite
# still collects and the property tests run as light fuzz tests.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))
