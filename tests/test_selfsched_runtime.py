"""The real threaded manager/worker runtime (paper §II.D protocol)."""

import threading
import time

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.messages import Task
from repro.core.selfsched import Manager, ManagerCheckpoint, run_self_scheduled

FAST = dict(poll_interval=0.002)


def _tasks(n, size_fn=lambda i: (i * 37) % 23 + 1):
    return [Task(task_id=f"t{i:04d}", size_bytes=size_fn(i), timestamp=i)
            for i in range(n)]


def test_all_tasks_complete_exactly_once():
    seen = []
    lock = threading.Lock()

    def fn(task):
        with lock:
            seen.append(task.task_id)
        return task.size_bytes

    r = run_self_scheduled(_tasks(40), 6, fn, **FAST)
    assert sorted(seen) == sorted(t.task_id for t in _tasks(40))
    assert len(r.results) == 40
    assert r.messages_sent == 40


@given(st.integers(1, 60), st.integers(1, 9), st.integers(1, 5),
       st.sampled_from(["largest_first", "chronological", "random"]))
@settings(max_examples=15, deadline=None)
def test_exactly_once_property(n_tasks, n_workers, k, organization):
    r = run_self_scheduled(
        _tasks(n_tasks), n_workers, lambda t: 1, tasks_per_message=k,
        organization=organization, **FAST)
    assert len(r.results) == n_tasks
    total_assigned = sum(s.tasks_completed for s in r.worker_stats.values())
    assert total_assigned == n_tasks


def test_eager_initial_allocation():
    """Manager sends to every worker up front, before any DONE."""
    started = []
    gate = threading.Event()

    def fn(task):
        started.append(task.task_id)
        gate.wait(timeout=2.0)
        return 0

    mgr = Manager(_tasks(8), 4, fn, **FAST)
    t = threading.Thread(target=mgr.run, daemon=True)
    t.start()
    time.sleep(0.2)
    assert len(started) == 4      # one in-flight per worker, none done
    gate.set()
    t.join(timeout=10)


def test_worker_failure_requeues_tasks():
    r = run_self_scheduled(
        _tasks(30), 4, lambda t: time.sleep(0.001) or 1,
        failure_timeout=0.15, worker_fail_after={"w0": 3}, **FAST)
    assert len(r.results) == 30
    assert r.failed_workers == ["w0"]
    assert r.reassigned_tasks >= 1


def test_task_exception_reported():
    def fn(task):
        if task.task_id == "t0002":
            raise ValueError("boom")
        return 1
    with pytest.raises(RuntimeError, match="1 tasks failed"):
        run_self_scheduled(_tasks(6), 2, fn, **FAST)


def test_checkpoint_restart_skips_completed():
    tasks = _tasks(20)
    m = Manager(tasks, 3, lambda t: 1, **FAST)
    m.completed = {f"t{i:04d}" for i in range(12)}
    m.pending = [t for t in m.pending if t.task_id not in m.completed]
    blob = m.checkpoint().dumps()
    m2 = Manager(tasks, 3, lambda t: 1,
                 checkpoint=ManagerCheckpoint.loads(blob), **FAST)
    r = m2.run()
    assert len(r.results) == 8


def test_tasks_per_message_batches():
    r = run_self_scheduled(_tasks(30), 2, lambda t: 1,
                           tasks_per_message=10, **FAST)
    assert len(r.results) == 30
    assert r.messages_sent == 3
