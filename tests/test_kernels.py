"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode on CPU (the TPU BlockSpec tiling is
exercised structurally; numerics match the oracle)."""

import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _tracks(B, N, C, M, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    t_in = np.sort(rng.uniform(0, 900, (B, N)), axis=1).astype(dtype)
    count = rng.integers(2, N + 1, size=B).astype(np.int32)
    for b in range(B):
        c = count[b]
        t_in[b, c:] = t_in[b, c - 1] + np.arange(1, N - c + 1)
    v_in = rng.normal(size=(B, C, N)).astype(dtype)
    t_out = rng.uniform(-100, 1000, (B, M)).astype(dtype)
    return t_in, v_in, count, t_out


@pytest.mark.parametrize("B,N,C,M", [
    (1, 16, 1, 32), (3, 100, 3, 257), (2, 128, 5, 512),
    (4, 300, 2, 64), (2, 1024, 3, 1024),
])
def test_track_interp_matches_oracle(B, N, C, M):
    t_in, v_in, count, t_out = _tracks(B, N, C, M, seed=B * 7 + M)
    got = np.asarray(ops.track_interp(t_in, v_in, count, t_out))
    want = np.asarray(ref.track_interp_ref(t_in, v_in, count, t_out))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_track_interp_exact_at_knots():
    """Interpolating at the observation times returns the observations."""
    B, N, C = 2, 64, 3
    t_in, v_in, count, _ = _tracks(B, N, C, 1, seed=9)
    got = np.asarray(ops.track_interp(t_in, v_in, count, t_in))
    for b in range(B):
        c = count[b]
        np.testing.assert_allclose(
            got[b, :c], v_in[b, :, :c].T, rtol=1e-4, atol=1e-3)


def test_track_interp_clamps_out_of_range():
    B, N, C, M = 1, 32, 2, 16
    t_in, v_in, count, _ = _tracks(B, N, C, M, seed=3)
    t_out = np.full((B, M), -1e6, np.float32)
    got = np.asarray(ops.track_interp(t_in, v_in, count, t_out))
    np.testing.assert_allclose(
        got[0], np.tile(v_in[0, :, 0], (M, 1)), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,M", [(1, 16), (3, 240), (2, 1024), (5, 100)])
def test_dynamic_rates_matches_oracle(B, M):
    rng = np.random.default_rng(B * 11 + M)
    v = np.zeros((B, 3, M), np.float32)
    v[:, 0] = 40 + np.cumsum(rng.normal(0, 1e-4, (B, M)), axis=1)
    v[:, 1] = -100 + np.cumsum(rng.normal(0, 1e-4, (B, M)), axis=1)
    v[:, 2] = 1000 + np.cumsum(rng.normal(0, 2, (B, M)), axis=1)
    count = rng.integers(2, M + 1, size=B).astype(np.int32)
    got = np.asarray(ops.dynamic_rates(v, count, 1.0))
    want = np.asarray(ref.dynamic_rates_ref(v, count, 1.0))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_dynamic_rates_constant_track_is_zero():
    v = np.full((1, 3, 64), 1.0, np.float32)
    v[0, 0] = 40.0
    v[0, 1] = -100.0
    v[0, 2] = 500.0
    out = np.asarray(ops.dynamic_rates(v, np.array([64], np.int32), 1.0))
    np.testing.assert_allclose(out[0, 0], 0.0, atol=1e-5)   # vrate
    np.testing.assert_allclose(out[0, 1], 0.0, atol=1e-3)   # gspeed


def test_dynamic_rates_straight_line_speed():
    """Due-north at constant speed: gspeed == v, turn == 0."""
    M = 128
    v = np.zeros((1, 3, M), np.float32)
    speed_ms = 100.0
    v[0, 0] = 40.0 + np.arange(M) * speed_ms / 111_111.0
    v[0, 1] = -100.0
    v[0, 2] = 1000.0
    out = np.asarray(ops.dynamic_rates(v, np.array([M], np.int32), 1.0))
    # f32 lat accumulation rounds ~4e-6 deg => ~0.5 m/s noise
    np.testing.assert_allclose(out[0, 1], speed_ms, rtol=1e-2)
    np.testing.assert_allclose(out[0, 3], 0.0, atol=2e-2)


@pytest.mark.parametrize("B,M,H,W", [
    (1, 16, 64, 64), (3, 300, 200, 400), (2, 128, 128, 256),
])
def test_agl_lookup_matches_oracle(B, M, H, W):
    rng = np.random.default_rng(B + M)
    dem = rng.uniform(0, 3000, (H, W)).astype(np.float32)
    fi = rng.uniform(2, min(H - 2, 100), (B, M)).astype(np.float32)
    fj = rng.uniform(2, min(W - 2, 200), (B, M)).astype(np.float32)
    alt = rng.uniform(0, 4000, (B, M)).astype(np.float32)
    got = np.asarray(ops.agl_lookup(dem, fi, fj, alt))
    want = np.asarray(ref.agl_lookup_ref(dem, fi, fj, alt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_agl_lookup_wide_track_fallback():
    """Tracks spanning multiple DEM tiles route to the oracle."""
    rng = np.random.default_rng(5)
    dem = rng.uniform(0, 3000, (512, 512)).astype(np.float32)
    fi = rng.uniform(0, 500, (2, 64)).astype(np.float32)   # spans tiles
    fj = rng.uniform(0, 500, (2, 64)).astype(np.float32)
    alt = rng.uniform(0, 4000, (2, 64)).astype(np.float32)
    got = np.asarray(ops.agl_lookup(dem, fi, fj, alt))
    want = np.asarray(ref.agl_lookup_ref(dem, fi, fj, alt))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_agl_lookup_routes_only_spanning_rows(monkeypatch):
    """A mixed batch sends JUST the tile-spanning rows to the oracle;
    the rest stay on the Pallas tile path (whole-batch fallback would
    forfeit the kernel for every narrow track in the batch)."""
    rng = np.random.default_rng(9)
    dem = rng.uniform(0, 3000, (512, 512)).astype(np.float32)
    B, M = 5, 64
    fi = rng.uniform(10, 100, (B, M)).astype(np.float32)   # one tile
    fj = rng.uniform(10, 200, (B, M)).astype(np.float32)
    fi[1] = rng.uniform(0, 500, M)                          # spans
    fj[3] = rng.uniform(0, 500, M)                          # spans
    alt = rng.uniform(0, 4000, (B, M)).astype(np.float32)

    oracle_rows = []
    orig = ops._agl_lookup_ref_jit
    monkeypatch.setattr(
        ops, "_agl_lookup_ref_jit",
        lambda d, a, b, c: oracle_rows.append(a.shape[0]) or orig(d, a, b, c))
    got = np.asarray(ops.agl_lookup(dem, fi, fj, alt))
    assert oracle_rows == [2]       # exactly the two spanning rows
    want = np.asarray(ref.agl_lookup_ref(dem, fi, fj, alt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_agl_lookup_host_inputs_no_device_roundtrip(monkeypatch):
    """Host (numpy) inputs must not be bounced to the device for the
    origin/routing math."""
    import jax.numpy as jnp
    rng = np.random.default_rng(10)
    dem = rng.uniform(0, 3000, (256, 512)).astype(np.float32)
    fi = rng.uniform(10, 100, (2, 64)).astype(np.float32)
    fj = rng.uniform(10, 200, (2, 64)).astype(np.float32)
    alt = rng.uniform(0, 4000, (2, 64)).astype(np.float32)

    # The routing math happens first; jnp conversion of fi/fj/alt comes
    # only when handing the already-routed rows to the kernel — assert
    # nothing upstream converted the full arrays by running the op with
    # conversion intercepted for the exact original objects.
    orig_asarray = jnp.asarray
    seen = []

    def spy(x, *a, **k):
        if x is fi or x is fj or x is alt:
            seen.append(x)
        return orig_asarray(x, *a, **k)
    monkeypatch.setattr(jnp, "asarray", spy)
    out = np.asarray(ops.agl_lookup(dem, fi, fj, alt))
    assert not seen                 # only routed row-subsets go up
    want = np.asarray(ref.agl_lookup_ref(dem, fi, fj, alt))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-2)


def test_agl_on_grid_points_is_exact():
    rng = np.random.default_rng(6)
    dem = rng.uniform(0, 3000, (128, 256)).astype(np.float32)
    ii = rng.integers(0, 100, (1, 32))
    jj = rng.integers(0, 200, (1, 32))
    alt = np.zeros((1, 32), np.float32)
    got = np.asarray(ops.agl_lookup(dem, ii.astype(np.float32),
                                    jj.astype(np.float32), alt))
    np.testing.assert_allclose(got[0], -dem[ii[0], jj[0]], rtol=1e-5)
