"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,KV,T,S,hd,causal", [
    (1, 4, 2, 256, 256, 64, True),
    (2, 8, 2, 128, 384, 64, True),      # S > T (chunked-prefill offset)
    (1, 2, 2, 256, 256, 128, False),
    (1, 12, 4, 384, 384, 192, True),    # nemotron head_dim
    (2, 4, 1, 256, 512, 64, True),      # MQA
    (1, 4, 4, 200, 300, 64, True),      # unaligned -> padded + masked
])
def test_flash_matches_oracle(B, H, KV, T, S, hd, causal):
    rng = np.random.default_rng(B * 31 + T)
    q = jnp.asarray(rng.normal(size=(B, H, T, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    got = np.asarray(ops.flash_attention(q, k, v, causal=causal))
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = np.asarray(ops.flash_attention(q, k, v).astype(jnp.float32))
    want = np.asarray(ref.flash_attention_ref(q, k, v))
    assert np.abs(got - want).max() < 0.05    # bf16 tolerance


def test_model_flash_impl_matches_xla():
    """attention_impl='flash' produces the same logits as stock XLA."""
    from repro.configs import get_arch
    from repro.models import model as M
    base = get_arch("stablelm-12b", reduced=True)
    cfg_flash = dataclasses.replace(base, attention_impl="flash")
    params = M.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, base.vocab_size, (2, 64)), jnp.int32)}
    lx = M.forward(base, params, batch, remat=False)
    lf = M.forward(cfg_flash, params, batch, remat=False)
    a, b = np.asarray(lf), np.asarray(lx)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert rel < 0.03, rel
