"""Optimizer: AdamW reference check, int8 state quantization, schedules."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.train.optimizer import (
    OptimizerConfig, apply_updates, dequantize_blockwise, global_norm,
    init_opt_state, quantize_blockwise)
from repro.train.schedules import cosine, get_schedule, wsd


def _problem(seed=0, n=100):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(7, 3)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(7, 3)), jnp.float32)}
    return params, grads


def _reference_adamw(params, grads, m, v, t, cfg):
    gnorm = np.sqrt(sum((np.asarray(g) ** 2).sum()
                        for g in jax.tree_util.tree_leaves(grads)))
    clip = min(1.0, cfg.grad_clip / (gnorm + 1e-9))
    out = {}
    for k in params:
        g = np.asarray(grads[k]) * clip
        m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
        v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mh = m[k] / (1 - cfg.b1 ** t)
        vh = v[k] / (1 - cfg.b2 ** t)
        out[k] = np.asarray(params[k]) - cfg.lr * (
            mh / (np.sqrt(vh) + cfg.eps)
            + cfg.weight_decay * np.asarray(params[k]))
    return out, m, v


def test_adamw_matches_reference_fp32():
    cfg = OptimizerConfig(lr=1e-2, state_dtype="float32")
    params, grads = _problem()
    state = init_opt_state(params, cfg)
    m = {k: np.zeros_like(np.asarray(v)) for k, v in params.items()}
    v = {k: np.zeros_like(np.asarray(vv)) for k, vv in params.items()}
    p_ref = {k: np.asarray(vv) for k, vv in params.items()}
    p, s = params, state
    for t in range(1, 4):
        p, s, _ = apply_updates(p, grads, s, cfg)
        p_ref, m, v = _reference_adamw(p_ref, grads, m, v, t, cfg)
    for k in params:
        np.testing.assert_allclose(np.asarray(p[k]), p_ref[k],
                                   rtol=1e-5, atol=1e-6)


def test_int8_adam_converges_like_fp32():
    """Per-element trajectory comparison is chaotic where v ~ 0 (Adam's
    normalized step flips sign on noise), so the meaningful check is
    optimization quality: int8-state Adam reaches the same loss as fp32
    Adam on a least-squares problem."""
    rng = np.random.default_rng(3)
    A = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64,)), jnp.float32)

    def loss_fn(w):
        r = A @ w - b
        return jnp.mean(r * r)

    def run(cfg):
        w = {"w": jnp.zeros((32,), jnp.float32)}
        s = init_opt_state(w, cfg)
        for _ in range(60):
            loss, g = jax.value_and_grad(
                lambda p: loss_fn(p["w"]))(w)
            w, s, _ = apply_updates(w, g, s, cfg)
        return float(loss_fn(w["w"]))

    l32 = run(OptimizerConfig(lr=3e-2, weight_decay=0.0,
                              state_dtype="float32"))
    l8 = run(OptimizerConfig(lr=3e-2, weight_decay=0.0,
                             state_dtype="int8"))
    l0 = float(loss_fn(jnp.zeros((32,))))
    opt = float(np.mean(
        (np.asarray(A) @ np.linalg.lstsq(np.asarray(A), np.asarray(b),
                                         rcond=None)[0]
         - np.asarray(b)) ** 2))
    # fp32 closed >=80 % of the closable gap; int8 matches it closely
    assert l32 - opt < 0.2 * (l0 - opt), (l32, opt, l0)
    assert abs(l8 - l32) < 0.05 * (l0 - opt) + 1e-4


@given(st.integers(0, 1000), st.integers(1, 2000))
@settings(max_examples=40, deadline=None)
def test_quantize_roundtrip_error_bounded(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q = quantize_blockwise(x)
    y = dequantize_blockwise(q, x.shape)
    # sqrt companding: |err| <= 2*sqrt(r)*bmax*(0.5/127) <= bmax/127
    flat = np.asarray(x)
    pad = (-len(flat)) % 256
    blocks = np.pad(flat, (0, pad)).reshape(-1, 256)
    bmax = np.abs(blocks).max(axis=1)
    tol = np.repeat(bmax / 127 + 1e-7, 256)[: len(flat)]
    assert np.all(np.abs(np.asarray(y) - flat) <= tol * 1.05)
    # relative error for SMALL elements is bounded too (the point of
    # companding): elements at 1e-3 of blockmax stay within ~30 %
    r = np.abs(flat) / np.repeat(np.where(bmax > 0, bmax, 1), 256)[: len(flat)]
    small = (r > 1e-3) & (r < 1e-2)
    if small.any():
        rel = np.abs(np.asarray(y) - flat)[small] / np.abs(flat)[small]
        assert rel.max() < 0.35


def test_sgd_path():
    cfg = OptimizerConfig(kind="sgd", lr=0.1)
    params, grads = _problem(5)
    state = init_opt_state(params, cfg)
    p, s, met = apply_updates(params, grads, state, cfg)
    assert float(met["grad_norm"]) > 0
    assert not np.allclose(np.asarray(p["w"]), np.asarray(params["w"]))


def test_grad_clip_limits_update():
    cfg = OptimizerConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params, grads = _problem(7)
    big = jax.tree_util.tree_map(lambda g: g * 1e6, grads)
    state = init_opt_state(params, cfg)
    _, _, met = apply_updates(params, big, state, cfg)
    assert float(met["grad_norm"]) > 1e3   # raw norm reported


def test_wsd_schedule_shape():
    lr = get_schedule("wsd", peak=1.0, warmup_steps=10, stable_steps=80,
                      decay_steps=10)
    xs = np.array([float(lr(jnp.asarray(s))) for s in range(110)])
    assert xs[0] == 0.0
    assert abs(xs[10] - 1.0) < 1e-6
    assert np.all(np.abs(xs[10:90] - 1.0) < 1e-6)     # plateau
    assert xs[-1] <= 0.12                              # decayed
    assert np.all(np.diff(xs[90:]) <= 1e-9)            # monotone decay


def test_cosine_schedule():
    xs = np.array([float(cosine(jnp.asarray(s), peak=2.0, warmup_steps=5,
                                total_steps=50)) for s in range(50)])
    assert xs.argmax() == 5
    assert xs[-1] < xs[5]


def test_global_norm():
    t = {"a": jnp.ones((4,)), "b": jnp.ones((3,))}
    assert abs(float(global_norm(t)) - np.sqrt(7)) < 1e-6
