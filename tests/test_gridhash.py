"""Spatial-hash grid binning: wrap/halo edge cases + cost model."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.geometry.gridhash import (
    GridSpec, bin_samples, cell_cost, cell_id, cells_for_samples,
    occupancy_stats, wrap_lon)

SPEC = GridSpec(cell_deg=0.25)


def _sample(t, la, lo, al):
    return (np.array([t]), np.array([la]), np.array([lo]), np.array([al]))


def test_gridspec_rejects_non_dividing_cell_deg():
    with pytest.raises(ValueError):
        GridSpec(cell_deg=0.7)
    with pytest.raises(ValueError):
        GridSpec(cell_deg=-1.0)


def test_wrap_lon_into_half_open_range():
    np.testing.assert_allclose(
        wrap_lon([181.0, -181.0, 360.0, -180.0, 179.9]),
        [-179.0, 179.0, 0.0, -180.0, 179.9])


def test_cell_id_roundtrips_negative_indices():
    # "/"-free so workflow task ids split cleanly; signs survive.
    assert cell_id((3, -1, -188, 1439)) == "t3_a-1_y-188_x1439"


def test_antimeridian_pad_wraps_modulo_n_lon():
    """A sample just east of -180 pads across the antimeridian: the raw
    floor index would be -721 (out of range); the ring wraps it to the
    +180-side neighbour instead."""
    west = SPEC.n_lon // 2          # cell whose left edge is -180
    keys = cells_for_samples(*_sample(10.0, 0.1, -179.999, 500.0),
                             spec=SPEC, h_pad_m=926.0)
    xis = {k[3] for k in keys}
    assert xis == {west - 1, west}
    assert all(0 <= k[3] < SPEC.n_lon for k in keys)


def test_antimeridian_neighbours_share_a_cell():
    """Rows straddling +/-180 at the same spot co-bin after padding."""
    a = cells_for_samples(*_sample(5.0, -30.0, 179.999, 1000.0),
                          spec=SPEC, h_pad_m=926.0, v_pad_m=152.4)
    b = cells_for_samples(*_sample(5.0, -30.0, -179.999, 1000.0),
                          spec=SPEC, h_pad_m=926.0, v_pad_m=152.4)
    assert set(a) & set(b)


def test_hemisphere_boundary_pads_into_negative_band():
    """Equator crossing needs no special case: padding just spills
    into latitude band -1."""
    keys = cells_for_samples(*_sample(0.0, 0.001, 10.0, 500.0),
                             spec=SPEC, h_pad_m=926.0)
    ais = {k[2] for k in keys}
    assert ais == {-1, 0}


def test_negative_altitude_layers_allowed():
    keys = cells_for_samples(*_sample(0.0, 40.0, 10.0, -50.0), spec=SPEC)
    assert {k[1] for k in keys} == {-1}


def test_time_axis_never_padded():
    keys = cells_for_samples(
        np.array([3599.0, 3601.0]), np.array([40.0, 40.0]),
        np.array([10.0, 10.0]), np.array([500.0, 500.0]),
        spec=SPEC, h_pad_m=926.0, v_pad_m=152.4)
    assert {k[0] for k in keys} == {0, 1}


def test_multi_cell_membership_deduplicates():
    """Samples revisiting the same cell emit it once, sorted."""
    t = np.zeros(6)
    la = np.array([40.1, 40.1, 40.6, 40.1, 40.6, 40.1])
    lo = np.full(6, 10.1)
    al = np.full(6, 500.0)
    keys = cells_for_samples(t, la, lo, al, spec=SPEC)
    assert keys == sorted(set(keys))
    assert len(keys) == 2


def test_empty_samples_bin_nowhere():
    assert cells_for_samples(np.array([]), np.array([]), np.array([]),
                             np.array([]), spec=SPEC) == []
    stats = occupancy_stats({})
    assert stats["cells"] == 0 and stats["max_occupancy"] == 0


def test_bin_samples_groups_row_ids_by_cell():
    rows = [("r1", *_sample(0.0, 40.1, 10.1, 500.0)),
            ("r2", *_sample(0.0, 40.1, 10.1, 500.0)),
            ("r3", *_sample(0.0, 45.0, 60.0, 500.0))]
    bins = bin_samples(rows, spec=SPEC)
    occ = occupancy_stats(bins)
    assert occ["multi_cells"] == 1 and occ["max_occupancy"] == 2
    assert any(ids == ["r1", "r2"] for ids in bins.values())


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_halo_guarantees_threshold_pairs_share_a_cell(seed):
    """The screening invariant: two single-sample rows within the
    thresholds at a common instant ALWAYS co-bin after halo padding —
    including across the antimeridian and the poles' cos(lat) blowup."""
    rng = np.random.default_rng(seed)
    h_pad, v_pad = 926.0, 152.4
    la = float(rng.uniform(-89.0, 89.0))
    lo = float(rng.uniform(-180.0, 180.0))
    al = float(rng.uniform(0.0, 12_000.0))
    t = float(rng.uniform(0.0, 7200.0))
    # Displace inside the threshold box (in metres, scaled to degrees).
    cos_lat = max(np.cos(np.deg2rad(la)), 0.2)
    dla = float(rng.uniform(-1, 1)) * h_pad / 111_111.0
    dlo = float(rng.uniform(-1, 1)) * h_pad / (111_111.0 * cos_lat)
    dal = float(rng.uniform(-1, 1)) * v_pad
    a = cells_for_samples(*_sample(t, la, lo, al), spec=SPEC,
                          h_pad_m=h_pad, v_pad_m=v_pad)
    b = cells_for_samples(
        *_sample(t, np.clip(la + dla, -90, 90),
                 float(wrap_lon(lo + dlo)), al + dal),
        spec=SPEC, h_pad_m=h_pad, v_pad_m=v_pad)
    assert set(a) & set(b)


def test_cell_cost_quadratic_and_incremental():
    assert cell_cost(0) == 0.0 and cell_cost(1) == 0.0
    assert cell_cost(2) > 0.0
    # quadratic: doubling occupancy ~4x the pairs
    assert cell_cost(200) / cell_cost(100) == pytest.approx(4.0, rel=0.05)
    # incremental generations tile the full quadratic cost exactly:
    # pairs(old+new) = pairs(old) + new*(old) + pairs-within-new
    for n_all, n_new in [(10, 3), (7, 7), (5, 1)]:
        assert cell_cost(n_all, n_new) + cell_cost(n_all - n_new) == \
            pytest.approx(cell_cost(n_all))
