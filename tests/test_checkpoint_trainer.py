"""Checkpointing (atomic, async, bf16) + trainer (resume, elastic)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 3)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.normal(size=(5,)),
                                        jnp.bfloat16),
                       "c": jnp.asarray([seed], jnp.int32)}}


def test_save_restore_roundtrip_with_bf16(tmp_path):
    tree = _tree(1)
    C.save(str(tmp_path), 7, tree)
    got = C.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoints_ignored(tmp_path):
    tree = _tree(2)
    C.save(str(tmp_path), 3, tree)
    C.save(str(tmp_path), 9, tree)
    os.remove(str(tmp_path / "step_000000009.COMMITTED"))
    got, step = C.restore_latest(str(tmp_path), tree)
    assert step == 3


def test_retention_gc(tmp_path):
    tree = _tree(3)
    for s in range(6):
        C.save(str(tmp_path), s, tree, keep=2)
    assert C.list_steps(str(tmp_path)) == [4, 5]


def test_async_checkpointer(tmp_path):
    ck = C.AsyncCheckpointer(str(tmp_path), keep=3)
    for s in (1, 2, 3):
        ck.save_async(s, _tree(s))
    ck.close()
    assert C.list_steps(str(tmp_path)) == [1, 2, 3]
    got = C.restore(str(tmp_path), 2, _tree(0))
    assert int(np.asarray(got["nested"]["c"])[0]) == 2


def test_trainer_loss_decreases_and_resumes(tmp_path):
    from repro.configs import get_arch
    from repro.data.pipeline import (SelfScheduledLoader,
                                     synthetic_token_shards)
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import Trainer, TrainerConfig

    import os
    cfg = get_arch("minicpm-2b", reduced=True)
    # Zipf-skewed tokens => a strongly learnable unigram signal (uniform
    # tokens leave ~nothing above the ln(V) floor and made this flaky).
    rng = np.random.default_rng(0)
    os.makedirs(tmp_path / "shards", exist_ok=True)
    shards = []
    from repro.data.pipeline import ShardManifest
    for i in range(4):
        toks = np.minimum(rng.zipf(1.5, size=4 * 65 * 40),
                          cfg.vocab_size - 1).astype(np.int32)
        path = str(tmp_path / "shards" / f"s{i}.npy")
        np.save(path, toks)
        shards.append(ShardManifest(f"s{i}", path, len(toks),
                                    int(toks.nbytes)))
    loader = SelfScheduledLoader(shards, batch_size=4, seq_len=64,
                                 poll_interval=0.003)
    tcfg = TrainerConfig(workdir=str(tmp_path), total_steps=30,
                         ckpt_every=10, log_every=100, peak_lr=1e-2)
    tr = Trainer(cfg, OptimizerConfig(), tcfg)
    log = tr.run(loader.batches(30), 30)
    tr.close()
    first = np.mean([r["loss"] for r in log[:5]])
    last = np.mean([r["loss"] for r in log[-5:]])
    assert last < first - 0.5, (first, last)

    # resume: a fresh Trainer picks up from the last committed step
    tr2 = Trainer(cfg, OptimizerConfig(), tcfg)
    assert tr2.step >= 21
    log2 = tr2.run(loader.batches(5), 5)
    tr2.close()
    assert log2[-1]["step"] >= tr2.step - 1


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import jax, numpy as np
from repro.configs import get_arch
from repro.data.pipeline import SelfScheduledLoader, synthetic_token_shards
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_arch("minicpm-2b", reduced=True)
shards = synthetic_token_shards("WORK/shards", n_shards=4,
    vocab_size=cfg.vocab_size, tokens_per_shard_mean=4*65*30)
loader = SelfScheduledLoader(shards, batch_size=8, seq_len=64,
                             poll_interval=0.003)
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
tcfg = TrainerConfig(workdir="WORK", total_steps=40, ckpt_every=5,
                     log_every=100)
tr = Trainer(cfg, OptimizerConfig(), tcfg, mesh=mesh8)
tr.run(loader.batches(10), 10)
loss_before = tr.metrics_log[-1]["loss"]
# simulate losing half the data-parallel workers -> re-mesh to 2x2
mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                          ("data", "model"))
tr.remesh(mesh4)
assert tr.mesh is mesh4
tr.run(loader.batches(10), 10)
tr.close()
loss_after = tr.metrics_log[-1]["loss"]
print("ELASTIC_OK", loss_before, loss_after, tr.step)
assert tr.step >= 20
"""


@pytest.mark.slow
def test_elastic_remesh_subprocess(tmp_path):
    """Elastic re-mesh needs >1 device => subprocess with 8 fake CPUs."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = ELASTIC_SCRIPT.replace("SRC", os.path.abspath(src)) \
                           .replace("WORK", str(tmp_path))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
