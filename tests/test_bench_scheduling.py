"""Tests for the scheduling-policy benchmark matrix + artifact tooling.

The quick tier IS the acceptance cell set (ISSUE-5 policy cells plus
the ISSUE-6 streaming-DAG cells), so running it here (and asserting
every cell passes) keeps the CI gate honest locally: adaptive_chunk
and sized_lpt >= 1.3x static makespan on the heavy-tail dataset under
20 % worker deaths, shard_affinity cutting measured prefetch wait vs
fifo_selfsched on the store-backed feed, the pipelined DAG >= 1.5x
over the 3-phase barrier run, 4 manager shards >= 1.3x
single-manager dispatch at 1024 workers, and the ISSUE-10 elastic
cells (speculation + speed feedback + autoscaler >= 1.2x the best
static-fleet policy under deaths20_stragglers10, plus a live threads
autoscaler under a 4x-slow worker).  Also covers
schema validation, deterministic re-runs of the sim cells, and the
compare CLI's schema dispatch (makespan_seconds gated, schema mismatch
exit-1).
"""

import copy
import json

import pytest

from repro.bench import scheduling as sched
from repro.bench.compare import compare_docs, default_metric
from repro.bench.compare import main as compare_main
from repro.bench.schema import (
    SCHEDULING_SCHEMA, canonical_bytes, validate_scheduling)


@pytest.fixture(scope="module")
def quick_doc():
    return sched.run_scheduling_campaign(quick=True)


def test_quick_tier_is_the_acceptance_cells(quick_doc):
    names = {r["name"] for r in quick_doc["scenarios"]}
    assert names == {"sched_heavy_tail_deaths20_adaptive_chunk",
                     "sched_heavy_tail_deaths20_sized_lpt",
                     "sched_store_affinity_prefetch_wait",
                     "sched_dag_stream_vs_barrier_heavy_tail",
                     "sched_elastic_vs_static_panel",
                     "sched_elastic_live_slow4_speculative",
                     "sched_msgwall_shards4_w256",
                     "sched_msgwall_shards4_w1024"}


def test_quick_tier_passes_and_validates(quick_doc):
    assert validate_scheduling(quick_doc) == []
    assert quick_doc["summary"]["fail"] == 0
    assert quick_doc["summary"]["error"] == 0
    by_name = {r["name"]: r for r in quick_doc["scenarios"]}
    adaptive = by_name["sched_heavy_tail_deaths20_adaptive_chunk"]
    lpt = by_name["sched_heavy_tail_deaths20_sized_lpt"]
    assert adaptive["metrics"]["makespan_speedup_x"] >= 1.3
    assert lpt["metrics"]["makespan_speedup_x"] >= 1.3
    # Exactly-once under the death wave, for run AND implicit baseline.
    assert adaptive["metrics"]["tasks_completed"] == \
        adaptive["metrics"]["n_tasks"]
    # ISSUE-10 acceptance: the elastic stack beats EVERY static-fleet
    # policy under the combined 20%-death + 4x-slow-straggler profile.
    panel = by_name["sched_elastic_vs_static_panel"]
    assert panel["metrics"]["makespan_speedup_vs_best_static_x"] >= 1.2
    assert panel["metrics"]["tasks_completed"] == panel["metrics"]["n_tasks"]
    assert panel["metrics"]["workers_added"] >= 1
    assert panel["metrics"]["speculated"] >= 1
    live = by_name["sched_elastic_live_slow4_speculative"]
    assert live["metrics"]["tasks_completed"] == live["metrics"]["n_tasks"]
    assert live["metrics"]["n_results"] == live["metrics"]["n_tasks"]
    aff = by_name["sched_store_affinity_prefetch_wait"]
    assert aff["measured"]["prefetch_wait_reduction_x"] > 1.0
    assert aff["metrics"]["batch_locality"] == 1.0
    # Wait attribution reaches the record via the worker breakdown.
    assert aff["measured"]["worker_breakdown"]
    assert sum(w["wait_s"] for w in
               aff["measured"]["worker_breakdown"].values()) == \
        pytest.approx(aff["measured"]["prefetch_wait_s"])


def test_sim_cells_are_deterministic_across_reruns():
    kw = dict(quick=True, filters=["sched_heavy_tail"])
    a = sched.run_scheduling_campaign(**kw)
    b = sched.run_scheduling_campaign(**kw)
    assert canonical_bytes(a) == canonical_bytes(b)


def test_validator_catches_missing_required_metric(quick_doc):
    doc = copy.deepcopy(quick_doc)
    rec = doc["scenarios"][0]
    rec["metrics"].pop("makespan_seconds", None)
    rec["measured"].pop("makespan_seconds", None)
    problems = validate_scheduling(doc)
    assert any("makespan_seconds" in p for p in problems)
    doc2 = copy.deepcopy(quick_doc)
    doc2["scenarios"][0]["spec"]["run"].pop("policy")
    assert any("policy" in p for p in validate_scheduling(doc2))


def test_spec_validation_rejects_bad_cells():
    with pytest.raises(ValueError, match="unknown policy"):
        sched.SchedulingSpec(policy="wat")
    with pytest.raises(ValueError, match="sim backend"):
        sched.SchedulingSpec(kind="sim", backend="threads")
    with pytest.raises(ValueError, match="threads"):
        sched.SchedulingSpec(kind="store_feed", backend="sim")
    with pytest.raises(ValueError, match="fault profile"):
        sched.SchedulingSpec(fault_profile="wat")


# ---------------------------------------------------------------------------
# compare CLI: schema dispatch + gating.
# ---------------------------------------------------------------------------

def _mini_doc(makespan, busy_p90=10.0):
    rec = {
        "name": "cell", "group": "g", "tier": "quick", "status": "ran",
        "spec": {"run": {"policy": "static", "dataset": "heavy_tail",
                         "backend": "sim", "n_workers": 4,
                         "organization": "chronological",
                         "tasks_per_message": 1, "fault_profile": "none",
                         "seed": 0}, "baseline": None},
        "metrics": {"tasks_completed": 5, "messages_sent": 5,
                    "makespan_seconds": makespan, "busy_p50_s": 5.0,
                    "busy_p90_s": busy_p90},
        "measured": {}, "checks": [],
        "timing": {"wall_s": 0.1}, "error": None,
    }
    return {"schema": SCHEDULING_SCHEMA, "schema_version": 1,
            "config": {}, "scenarios": [rec],
            "summary": {"total": 1, "pass": 0, "fail": 0, "ran": 1,
                        "error": 0}}


def test_compare_dispatches_makespan_for_scheduling_schema(tmp_path):
    old, new = _mini_doc(100.0), _mini_doc(95.0)
    assert default_metric(old) == "makespan_seconds"
    rows, regressions = compare_docs(old, new)
    assert rows[0]["metric"] == "makespan_seconds"
    assert not regressions
    # >10% slower makespan regresses -> CLI exit 1.
    worse = _mini_doc(120.0)
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    p_old.write_text(json.dumps(old))
    p_new.write_text(json.dumps(worse))
    assert compare_main([str(p_old), str(p_new)]) == 1
    p_new.write_text(json.dumps(new))
    assert compare_main([str(p_old), str(p_new)]) == 0


def test_compare_schema_mismatch_stays_exit_1(tmp_path):
    storage_doc = {"schema": "repro.bench.storage/v1", "scenarios": []}
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    p_old.write_text(json.dumps(_mini_doc(100.0)))
    p_new.write_text(json.dumps(storage_doc))
    assert compare_main([str(p_old), str(p_new)]) == 1


def test_compare_busy_quantile_info_rows(capsys, tmp_path):
    """Busy-quantile deltas print alongside but never gate."""
    old = _mini_doc(100.0, busy_p90=10.0)
    new = _mini_doc(100.0, busy_p90=50.0)      # 5x worse p90, same makespan
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    p_old.write_text(json.dumps(old))
    p_new.write_text(json.dumps(new))
    assert compare_main([str(p_old), str(p_new)]) == 0   # not gated
    out = capsys.readouterr().out
    assert "busy_p90_s" in out and "+400.0%" in out


def test_campaign_cli_flag_lists_scheduling_scenarios():
    names = [sc.name for sc in sched.scheduling_scenarios()]
    assert len(names) == len(set(names))
    assert sum(1 for sc in sched.scheduling_scenarios()
               if sc.tier == "quick") == 8
