"""Fused encounter-screen kernel vs oracle + grid-vs-brute exactness."""

import numpy as np
import pytest

from repro.geometry.gridhash import GridSpec
from repro.kernels.encounter_screen import (
    ScreenConfig, ScreenRow, bin_screen_rows, brute_force_screen,
    dedup_candidates, get_screen_stats, reset_screen_stats,
    screen_aligned, screen_cells, screen_rows_grid)
from repro.kernels.ref import encounter_screen_ref

H, V = 926.0, 152.4


def _batch(C, K, T, seed=0, spread=0.02):
    """Clustered random (C, K, T) planes with ragged validity."""
    rng = np.random.default_rng(seed)
    lat = (40.0 + rng.normal(0, spread, (C, K, 1))
           + rng.normal(0, 1e-4, (C, K, T))).astype(np.float32)
    lon = (-100.0 + rng.normal(0, spread, (C, K, 1))
           + rng.normal(0, 1e-4, (C, K, T))).astype(np.float32)
    alt = rng.uniform(400, 900, (C, K, 1)).astype(np.float32) \
        + rng.normal(0, 5, (C, K, T)).astype(np.float32)
    val = np.zeros((C, K, T), np.float32)
    for c in range(C):
        for k in range(K):
            s = int(rng.integers(0, max(1, T // 2)))
            e = int(rng.integers(s + 1, T + 1))
            val[c, k, s:e] = 1.0
    return lat, lon, alt, val


@pytest.mark.parametrize("backend", ["pallas", "jit"])
@pytest.mark.parametrize("C,K,T", [
    (1, 8, 128), (2, 16, 128), (3, 8, 256), (1, 24, 384), (5, 32, 128),
])
def test_screen_aligned_matches_oracle(backend, C, K, T):
    """pallas (interpret) and jit agree with the full-broadcast oracle
    on hits bitwise and minima to float32 tolerance."""
    lat, lon, alt, val = _batch(C, K, T, seed=C * 31 + K + T)
    got = screen_aligned(lat, lon, alt, val, h_thresh_m=H, v_thresh_m=V,
                         backend=backend)
    hit, mdh, mdv, tix = (np.zeros((C, K, K), np.float32) for _ in range(4))
    for c in range(C):
        h, dh, dv, ti = encounter_screen_ref(
            lat[c], lon[c], alt[c], val[c], h_thresh_m=H, v_thresh_m=V)
        hit[c], mdh[c], mdv[c], tix[c] = h, dh, dv, ti
    np.testing.assert_array_equal(got["hit"], hit)
    where = hit > 0.5
    np.testing.assert_allclose(got["min_dh"][where], mdh[where],
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(got["min_dv"][where], mdv[where],
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(got["t_idx"][where], tix[where])


def test_pallas_and_jit_bitwise_identical():
    lat, lon, alt, val = _batch(4, 16, 256, seed=7)
    a = screen_aligned(lat, lon, alt, val, h_thresh_m=H, v_thresh_m=V,
                       backend="pallas")
    b = screen_aligned(lat, lon, alt, val, h_thresh_m=H, v_thresh_m=V,
                       backend="jit")
    for key in ("hit", "min_dh", "min_dv", "t_idx"):
        np.testing.assert_array_equal(a[key], b[key])


def _trail(rid, group, t0, la, lo, al, n=8, dt=15.0, seed=0):
    rng = np.random.default_rng(seed)
    return ScreenRow(
        row_id=rid, group=group, t0=t0,
        lat=(la + np.cumsum(rng.normal(0, 1e-4, n))).astype(np.float32),
        lon=(lo + np.cumsum(rng.normal(0, 1e-4, n))).astype(np.float32),
        alt=(al + rng.normal(0, 3, n)).astype(np.float32), dt_s=dt)


def _cloud(n, seed=0, spread=0.01):
    """n clustered single-segment rows on a shared 15 s grid."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        rows.append(_trail(
            f"a{i:04d}#s000", f"a{i:04d}",
            t0=float(rng.integers(0, 40)) * 15.0,
            la=40.0 + float(rng.normal(0, spread)),
            lo=-100.0 + float(rng.normal(0, spread)),
            al=float(rng.uniform(400, 700)), seed=seed * 1000 + i))
    return rows


@pytest.mark.parametrize("backend", ["jit", "pallas"])
@pytest.mark.parametrize("cell_t_s", [3600.0, 300.0])
def test_grid_screen_equals_brute_force(backend, cell_t_s):
    """The headline exactness property: spatial-hash + kernel emits
    exactly the brute-force all-pairs candidate set, for hour-scale
    AND fine time windows (a pair meeting in several windows is
    screened over its full joint span in each, so dedup is exact)."""
    rows = _cloud(40, seed=3)
    config = ScreenConfig(dt_s=15.0, backend=backend)
    grid = GridSpec(cell_deg=0.25, cell_t_s=cell_t_s)
    got, stats = screen_rows_grid(rows, grid=grid, config=config)
    want = brute_force_screen(rows, config=config)
    assert want, "fixture must produce a non-empty candidate set"
    assert [(c["a"], c["b"]) for c in got] == \
        [(c["a"], c["b"]) for c in want]
    for g, w in zip(got, want):
        assert g["t_s"] == w["t_s"]
        assert g["h_m"] == pytest.approx(w["h_m"], abs=1e-2)
        assert g["v_m"] == pytest.approx(w["v_m"], abs=1e-2)


def test_same_group_rows_never_pair():
    a = _trail("t1#s000", "t1", 0.0, 40.0, -100.0, 500.0, seed=1)
    b = _trail("t1#s001", "t1", 0.0, 40.0, -100.0, 500.0, seed=1)
    cands, _ = screen_cells({(0, 1, 160, 320): [a, b]},
                            config=ScreenConfig(dt_s=15.0))
    assert cands == []
    assert brute_force_screen([a, b],
                              config=ScreenConfig(dt_s=15.0)) == []


def test_empty_and_singleton_cells_skip_kernel():
    a = _trail("t1#s000", "t1", 0.0, 40.0, -100.0, 500.0)
    reset_screen_stats()
    cands, stats = screen_cells({(0, 1, 160, 320): [a],
                                 (0, 1, 160, 321): []},
                                config=ScreenConfig(dt_s=15.0))
    assert cands == []
    assert stats["cells_skipped"] == 2 and stats["cells_screened"] == 0
    assert get_screen_stats()["kernel_calls"] == 0


def test_dedup_canonical_order_keeps_first():
    cands = [{"a": "x", "b": "y", "t_s": 1.0, "h_m": 2.0, "v_m": 3.0},
             {"a": "p", "b": "q", "t_s": 0.0, "h_m": 1.0, "v_m": 1.0},
             {"a": "x", "b": "y", "t_s": 1.0, "h_m": 2.0, "v_m": 3.0}]
    out = dedup_candidates(cands)
    assert [(c["a"], c["b"]) for c in out] == [("p", "q"), ("x", "y")]


def test_incremental_generations_union_equals_full_screen():
    """new_ids generations tile the pair set: screening {old} then
    {old+new, new=new} unions to exactly the full-cell candidates."""
    rows = _cloud(12, seed=5, spread=0.003)
    key = (0, 1, 160, 320)
    config = ScreenConfig(dt_s=15.0)
    full, _ = screen_cells({key: rows}, config=config)
    old, new = rows[:7], rows[7:]
    g1, _ = screen_cells({key: old}, config=config)
    g2, _ = screen_cells({key: rows}, config=config,
                         new_ids={key: {r.row_id for r in new}})
    merged = dedup_candidates(g1 + g2)
    assert merged == full


def test_binning_respects_thresholds_as_halo():
    """bin_screen_rows pads by the config thresholds, so two rows a
    hair inside the thresholds share a cell even across a boundary."""
    a = _trail("a#s000", "a", 0.0, 40.0 + 0.0001, -100.0, 500.0)
    b = _trail("b#s000", "b", 0.0, 40.0 - 0.0001, -100.0, 500.0)
    a.lat[:] = 40.000001  # hug the 40.0 cell edge from above
    b.lat[:] = 39.999999  # ... and below
    bins = bin_screen_rows([a, b], grid=GridSpec(cell_deg=0.25),
                           config=ScreenConfig(dt_s=15.0))
    assert any(len(ids) == 2 for ids in bins.values())
