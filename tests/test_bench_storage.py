"""Storage benchmark matrix: schema validity, deterministic metrics,
and the schema-dispatching compare CLI."""

import copy

import pytest

from repro.bench import storage
from repro.bench.compare import compare_docs, default_metric
from repro.bench.schema import (
    STORAGE_SCHEMA, canonical_bytes, validate_storage)


@pytest.fixture(scope="module")
def storage_doc():
    """One quick-tier run on a small fixture (shared across tests)."""
    return storage.run_storage_campaign(quick=True)


def test_scenario_matrix_declares_acceptance_cell():
    names = {sc.name for sc in storage.storage_scenarios()}
    assert "storage_feed_heavy_tail_store_prefetch" in names
    quick = [sc for sc in storage.storage_scenarios()
             if sc.tier == "quick"]
    assert quick, "quick tier must not be empty (CI gates on it)"
    for sc in quick:
        metrics = {c.metric for c in sc.checks}
        assert {"feed_speedup_x", "feed_bitwise_equal",
                "rebuild_identical"} <= metrics


def test_storage_doc_schema_valid(storage_doc):
    assert validate_storage(storage_doc) == []
    assert storage_doc["schema"] == STORAGE_SCHEMA
    assert storage_doc["summary"]["error"] == 0


def test_storage_deterministic_metrics(storage_doc):
    """The deterministic half of the acceptance cell must hold in
    tier-1 (wall-clock speedup is gated by CI's store-smoke job, not
    here, so a loaded test machine can't flake the suite)."""
    rec = storage_doc["scenarios"][0]
    m = rec["metrics"]
    assert m["feed_bitwise_equal"] == 1.0
    assert m["rebuild_identical"] == 1.0
    assert m["n_points"] > 0 and m["n_tracks"] > 0
    assert m["bytes_on_disk"] > 0
    # zlib columns beat the CSV-in-zip encoding on bytes per point
    assert m["bytes_per_point"] < m["baseline_bytes_on_disk"] / m["n_points"]
    assert "feed_speedup_x" in rec["measured"]


def test_storage_canonical_bytes_reproducible(storage_doc):
    """Two same-seed campaign runs agree byte-for-byte after stripping
    the nondeterministic keys.  Like the kernels artifact, the quick
    tier gates wall-clock throughput, so ``checks`` (which record the
    measured actuals) are stripped too — ``metrics`` is the
    reproducible surface."""
    import json

    def strip_checks(blob):
        doc = json.loads(blob)
        for rec in doc["scenarios"]:
            rec.pop("checks", None)
            rec.pop("status", None)      # depends on the measured check
        return json.dumps(doc, sort_keys=True)

    again = storage.run_storage_campaign(quick=True)
    assert strip_checks(canonical_bytes(storage_doc)) == \
        strip_checks(canonical_bytes(again))


def test_prefetch_wait_attribution_fake_clock(storage_doc):
    """The quantity behind ``prefetch_wait_frac`` must be exact under an
    injected monotonic clock — attribution is asserted on fake-clock
    units, never on wall-time ratios (which flake on loaded machines)."""
    from repro.store.reader import TrackStore

    fx = storage._fixture(storage.StorageSpec())

    class Tick:
        t = 0.0

        def __call__(self):
            self.t += 1.0
            return self.t

    store = TrackStore(fx["store_root"], clock=Tick())
    n = len(list(store.iter_batches(prefetch=0)))
    assert n == fx["n_shards"] > 0
    assert store.stats["decode_s"] == n          # one tick per decode
    assert store.stats["wait_s"] == 0.0
    frozen = TrackStore(fx["store_root"], clock=lambda: 0.0)
    assert len(list(frozen.iter_batches(prefetch=2))) == n
    assert frozen.stats["wait_s"] == 0.0         # no wall-time leaks


def test_spec_validation():
    with pytest.raises(ValueError):
        storage.StorageSpec(source="tape")
    with pytest.raises(ValueError):
        storage.StorageSpec(phase="tepid")
    with pytest.raises(ValueError):
        storage.StorageSpec(consume="eat")
    with pytest.raises(ValueError):
        storage.StorageSpec(workload="nope")


# ---------------------------------------------------------------------------
# compare.py schema dispatch (satellite).
# ---------------------------------------------------------------------------

def _fake_doc(schema, metric, values):
    return {"schema": schema,
            "scenarios": [{"name": n, "metrics": {metric: v}}
                          for n, v in values.items()]}


def test_compare_dispatches_on_schema(storage_doc):
    assert default_metric(storage_doc) == "bytes_per_point"
    assert default_metric({"schema": "repro.bench.kernels/v1"}) == \
        "padded_fraction"
    assert default_metric({"schema": "repro.bench.campaign/v1"}) == \
        "job_seconds"
    with pytest.raises(ValueError):
        default_metric({"schema": "repro.bench.unknown/v9"})


def test_compare_storage_regression_gate(storage_doc):
    worse = copy.deepcopy(storage_doc)
    for rec in worse["scenarios"]:
        rec["metrics"]["bytes_per_point"] *= 1.5
    rows, regs = compare_docs(storage_doc, worse, threshold=0.10)
    assert regs and all(r["regressed"] for r in regs)
    rows2, regs2 = compare_docs(storage_doc, storage_doc,
                                threshold=0.10)
    assert regs2 == []


def test_compare_kernels_schema_dispatch():
    old = _fake_doc("repro.bench.kernels/v1", "padded_fraction",
                    {"a": 0.5, "b": 0.7})
    new = _fake_doc("repro.bench.kernels/v1", "padded_fraction",
                    {"a": 0.8, "b": 0.7})
    rows, regs = compare_docs(old, new, threshold=0.10)
    assert [r["name"] for r in regs] == ["a"]
    assert rows[0]["metric"] == "padded_fraction"


def test_compare_rejects_schema_mismatch(storage_doc):
    kern = _fake_doc("repro.bench.kernels/v1", "padded_fraction",
                     {"a": 0.5})
    with pytest.raises(ValueError, match="different schemas"):
        compare_docs(storage_doc, kern)


def test_compare_smoke_single_record():
    old = {"schema": "repro.bench.smoke/v1",
           "scenario": {"name": "s", "metrics": {"job_seconds": 10.0}}}
    new = {"schema": "repro.bench.smoke/v1",
           "scenario": {"name": "s", "metrics": {"job_seconds": 20.0}}}
    rows, regs = compare_docs(old, new, threshold=0.10)
    assert len(rows) == 1 and len(regs) == 1
