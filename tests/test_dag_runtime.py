"""Streaming-DAG runtime invariants.

Extends the exactly-once and dispatch-determinism properties of
``tests/test_scheduler_properties.py`` from the flat ``run_job`` path to
:func:`repro.runtime.dag.run_dag`: every (node, original-id) pair must
complete exactly once across all three backends and across manager
sharding, dynamically admitted downstream tasks included, and the sim
dispatch log must be bitwise repeatable.  The hypothesis test below
additionally kills a :class:`DagCoordinator` mid-stream at a random
point, serializes its frontier through ``ManagerCheckpoint`` text, and
resumes into a *fresh* DAG instance — the union of fresh completions
before and after the restart must cover every task exactly once.
"""

from __future__ import annotations

import random
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import PhaseCostModel
from repro.core.messages import Task
from repro.runtime.dag import DagCoordinator, StreamingDAG, run_dag
from repro.runtime.protocol import ManagerCheckpoint

FAST = dict(poll_interval=0.002)
BACKENDS = ("threads", "processes", "sim")

SIM_MODEL = PhaseCostModel(
    name="t", r_process=1e6, b_node=8e6, b_global=64e6,
    cpu_rate=50e6, contention_alpha=0.001, task_overhead_s=0.01,
    msg_overhead_s=0.001)


def _tasks(n, size_fn=lambda i: (i * 37) % 23 + 1):
    return [Task(task_id=f"t{i:04d}", size_bytes=size_fn(i), timestamp=i)
            for i in range(n)]


def _double(task):            # module-level: picklable for processes
    return task.size_bytes * 2


def _size(task):
    return task.size_bytes


def _slow_double(task):
    time.sleep(0.005)
    return task.size_bytes * 2


def _slow_size(task):
    time.sleep(0.005)
    return task.size_bytes


def _fanout(task, _result):
    """Stateless 1:2 streaming expansion (downstream sizes preserved)."""
    return [Task(task_id=f"{task.task_id}/{suffix}",
                 size_bytes=task.size_bytes, timestamp=task.timestamp)
            for suffix in ("x", "y")]


def _make_dag(n, *, a_fn=_double, b_fn=_size, size_fn=None):
    """Source node ``a`` (n seeded tasks) streaming 1:2 into node ``b``.

    StreamingDAG instances are single-use — callers build a fresh one
    per run (the module docstring of repro.runtime.dag requires it).
    """
    tasks = _tasks(n) if size_fn is None else _tasks(n, size_fn=size_fn)
    dag = StreamingDAG()
    dag.add_node("a", fn=a_fn, tasks=tasks)
    dag.add_node("b", fn=b_fn)
    dag.add_edge("a", "b", expand=_fanout)
    return dag


def _expected_ids(n):
    a_ids = {f"t{i:04d}" for i in range(n)}
    b_ids = {f"{t}/{s}" for t in a_ids for s in ("x", "y")}
    return a_ids, b_ids


# -- exactly-once across backends and manager shards --------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shards", (1, 2))
def test_dag_exactly_once_across_backends(backend, shards):
    n = 18
    dres = run_dag(_make_dag(n), backend=backend, n_workers=4,
                   n_manager_shards=shards, tasks_per_message=2,
                   cost_model=SIM_MODEL, **FAST)
    a_ids, b_ids = _expected_ids(n)
    assert dres.node_completed["a"] == a_ids
    assert dres.node_completed["b"] == b_ids
    assert dres.run.completed_ids == (
        {f"a:{t}" for t in a_ids} | {f"b:{t}" for t in b_ids})
    # Fault-free: the dispatch log covers every namespaced id once.
    flat = [tid for batch in dres.run.batches for tid in batch]
    assert len(flat) == len(set(flat)) == 3 * n
    if backend != "sim":
        for i in range(n):
            oid = f"t{i:04d}"
            assert dres.node_results["a"][oid] == 2 * ((i * 37) % 23 + 1)


# -- exactly-once under 20% worker deaths -------------------------------


@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_dag_exactly_once_under_live_worker_death(backend):
    # 1 of 5 workers (20%) dies on its 3rd task; enough aggregate work
    # (60 executions x 5ms) that w0 is guaranteed to reach its fatal
    # task even with spawn-staggered worker boot.
    n = 20
    dres = run_dag(_make_dag(n, a_fn=_slow_double, b_fn=_slow_size),
                   backend=backend, n_workers=5, tasks_per_message=2,
                   worker_fail_after={"w0": 3}, failure_timeout=0.5,
                   **FAST)
    a_ids, b_ids = _expected_ids(n)
    assert dres.run.failed_workers == ["w0"]
    assert dres.run.reassigned_tasks >= 1
    assert dres.node_completed["a"] == a_ids
    assert dres.node_completed["b"] == b_ids
    assert dres.run.completed_ids == (
        {f"a:{t}" for t in a_ids} | {f"b:{t}" for t in b_ids})


@pytest.mark.parametrize("shards", (1, 2))
def test_dag_exactly_once_under_sim_worker_deaths(shards):
    # 2 of 10 workers (20%) die mid-run (10 MB tasks take ~10 s of sim
    # time each, so t=5/9 s lands inside the job); their in-flight
    # tasks must be re-queued and every node still completes fully.
    n = 40
    dres = run_dag(_make_dag(n, size_fn=lambda i: 10_000_000),
                   backend="sim", n_workers=10, n_manager_shards=shards,
                   worker_death={0: 5.0, 1: 9.0}, failure_timeout=2.0,
                   cost_model=SIM_MODEL, **FAST)
    a_ids, b_ids = _expected_ids(n)
    assert set(dres.run.failed_workers) == {0, 1}
    assert dres.run.reassigned_tasks >= 1
    assert dres.node_completed["a"] == a_ids
    assert dres.node_completed["b"] == b_ids


# -- dispatch determinism ------------------------------------------------


def test_dag_sim_dispatch_is_deterministic():
    n = 30
    runs = [run_dag(_make_dag(n), backend="sim", n_workers=6,
                    tasks_per_message=3, cost_model=SIM_MODEL, **FAST)
            for _ in range(2)]
    assert runs[0].run.batches == runs[1].run.batches
    assert runs[0].run.job_seconds == runs[1].run.job_seconds
    assert runs[0].run.dispatch_digest == runs[1].run.dispatch_digest


@pytest.mark.parametrize("shards", (2, 3))
def test_dag_sharded_sim_deterministic_and_equivalent(shards):
    n = 30
    base = run_dag(_make_dag(n), backend="sim", n_workers=6,
                   cost_model=SIM_MODEL, **FAST)
    first, second = [
        run_dag(_make_dag(n), backend="sim", n_workers=6,
                n_manager_shards=shards, cost_model=SIM_MODEL, **FAST)
        for _ in range(2)]
    # Sharded dispatch is repeatable bit-for-bit ...
    assert first.run.batches == second.run.batches
    assert first.run.job_seconds == second.run.job_seconds
    # ... splits the ASSIGN load across all shards ...
    assert len(first.run.shard_messages) == shards
    assert all(m > 0 for m in first.run.shard_messages)
    # ... and completes the same work as the single-manager baseline.
    assert first.run.completed_ids == base.run.completed_ids


# -- mid-stream kill / resume -------------------------------------------


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
       st.integers(0, 80))
@settings(max_examples=25, deadline=None)
def test_dag_mid_stream_kill_resume_exactly_once(opseed, per_msg, steps):
    """Kill the coordinator at a random mid-stream point and resume.

    Drives a DagCoordinator by hand for a random number of dispatch /
    partial-DONE operations, then 'kills' it: the frontier checkpoint is
    serialized to text (anything in flight at that instant is lost) and
    restored into a coordinator over a FRESH DAG instance.  Fresh
    completions before the kill plus fresh completions after the resume
    must cover every (node, id) pair exactly once — nothing re-runs,
    nothing is dropped, streamed ``b`` tasks included.
    """
    n = 10
    workers = ["w0", "w1", "w2"]
    coord = DagCoordinator(_make_dag(n), n_workers=len(workers),
                           tasks_per_message=per_msg)
    rng = random.Random(opseed)
    inflight = {w: [] for w in workers}
    fresh: list[str] = []
    for _ in range(steps):
        if coord.done:
            break
        w = rng.choice(workers)
        if rng.random() < 0.6:
            inflight[w].extend(t.task_id for t in coord.next_batch(w))
        elif inflight[w]:
            take = rng.randint(1, len(inflight[w]))
            done_ids, inflight[w] = inflight[w][:take], inflight[w][take:]
            fresh.extend(coord.on_done(w, done_ids))

    ck = ManagerCheckpoint.loads(coord.checkpoint().dumps())
    coord2 = DagCoordinator(_make_dag(n), n_workers=len(workers),
                            tasks_per_message=per_msg, checkpoint=ck)

    guard = 0
    while not coord2.done:
        guard += 1
        assert guard < 10_000, "resumed DAG coordinator made no progress"
        for w in workers:
            batch = coord2.next_batch(w)
            if batch:
                fresh.extend(
                    coord2.on_done(w, [t.task_id for t in batch]))

    a_ids, b_ids = _expected_ids(n)
    expected = sorted({f"a:{t}" for t in a_ids}
                      | {f"b:{t}" for t in b_ids})
    assert sorted(fresh) == expected
    assert coord2.node_completed["a"] == a_ids
    assert coord2.node_completed["b"] == b_ids
