"""Block/cyclic distribution rules (paper §II.D) + properties."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.distribution import (
    DistributionPolicy, assignment_imbalance, block_distribution,
    cyclic_distribution, distribute)
from repro.core.messages import (
    Task, organize_by_filename, organize_chronological,
    organize_largest_first, organize_random)


def test_paper_examples():
    # "if there are two processes and four tasks, process #1 would be
    # allocated tasks 1-2 and process #2 would be responsible for 3-4"
    assert block_distribution([1, 2, 3, 4], 2) == [[1, 2], [3, 4]]
    # cyclic: "the first process would be allocated tasks 1 and 3"
    assert cyclic_distribution([1, 2, 3, 4], 2) == [[1, 3], [2, 4]]


@given(st.lists(st.integers(), max_size=200), st.integers(1, 17))
@settings(max_examples=50, deadline=None)
def test_policies_partition_exactly(tasks, n):
    for fn in (block_distribution, cyclic_distribution):
        parts = fn(tasks, n)
        assert len(parts) == n
        flat = [t for p in parts for t in p]
        assert sorted(flat) == sorted(tasks)


@given(st.lists(st.integers(), min_size=1, max_size=100), st.integers(1, 9))
@settings(max_examples=50, deadline=None)
def test_block_is_consecutive_and_balanced(tasks, n):
    parts = block_distribution(tasks, n)
    # concatenation preserves order
    assert [t for p in parts for t in p] == list(tasks)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


@given(st.lists(st.integers(), min_size=1, max_size=100), st.integers(1, 9))
@settings(max_examples=50, deadline=None)
def test_cyclic_stride(tasks, n):
    parts = cyclic_distribution(tasks, n)
    for w, p in enumerate(parts):
        assert p == list(tasks)[w::n]


def test_distribute_dispatch():
    assert distribute([1, 2, 3], 2, "block") == [[1, 2], [3]]
    assert distribute([1, 2, 3], 2, DistributionPolicy.CYCLIC) == \
        [[1, 3], [2]]


def test_organizers():
    tasks = [Task("b", size_bytes=5, timestamp=2.0),
             Task("a", size_bytes=9, timestamp=3.0),
             Task("c", size_bytes=1, timestamp=1.0)]
    assert [t.task_id for t in organize_chronological(tasks)] == \
        ["c", "b", "a"]
    assert [t.task_id for t in organize_largest_first(tasks)] == \
        ["a", "b", "c"]
    assert [t.task_id for t in organize_by_filename(tasks)] == \
        ["a", "b", "c"]
    r = organize_random(tasks, seed=0)
    assert sorted(t.task_id for t in r) == ["a", "b", "c"]
    assert organize_random(tasks, seed=0) == organize_random(tasks, seed=0)


def test_imbalance_metric():
    even = [[Task("a", size_bytes=5)], [Task("b", size_bytes=5)]]
    skew = [[Task("a", size_bytes=9)], [Task("b", size_bytes=1)]]
    assert assignment_imbalance(even) == 1.0
    assert assignment_imbalance(skew) == 1.8
