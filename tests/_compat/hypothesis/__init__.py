"""Minimal hypothesis shim (used only when the real package is absent).

tests/conftest.py puts this package on sys.path when ``import hypothesis``
fails, so the tier-1 suite collects and the property tests still run as
light deterministic fuzz tests: ``@given`` draws a fixed number of
pseudo-random examples per test (seeded by test name + example index, so
failures reproduce).  Install the real ``hypothesis`` (see
requirements-dev.txt) for shrinking, coverage-guided generation, and the
full strategy library.
"""

from __future__ import annotations

import random

from . import strategies  # noqa: F401  (hypothesis.strategies importable)

__version__ = "0.0.0-shim"
__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 10
_MAX_EXAMPLES = 10      # cap: this is a smoke-fuzz shim, not the real thing


def settings(**kwargs):
    """Accept and mostly ignore hypothesis settings; honours max_examples
    (capped) for the shim's example loop."""
    def deco(test):
        test._shim_max_examples = min(
            kwargs.get("max_examples", _DEFAULT_EXAMPLES), _MAX_EXAMPLES)
        return test
    return deco


def given(*gargs, **gkwargs):
    """Run the wrapped test on deterministic pseudo-random examples."""
    if gkwargs:
        raise NotImplementedError(
            "the hypothesis shim supports positional @given only")

    def deco(test):
        n = min(getattr(test, "_shim_max_examples", _DEFAULT_EXAMPLES),
                _MAX_EXAMPLES)

        def runner():
            for i in range(n):
                rng = random.Random(f"{test.__module__}.{test.__name__}:{i}")
                vals = [s.draw(rng) for s in gargs]
                try:
                    test(*vals)
                except AssertionError as e:
                    raise AssertionError(
                        f"{e} [hypothesis-shim example #{i}: "
                        f"args={vals!r}]") from e

        # Plain zero-arg function (NOT functools.wraps): pytest must not
        # see the original signature, or it would treat the strategy
        # parameters as fixtures.
        runner.__name__ = test.__name__
        runner.__doc__ = test.__doc__
        runner.__module__ = test.__module__
        return runner
    return deco
