"""Strategy subset for the hypothesis shim (see package docstring).

Each strategy is just a deterministic ``draw(rng)``; only the strategies
the test suite uses are implemented: integers, lists, sampled_from,
floats, booleans, just, tuples, composite.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any],
                 label: str = "strategy"):
        self._draw = draw_fn
        self._label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              f"{self._label}.map")

    def __repr__(self) -> str:
        return self._label


def integers(min_value: int = -(2 ** 16),
             max_value: int = 2 ** 16) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_kw) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.uniform(min_value, max_value),
        f"floats({min_value}, {max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from needs a non-empty sequence")
    return SearchStrategy(
        lambda rng: elements[rng.randrange(len(elements))],
        f"sampled_from({elements!r})")


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(draw, f"lists({elements!r})")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.draw(rng) for s in strategies), "tuples")


def composite(f: Callable[..., Any]) -> Callable[..., SearchStrategy]:
    """``@st.composite`` — f's first arg is the ``draw`` callable."""
    def builder(*args, **kwargs) -> SearchStrategy:
        def draw_value(rng: random.Random):
            return f(lambda strategy: strategy.draw(rng), *args, **kwargs)
        return SearchStrategy(draw_value, f"composite({f.__name__})")
    return builder
