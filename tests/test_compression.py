"""Gradient compression: int8 stochastic-rounded all-reduce."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import (
    dequantize, quantize_stochastic)


def test_stochastic_rounding_unbiased():
    key = jax.random.key(0)
    x = jnp.full((4096,), 0.3)
    q, scale = quantize_stochastic(x, key)
    y = np.asarray(dequantize(q, scale, x.shape))
    # mean of dequantized ~ 0.3 despite int8 grid
    assert abs(y.mean() - 0.3) < 0.003


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, scale = quantize_stochastic(x, jax.random.key(1))
    y = np.asarray(dequantize(q, scale, x.shape))
    bmax = np.abs(np.asarray(x)).max()
    assert np.abs(y - np.asarray(x)).max() <= bmax / 127 * 1.5


POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp, numpy as np
from repro.train.compression import make_compressed_allreduce
mesh = jax.make_mesh((4, 2), ("pod", "data"))
fn = jax.jit(make_compressed_allreduce(mesh, axis="pod"))
rng = np.random.default_rng(0)
tree = {"g": jnp.asarray(rng.normal(size=(2048,)), jnp.float32)}
out = fn(tree, jax.random.key(0))
err = float(jnp.abs(out["g"] - tree["g"]).max())
scale = float(jnp.abs(tree["g"]).max())
print("POD_OK", err / scale)
assert err / scale < 0.02
"""


@pytest.mark.slow
def test_compressed_allreduce_multidevice(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = POD_SCRIPT.replace("SRC", os.path.abspath(src))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300)
    assert "POD_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
