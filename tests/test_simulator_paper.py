"""EXPERIMENTS.md §Paper-validation: the simulator reproduces the paper's
measured relations (Tables I-II, Figs 4-9, §IV.A-C, §V)."""

import numpy as np
import pytest

from repro.core import (
    ARCHIVE_PHASE, ORGANIZE_PHASE, PROCESS_PHASE, RADAR_PHASE,
    feasible_table_cells, simulate_self_scheduling, simulate_static)
from repro.core.cost_model import LEGACY_LAUNCH_PENALTY
from repro.tracks.datasets import (
    aircraft_archive_manifest, monday_manifest, processing_manifest,
    radar_message_manifest)

PAPER_CHRONO = {(2048, 32): 5640, (1024, 32): 5944, (512, 32): 7493,
                (256, 32): 11944, (1024, 16): 5963, (512, 16): 7157,
                (256, 16): 11860, (512, 8): 6989, (256, 8): 11860}
PAPER_SIZE = {(2048, 32): 5456, (1024, 32): 5704, (512, 32): 6608,
              (256, 32): 11015, (1024, 16): 5568, (512, 16): 6330,
              (256, 16): 10428, (512, 8): 6171, (256, 8): 10428}


@pytest.fixture(scope="module")
def organize_sims():
    tasks = monday_manifest()
    out = {}
    for org in ("chronological", "largest_first"):
        for cores, nppn in feasible_table_cells():
            r = simulate_self_scheduling(
                tasks, n_workers=cores - 1, nodes=cores // nppn, nppn=nppn,
                model=ORGANIZE_PHASE, organization=org)
            out[(org, cores, nppn)] = r
    return out


def test_tables_within_20pct(organize_sims):
    for (org, cores, nppn), r in organize_sims.items():
        paper = (PAPER_CHRONO if org == "chronological"
                 else PAPER_SIZE)[(cores, nppn)]
        assert abs(r.job_seconds / paper - 1) < 0.20, \
            (org, cores, nppn, r.job_seconds, paper)


def test_largest_first_always_wins(organize_sims):
    """Paper: 'organizing tasks by size always outperformed
    chronological task organization.'"""
    for cores, nppn in feasible_table_cells():
        size = organize_sims[("largest_first", cores, nppn)].job_seconds
        chrono = organize_sims[("chronological", cores, nppn)].job_seconds
        assert size <= chrono * 1.001, (cores, nppn)


def test_min_nppn_wins_at_fixed_cores(organize_sims):
    """Paper: 'minimizing NPPN also improved performance.'"""
    for org in ("chronological", "largest_first"):
        for cores in (256, 512):
            t8 = organize_sims[(org, cores, 8)].job_seconds
            t16 = organize_sims[(org, cores, 16)].job_seconds
            t32 = organize_sims[(org, cores, 32)].job_seconds
            assert t8 <= t16 * 1.01 <= t32 * 1.02, (org, cores)


def test_fig4_half_nodes_same_performance(organize_sims):
    """Paper Fig 4: 1024 cores/NPPN=16/size-order outperformed
    2048 cores/NPPN=32/chronological => 50% fewer nodes, same perf."""
    better = organize_sims[("largest_first", 1024, 16)].job_seconds
    worse = organize_sims[("chronological", 2048, 32)].job_seconds
    assert better < worse


def test_fig56_size_order_minimizes_span(organize_sims):
    """Paper Figs 5-6: size organization 'minimized the time span between
    the slowest and fastest workers'."""
    chrono = organize_sims[("chronological", 256, 8)]
    size = organize_sims[("largest_first", 256, 8)]
    assert size.worker_time_span < 0.75 * chrono.worker_time_span


def test_fig56_nppn_shifts_distribution_not_shape(organize_sims):
    """Paper Figs 5-6: 'reducing NPPN shifts the distribution to faster
    times, rather than changing the distribution's shape'."""
    lo = organize_sims[("chronological", 256, 8)]
    hi = organize_sims[("chronological", 256, 32)]
    med_lo = np.median([b for b in lo.worker_busy if b > 0])
    med_hi = np.median([b for b in hi.worker_busy if b > 0])
    assert med_lo < med_hi                               # faster
    ratio = np.std(lo.worker_busy) / np.std(hi.worker_busy)
    assert 0.8 < ratio < 1.25                            # same shape


def test_fig7_tasks_per_message_degrades():
    tasks = monday_manifest()
    times = []
    for k in (1, 2, 4, 8):
        r = simulate_self_scheduling(
            tasks, n_workers=511, nodes=64, nppn=8, model=ORGANIZE_PHASE,
            organization="largest_first", tasks_per_message=k)
        times.append(r.job_seconds)
    assert times == sorted(times), times      # monotonic degradation


def test_sec4b_cyclic_cuts_archive_time_90pct():
    """Paper §IV.B: block->cyclic reduced archive job time by >90 %."""
    arch = aircraft_archive_manifest()
    rb = simulate_static(arch, n_workers=1023, nodes=64, nppn=16,
                         model=ARCHIVE_PHASE, policy="block")
    rc = simulate_static(arch, n_workers=1023, nodes=64, nppn=16,
                         model=ARCHIVE_PHASE, policy="cyclic")
    assert 1 - rc.job_seconds / rb.job_seconds > 0.90


def test_sec4a_median_worker_minus_14pct():
    """Paper §IV.A: self-scheduling + triples-mode cut the median worker
    time by 14 % vs the legacy batch/block launcher."""
    tasks = monday_manifest()
    rs = simulate_self_scheduling(
        tasks, n_workers=255, nodes=32, nppn=8, model=ORGANIZE_PHASE,
        organization="largest_first")
    rb = simulate_static(
        tasks, n_workers=255, nodes=32, nppn=8, model=ORGANIZE_PHASE,
        policy="block", organization="chronological",
        legacy_launch_penalty=LEGACY_LAUNCH_PENALTY)
    delta = rs.median_worker_busy / rb.median_worker_busy - 1
    assert -0.18 < delta < -0.10, delta


def test_sec4c_processing_worker_distribution():
    """Paper §IV.C: median 13.1 h, 99.1 % < 18 h, all < 29.6 h."""
    proc = processing_manifest()
    r = simulate_self_scheduling(
        proc, n_workers=1023, nodes=64, nppn=16, model=PROCESS_PHASE,
        organization="random")
    busy = np.array([b for b in r.worker_busy if b > 0])
    assert abs(np.median(busy) / (13.1 * 3600) - 1) < 0.10
    assert np.percentile(busy, 99.1) < 20 * 3600
    assert busy.max() < 32 * 3600


def test_sec4c_legacy_batch_needs_days():
    """Paper: batch distribution without self-scheduling/triples-mode
    required more than 7 days."""
    proc = processing_manifest()
    r = simulate_static(
        proc, n_workers=1023, nodes=32, nppn=32, model=PROCESS_PHASE,
        policy="block", organization="filename",
        legacy_launch_penalty=LEGACY_LAUNCH_PENALTY)
    assert r.job_seconds > 7 * 86400


def test_sec5_radar_tight_span():
    """Paper §V: median 24.34 h, span only 1.12 h, 300 tasks/message."""
    rad = radar_message_manifest()
    r = simulate_self_scheduling(
        rad, n_workers=1023, nodes=128, nppn=8, model=RADAR_PHASE,
        organization="random")
    busy = np.array([b for b in r.worker_busy if b > 0])
    assert abs(np.median(busy) / 87633 - 1) < 0.05
    span_h = (busy.max() - busy.min()) / 3600
    assert span_h < 2.5          # paper: 1.12 h; tight by construction
    assert len(rad) == 43_969    # 13,190,700 ids / 300 per message
