"""Fused on-device segment pipeline: pallas-vs-ref, fused-vs-unfused,
bucketing/reassembly invariance, and the satellite vectorizations.

Tolerancing notes: the fused-vs-unfused comparison is gated at 1e-5 —
the two paths run the same kernels on the same values (padding columns
contribute exact zeros; stage boundaries are pinned with optimization
barriers), so in practice they agree bitwise.  The pallas-vs-ref
comparison tolerates ulp-level association differences (the AGL matmul
formulation vs the 4-term oracle), amplified by the terrain gradient;
tracks drift east so dynamic-rate headings stay clear of the arctan2
branch cut at +-pi.
"""

import zipfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.geometry.aerodromes import synthetic_aerodromes
from repro.kernels import ops
from repro.kernels.segment_pipeline import FIELDS
from repro.tracks.segments import (
    BUCKET_SIZES, MAX_SEG_POINTS, SegmentProcessor, _round_rows,
    bucket_width, split_segments)

# Equatorial test grid: f32 lat/lon ulp is ~60x smaller near 0 than at
# CONUS latitudes, so central-difference rates don't amplify the
# pallas-vs-ref interp ulp into m/s-scale noise.
GRID = (0.0, 26.0, 0.0, 59.0, 8.0)
ATTRS = ("times", "lat", "lon", "alt_msl_m", "alt_agl_m", "vrate_ms",
         "gspeed_ms", "heading_rad", "turn_rad_s")


def _dem(seed=7, H=209, W=473):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 2500, (H, W)).astype(np.float32)


def _ragged_inputs(B, K, seed=0):
    """One bucket batch: B tracks of <=K knots drifting east (headings
    stay off the arctan2 branch cut)."""
    rng = np.random.default_rng(seed)
    t_in = np.zeros((B, K), np.float32)
    v_in = np.zeros((B, 3, K), np.float32)
    count_in = np.zeros((B,), np.int32)
    t_out = np.zeros((B, K), np.float32)
    count_out = np.zeros((B,), np.int32)
    for b in range(B):
        n = int(rng.integers(10, K + 1))
        m = int(rng.integers(2, K + 1))
        t = np.cumsum(rng.uniform(1.0, 6.0, n))
        t -= t[0]
        t_in[b, :n] = t
        t_in[b, n:] = t[-1] + np.arange(1, K - n + 1)
        v_in[b, 0, :n] = rng.uniform(1, 3) \
            + np.cumsum(rng.normal(0, 2e-4, n))
        v_in[b, 1, :n] = rng.uniform(2, 20) \
            + np.cumsum(rng.uniform(5e-4, 2e-3, n))        # eastward
        v_in[b, 2, :n] = 1500 + np.cumsum(rng.normal(0, 2, n))
        v_in[b, :, n:] = v_in[b, :, n - 1:n]
        count_in[b] = n
        t_out[b, :m] = np.arange(m)
        t_out[b, m:] = t_out[b, m - 1]
        count_out[b] = m
    return t_in, v_in, count_in, t_out, count_out


@pytest.mark.parametrize("K", BUCKET_SIZES)
def test_process_segments_pallas_matches_ref_across_buckets(K):
    dem = _dem()
    args = _ragged_inputs(3, K, seed=K)
    got = {k: np.asarray(v) for k, v in ops.process_segments(
        dem, *args, grid=GRID, backend="pallas").items()}
    want = {k: np.asarray(v) for k, v in ops.process_segments(
        dem, *args, grid=GRID, backend="ref").items()}
    assert set(got) == set(FIELDS)
    # Rate fields amplify interp ulp by ~m_per_deg/(2 dt), and a query
    # landing on a knot boundary may bracket the adjacent interval —
    # both are sub-m/s effects; structural kernel bugs are orders of
    # magnitude larger.
    atol = {"vrate": 0.5, "gspeed": 0.5, "heading": 0.1, "turn": 0.5}
    for f in FIELDS:
        np.testing.assert_allclose(got[f], want[f], rtol=1e-3,
                                   atol=atol.get(f, 1e-2), err_msg=f)


def test_process_segments_masks_padding():
    dem = _dem()
    args = _ragged_inputs(4, 128, seed=1)
    count_out = args[4]
    out = ops.process_segments(dem, *args, grid=GRID)
    idx = np.arange(128)[None, :]
    for f in FIELDS:
        plane = np.asarray(out[f])
        assert (plane[idx >= count_out[:, None]] == 0).all(), f


def test_process_segments_counts_compile_cache():
    dem = _dem()
    ops.reset_pipeline_stats()
    args = _ragged_inputs(2, 128, seed=3)
    ops.process_segments(dem, *args, grid=GRID)
    ops.process_segments(dem, *args, grid=GRID)
    stats = ops.get_pipeline_stats()
    assert stats["compile_misses"] == 1
    assert stats["compile_hits"] == 1


# ---------------------------------------------------------------------------
# Fused vs unfused on golden (real workflow) archives.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden_archives(tmp_path_factory):
    from repro.tracks.segments import segment_tasks_from_archive_tree
    from repro.tracks.workflow import TrackWorkflow
    root = str(tmp_path_factory.mktemp("golden"))
    wf = TrackWorkflow(root, n_workers=2, poll_interval=0.003)
    wf.generate_raw(n_files=4, scale=2e4)
    wf.run()
    tasks = segment_tasks_from_archive_tree(wf.archive_dir)
    assert tasks
    return tasks


def _processors():
    aero = synthetic_aerodromes(n=64)
    return (SegmentProcessor(aerodromes=aero, pipeline="fused"),
            SegmentProcessor(aerodromes=aero, pipeline="unfused"))


def test_fused_matches_unfused_on_golden_archives(golden_archives):
    """ISSUE 3 acceptance: fused == unfused within 1e-5 on golden
    archives (the fused planes are narrower; the unfused tail beyond
    the archive's bucket width must be pure padding)."""
    fused, unfused = _processors()
    fb = fused.process_batch(golden_archives)
    ub = unfused.process_batch(golden_archives)
    assert set(fb) == set(ub)
    compared = 0
    for tid in fb:
        f, u = fb[tid], ub[tid]
        assert f.icao24 == u.icao24
        assert f.airspace == u.airspace
        np.testing.assert_array_equal(f.count, u.count)
        w = f.times.shape[1]
        for attr in ATTRS:
            a, b = getattr(f, attr), getattr(u, attr)
            if a.size:
                np.testing.assert_allclose(a, b[:, :w], atol=1e-5,
                                           rtol=1e-5, err_msg=attr)
                assert not b[:, w:].any()
                compared += 1
    assert compared > 0
    assert fused.last_stats["padded_fraction"] < \
        unfused.last_stats["padded_fraction"]


def test_fused_zero_intermediate_transfers(golden_archives):
    fused, unfused = _processors()
    ops.reset_pipeline_stats()
    fused.process_batch(golden_archives)
    assert ops.get_pipeline_stats()["intermediate_transfers"] == 0
    ops.reset_pipeline_stats()
    unfused.process_batch(golden_archives[:2])
    # interp down, fi/fj up, agl down, rates down — per batch
    assert ops.get_pipeline_stats()["intermediate_transfers"] == 4


def test_read_observations_golden_zip_roundtrip(golden_archives):
    """The vectorized zip/CSV parse yields sorted, finite columns."""
    proc, _ = _processors()
    obs = proc.read_observations(golden_archives[0].payload)
    if not obs:
        pytest.skip("first archive empty")
    assert (np.diff(obs["time"]) >= 0).all()
    for key in ("time", "lat", "lon", "alt"):
        assert np.isfinite(obs[key]).all()
    assert len(obs["icao24"]) == len(obs["time"])


# ---------------------------------------------------------------------------
# Bucketing / reassembly.
# ---------------------------------------------------------------------------

def test_bucket_width_boundaries():
    assert bucket_width(1) == 128
    assert bucket_width(128) == 128
    assert bucket_width(129) == 256
    assert bucket_width(256) == 256
    assert bucket_width(1024) == 1024
    assert bucket_width(5000) == 1024      # capped at MAX_SEG_POINTS
    assert bucket_width(MAX_SEG_POINTS) == MAX_SEG_POINTS


def test_round_rows():
    assert [_round_rows(b) for b in (1, 2, 3, 5, 8, 9, 17)] == \
        [1, 2, 4, 8, 8, 16, 24]


def _synth_archive(rng, n_segs):
    """One archive of eastward-drifting segments (10-400 obs each)."""
    ts, lats, lons, alts = [], [], [], []
    t = 0.0
    for _ in range(n_segs):
        n = int(rng.integers(10, 400))
        seg_t = t + np.cumsum(rng.uniform(1.0, 7.0, n))
        ts.append(seg_t)
        lats.append(rng.uniform(30, 45) + np.cumsum(rng.normal(0, 2e-4, n)))
        lons.append(rng.uniform(-115, -80)
                    + np.cumsum(rng.uniform(5e-4, 2e-3, n)))
        alts.append(1000 + np.cumsum(rng.normal(0, 2, n)))
        t = seg_t[-1] + 400.0
    obs = {"time": np.concatenate(ts), "lat": np.concatenate(lats),
           "lon": np.concatenate(lons), "alt": np.concatenate(alts),
           "icao24": np.array(["deadbe"] * sum(len(x) for x in ts))}
    return obs, split_segments(obs["time"])


def test_fused_handles_zero_segment_archives():
    """An items entry with no segments yields an empty ProcessedSegments
    from both pipelines (the fused path must not choke on empty rows)."""
    rng = np.random.default_rng(3)
    full = _synth_archive(rng, 2)
    empty = ({"time": np.array([0.0, 1.0]), "lat": np.zeros(2),
              "lon": np.zeros(2), "alt": np.zeros(2),
              "icao24": np.array(["x", "x"])}, [])
    for pipeline in ("fused", "unfused"):
        proc = SegmentProcessor(pipeline=pipeline)
        out = proc._process_many([full, empty])
        assert len(out) == 2
        assert len(out[0]) == 2
        assert len(out[1]) == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4))
def test_bucketing_reassembly_is_batch_composition_invariant(seed, n_arch):
    """Per-archive outputs must not depend on what else shares the
    batch: processing archives together == processing them alone."""
    rng = np.random.default_rng(seed)
    items = [_synth_archive(rng, int(rng.integers(1, 4)))
             for _ in range(n_arch)]
    proc = SegmentProcessor(aerodromes=synthetic_aerodromes(n=16))
    together = proc._process_many(items)
    for item, batched in zip(items, together):
        alone = proc._process_many([item])[0]
        assert alone.icao24 == batched.icao24
        assert alone.airspace == batched.airspace
        np.testing.assert_array_equal(alone.count, batched.count)
        for attr in ATTRS:
            np.testing.assert_array_equal(
                getattr(alone, attr), getattr(batched, attr), err_msg=attr)


# ---------------------------------------------------------------------------
# Satellite: vectorized CSV parse.
# ---------------------------------------------------------------------------

CSV = ("time,icao24,lat,lon,geoaltitude\n"
       "30.0,abc123,40.5,-100.25,1200.0\n"
       "\n"
       "10.0,abc123,40.1,-100.10,1100.0\n"
       "10.0,abc123,40.2,-100.15,1150.0\n"
       "20.5,abc123,40.3,-100.20,1180.0\n")


def test_read_observations_vectorized_parse(tmp_path):
    p = tmp_path / "abc123.csv"
    p.write_text(CSV)
    proc = SegmentProcessor()
    obs = proc.read_observations(str(p))
    np.testing.assert_array_equal(obs["time"], [10.0, 10.0, 20.5, 30.0])
    # stable sort: the two t=10 rows keep file order
    np.testing.assert_array_equal(obs["lat"], [40.1, 40.2, 40.3, 40.5])
    np.testing.assert_array_equal(obs["lon"],
                                  [-100.10, -100.15, -100.20, -100.25])
    np.testing.assert_array_equal(obs["alt"],
                                  [1100.0, 1150.0, 1180.0, 1200.0])
    assert list(obs["icao24"]) == ["abc123"] * 4


def test_read_observations_zip_and_column_order(tmp_path):
    # shuffled header order must not matter
    csv = ("lat,geoaltitude,time,icao24,lon\n"
           "40.0,1000.0,5.0,ff0011,-99.5\n"
           "40.1,1001.0,4.0,ff0011,-99.6\n")
    z = tmp_path / "ff0011.zip"
    with zipfile.ZipFile(z, "w") as zf:
        zf.writestr("ff0011.csv", csv)
    obs = SegmentProcessor().read_observations(str(z))
    np.testing.assert_array_equal(obs["time"], [4.0, 5.0])
    np.testing.assert_array_equal(obs["lat"], [40.1, 40.0])
    np.testing.assert_array_equal(obs["alt"], [1001.0, 1000.0])
    assert list(obs["icao24"]) == ["ff0011"] * 2


def test_read_observations_header_only(tmp_path):
    p = tmp_path / "empty.csv"
    p.write_text("time,icao24,lat,lon,geoaltitude\n")
    assert SegmentProcessor().read_observations(str(p)) == {}


# ---------------------------------------------------------------------------
# Satellite: vectorized airspace classification.
# ---------------------------------------------------------------------------

def test_airspace_classes_match_scalar_reference():
    from repro.geometry.queries import RADIUS_DEG
    aero = synthetic_aerodromes(n=40)
    proc = SegmentProcessor(aerodromes=aero)
    rng = np.random.default_rng(11)
    # half random points, half exactly on aerodromes (inside the radius)
    lat = np.r_[rng.uniform(25, 49, 20), [a.lat for a in aero[:20]]]
    lon = np.r_[rng.uniform(-124, -67, 20), [a.lon for a in aero[:20]]]
    got = proc._airspace_classes(lat, lon)

    def scalar(la, lo):
        d2 = ((np.array([a.lat for a in aero]) - la) ** 2
              + ((np.array([a.lon for a in aero]) - lo)
                 * np.cos(np.deg2rad(la))) ** 2)
        i = int(np.argmin(d2))
        return aero[i].airspace_class if d2[i] <= RADIUS_DEG ** 2 else "G"

    assert got == [scalar(la, lo) for la, lo in zip(lat, lon)]
    assert any(g != "G" for g in got)       # on-aerodrome points classified
    assert proc._airspace_class(lat[0], lon[0]) == got[0]


def test_airspace_classes_no_aerodromes():
    proc = SegmentProcessor()
    assert proc._airspace_classes(np.array([40.0]),
                                  np.array([-100.0])) == ["G"]
