"""BENCH_kernels artifact: schema validity, deterministic metrics, and
the ISSUE-3 acceptance cell's deterministic checks.

Wall-clock gates (speedup_x) are asserted loosely here — the CI
kernel-bench job owns the >=2x throughput gate; under pytest the
machine is busy with the rest of the suite.
"""

import dataclasses
import json

import pytest

from repro.bench.kernels import (
    KernelScenario, KernelSpec, kernel_scenarios, run_kernel_campaign,
    run_kernel_scenario, synth_items)
from repro.bench.schema import (
    KERNELS_SCHEMA, canonical_bytes, validate_kernels)

TINY = KernelSpec(workload="heavy_tail", n_archives=2,
                  segments_per_archive=3, repeats=1, seed=5)


def _tiny_scenario(**kw):
    run = dataclasses.replace(TINY, **kw)
    return KernelScenario(
        name="tiny", group="tiny", run=run,
        baseline=dataclasses.replace(run, pipeline="unfused"))


@pytest.fixture(scope="module")
def quick_doc():
    return run_kernel_campaign(quick=True)


def test_quick_campaign_is_schema_valid(quick_doc):
    assert quick_doc["schema"] == KERNELS_SCHEMA
    assert validate_kernels(quick_doc) == []
    assert quick_doc["summary"]["total"] >= 1
    # canonical serialization drops measured/timing and stays stable
    assert canonical_bytes(quick_doc) == canonical_bytes(
        json.loads(json.dumps(quick_doc)))


def test_acceptance_cell_deterministic_checks(quick_doc):
    """The ISSUE-3 gates that do not depend on wall clocks."""
    rec = next(r for r in quick_doc["scenarios"]
               if r["name"] == "segment_pipeline_heavy_tail")
    m = rec["metrics"]
    assert m["intermediate_transfers"] == 0
    assert m["baseline_intermediate_transfers"] == 4
    assert m["padded_fraction_reduction_x"] >= 5.0
    assert m["max_abs_diff_vs_baseline"] <= 1e-5
    # steady-state batches reuse every bucket compilation
    assert m["compile_misses_steady"] == 0
    assert m["compile_hits_steady"] > 0
    # wall-clock numbers exist and are sane (the >=2x gate runs in CI)
    assert rec["measured"]["speedup_x"] > 0
    assert rec["measured"]["points_per_s"] > 0


def test_metrics_deterministic_for_fixed_seed():
    a = run_kernel_scenario(_tiny_scenario())
    b = run_kernel_scenario(_tiny_scenario())
    assert a["status"] != "error", a["error"]
    assert a["metrics"] == b["metrics"]


def test_synth_items_deterministic_and_segmented():
    items_a = synth_items(TINY)
    items_b = synth_items(TINY)
    assert len(items_a) == TINY.n_archives
    for (oa, sa), (ob, sb) in zip(items_a, items_b):
        assert sa == sb and len(sa) == TINY.segments_per_archive
        for k in oa:
            assert (oa[k] == ob[k]).all()


def test_scenarios_declare_the_acceptance_tier():
    scs = kernel_scenarios()
    quick = [sc for sc in scs if sc.tier == "quick"]
    assert any(sc.name == "segment_pipeline_heavy_tail" for sc in quick)
    for sc in scs:
        assert sc.baseline is not None
        assert sc.baseline.pipeline == "unfused"


def test_no_matching_scenarios_is_a_clean_error(capsys):
    from repro.bench.kernels import main
    with pytest.raises(ValueError):
        run_kernel_campaign(filters=["no-such-scenario"])
    assert main(["--filter", "no-such-scenario", "--out", "-"]) == 1
    assert "no kernel scenarios match" in capsys.readouterr().err


def test_bad_spec_rejected():
    with pytest.raises(ValueError):
        KernelSpec(workload="nope")
    with pytest.raises(ValueError):
        KernelSpec(pipeline="blended")


def test_validate_kernels_flags_broken_docs(quick_doc):
    doc = json.loads(json.dumps(quick_doc))
    doc["scenarios"][0]["metrics"].pop("padded_fraction")
    doc["scenarios"][0]["spec"]["run"].pop("workload")
    probs = validate_kernels(doc)
    assert any("padded_fraction" in p for p in probs)
    assert any("workload" in p for p in probs)
