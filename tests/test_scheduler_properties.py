"""Property-based SchedulerCore invariants (hypothesis / tests/_compat shim).

The protocol core is the single decision-maker behind all three execution
backends, so its invariants are the system's invariants — and since the
scheduling-policy layer (repro.runtime.policies) owns dispatch order and
batch size, every invariant is checked for EVERY policy:

  * exactly-once completion under arbitrary interleavings of dispatch,
    (duplicate) DONE reports, and worker deaths;
  * no lost and no duplicated tasks across checkpoint save -> restore
    (including the policy's own mid-run state, e.g. adaptive_chunk's
    open round);
  * dispatch-order determinism for a fixed seed.  The order-based
    policies (static, fifo_selfsched, sized_lpt, adaptive_chunk) emit
    bit-identical dispatch logs across the threads, processes, and sim
    backends; shard_affinity's batch contents depend on the asking
    worker's binding, so on the live backends the *interleaving*
    follows real completion timing — for it we assert the per-seed sim
    log bit-identically, exactly-once everywhere, and the single-run
    batch-locality invariant (see repro.runtime.policies docstring).
"""

import random

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.messages import Task
from repro.runtime import (
    POLICY_NAMES, FleetController, ManagerCheckpoint, SchedulerCore,
    WorkerSpeedModel, run_job)
from repro.runtime.policies import locality_key

BACKENDS = ("threads", "processes", "sim")

#: Policies whose ASSIGN contents are independent of the asking worker,
#: hence bit-identical dispatch logs across backends (run_job resolves
#: ONE model-based cost estimator for every backend, so the cost-aware
#: policies qualify too); shard_affinity is the documented exception.
ORDER_POLICIES = ("static", "fifo_selfsched", "sized_lpt",
                  "adaptive_chunk")


def _tasks(sizes):
    # Grouped ids ("g<k>/t<i>") give shard_affinity real locality runs;
    # for every other policy the prefix is just part of the tie-break.
    return [Task(task_id=f"g{i % 4}/t{i:04d}", size_bytes=s, timestamp=i)
            for i, s in enumerate(sizes)]


def _pickle_safe_fn(task):          # module-level: picklable for processes
    return task.size_bytes


@st.composite
def job_shapes(draw):
    n = draw(st.integers(1, 40))
    sizes = draw(st.lists(st.integers(1, 10_000_000),
                          min_size=n, max_size=n))
    k = draw(st.integers(1, 6))
    org = draw(st.sampled_from(["largest_first", "chronological",
                                "random"]))
    seed = draw(st.integers(0, 5))
    return sizes, k, org, seed


# ---------------------------------------------------------------------------
# Exactly-once under adversarial interleavings — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_core_exactly_once_under_random_interleaving(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy=policy, n_workers=3)
        rng = random.Random(opseed)
        workers = ["w0", "w1", "w2"]
        inflight = {w: [] for w in workers}
        fresh_total = []
        for _ in range(400):
            if core.done:
                break
            op = rng.random()
            w = rng.choice(workers)
            if op < 0.45:                          # dispatch
                if w not in core.dead:
                    inflight[w].extend(
                        t.task_id for t in core.next_batch(w))
            elif op < 0.85 and inflight[w]:        # (possibly dup) DONE
                ids = rng.sample(inflight[w],
                                 rng.randint(1, len(inflight[w])))
                if rng.random() < 0.3:
                    ids = ids + ids                # dup within one message
                fresh_total.extend(core.on_done(w, ids))
                for tid in set(ids):
                    inflight[w].remove(tid)
            elif op < 0.95 and len(core.dead) < 2:  # kill (keep one alive)
                core.mark_dead(w)
                inflight[w] = []
            elif inflight[w]:                      # late DONE replay
                fresh_total.extend(
                    core.on_done(w, [rng.choice(inflight[w])]))
        # Drain deterministically through the surviving workers.
        alive = [w for w in workers if w not in core.dead]
        while not core.done:
            progressed = False
            for w in alive:
                batch = core.next_batch(w)
                if batch:
                    progressed = True
                    fresh_total.extend(
                        core.on_done(w, [t.task_id for t in batch]))
            for w in alive:
                if inflight[w]:
                    progressed = True
                    fresh_total.extend(core.on_done(w, list(inflight[w])))
                    inflight[w] = []
            assert progressed, \
                f"{policy}: scheduler stuck with work outstanding"
        all_ids = {t.task_id for t in tasks}
        assert core.completed == all_ids, policy         # nothing lost
        assert len(fresh_total) == len(all_ids), policy  # nothing doubled
        assert sorted(fresh_total) == sorted(all_ids), policy


# ---------------------------------------------------------------------------
# Checkpoint save -> restore: no lost, no duplicated tasks — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_cycle_loses_and_duplicates_nothing(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy=policy, n_workers=3)
        rng = random.Random(opseed)
        fresh_before = []
        # Partially run: some dispatches completed, some left in flight
        # (those must re-run after restore — the checkpoint only trusts
        # DONEs).
        for _ in range(rng.randint(0, len(tasks))):
            batch = core.next_batch("w0")
            if not batch:
                break
            if rng.random() < 0.6:
                fresh_before.extend(
                    core.on_done("w0", [t.task_id for t in batch]))
        ck = ManagerCheckpoint.loads(core.checkpoint().dumps())  # round-trip
        assert ck.completed == core.completed
        assert ck.policy_state == core.policy.state()

        restored = SchedulerCore(tasks, organization=org,
                                 tasks_per_message=k, organize_seed=seed,
                                 policy=policy, n_workers=3, checkpoint=ck)
        fresh_after = []
        while not restored.done:
            batch = restored.next_batch("w1")
            assert batch, f"{policy}: restored scheduler stuck"
            fresh_after.extend(
                restored.on_done("w1", [t.task_id for t in batch]))
        all_ids = {t.task_id for t in tasks}
        assert restored.completed == all_ids, policy     # nothing lost
        # Exactly-once ACROSS the restart: completed-before tasks never
        # re-complete fresh, and nothing completes fresh twice.
        assert sorted(fresh_before + fresh_after) == sorted(all_ids), policy
        # The restored queue never re-dispatched a completed task.
        assert not (set(fresh_after) & set(fresh_before)), policy


# ---------------------------------------------------------------------------
# Dispatch-order determinism across all three backends — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes())
@settings(max_examples=3, deadline=None)
def test_dispatch_order_deterministic_across_backends(shape):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    all_ids = {t.task_id for t in tasks}
    for policy in POLICY_NAMES:
        batches = {}
        for backend in BACKENDS:
            r = run_job(tasks, _pickle_safe_fn, backend=backend,
                        n_workers=3, organization=org,
                        tasks_per_message=k, organize_seed=seed,
                        policy=policy, poll_interval=0.002)
            batches[backend] = r.batches
            assert r.completed_ids == all_ids, (policy, backend)
        # A repeat sim run reproduces the log bit-identically (the sim
        # is a deterministic machine, so this covers shard_affinity's
        # worker-binding decisions too).
        again = run_job(tasks, _pickle_safe_fn, backend="sim", n_workers=3,
                        organization=org, tasks_per_message=k,
                        organize_seed=seed, policy=policy,
                        poll_interval=0.002)
        assert again.batches == batches["sim"], policy
        if policy in ORDER_POLICIES:
            # Worker-ask order cannot change batch contents: the three
            # backends' dispatch logs agree bitwise.
            assert batches["threads"] == batches["processes"] \
                == batches["sim"], policy
        else:
            # shard_affinity: the live interleaving follows completion
            # timing, but every ASSIGN stays within one locality run.
            by_id = {t.task_id: t for t in tasks}
            for backend in BACKENDS:
                for b in batches[backend]:
                    keys = {locality_key(by_id[tid]) for tid in b}
                    assert len(keys) == 1, (backend, b)


# ---------------------------------------------------------------------------
# Screen phase: the invariants extend to quadratic-cost screen cells.
# ---------------------------------------------------------------------------

def test_screen_phase_exactly_once_deterministic_with_deaths():
    """ISSUE 8: the exactly-once / determinism invariants hold for the
    screen phase — ``screen/<cell>`` task ids carrying quadratic
    ``cpu_cost_hint`` (occupancy^2 pairs) under the SCREEN_PHASE cost
    model, including with workers dying mid-job."""
    from repro.core.cost_model import PHASES
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest("aerodrome_dense", limit=60)
    all_ids = {t.task_id for t in tasks}
    deaths = {1: 1.0}                # one worker dies a second in
    for policy in POLICY_NAMES:
        logs = []
        for _ in range(2):
            r = run_job(tasks, None, backend="sim", n_workers=4,
                        organization="chronological", tasks_per_message=2,
                        organize_seed=3, policy=policy,
                        cost_model=PHASES["screen"], worker_death=deaths)
            assert r.completed_ids == all_ids, policy    # nothing lost
            logs.append(r.batches)
        assert logs[0] == logs[1], policy                # bit-stable sim


def test_screen_phase_checkpoint_cycle():
    """A screen-phase scheduler checkpointed mid-run restores without
    losing or duplicating any cell, cost hints intact."""
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest("aerodrome_dense", limit=40)
    all_ids = {t.task_id for t in tasks}
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization="largest_first",
                             tasks_per_message=3, policy=policy,
                             n_workers=3)
        fresh_before = []
        for _ in range(5):
            batch = core.next_batch("w0")
            if batch:
                fresh_before.extend(
                    core.on_done("w0", [t.task_id for t in batch]))
        ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
        restored = SchedulerCore(tasks, organization="largest_first",
                                 tasks_per_message=3, policy=policy,
                                 n_workers=3, checkpoint=ck)
        fresh_after = []
        while not restored.done:
            batch = restored.next_batch("w1")
            assert batch, f"{policy}: restored screen scheduler stuck"
            fresh_after.extend(
                restored.on_done("w1", [t.task_id for t in batch]))
        assert sorted(fresh_before + fresh_after) == sorted(all_ids), policy


# ---------------------------------------------------------------------------
# adaptive_chunk: a mid-phase restore continues the chunk schedule.
# ---------------------------------------------------------------------------

def test_adaptive_chunk_resume_keeps_chunk_schedule():
    """Regression: restoring from a mid-phase checkpoint must continue
    the open factoring round (the checkpointed cost budget), not re-open
    a round from the shrunken queue as a fresh scheduler would."""
    tasks = [Task(task_id=f"u{i:04d}", size_bytes=100, timestamp=i,
                  cpu_cost_hint=1.0) for i in range(64)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="adaptive_chunk",
                         n_workers=4)
    # Round opens at 64 tasks: budget = 64 / (2 * 4) = 8 cost units ->
    # 8-task batches, 4 ASSIGNs per round.
    first = core.next_batch("w0")
    assert len(first) == 8
    core.on_done("w0", [t.task_id for t in first])
    second = core.next_batch("w1")
    assert len(second) == 8
    core.on_done("w1", [t.task_id for t in second])

    ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
    assert ck.policy_state == {"budget": 8.0, "round_left": 2}

    restored = SchedulerCore(tasks, organization="chronological",
                             tasks_per_message=1, policy="adaptive_chunk",
                             n_workers=4, checkpoint=ck)
    # 48 tasks remain; WITHOUT the policy state a fresh round would open
    # at 48 / 8 = 6 — the restored scheduler must keep issuing the
    # checkpointed 8-task budget for the 2 ASSIGNs left in its round.
    assert len(restored.next_batch("w0")) == 8
    assert len(restored.next_batch("w1")) == 8
    # ...and only then open a new, smaller round from what remains.
    assert len(restored.next_batch("w2")) == 4    # 32 left / (2 * 4)

    # Control: the same ledger with the policy state stripped resets the
    # schedule (this is the bug the checkpointed state prevents).
    stripped = ManagerCheckpoint(ck.completed, ck.pending_ids)
    fresh = SchedulerCore(tasks, organization="chronological",
                          tasks_per_message=1, policy="adaptive_chunk",
                          n_workers=4, checkpoint=stripped)
    assert len(fresh.next_batch("w0")) == 6


# ---------------------------------------------------------------------------
# ISSUE 10: speculation as a protocol concern — every backend, every policy.
# ---------------------------------------------------------------------------

def test_speculative_exactly_once_and_primary_schedule_across_backends():
    """Speculation ON: exactly-once still holds on threads, processes and
    sim, and — because backup copies are accounted in ``extra_messages``
    only — the primary dispatch log (hence ``dispatch_digest``) of every
    order-based policy stays bit-identical to a non-speculative run."""
    tasks = _tasks([(i * 37) % 9000 + 100 for i in range(24)])
    all_ids = {t.task_id for t in tasks}
    for policy in POLICY_NAMES:
        base = run_job(tasks, _pickle_safe_fn, backend="sim", n_workers=3,
                       tasks_per_message=2, policy=policy,
                       poll_interval=0.002)
        for backend in BACKENDS:
            r = run_job(tasks, _pickle_safe_fn, backend=backend,
                        n_workers=3, tasks_per_message=2, policy=policy,
                        poll_interval=0.002, speculative=True)
            assert r.completed_ids == all_ids, (policy, backend)
            assert r.speculated >= 0, (policy, backend)
            # messages_sent includes the extra sends; the batch log does
            # not — speculation never perturbs the primary schedule.
            assert r.messages_sent == len(r.batches) + r.extra_messages, \
                (policy, backend)
            if policy in ORDER_POLICIES:
                assert r.batches == base.batches, (policy, backend)
                assert r.dispatch_digest == base.dispatch_digest, \
                    (policy, backend)


def test_speculate_picks_oldest_assignment_and_caps_copies():
    """The victim is the in-flight task with the oldest assignment
    sequence (ties by id), never the asker's own work, and never past
    ``speculation_max_copies`` outstanding copies."""
    tasks = [Task(task_id=f"s{i}", size_bytes=100, timestamp=i)
             for i in range(2)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="fifo_selfsched",
                         n_workers=4, speculative=True)
    assert [t.task_id for t in core.next_batch("w0")] == ["s0"]
    assert [t.task_id for t in core.next_batch("w1")] == ["s1"]
    assert not core.pending
    # w0's oldest candidate is its OWN s0 — it must duplicate s1 instead.
    assert [t.task_id for t in core.speculate("w0")] == ["s1"]
    # s1 is now at the 2-copy cap; the next idle worker takes s0 (the
    # oldest assignment overall).
    assert [t.task_id for t in core.speculate("w2")] == ["s0"]
    assert core.speculate("w3") == ()      # both at the 2-copy cap
    assert core.speculated == 2 and core.extra_messages == 2
    assert core.messages_sent == 2         # two primary ASSIGNs, ever
    assert len(core.batches) == 2


def test_speculative_duplicate_done_ignored_bitwise():
    """First DONE wins; the loser's DONE is a complete no-op — the
    checkpoint serialization is byte-identical before and after it."""
    tasks = _tasks([500] * 8)
    core = SchedulerCore(tasks, tasks_per_message=4,
                         policy="fifo_selfsched", n_workers=3,
                         speculative=True)
    inflight = {"w0": [], "w1": []}
    turn = 0
    while core.pending:                    # drain the queue onto w0/w1
        w = f"w{turn % 2}"
        turn += 1
        inflight[w].extend(t.task_id for t in core.next_batch(w))
    ids0, ids1 = inflight["w0"], inflight["w1"]
    assert ids0 and ids1
    dup = core.speculate("w2")             # backup copy of w0's oldest
    assert len(dup) == 1 and dup[0].task_id == ids0[0]
    victim = dup[0].task_id
    assert core.on_done("w2", [victim]) == [victim]   # backup wins
    snap = core.checkpoint().dumps()
    assert core.on_done("w0", [victim]) == []         # loser: no-op
    assert core.checkpoint().dumps() == snap          # bitwise
    core.record_waste("w0", 1.5)                      # accounting only
    assert core.wasted_seconds == 1.5
    assert core.checkpoint().dumps() == snap
    # Drain: every remaining completion is fresh exactly once.
    fresh = [victim]
    for w, ids in (("w0", ids0), ("w1", ids1)):
        fresh += core.on_done(w, ids)
    assert sorted(fresh) == sorted(t.task_id for t in tasks)


def test_losing_copy_failure_never_poisons_the_ledger():
    """First outcome wins for FAILED too: a speculative duplicate of a
    non-idempotent fn often crashes (its input was consumed by the
    winner).  A FAILED after the winner's DONE is a no-op; a FAILED
    while another live copy still runs is not recorded (the survivor
    decides); and a late DONE supersedes a lost copy's failure."""
    tasks = [Task(task_id=f"f{i}", size_bytes=10, timestamp=i)
             for i in range(2)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="fifo_selfsched",
                         n_workers=3, speculative=True)
    assert [t.task_id for t in core.next_batch("w0")] == ["f0"]
    assert [t.task_id for t in core.next_batch("w1")] == ["f1"]
    assert [t.task_id for t in core.speculate("w2")] == ["f0"]
    # Backup crashes while the primary still runs: nothing recorded.
    core.on_failed("w2", ["f0"], "boom")
    assert "f0" not in core.failures and not core.done
    # Primary completes; a LATE failure from a re-sent copy is a no-op.
    assert core.on_done("w0", ["f0"]) == ["f0"]
    core.on_failed("w0", ["f0"], "late boom")
    assert "f0" not in core.failures
    # Reverse race on f1: the last outstanding copy's failure DOES
    # record, and a later DONE from the other (already-failed-then-
    # resent) copy supersedes it.
    assert [t.task_id for t in core.speculate("w2")] == ["f1"]
    core.on_failed("w1", ["f1"], "primary died")   # w2's copy still live
    assert "f1" not in core.failures
    core.on_failed("w2", ["f1"], "backup died")    # last copy: recorded
    assert core.failures["f1"] == "backup died"
    assert core.on_done("w1", ["f1"]) == ["f1"]    # success supersedes
    assert "f1" not in core.failures
    assert core.done and core.completed == {"f0", "f1"}


@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_speculative_checkpoint_cycle_loses_and_duplicates_nothing(
        shape, opseed):
    """The checkpoint-losslessness invariant with speculation live:
    backup copies in flight at save time never double-complete after the
    restore, and nothing is lost."""
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy=policy,
                             n_workers=3, speculative=True)
        rng = random.Random(opseed)
        fresh_before = []
        inflight = {w: [] for w in ("w0", "w1", "w2")}
        for _ in range(rng.randint(0, 2 * len(tasks))):
            w = rng.choice(("w0", "w1", "w2"))
            batch = core.next_batch(w) or core.speculate(w)
            inflight[w].extend(t.task_id for t in batch)
            if inflight[w] and rng.random() < 0.5:
                tid = inflight[w].pop(rng.randrange(len(inflight[w])))
                fresh_before.extend(core.on_done(w, [tid]))
        ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
        restored = SchedulerCore(tasks, organization=org,
                                 tasks_per_message=k, organize_seed=seed,
                                 policy=policy, n_workers=3,
                                 speculative=True, checkpoint=ck)
        fresh_after = []
        while not restored.done:
            batch = restored.next_batch("w1")
            assert batch, f"{policy}: restored speculative core stuck"
            fresh_after.extend(
                restored.on_done("w1", [t.task_id for t in batch]))
        all_ids = {t.task_id for t in tasks}
        assert restored.completed == all_ids, policy
        assert sorted(fresh_before + fresh_after) == sorted(all_ids), policy


# ---------------------------------------------------------------------------
# ISSUE 10: kill/resume restores the speed model and fleet controller.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_kill_resume_restores_speed_model_and_fleet_state(shape, opseed):
    """A mid-run kill/resume round-trips ``ManagerCheckpoint.runtime_state``:
    the restored WorkerSpeedModel gives bit-identical relative speeds and
    the restored FleetController continues its counters and cooldown
    clock instead of resetting them."""
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    speed = WorkerSpeedModel()
    fleet = FleetController(min_workers=1, max_workers=8, interval_s=1.0,
                            cooldown_s=2.0)
    core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                         organize_seed=seed, policy="sized_lpt",
                         n_workers=3, speculative=True,
                         speed_model=speed, fleet=fleet)
    rng = random.Random(opseed)
    now = 0.0
    for _ in range(rng.randint(1, 12)):
        w = f"w{rng.randint(0, 2)}"
        batch = core.next_batch(w)
        if batch:
            ids = [t.task_id for t in batch]
            core.observe_speed(w, ids, rng.uniform(0.1, 5.0))
            core.on_done(w, ids)
        now += 1.0
        delta = fleet.decide(now, n_workers=3,
                             queue_depth=len(core.pending),
                             busy_frac=rng.random())
        if delta:
            fleet.applied(delta)

    ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
    assert ck.runtime_state == core._runtime_state()

    speed2 = WorkerSpeedModel()
    fleet2 = FleetController(min_workers=1, max_workers=8, interval_s=1.0,
                             cooldown_s=2.0)
    restored = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy="sized_lpt",
                             n_workers=3, speculative=True,
                             speed_model=speed2, fleet=fleet2,
                             checkpoint=ck)
    assert speed2.state() == speed.state()
    assert fleet2.state() == fleet.state()
    for w in ("w0", "w1", "w2"):
        assert speed2.relative_speed(w) == speed.relative_speed(w)
    # Continuing the run keeps exactly-once across the restart.
    fresh = []
    while not restored.done:
        batch = restored.next_batch("w0")
        assert batch, "restored elastic core stuck"
        fresh.extend(restored.on_done("w0", [t.task_id for t in batch]))
    assert restored.completed == {t.task_id for t in tasks}
    assert not (set(fresh) & ck.completed)


def test_elastic_sim_run_is_deterministic_per_seed():
    """The full elastic stack (speculation + speed feedback + autoscaler)
    on the sim backend is a deterministic machine: two runs of the same
    seed agree bitwise on the dispatch digest and on every fleet/
    speculation counter, even under deaths and stragglers."""
    tasks = _tasks([(i * 61) % 8000 + 200 for i in range(60)])
    runs = []
    for _ in range(2):
        r = run_job(tasks, None, backend="sim", n_workers=6,
                    policy="adaptive_chunk", tasks_per_message=1,
                    organize_seed=7, speculative=True, speed_feedback=True,
                    elastic=True, worker_death={0: 3.0},
                    worker_speed=[1.0, 1.0, 0.25, 1.0, 1.0, 1.0])
        runs.append(r)
    a, b = runs
    assert a.completed_ids == {t.task_id for t in tasks}
    assert a.dispatch_digest == b.dispatch_digest
    assert a.batches == b.batches
    assert (a.speculated, a.extra_messages, a.wasted_seconds,
            a.workers_added, a.workers_retired) \
        == (b.speculated, b.extra_messages, b.wasted_seconds,
            b.workers_added, b.workers_retired)
