"""Property-based SchedulerCore invariants (hypothesis / tests/_compat shim).

The protocol core is the single decision-maker behind all three execution
backends, so its invariants are the system's invariants — and since the
scheduling-policy layer (repro.runtime.policies) owns dispatch order and
batch size, every invariant is checked for EVERY policy:

  * exactly-once completion under arbitrary interleavings of dispatch,
    (duplicate) DONE reports, and worker deaths;
  * no lost and no duplicated tasks across checkpoint save -> restore
    (including the policy's own mid-run state, e.g. adaptive_chunk's
    open round);
  * dispatch-order determinism for a fixed seed.  The order-based
    policies (static, fifo_selfsched, sized_lpt, adaptive_chunk) emit
    bit-identical dispatch logs across the threads, processes, and sim
    backends; shard_affinity's batch contents depend on the asking
    worker's binding, so on the live backends the *interleaving*
    follows real completion timing — for it we assert the per-seed sim
    log bit-identically, exactly-once everywhere, and the single-run
    batch-locality invariant (see repro.runtime.policies docstring).
"""

import random

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.messages import Task
from repro.runtime import (
    POLICY_NAMES, ManagerCheckpoint, SchedulerCore, run_job)
from repro.runtime.policies import locality_key

BACKENDS = ("threads", "processes", "sim")

#: Policies whose ASSIGN contents are independent of the asking worker,
#: hence bit-identical dispatch logs across backends (run_job resolves
#: ONE model-based cost estimator for every backend, so the cost-aware
#: policies qualify too); shard_affinity is the documented exception.
ORDER_POLICIES = ("static", "fifo_selfsched", "sized_lpt",
                  "adaptive_chunk")


def _tasks(sizes):
    # Grouped ids ("g<k>/t<i>") give shard_affinity real locality runs;
    # for every other policy the prefix is just part of the tie-break.
    return [Task(task_id=f"g{i % 4}/t{i:04d}", size_bytes=s, timestamp=i)
            for i, s in enumerate(sizes)]


def _pickle_safe_fn(task):          # module-level: picklable for processes
    return task.size_bytes


@st.composite
def job_shapes(draw):
    n = draw(st.integers(1, 40))
    sizes = draw(st.lists(st.integers(1, 10_000_000),
                          min_size=n, max_size=n))
    k = draw(st.integers(1, 6))
    org = draw(st.sampled_from(["largest_first", "chronological",
                                "random"]))
    seed = draw(st.integers(0, 5))
    return sizes, k, org, seed


# ---------------------------------------------------------------------------
# Exactly-once under adversarial interleavings — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_core_exactly_once_under_random_interleaving(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy=policy, n_workers=3)
        rng = random.Random(opseed)
        workers = ["w0", "w1", "w2"]
        inflight = {w: [] for w in workers}
        fresh_total = []
        for _ in range(400):
            if core.done:
                break
            op = rng.random()
            w = rng.choice(workers)
            if op < 0.45:                          # dispatch
                if w not in core.dead:
                    inflight[w].extend(
                        t.task_id for t in core.next_batch(w))
            elif op < 0.85 and inflight[w]:        # (possibly dup) DONE
                ids = rng.sample(inflight[w],
                                 rng.randint(1, len(inflight[w])))
                if rng.random() < 0.3:
                    ids = ids + ids                # dup within one message
                fresh_total.extend(core.on_done(w, ids))
                for tid in set(ids):
                    inflight[w].remove(tid)
            elif op < 0.95 and len(core.dead) < 2:  # kill (keep one alive)
                core.mark_dead(w)
                inflight[w] = []
            elif inflight[w]:                      # late DONE replay
                fresh_total.extend(
                    core.on_done(w, [rng.choice(inflight[w])]))
        # Drain deterministically through the surviving workers.
        alive = [w for w in workers if w not in core.dead]
        while not core.done:
            progressed = False
            for w in alive:
                batch = core.next_batch(w)
                if batch:
                    progressed = True
                    fresh_total.extend(
                        core.on_done(w, [t.task_id for t in batch]))
            for w in alive:
                if inflight[w]:
                    progressed = True
                    fresh_total.extend(core.on_done(w, list(inflight[w])))
                    inflight[w] = []
            assert progressed, \
                f"{policy}: scheduler stuck with work outstanding"
        all_ids = {t.task_id for t in tasks}
        assert core.completed == all_ids, policy         # nothing lost
        assert len(fresh_total) == len(all_ids), policy  # nothing doubled
        assert sorted(fresh_total) == sorted(all_ids), policy


# ---------------------------------------------------------------------------
# Checkpoint save -> restore: no lost, no duplicated tasks — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_cycle_loses_and_duplicates_nothing(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, policy=policy, n_workers=3)
        rng = random.Random(opseed)
        fresh_before = []
        # Partially run: some dispatches completed, some left in flight
        # (those must re-run after restore — the checkpoint only trusts
        # DONEs).
        for _ in range(rng.randint(0, len(tasks))):
            batch = core.next_batch("w0")
            if not batch:
                break
            if rng.random() < 0.6:
                fresh_before.extend(
                    core.on_done("w0", [t.task_id for t in batch]))
        ck = ManagerCheckpoint.loads(core.checkpoint().dumps())  # round-trip
        assert ck.completed == core.completed
        assert ck.policy_state == core.policy.state()

        restored = SchedulerCore(tasks, organization=org,
                                 tasks_per_message=k, organize_seed=seed,
                                 policy=policy, n_workers=3, checkpoint=ck)
        fresh_after = []
        while not restored.done:
            batch = restored.next_batch("w1")
            assert batch, f"{policy}: restored scheduler stuck"
            fresh_after.extend(
                restored.on_done("w1", [t.task_id for t in batch]))
        all_ids = {t.task_id for t in tasks}
        assert restored.completed == all_ids, policy     # nothing lost
        # Exactly-once ACROSS the restart: completed-before tasks never
        # re-complete fresh, and nothing completes fresh twice.
        assert sorted(fresh_before + fresh_after) == sorted(all_ids), policy
        # The restored queue never re-dispatched a completed task.
        assert not (set(fresh_after) & set(fresh_before)), policy


# ---------------------------------------------------------------------------
# Dispatch-order determinism across all three backends — every policy.
# ---------------------------------------------------------------------------

@given(job_shapes())
@settings(max_examples=3, deadline=None)
def test_dispatch_order_deterministic_across_backends(shape):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    all_ids = {t.task_id for t in tasks}
    for policy in POLICY_NAMES:
        batches = {}
        for backend in BACKENDS:
            r = run_job(tasks, _pickle_safe_fn, backend=backend,
                        n_workers=3, organization=org,
                        tasks_per_message=k, organize_seed=seed,
                        policy=policy, poll_interval=0.002)
            batches[backend] = r.batches
            assert r.completed_ids == all_ids, (policy, backend)
        # A repeat sim run reproduces the log bit-identically (the sim
        # is a deterministic machine, so this covers shard_affinity's
        # worker-binding decisions too).
        again = run_job(tasks, _pickle_safe_fn, backend="sim", n_workers=3,
                        organization=org, tasks_per_message=k,
                        organize_seed=seed, policy=policy,
                        poll_interval=0.002)
        assert again.batches == batches["sim"], policy
        if policy in ORDER_POLICIES:
            # Worker-ask order cannot change batch contents: the three
            # backends' dispatch logs agree bitwise.
            assert batches["threads"] == batches["processes"] \
                == batches["sim"], policy
        else:
            # shard_affinity: the live interleaving follows completion
            # timing, but every ASSIGN stays within one locality run.
            by_id = {t.task_id: t for t in tasks}
            for backend in BACKENDS:
                for b in batches[backend]:
                    keys = {locality_key(by_id[tid]) for tid in b}
                    assert len(keys) == 1, (backend, b)


# ---------------------------------------------------------------------------
# Screen phase: the invariants extend to quadratic-cost screen cells.
# ---------------------------------------------------------------------------

def test_screen_phase_exactly_once_deterministic_with_deaths():
    """ISSUE 8: the exactly-once / determinism invariants hold for the
    screen phase — ``screen/<cell>`` task ids carrying quadratic
    ``cpu_cost_hint`` (occupancy^2 pairs) under the SCREEN_PHASE cost
    model, including with workers dying mid-job."""
    from repro.core.cost_model import PHASES
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest("aerodrome_dense", limit=60)
    all_ids = {t.task_id for t in tasks}
    deaths = {1: 1.0}                # one worker dies a second in
    for policy in POLICY_NAMES:
        logs = []
        for _ in range(2):
            r = run_job(tasks, None, backend="sim", n_workers=4,
                        organization="chronological", tasks_per_message=2,
                        organize_seed=3, policy=policy,
                        cost_model=PHASES["screen"], worker_death=deaths)
            assert r.completed_ids == all_ids, policy    # nothing lost
            logs.append(r.batches)
        assert logs[0] == logs[1], policy                # bit-stable sim


def test_screen_phase_checkpoint_cycle():
    """A screen-phase scheduler checkpointed mid-run restores without
    losing or duplicating any cell, cost hints intact."""
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest("aerodrome_dense", limit=40)
    all_ids = {t.task_id for t in tasks}
    for policy in POLICY_NAMES:
        core = SchedulerCore(tasks, organization="largest_first",
                             tasks_per_message=3, policy=policy,
                             n_workers=3)
        fresh_before = []
        for _ in range(5):
            batch = core.next_batch("w0")
            if batch:
                fresh_before.extend(
                    core.on_done("w0", [t.task_id for t in batch]))
        ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
        restored = SchedulerCore(tasks, organization="largest_first",
                                 tasks_per_message=3, policy=policy,
                                 n_workers=3, checkpoint=ck)
        fresh_after = []
        while not restored.done:
            batch = restored.next_batch("w1")
            assert batch, f"{policy}: restored screen scheduler stuck"
            fresh_after.extend(
                restored.on_done("w1", [t.task_id for t in batch]))
        assert sorted(fresh_before + fresh_after) == sorted(all_ids), policy


# ---------------------------------------------------------------------------
# adaptive_chunk: a mid-phase restore continues the chunk schedule.
# ---------------------------------------------------------------------------

def test_adaptive_chunk_resume_keeps_chunk_schedule():
    """Regression: restoring from a mid-phase checkpoint must continue
    the open factoring round (the checkpointed cost budget), not re-open
    a round from the shrunken queue as a fresh scheduler would."""
    tasks = [Task(task_id=f"u{i:04d}", size_bytes=100, timestamp=i,
                  cpu_cost_hint=1.0) for i in range(64)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="adaptive_chunk",
                         n_workers=4)
    # Round opens at 64 tasks: budget = 64 / (2 * 4) = 8 cost units ->
    # 8-task batches, 4 ASSIGNs per round.
    first = core.next_batch("w0")
    assert len(first) == 8
    core.on_done("w0", [t.task_id for t in first])
    second = core.next_batch("w1")
    assert len(second) == 8
    core.on_done("w1", [t.task_id for t in second])

    ck = ManagerCheckpoint.loads(core.checkpoint().dumps())
    assert ck.policy_state == {"budget": 8.0, "round_left": 2}

    restored = SchedulerCore(tasks, organization="chronological",
                             tasks_per_message=1, policy="adaptive_chunk",
                             n_workers=4, checkpoint=ck)
    # 48 tasks remain; WITHOUT the policy state a fresh round would open
    # at 48 / 8 = 6 — the restored scheduler must keep issuing the
    # checkpointed 8-task budget for the 2 ASSIGNs left in its round.
    assert len(restored.next_batch("w0")) == 8
    assert len(restored.next_batch("w1")) == 8
    # ...and only then open a new, smaller round from what remains.
    assert len(restored.next_batch("w2")) == 4    # 32 left / (2 * 4)

    # Control: the same ledger with the policy state stripped resets the
    # schedule (this is the bug the checkpointed state prevents).
    stripped = ManagerCheckpoint(ck.completed, ck.pending_ids)
    fresh = SchedulerCore(tasks, organization="chronological",
                          tasks_per_message=1, policy="adaptive_chunk",
                          n_workers=4, checkpoint=stripped)
    assert len(fresh.next_batch("w0")) == 6
