"""Property-based SchedulerCore invariants (hypothesis / tests/_compat shim).

The protocol core is the single decision-maker behind all three execution
backends, so its invariants are the system's invariants:

  * exactly-once completion under arbitrary interleavings of dispatch,
    (duplicate) DONE reports, and worker deaths;
  * no lost and no duplicated tasks across checkpoint save -> restore;
  * dispatch-order determinism for a fixed seed, bit-identical across the
    threads, processes, and sim backends.
"""

import random

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.messages import Task
from repro.runtime import ManagerCheckpoint, SchedulerCore, run_job

BACKENDS = ("threads", "processes", "sim")


def _tasks(sizes):
    return [Task(task_id=f"t{i:04d}", size_bytes=s, timestamp=i)
            for i, s in enumerate(sizes)]


def _pickle_safe_fn(task):          # module-level: picklable for processes
    return task.size_bytes


@st.composite
def job_shapes(draw):
    n = draw(st.integers(1, 40))
    sizes = draw(st.lists(st.integers(1, 10_000_000),
                          min_size=n, max_size=n))
    k = draw(st.integers(1, 6))
    org = draw(st.sampled_from(["largest_first", "chronological",
                                "random"]))
    seed = draw(st.integers(0, 5))
    return sizes, k, org, seed


# ---------------------------------------------------------------------------
# Exactly-once under adversarial interleavings.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_core_exactly_once_under_random_interleaving(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                         organize_seed=seed)
    rng = random.Random(opseed)
    workers = ["w0", "w1", "w2"]
    inflight = {w: [] for w in workers}
    fresh_total = []
    for _ in range(400):
        if core.done:
            break
        op = rng.random()
        w = rng.choice(workers)
        if op < 0.45:                          # dispatch
            if w not in core.dead:
                inflight[w].extend(
                    t.task_id for t in core.next_batch(w))
        elif op < 0.85 and inflight[w]:        # (possibly duplicate) DONE
            ids = rng.sample(inflight[w],
                             rng.randint(1, len(inflight[w])))
            if rng.random() < 0.3:
                ids = ids + ids                # duplicate within one message
            fresh_total.extend(core.on_done(w, ids))
            for tid in set(ids):
                inflight[w].remove(tid)
        elif op < 0.95 and len(core.dead) < 2:  # kill (keep one alive)
            core.mark_dead(w)
            inflight[w] = []
        elif inflight[w]:                      # late DONE replay
            fresh_total.extend(
                core.on_done(w, [rng.choice(inflight[w])]))
    # Drain deterministically through the surviving workers.
    alive = [w for w in workers if w not in core.dead]
    while not core.done:
        progressed = False
        for w in alive:
            batch = core.next_batch(w)
            if batch:
                progressed = True
                fresh_total.extend(
                    core.on_done(w, [t.task_id for t in batch]))
        for w in alive:
            if inflight[w]:
                progressed = True
                fresh_total.extend(core.on_done(w, list(inflight[w])))
                inflight[w] = []
        assert progressed, "scheduler stuck with work outstanding"
    all_ids = {t.task_id for t in tasks}
    assert core.completed == all_ids                    # nothing lost
    assert len(fresh_total) == len(all_ids)             # nothing doubled
    assert sorted(fresh_total) == sorted(all_ids)


# ---------------------------------------------------------------------------
# Checkpoint save -> restore: no lost, no duplicated tasks.
# ---------------------------------------------------------------------------

@given(job_shapes(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_cycle_loses_and_duplicates_nothing(shape, opseed):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    core = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                         organize_seed=seed)
    rng = random.Random(opseed)
    fresh_before = []
    # Partially run: some dispatches completed, some left in flight (those
    # must be re-run after restore — the checkpoint only trusts DONEs).
    for _ in range(rng.randint(0, len(tasks))):
        batch = core.next_batch("w0")
        if not batch:
            break
        if rng.random() < 0.6:
            fresh_before.extend(
                core.on_done("w0", [t.task_id for t in batch]))
    ck = ManagerCheckpoint.loads(core.checkpoint().dumps())   # round-trip
    assert ck.completed == core.completed

    restored = SchedulerCore(tasks, organization=org, tasks_per_message=k,
                             organize_seed=seed, checkpoint=ck)
    fresh_after = []
    while not restored.done:
        batch = restored.next_batch("w1")
        assert batch, "restored scheduler stuck"
        fresh_after.extend(
            restored.on_done("w1", [t.task_id for t in batch]))
    all_ids = {t.task_id for t in tasks}
    assert restored.completed == all_ids                     # nothing lost
    # Exactly-once ACROSS the restart: completed-before tasks never
    # re-complete fresh, and nothing completes fresh twice.
    assert sorted(fresh_before + fresh_after) == sorted(all_ids)
    # The restored queue never re-dispatched an already-completed task.
    assert not (set(fresh_after) & set(fresh_before))


# ---------------------------------------------------------------------------
# Dispatch-order determinism across all three backends.
# ---------------------------------------------------------------------------

@given(job_shapes())
@settings(max_examples=5, deadline=None)
def test_dispatch_order_deterministic_across_backends(shape):
    sizes, k, org, seed = shape
    tasks = _tasks(sizes)
    batches = {}
    for backend in BACKENDS:
        r = run_job(tasks, _pickle_safe_fn, backend=backend, n_workers=3,
                    organization=org, tasks_per_message=k,
                    organize_seed=seed, poll_interval=0.002)
        batches[backend] = r.batches
        assert r.completed_ids == {t.task_id for t in tasks}
    assert batches["threads"] == batches["processes"] == batches["sim"]
    # And a repeat run reproduces the log bit-identically.
    again = run_job(tasks, _pickle_safe_fn, backend="sim", n_workers=3,
                    organization=org, tasks_per_message=k,
                    organize_seed=seed, poll_interval=0.002)
    assert again.batches == batches["sim"]
