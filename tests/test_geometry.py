"""Aerodrome query-generation geometry (paper §III.B, Figs 1-2)."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.geometry import (
    SyntheticGlobeDEM, generate_queries, make_bounding_boxes,
    synthetic_aerodromes)
from repro.geometry.queries import HARD_MSL_CEILING_FT
from repro.geometry.rectilinear import (
    connected_components, decompose_mask_into_rectangles,
    rasterize_circles, rectangles_cover_mask, split_large_rectangles)


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_decompose_exact_cover_random_masks(seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((rng.integers(1, 24), rng.integers(1, 24))) < 0.45
    rects = decompose_mask_into_rectangles(mask)
    assert rectangles_cover_mask(rects, mask)


@given(st.integers(0, 10_000), st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_split_preserves_cover_and_bounds(seed, max_cells):
    rng = np.random.default_rng(seed)
    mask = rng.random((16, 16)) < 0.5
    rects = split_large_rectangles(
        decompose_mask_into_rectangles(mask), max_cells)
    assert rectangles_cover_mask(rects, mask)
    for r0, c0, r1, c1 in rects:
        assert (r1 - r0) * (c1 - c0) <= max(max_cells, 1)


def test_connected_components_partition():
    rng = np.random.default_rng(1)
    mask = rng.random((30, 30)) < 0.4
    comps = connected_components(mask)
    acc = np.zeros_like(mask, dtype=int)
    for c in comps:
        acc += c
    assert np.array_equal(acc > 0, mask)
    assert acc.max() <= 1                      # disjoint


@pytest.fixture(scope="module")
def boxes():
    return make_bounding_boxes()


def test_paper_box_count(boxes):
    """Tuned to the paper's 695 bounding boxes (synthetic aerodrome set
    lands at 696 — within one box)."""
    assert abs(len(boxes) - 695) <= 2


def test_boxes_cover_every_aerodrome(boxes):
    """Every in-class aerodrome lies inside some box (its circle's
    center is in the union, so a covering rectangle must contain it)."""
    aero = [a for a in synthetic_aerodromes()
            if a.airspace_class in ("B", "C", "D")]
    for a in aero:
        assert any(b.lat_min - 1e-9 <= a.lat <= b.lat_max + 1e-9 and
                   b.lon_min - 1e-9 <= a.lon <= b.lon_max + 1e-9
                   for b in boxes), a.ident


def test_msl_range_rules(boxes):
    for b in boxes:
        assert b.msl_max_ft <= HARD_MSL_CEILING_FT + 1e-6
        assert b.msl_min_ft <= b.msl_max_ft
        assert b.elev_min_ft <= b.elev_max_ft + 1e-6
        assert -10 <= b.timezone_offset_h <= 0     # continental US


def test_query_generation(boxes):
    qs = generate_queries(boxes, n_days=196, n_groups=64)
    assert len(qs) == len(boxes) * 196
    assert len({q.query_id for q in qs}) == len(qs)
    groups = {}
    for q in qs:
        groups.setdefault(q.group, set()).add(q.box_id)
    # greedy largest-first balancing: every group used
    assert len(groups) == 64
    # every query's SQL carries its box's ranges
    q0 = qs[0]
    b0 = boxes[q0.box_id]
    assert f"{b0.lat_min:.4f}" in q0.sql
    assert "hour >=" in q0.sql


def test_group_area_balance(boxes):
    """Largest-first greedy grouping: group areas within 3x of mean."""
    qs = generate_queries(boxes, n_days=1, n_groups=64)
    area = {g: 0.0 for g in range(64)}
    for q in qs:
        area[q.group] += boxes[q.box_id].area_deg2
    vals = np.array(list(area.values()))
    assert vals.max() < 3.0 * vals.mean()


def test_dem_bilinear_between_grid():
    dem = SyntheticGlobeDEM(cells_per_deg=4)
    lat = np.array([35.0, 40.125, 44.9])
    lon = np.array([-100.0, -90.06, -75.3])
    z = dem.bilinear(lat, lon)
    assert z.shape == (3,)
    assert np.all(z >= 0)
    lo, hi = dem.minmax_in_box(34.9, 35.1, -100.1, -99.9)
    assert lo <= z[0] <= hi + 1e-6 or abs(z[0] - lo) < 50
