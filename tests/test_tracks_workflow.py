"""Real (scaled) track workflow: organize -> archive -> process."""

import os
import zipfile

import numpy as np
import pytest

from repro.core.messages import Task
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import (
    MONDAY_FILE_COUNT, ScaledDatasetSpec, aerodrome_manifest,
    monday_manifest, write_scaled_dataset)
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import HierarchySpec, synthetic_registry
from repro.tracks.segments import (
    MIN_OBS_PER_SEGMENT, SegmentProcessor, split_segments)
from repro.tracks.workflow import TrackWorkflow


def test_manifests_match_paper_statistics():
    m = monday_manifest()
    assert len(m) == MONDAY_FILE_COUNT == 2425
    assert abs(sum(t.size_bytes for t in m) / 714e9 - 1) < 0.01
    a = aerodrome_manifest()
    assert len(a) == 136_884
    assert abs(sum(t.size_bytes for t in a) / 847e9 - 1) < 0.01
    # Fig 3: aerodrome sizes are heavy-tailed vs Monday's diurnal bump
    ms = np.array([t.size_bytes for t in m], float)
    as_ = np.array([t.size_bytes for t in a], float)
    assert ms.std() / ms.mean() < 0.5          # compact (Gaussian-ish)
    assert as_.std() / as_.mean() > 2.0        # sloping / heavy-tailed


def test_hierarchy_fanout_under_1000():
    reg = synthetic_registry(n=3000)
    h = HierarchySpec()
    paths = [h.aircraft_dir(2019, e, e.icao24) for e in reg.values()]
    assert h.validate_fanout(paths)


def test_split_segments_ten_obs_rule():
    t = np.concatenate([np.arange(0, 9),          # 9 obs -> dropped
                        1000 + np.arange(0, 50),   # 50 obs -> kept
                        5000 + np.arange(0, 10)])  # exactly 10 -> kept
    segs = split_segments(t, gap_s=120.0)
    assert len(segs) == 2
    assert segs[0].stop - segs[0].start == 50
    assert segs[1].stop - segs[1].start == MIN_OBS_PER_SEGMENT


@pytest.fixture(scope="module")
def workflow(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("wf"))
    wf = TrackWorkflow(root, n_workers=4, poll_interval=0.003)
    wf.generate_raw(n_files=5, scale=2e4)
    wf.run()
    return wf


def test_workflow_phases_complete(workflow):
    assert [r.phase for r in workflow.reports] == \
        ["organize", "archive", "process"]
    assert all(r.tasks > 0 for r in workflow.reports)


def test_organize_groups_by_aircraft(workflow):
    csvs = []
    for dirpath, _d, files in os.walk(workflow.organized_dir):
        csvs += [os.path.join(dirpath, f) for f in files
                 if f.endswith(".csv")]
    assert csvs
    for p in csvs:
        icao = os.path.basename(p)[:-4]
        with open(p) as f:
            header = f.readline().strip().split(",")
            idx = header.index("icao24")
            for line in f:
                assert line.split(",")[idx] == icao


def test_archive_mirrors_hierarchy_and_roundtrips(workflow):
    zips = []
    for dirpath, _d, files in os.walk(workflow.archive_dir):
        zips += [os.path.join(dirpath, f) for f in files
                 if f.endswith(".zip")]
    assert zips
    z = zips[0]
    rel = os.path.relpath(z, workflow.archive_dir)
    # replicated first three tiers: year/type/seats/bucket/<icao>.zip
    assert len(rel.split(os.sep)) == 5
    with zipfile.ZipFile(z) as zf:
        names = zf.namelist()
        assert names and all(n.endswith(".csv") for n in names)


def test_processing_produces_valid_segments(workflow):
    from repro.tracks.segments import segment_tasks_from_archive_tree
    tasks = segment_tasks_from_archive_tree(workflow.archive_dir)
    proc = SegmentProcessor(backend="pallas")
    out = proc(tasks[0])
    if len(out) == 0:
        pytest.skip("first archive had only short segments")
    assert np.isfinite(out.alt_agl_m).all()
    assert (out.count >= MIN_OBS_PER_SEGMENT).all() or \
        (out.count >= 2).all()    # resampled count can differ from raw
    # uniform 1 Hz grid
    b = 0
    m = out.count[b]
    if m > 2:
        dt = np.diff(out.times[b, :m])
        np.testing.assert_allclose(dt, 1.0, atol=1e-5)
    # AGL = MSL - DEM <= MSL for non-negative terrain
    mask = np.arange(out.times.shape[1])[None, :] < out.count[:, None]
    assert np.all(out.alt_agl_m[mask] <= out.alt_msl_m[mask] + 1e-3)


def test_segment_batch_matches_per_task(workflow):
    """process_batch (one vectorized pallas call per ASSIGN message) must
    agree with per-task dispatch."""
    from repro.tracks.segments import segment_tasks_from_archive_tree
    tasks = segment_tasks_from_archive_tree(workflow.archive_dir)[:3]
    assert tasks
    proc = SegmentProcessor(backend="pallas")
    batched = proc.process_batch(tasks)
    assert set(batched) == {t.task_id for t in tasks}
    for t in tasks:
        single = proc(t)
        b = batched[t.task_id]
        assert b.icao24 == single.icao24
        assert b.airspace == single.airspace
        np.testing.assert_array_equal(b.count, single.count)
        for field in ("times", "lat", "lon", "alt_msl_m", "alt_agl_m",
                      "vrate_ms", "gspeed_ms", "heading_rad", "turn_rad_s"):
            np.testing.assert_allclose(
                getattr(b, field), getattr(single, field),
                atol=1e-4, rtol=1e-4, err_msg=field)


def test_workflow_runs_on_process_backend(tmp_path):
    wf = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003,
                       exec_backend="processes", tasks_per_message=2)
    wf.generate_raw(n_files=3, scale=2e4)
    reports = wf.run()
    assert [r.phase for r in reports] == ["organize", "archive", "process"]
    assert all(r.tasks > 0 for r in reports)


def test_workflow_checkpoint_resume(tmp_path):
    wf = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003)
    wf.generate_raw(n_files=3, scale=2e4)
    wf.run()
    n_reports = len(wf.reports)
    # a second run must skip all completed phases
    wf2 = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003)
    reports2 = wf2.run()
    assert reports2 == []
    assert n_reports == 3


def test_organizer_counts(tmp_path):
    spec = ScaledDatasetSpec(name="t", n_files=2, scale=2e4)
    paths = write_scaled_dataset(str(tmp_path / "raw"), spec)
    reg = synthetic_registry(n=500)
    org = Organizer(str(tmp_path / "org"), reg)
    res = org(Task(task_id=paths[0], payload=paths[0]))
    assert res.rows > 0 and res.aircraft > 0
    assert res.files_written == res.aircraft
