"""Triples-mode exclusive-allocation arithmetic (paper §II.C)."""

import pytest

from repro.core.triples import (
    DEFAULT_ALLOCATION_CORES, NodeType, TriplesConfig, TriplesError,
    UPGRADED_ALLOCATION_CORES, feasible_table_cells, paper_configs)


def test_exclusive_mode_charges_full_nodes():
    c = TriplesConfig(nodes=4, nppn=8)
    assert c.allocated_cores == 4 * 64
    assert c.total_processes == 32


def test_max_nodes_is_64_at_default_allocation():
    assert TriplesConfig.max_nodes() == 64
    TriplesConfig(nodes=64, nppn=32)          # fits
    with pytest.raises(TriplesError):
        TriplesConfig(nodes=65, nppn=32)      # 65*64 > 4096


def test_two_slot_processes_halve_worker_count():
    # paper: 6 GB jobs need 2 slots; 2048 workers x 2 slots = 4096 cores
    c = TriplesConfig(nodes=64, nppn=32, slots_per_process=2)
    assert c.total_processes == 2048
    assert c.gb_per_process == 6
    with pytest.raises(TriplesError):
        TriplesConfig(nodes=64, nppn=33, slots_per_process=2)  # >64 slots


def test_upgraded_allocation_allows_128_nodes():
    c = TriplesConfig(nodes=128, nppn=8, threads_per_process=2,
                      allocation_cores=UPGRADED_ALLOCATION_CORES)
    assert c.allocated_cores == 8192
    with pytest.raises(TriplesError):
        TriplesConfig(nodes=128, nppn=8,
                      allocation_cores=DEFAULT_ALLOCATION_CORES)


def test_table_cells_match_paper_dashes():
    """Tables I/II have dashes exactly where nodes would exceed 64."""
    cells = set(feasible_table_cells())
    assert (2048, 32) in cells
    assert (2048, 16) not in cells      # 128 nodes > 64
    assert (2048, 8) not in cells
    assert (1024, 8) not in cells       # 128 nodes > 64
    assert (1024, 16) in cells
    assert len(cells) == 9              # 12 cells - 3 dashes


def test_paper_configs_all_valid():
    cfgs = paper_configs()
    assert "organize_c2048_n32" in cfgs
    assert cfgs["process_64n_nppn16"].total_processes == 1024
    assert cfgs["radar_128n_nppn8"].total_processes == 1024
    assert cfgs["radar_128n_nppn8"].threads_per_process == 2


def test_recommendation_warnings():
    assert TriplesConfig(nodes=2, nppn=40).validate_recommended()
    assert not TriplesConfig(nodes=2, nppn=16).validate_recommended()


def test_mesh_shape_from_triple():
    c = TriplesConfig(nodes=2, nppn=16, threads_per_process=2)
    assert c.mesh_shape(chips_per_node=4) == (2, 16, 8)
