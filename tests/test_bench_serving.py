"""BENCH_serving artifact: schema, acceptance gates, reproducibility."""

import copy
import json

import pytest

from repro.bench import serving
from repro.bench.compare import compare_docs
from repro.bench.schema import (
    SERVING_SCHEMA, canonical_bytes, validate_serving)


@pytest.fixture(scope="module")
def quick_doc():
    return serving.run_serving_campaign(quick=True)


def test_quick_campaign_is_schema_valid_and_passes(quick_doc):
    assert quick_doc["schema"] == SERVING_SCHEMA
    assert validate_serving(quick_doc) == []
    assert quick_doc["summary"]["fail"] == 0
    assert quick_doc["summary"]["error"] == 0
    rec = quick_doc["scenarios"][0]
    m = rec["metrics"]
    # The ISSUE-7 acceptance surface, straight off the record.
    assert m["snapshot_identical"] == 1.0
    assert m["ingest_lag_max_points"] <= rec["spec"]["run"]["target_points"]
    assert rec["measured"]["tiny_p99_ratio"] <= 3.0
    assert m["shards_committed"] >= 2
    assert m["generation"] == m["shards_committed"]


def test_serving_canonical_bytes_reproducible(quick_doc):
    """Same-seed reruns agree byte-for-byte on the deterministic
    surface.  Like the storage artifact, the latency checks record
    measured actuals, so ``checks``/``status`` are stripped —
    ``metrics`` is the reproducible surface."""

    def strip_checks(blob):
        doc = json.loads(blob)
        for rec in doc["scenarios"]:
            rec.pop("checks", None)
            rec.pop("status", None)
        return json.dumps(doc, sort_keys=True)

    again = serving.run_serving_campaign(quick=True)
    assert strip_checks(canonical_bytes(quick_doc)) == \
        strip_checks(canonical_bytes(again))


def test_validator_catches_missing_required_metric(quick_doc):
    doc = copy.deepcopy(quick_doc)
    doc["scenarios"][0]["metrics"].pop("snapshot_identical")
    assert any("snapshot_identical" in p for p in validate_serving(doc))


def test_compare_gates_on_ingest_lag(quick_doc):
    """Schema dispatch picks ingest_lag_max_points; inflating it beyond
    the threshold regresses, equal artifacts do not."""
    rows, regressions = compare_docs(quick_doc, quick_doc)
    assert rows and not regressions
    assert rows[0]["metric"] == "ingest_lag_max_points"
    worse = copy.deepcopy(quick_doc)
    worse["scenarios"][0]["metrics"]["ingest_lag_max_points"] *= 2
    _rows, regressions = compare_docs(quick_doc, worse, threshold=0.10)
    assert len(regressions) == 1


def test_dag_cell_runs_and_matches_batch():
    doc = serving.run_serving_campaign(filters=["serving_dag_fleet"])
    rec = doc["scenarios"][0]
    assert rec["status"] == "pass"
    assert rec["metrics"]["snapshot_identical"] == 1.0
    # DAG-mode lag depends on worker timing => measured, not metrics.
    assert "ingest_lag_max_points" not in rec["metrics"]
    assert "ingest_lag_max_points" in rec["measured"]


def test_spec_validation_rejects_bad_mode():
    with pytest.raises(ValueError):
        serving.ServingSpec(mode="batch")
