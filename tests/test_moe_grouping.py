"""Grouped-MoE properties: grouping granularity must not change the math
when capacity is ample (perf iteration A1 correctness guarantee)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import moe


def _params(key, E, D, F, gated=True):
    ks = jax.random.split(key, 3)
    z = 2 if gated else 1
    return {
        "router": jax.random.normal(ks[0], (D, E), jnp.float32) * 0.1,
        "wi": jax.random.normal(ks[1], (E, D, z, F), jnp.float32) * 0.05,
        "wo": jax.random.normal(ks[2], (E, F, D), jnp.float32) * 0.05,
    }


@pytest.mark.parametrize("gated", [True, False])
def test_group_size_invariance_with_ample_capacity(gated):
    """With capacity_factor high enough that nothing drops, the output
    must be identical for any dispatch group size."""
    key = jax.random.PRNGKey(0)
    B, T, D, F, E, K = 2, 32, 16, 24, 8, 2
    p = _params(key, E, D, F, gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D), jnp.float32)
    outs = []
    for gs in (8, 16, 64):
        outs.append(np.asarray(moe(
            x, p, n_experts=E, top_k=K, activation="silu",
            capacity_factor=float(E), group_size=gs)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-5)


def test_moe_matches_dense_reference():
    """Ample-capacity MoE == explicit per-token expert sum."""
    key = jax.random.PRNGKey(2)
    B, T, D, F, E, K = 1, 16, 8, 12, 4, 2
    p = _params(key, E, D, F)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, D), jnp.float32)
    got = np.asarray(moe(x, p, n_experts=E, top_k=K, activation="silu",
                         capacity_factor=float(E), group_size=16))

    # reference: route each token independently
    logits = np.asarray(x.reshape(-1, D) @ np.asarray(p["router"]))
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :K]
    ref = np.zeros((T, D), np.float32)
    xf = np.asarray(x.reshape(-1, D))
    import jax.nn as jnn
    for s in range(T):
        for k in range(K):
            e = top[s, k]
            h = np.einsum("d,dzf->zf", xf[s], np.asarray(p["wi"][e]))
            act = np.asarray(jnn.silu(jnp.asarray(h[0]))) * h[1]
            ref[s] += probs[s, e] * (act @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(got[0], ref, rtol=2e-3, atol=2e-4)


def test_capacity_drops_are_deterministic_and_bounded():
    key = jax.random.PRNGKey(4)
    B, T, D, F, E, K = 2, 64, 8, 12, 4, 2
    p = _params(key, E, D, F)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, T, D), jnp.float32)
    lo = moe(x, p, n_experts=E, top_k=K, activation="silu",
             capacity_factor=0.25, group_size=32)
    lo2 = moe(x, p, n_experts=E, top_k=K, activation="silu",
              capacity_factor=0.25, group_size=32)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo2))
    assert bool(jnp.isfinite(lo).all())


def test_sort_rank_matches_cumsum_semantics():
    """Sort-based rank-in-expert == the classic cumsum position."""
    rng = np.random.default_rng(0)
    SK, E = 256, 8
    eid = rng.integers(0, E, SK)
    # reference: cumsum semantics (first-come first-ranked)
    want = np.zeros(SK, np.int64)
    counts = np.zeros(E, np.int64)
    for i, e in enumerate(eid):
        want[i] = counts[e]
        counts[e] += 1
    # sort-based (as in layers.moe)
    order = np.argsort(eid, kind="stable")
    es = eid[order]
    start = np.searchsorted(es, es, side="left")
    pos_sorted = np.arange(SK) - start
    got = np.zeros(SK, np.int64)
    got[order] = pos_sorted
    np.testing.assert_array_equal(got, want)
