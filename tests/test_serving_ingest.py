"""Continuous-ingest serving: scripted interleavings + kill/resume.

Zero sleeps anywhere: :class:`~repro.serving.IngestService` is
synchronously drivable and fires named lifecycle hooks (``scan``,
``cut``, ``pre_build``, ``post_build``, ``pre_commit``, ``post_commit``,
``seal``), so tests interleave reader checks, front-end queries, and
kills at *exact* points in the ingest cycle.  The hypothesis test
mirrors ``test_dag_runtime``'s kill/resume pattern: die at a random
lifecycle event, construct a fresh service over the same roots, and the
sealed store must be byte-identical to an uninterrupted batch build of
the same source files.
"""

import os
import random
import shutil
import tempfile

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import (
    FeedSpec, IngestService, Query, ServiceKilled, StoreFrontEnd,
    SyntheticFeed)
from repro.serving.service import snapshot_digest
from repro.store.format import StoreManifest
from repro.store.reader import TrackStore
from repro.store.writer import build_store

# Small shard target so a dozen ~2 KB feed files (~25 estimated points
# each) cut several shards before seal.
TARGET = 96
SPEC = FeedSpec(n_files=12, obs_per_file=48, seed=3)


def _roots(tmp_path):
    feed_dir = str(tmp_path / "feed")
    store_dir = str(tmp_path / "store")
    os.makedirs(feed_dir)
    return feed_dir, store_dir


def _read_all(store_dir, manifest=None):
    """Full decode of a store -> [(track_id, obs)...] in plan order."""
    store = TrackStore(store_dir, manifest=manifest, prefetch=0)
    items = []
    for plan in store.plan():
        batch = store.read_shard_batch(plan.shard.shard_id)
        items.extend(
            (tid, obs) for tid, (obs, _s) in zip(batch.track_ids,
                                                 batch.items))
    return items


def _store_bytes(root, manifest):
    blobs = [open(os.path.join(root, "store_manifest.json"), "rb").read()]
    for s in manifest.shards:
        blobs.append(open(os.path.join(root, s.filename), "rb").read())
    return blobs


# -- no reader ever observes a partially-committed shard ----------------


def test_reader_never_observes_partial_shard(tmp_path):
    """At EVERY lifecycle point — including ``post_build``, where the
    new shard file is already on disk but the manifest does not name it
    yet — a reader opening the store sees a fully-consistent prefix:
    every manifest-named shard file exists, decodes, and yields exactly
    the manifest's track count."""
    feed_dir, store_dir = _roots(tmp_path)
    checks = {"n": 0, "max_gap": 0}

    def check_consistent(**_info):
        checks["n"] += 1
        try:
            manifest = StoreManifest.load(store_dir)
        except FileNotFoundError:
            return                       # no store yet: trivially clean
        on_disk = {f for f in os.listdir(os.path.join(store_dir, "shards"))
                   } if os.path.isdir(os.path.join(store_dir, "shards")) \
            else set()
        extra = on_disk - {os.path.basename(s.filename)
                           for s in manifest.shards}
        checks["max_gap"] = max(checks["max_gap"], len(extra))
        items = _read_all(store_dir, manifest=manifest)
        assert len(items) == len(manifest.tracks)
        assert sum(len(obs["time"]) for _t, obs in items) \
            == manifest.n_points

    hooks = {name: check_consistent
             for name in ("scan", "cut", "pre_build", "post_build",
                          "pre_commit", "post_commit", "seal")}
    feed = SyntheticFeed(feed_dir, SPEC)
    svc = IngestService(feed_dir, store_dir, target_points=TARGET,
                        hooks=hooks)
    while not feed.exhausted:
        feed.emit(2)
        svc.poll_once()
    manifest = svc.seal()
    assert checks["n"] > 10
    # The interesting window really occurred: at some point a built
    # shard file existed on disk ahead of the manifest naming it.
    assert checks["max_gap"] >= 1
    assert len(manifest.shards) >= 2     # the scenario cut several


# -- snapshot reads are manifest-generation-consistent ------------------


def test_snapshot_reads_pin_their_generation(tmp_path):
    """A snapshot admitted at generation G returns exactly generation
    G's store even when commits land between its steps; tiny queries
    issued during the same window see the NEW generation."""
    feed_dir, store_dir = _roots(tmp_path)
    feed = SyntheticFeed(feed_dir, SPEC)
    svc = IngestService(feed_dir, store_dir, target_points=TARGET)
    feed.emit(6)
    svc.poll_once()
    pinned = StoreManifest.load(store_dir)
    assert pinned.generation >= 1

    front = StoreFrontEnd(svc)
    snap = Query(1, "snapshot")
    assert front.admit(snap)
    assert snap.generation == pinned.generation

    # Interleave: one shard decode, then let ingest advance the store.
    front.step()
    feed.emit_all()
    svc.poll_once()
    svc.seal()
    after = StoreManifest.load(store_dir)
    assert after.generation > pinned.generation

    tiny = Query(2, "latest",
                 {"track_id": sorted(svc.retained)[-1]})
    assert front.admit(tiny)
    while not (snap.done and tiny.done):
        front.step()
    # Tiny query observed the advanced store...
    assert tiny.generation == after.generation
    # ...while the snapshot returned exactly the pinned generation.
    got = {tid for tid, _obs in snap.result}
    assert got == {t.track_id for t in pinned.tracks}
    assert snapshot_digest(sorted(snap.result, key=lambda kv: kv[0])) \
        == snapshot_digest(sorted(_read_all(store_dir, manifest=pinned),
                                  key=lambda kv: kv[0]))


def test_front_end_rejects_without_trace(tmp_path):
    """Admission with all slots of a class full returns False and leaves
    no partial state (no pinned manifest entry, stats intact); tiny and
    bulk slot classes do not contend."""
    feed_dir, store_dir = _roots(tmp_path)
    feed = SyntheticFeed(feed_dir, SPEC)
    svc = IngestService(feed_dir, store_dir, target_points=TARGET)
    feed.emit_all()
    svc.poll_once()
    svc.seal()

    front = StoreFrontEnd(svc, tiny_slots=1, bulk_slots=1)
    first = Query(1, "snapshot")
    assert front.admit(first)
    second = Query(2, "snapshot")
    assert not front.admit(second)
    assert second.generation is None         # nothing was pinned
    assert second.query_id not in front._bulk_reads
    assert front.stats["rejected"] == 1
    # A tiny query still admits: separate slot class, no starvation.
    tiny = Query(3, "nearest", {"lat": 40.0, "lon": -100.0})
    assert front.admit(tiny)
    while not first.done:
        front.step()
    assert tiny.done
    # The rejected query re-offers cleanly once the slot frees.
    assert front.admit(second)
    while not second.done:
        front.step()
    assert {t for t, _o in first.result} == {t for t, _o in second.result}


# -- mid-append kill + restart converges to identical bytes -------------


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 60))
@settings(max_examples=10, deadline=None)
def test_mid_append_kill_resume_byte_identical(opseed, kill_at):
    """Kill the service at a random lifecycle event mid-append; a fresh
    service over the same roots resumes, and seal converges to a store
    byte-identical (manifest AND every shard file) to an uninterrupted
    batch build of the same source directory.  Exercises every window:
    after a cut, between build and commit (orphan shard file on disk),
    between commit and the next scan, and during seal."""
    rng = random.Random(opseed)
    tmp = tempfile.mkdtemp(prefix="repro-serving-kill-")
    try:
        feed_dir = os.path.join(tmp, "feed")
        store_dir = os.path.join(tmp, "store")
        batch_dir = os.path.join(tmp, "batch")
        os.makedirs(feed_dir)
        feed = SyntheticFeed(feed_dir, SPEC)
        events = {"n": 0}

        def bomb(**_info):
            events["n"] += 1
            if events["n"] == kill_at:
                raise ServiceKilled(f"scripted kill at event {kill_at}")

        hooks = {name: bomb
                 for name in ("scan", "cut", "pre_build", "post_build",
                              "pre_commit", "post_commit", "seal")}
        svc = IngestService(feed_dir, store_dir, target_points=TARGET,
                            hooks=hooks)
        try:
            while not feed.exhausted:
                feed.emit(rng.randint(1, 3))
                svc.poll_once()
            svc.seal()
        except ServiceKilled:
            pass

        # Restart: all durable state reloads from the manifest alone.
        feed.emit_all()
        svc2 = IngestService(feed_dir, store_dir, target_points=TARGET)
        if svc2.sealed:
            manifest = StoreManifest.load(store_dir)
        else:
            svc2.poll_once()
            manifest = svc2.seal()

        build_store(feed_dir, batch_dir, target_points=TARGET)
        assert _store_bytes(store_dir, manifest) \
            == _store_bytes(batch_dir, StoreManifest.load(batch_dir))
        # The resumed retained snapshot covers every track exactly.
        svc3 = IngestService(feed_dir, store_dir, target_points=TARGET)
        assert svc3.sealed
        assert set(svc3.retained) == {t.track_id for t in manifest.tracks}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_resumed_service_does_not_reingest_committed(tmp_path):
    """After a restart no committed file is re-accepted: the second
    service's scan over an unchanged tree is empty, and poll_once is a
    no-op (commit idempotence at the service level)."""
    feed_dir, store_dir = _roots(tmp_path)
    feed = SyntheticFeed(feed_dir, SPEC)
    svc = IngestService(feed_dir, store_dir, target_points=TARGET)
    feed.emit(8)
    svc.poll_once()
    gen = svc.generation
    committed = svc.stats["shards_committed"]
    assert committed >= 1

    svc2 = IngestService(feed_dir, store_dir, target_points=TARGET)
    fresh = svc2.scan()
    # Only the sub-target remainder (never committed) reappears.
    assert {t for t, _p, _s in fresh} \
        == {t for t, _p, _s in svc._pending}
    assert svc2.poll_once() == 0         # remainder stays pending
    assert svc2.generation == gen
    assert svc2.stats["shards_committed"] == 0


def test_ingest_service_dag_mode_matches_batch(tmp_path):
    """The fleet path — open build node, parallel workers, ordered
    commits — seals to the same bytes as the batch build."""
    feed_dir, store_dir = _roots(tmp_path)
    batch_dir = str(tmp_path / "batch")
    feed = SyntheticFeed(feed_dir, SPEC)
    svc = IngestService(feed_dir, store_dir, target_points=TARGET)

    def stop_when():
        if not feed.exhausted:
            feed.emit(3)
            return False
        return not svc.scan()

    svc.run_service(backend="threads", n_workers=2, stop_when=stop_when)
    assert svc.sealed
    manifest = StoreManifest.load(store_dir)
    build_store(feed_dir, batch_dir, target_points=TARGET)
    assert _store_bytes(store_dir, manifest) \
        == _store_bytes(batch_dir, StoreManifest.load(batch_dir))


def test_sealed_service_rejects_new_accepts(tmp_path):
    feed_dir, store_dir = _roots(tmp_path)
    feed = SyntheticFeed(feed_dir, FeedSpec(n_files=3, obs_per_file=16))
    svc = IngestService(feed_dir, store_dir, target_points=TARGET)
    feed.emit_all()
    svc.poll_once()
    svc.seal()
    with pytest.raises(RuntimeError, match="sealed"):
        svc.accept(svc.scan())
