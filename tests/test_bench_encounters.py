"""Tests for the encounter-screening benchmark matrix + tooling.

The quick tier IS the ISSUE-8 acceptance cell set, so running it here
(and asserting every cell passes) keeps the CI gate honest locally:
grid + fused-kernel candidates exactly equal to the brute-force
all-pairs reference on the dense jit AND pallas cells, the fused
screen >= 5x over numpy brute force at full aerodrome density,
sparse cells an order of magnitude below dense occupancy, and
sized_lpt / adaptive_chunk each >= 1.3x static makespan on the
quadratic-skew screen-cell manifest.  Also covers spec validation,
deterministic re-runs, schema validation, and the compare CLI's
schema dispatch.
"""

import copy
import dataclasses
import json

import pytest

from repro.bench import encounters as enc
from repro.bench.compare import compare_docs, default_metric
from repro.bench.compare import main as compare_main
from repro.bench.schema import ENCOUNTERS_SCHEMA, validate_encounters


@pytest.fixture(scope="module")
def quick_doc():
    return enc.run_encounter_campaign(quick=True)


def test_quick_tier_is_the_acceptance_cells(quick_doc):
    names = {r["name"] for r in quick_doc["scenarios"]}
    assert names == {"enc_exact_tiny_dense_jit",
                     "enc_exact_tiny_dense_pallas",
                     "enc_dense_kernel_speedup",
                     "enc_sparse_density",
                     "enc_policy_quadratic_sized_lpt",
                     "enc_policy_quadratic_adaptive_chunk"}


def test_quick_tier_passes_and_validates(quick_doc):
    assert validate_encounters(quick_doc) == []
    assert quick_doc["summary"]["fail"] == 0
    assert quick_doc["summary"]["error"] == 0
    by_name = {r["name"]: r for r in quick_doc["scenarios"]}
    for name in ("enc_exact_tiny_dense_jit", "enc_exact_tiny_dense_pallas",
                 "enc_dense_kernel_speedup", "enc_sparse_density"):
        assert by_name[name]["metrics"]["candidate_set_equal"] == 1, name
    assert by_name["enc_dense_kernel_speedup"][
        "measured"]["kernel_speedup_x"] >= 5.0
    for policy in ("sized_lpt", "adaptive_chunk"):
        rec = by_name[f"enc_policy_quadratic_{policy}"]
        assert rec["metrics"]["makespan_speedup_x"] >= 1.3
        assert rec["metrics"]["tasks_completed"] == rec["metrics"]["cells"]
    # Density contrast: sparse cells stay an order of magnitude below
    # the dense manifest's hotspot occupancy.
    assert by_name["enc_sparse_density"][
        "metrics"]["max_cell_occupancy"] <= 8


def test_policy_cells_deterministic(quick_doc):
    """The sim cells are pure functions of (spec, seed): re-running
    reproduces metrics (incl. the dispatch digest) bit-identically."""
    by_name = {r["name"]: r for r in quick_doc["scenarios"]}
    rec = by_name["enc_policy_quadratic_sized_lpt"]
    again = enc._execute_policy_sim(enc.EncounterSpec(**rec["spec"]["run"]))
    want = {k: v for k, v in rec["metrics"].items()
            if k not in ("baseline_makespan_seconds", "makespan_speedup_x")}
    assert again["metrics"] == want


def test_spec_validation():
    with pytest.raises(ValueError, match="cell kind"):
        enc.EncounterSpec(kind="nope")
    with pytest.raises(ValueError, match="kernel backend"):
        enc.EncounterSpec(kind="screen", backend="sim")
    with pytest.raises(ValueError, match="trail kind"):
        enc.EncounterSpec(kind="screen", dataset="aerodrome_dense")
    with pytest.raises(ValueError, match="sim backend"):
        enc.EncounterSpec(kind="policy_sim", backend="jit")
    with pytest.raises(ValueError, match="policy"):
        enc.EncounterSpec(kind="policy_sim", backend="sim",
                          policy="nope")


def test_scenario_matrix_declares_unique_names():
    scs = enc.encounter_scenarios()
    names = [sc.name for sc in scs]
    assert len(names) == len(set(names))
    assert sum(1 for sc in scs if sc.tier == "quick") == 6


def test_campaign_filters_and_seed_override():
    with pytest.raises(ValueError, match="match"):
        enc.run_encounter_campaign(filters=["no_such_cell"])


def test_compare_dispatch_and_gate(tmp_path, quick_doc, capsys):
    assert default_metric(quick_doc) == "screen_seconds_per_candidate"
    worse = copy.deepcopy(quick_doc)
    for rec in worse["scenarios"]:
        if "screen_seconds_per_candidate" in rec["metrics"]:
            rec["metrics"]["screen_seconds_per_candidate"] *= 2.0
    rows, regressions = compare_docs(quick_doc, worse, threshold=0.10)
    assert regressions and all(r["delta_pct"] > 10 for r in regressions)
    # Policy cells don't publish the screen metric -> never gated on it.
    gated = {r["name"] for r in rows}
    assert not any(n.startswith("enc_policy") for n in gated)

    old_p, new_p = tmp_path / "old.json", tmp_path / "new.json"
    old_p.write_text(json.dumps(quick_doc))
    new_p.write_text(json.dumps(worse))
    assert compare_main([str(old_p), str(new_p)]) == 1
    assert compare_main([str(old_p), str(old_p)]) == 0
    out = capsys.readouterr().out
    assert "screen_seconds_per_candidate" in out
    assert "max_cell_occupancy" in out          # info row, not gated

    mismatched = copy.deepcopy(quick_doc)
    mismatched["schema"] = "repro.bench.scheduling/v1"
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(mismatched))
    assert compare_main([str(old_p), str(bad)]) == 1


def test_summary_lines_render(quick_doc):
    lines = enc.encounter_summary_lines(quick_doc)
    assert "6 encounter scenarios" in lines[0]
    assert any("kernel=" in ln for ln in lines)
    assert any("speedup=" in ln for ln in lines)
