"""Sharding-rule engine: divisibility fallbacks + real-config specs.

These run on 1 device by constructing abstract meshes (Mesh over a numpy
array of the single CPU device is not possible for 256 entries, so we
use jax.sharding.AbstractMesh, which PartitionSpec validation accepts).
"""

import hypothesis.strategies as st
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_names, get_arch
from repro.distribution.sharding import (
    batch_spec, cache_shardings, make_spec, param_shardings)
from repro.launch import steps
from repro.launch.mesh import make_abstract_mesh


def mesh16x16():
    return make_abstract_mesh((16, 16), ("data", "model"))


def mesh2x16x16():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _spec_divides(spec, shape, mesh) -> bool:
    for dim, axes in zip(shape, tuple(spec)):
        if axes is None:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % n:
            return False
    return True


def test_make_spec_falls_back_on_indivisible():
    mesh = mesh16x16()
    # dim 8 can't shard over 16-way model => replicated
    spec = make_spec([[("model",)]], (8,), mesh)
    assert tuple(spec) == (None,)
    spec = make_spec([[("model",)]], (32,), mesh)
    assert tuple(spec) == ("model",)


def test_make_spec_priority_order():
    mesh = mesh2x16x16()
    # prefer (pod,data) jointly; batch 8 only divides by pod(2) -> falls
    # through to data? 8 % (2*16)=8 !=0; [("pod","data")] then [("data",)]
    spec = make_spec([[("pod", "data"), ("data",)]], (8,), mesh)
    assert tuple(spec) == (None,)          # 8 % 16 != 0 too
    spec = make_spec([[("pod", "data"), ("data",)]], (16,), mesh)
    assert tuple(spec) == ("data",)
    spec = make_spec([[("pod", "data"), ("data",)]], (64,), mesh)
    assert tuple(spec) == (("pod", "data"),)


def test_no_axis_used_twice():
    mesh = mesh16x16()
    spec = make_spec([[("model",)], [("model",), ("data",)]],
                     (32, 32), mesh)
    assert tuple(spec) == ("model", "data")


@pytest.mark.parametrize("name", all_arch_names())
@pytest.mark.parametrize("mk", [mesh16x16, mesh2x16x16])
def test_param_shardings_valid_for_all_archs(name, mk):
    """Every sharded dim divides its axis product, for the FULL configs
    on both production meshes."""
    cfg = get_arch(name)
    mesh = mk()
    pspecs = steps.param_specs(cfg)
    shardings = param_shardings(pspecs, mesh)
    leaves = jax.tree_util.tree_leaves(pspecs)
    shs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) == len(shs)
    n_sharded = 0
    for leaf, sh in zip(leaves, shs):
        assert _spec_divides(sh.spec, leaf.shape, mesh), \
            (leaf.shape, sh.spec)
        if any(a is not None for a in tuple(sh.spec)):
            n_sharded += 1
    # the big tensors must actually shard (not everything replicated)
    assert n_sharded >= len(leaves) // 2


@pytest.mark.parametrize("name", ["jamba-v0.1-52b", "rwkv6-3b",
                                  "nemotron-4-340b"])
def test_cache_shardings_valid(name):
    from repro.configs.base import SHAPES
    cfg = get_arch(name)
    mesh = mesh16x16()
    cspecs = steps.cache_specs(cfg, SHAPES["decode_32k"])
    shardings = cache_shardings(cspecs, mesh)
    leaves = jax.tree_util.tree_leaves(cspecs)
    shs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    for leaf, sh in zip(leaves, shs):
        assert _spec_divides(sh.spec, leaf.shape, mesh), \
            (leaf.shape, sh.spec)


def test_batch_spec_long_context_batch1():
    mesh = mesh16x16()
    assert tuple(batch_spec(mesh, 1, 1)) == (None, None)
    assert tuple(batch_spec(mesh, 32, 1)) == ("data", None)
    mesh3 = mesh2x16x16()
    assert tuple(batch_spec(mesh3, 256, 1))[0] == ("pod", "data")


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4))
@settings(max_examples=40, deadline=None)
def test_make_spec_always_valid(shape):
    mesh = mesh2x16x16()
    rule = [[("pod", "data"), ("data",), ("model",)]] * len(shape)
    spec = make_spec(rule, shape, mesh)
    assert _spec_divides(spec, shape, mesh)
