"""Self-scheduled data pipeline + batched serving."""

import numpy as np
import pytest

from repro.data.pipeline import SelfScheduledLoader, synthetic_token_shards


def test_loader_ingests_every_shard_once(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=6,
                                    vocab_size=128,
                                    tokens_per_shard_mean=4000)
    loader = SelfScheduledLoader(shards, batch_size=2, seq_len=32,
                                 poll_interval=0.003)
    jr = loader.job_result
    assert len(jr.results) == 6
    # every token that fits a full sequence is buffered exactly once
    L = 33
    expected = sum((s.n_tokens // L) * L for s in shards)
    assert loader._ingested_tokens == expected


def test_loader_batch_shapes_and_determinism(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=4,
                                    vocab_size=64,
                                    tokens_per_shard_mean=3000, seed=1)
    loader = SelfScheduledLoader(shards, batch_size=3, seq_len=16,
                                 poll_interval=0.003, seed=7)
    batches = list(loader.batches(5))
    assert len(batches) == 5
    for b in batches:
        assert b["tokens"].shape == (3, 16)
        assert b["labels"].shape == (3, 16)
        # labels are next-token shifted
        assert b["tokens"].dtype == np.int32


def test_loader_largest_first_order(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=8,
                                    vocab_size=64,
                                    tokens_per_shard_mean=2000, seed=2)
    loader = SelfScheduledLoader(shards, batch_size=2, seq_len=16,
                                 poll_interval=0.003,
                                 organization="largest_first")
    assert loader.job_result.messages_sent == 8


def test_batched_server_completes_all_requests():
    import jax
    from repro.configs import get_arch
    from repro.models import model as M
    from repro.serving.server import BatchedServer, Request

    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=3, prompt_len=16,
                           cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(3, 14))),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(7)]
    server.serve(reqs)
    for r in reqs:
        assert r.done
        assert 1 <= len(r.tokens_out) <= r.max_new_tokens
    # continuous batching: more requests than slots were processed
    assert len(reqs) > server.slots


def test_server_eos_stops_early():
    import jax
    from repro.configs import get_arch
    from repro.models import model as M
    from repro.serving.server import BatchedServer, Request

    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=2, prompt_len=8,
                           cache_len=64)
    r = Request(0, np.array([1, 2, 3]), max_new_tokens=50)
    server.serve([r])
    assert r.done and len(r.tokens_out) <= 50
