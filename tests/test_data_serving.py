"""Self-scheduled data pipeline + batched serving."""

import numpy as np
import pytest

from repro.data.pipeline import SelfScheduledLoader, synthetic_token_shards


def test_loader_ingests_every_shard_once(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=6,
                                    vocab_size=128,
                                    tokens_per_shard_mean=4000)
    loader = SelfScheduledLoader(shards, batch_size=2, seq_len=32,
                                 poll_interval=0.003)
    jr = loader.job_result
    assert len(jr.results) == 6
    # every token that fits a full sequence is buffered exactly once
    L = 33
    expected = sum((s.n_tokens // L) * L for s in shards)
    assert loader._ingested_tokens == expected


def test_loader_batch_shapes_and_determinism(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=4,
                                    vocab_size=64,
                                    tokens_per_shard_mean=3000, seed=1)
    loader = SelfScheduledLoader(shards, batch_size=3, seq_len=16,
                                 poll_interval=0.003, seed=7)
    batches = list(loader.batches(5))
    assert len(batches) == 5
    for b in batches:
        assert b["tokens"].shape == (3, 16)
        assert b["labels"].shape == (3, 16)
        # labels are next-token shifted
        assert b["tokens"].dtype == np.int32


def test_loader_largest_first_order(tmp_path):
    shards = synthetic_token_shards(str(tmp_path), n_shards=8,
                                    vocab_size=64,
                                    tokens_per_shard_mean=2000, seed=2)
    loader = SelfScheduledLoader(shards, batch_size=2, seq_len=16,
                                 poll_interval=0.003,
                                 organization="largest_first")
    assert loader.job_result.messages_sent == 8


def test_batched_server_completes_all_requests():
    import jax
    from repro.configs import get_arch
    from repro.models import model as M
    from repro.serving.server import BatchedServer, Request

    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=3, prompt_len=16,
                           cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(3, 14))),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(7)]
    server.serve(reqs)
    for r in reqs:
        assert r.done
        assert 1 <= len(r.tokens_out) <= r.max_new_tokens
    # continuous batching: more requests than slots were processed
    assert len(reqs) > server.slots


def test_server_eos_stops_early():
    import jax
    from repro.configs import get_arch
    from repro.models import model as M
    from repro.serving.server import BatchedServer, Request

    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=2, prompt_len=8,
                           cache_len=64)
    r = Request(0, np.array([1, 2, 3]), max_new_tokens=50)
    server.serve([r])
    assert r.done and len(r.tokens_out) <= 50


@pytest.fixture(scope="module")
def server_env():
    """One reduced-arch param set shared by the slot-semantics tests."""
    import jax
    from repro.configs import get_arch
    from repro.models import model as M

    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_admit_full_returns_false_without_cache_corruption(server_env):
    """With every slot occupied, ``admit`` returns False and leaves NO
    trace: the KV cache, the last-token buffer, and the slot table are
    bitwise what they were, and the rejected request is untouched — so
    re-offering it later decodes exactly as if it had been first in
    line."""
    import jax
    from repro.serving.server import BatchedServer, Request

    cfg, params = server_env
    server = BatchedServer(cfg, params, slots=2, prompt_len=8,
                           cache_len=64)
    occupants = [Request(i, np.arange(1, 5 + i), max_new_tokens=40)
                 for i in range(2)]
    for r in occupants:
        assert server.admit(r)
    cache_before = jax.tree_util.tree_map(np.asarray, server.cache)
    last_before = server._last_token.copy()
    slots_before = list(server.slot_req)

    late = Request(9, np.array([7, 8, 9]), max_new_tokens=4)
    assert not server.admit(late)
    assert not late.tokens_out and not late.done
    assert server.slot_req == slots_before
    assert np.array_equal(server._last_token, last_before)
    for a, b in zip(jax.tree_util.tree_leaves(cache_before),
                    jax.tree_util.tree_leaves(server.cache)):
        assert np.array_equal(a, np.asarray(b))

    # Once a slot frees, the same request object admits and completes.
    server.serve([late])
    assert late.done and all(r.done for r in occupants)


def test_slot_frees_on_eos_and_on_max_new_tokens(server_env):
    """Both completion paths release the slot: max_new_tokens yields
    exactly that many tokens, and an EOS hit stops at the EOS token —
    earlier than the budget — with the slot back in the free list."""
    from repro.serving.server import BatchedServer, Request

    cfg, params = server_env
    prompt = np.array([3, 1, 4, 1, 5])

    # Budget path: 1 prefill token + (max-1) decode steps, slot free.
    server = BatchedServer(cfg, params, slots=2, prompt_len=8,
                           cache_len=64)
    capped = Request(0, prompt, max_new_tokens=3)
    server.serve([capped])
    assert capped.done and len(capped.tokens_out) == 3
    assert server.slot_req == [None, None]

    # EOS path: replay greedily, declaring the recorded first decode
    # token as EOS — the rerun must stop right there.
    eos_id = capped.tokens_out[1]
    server2 = BatchedServer(cfg, params, slots=2, prompt_len=8,
                            cache_len=64)
    eased = Request(1, prompt, max_new_tokens=50, eos_id=eos_id)
    server2.serve([eased])
    assert eased.done
    assert eased.tokens_out[-1] == eos_id
    assert len(eased.tokens_out) == 2 < eased.max_new_tokens
    assert server2.slot_req == [None, None]


def test_request_order_determinism_under_greedy_decode(server_env):
    """Greedy decode + fixed admission order => two fresh servers fed
    the same request list emit identical token streams per request,
    even with more requests than slots (continuous batching reuses
    slots in a deterministic order)."""
    from repro.serving.server import BatchedServer, Request

    cfg, params = server_env

    def run():
        rng = np.random.default_rng(42)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 8))),
                        max_new_tokens=int(rng.integers(2, 5)))
                for i in range(5)]
        server = BatchedServer(cfg, params, slots=2, prompt_len=8,
                               cache_len=64)
        server.serve(reqs)
        return {r.request_id: list(r.tokens_out) for r in reqs}

    first, second = run(), run()
    assert first == second
    assert all(out for out in first.values())
