"""Unit tests for the pluggable scheduling-policy layer.

Covers the per-policy dispatch semantics (repro.runtime.policies), the
SchedulerCore delegation + checkpoint plumbing, the wait-attribution
path (worker ``take_wait_s`` -> DONE -> RunResult breakdown), the
cost-estimate helpers (PhaseCostModel.task_seconds,
StoreManifest.row_range_bytes), and the store row-range task builder
the shard_affinity policy groups by.
"""

import json
import threading
import time

import pytest

from repro.core.cost_model import PROCESS_PHASE
from repro.core.messages import Task
from repro.runtime import (
    POLICY_NAMES, ManagerCheckpoint, SchedulerCore, run_job)
from repro.runtime.policies import (
    AdaptiveChunkPolicy, default_task_cost, get_policy, locality_key,
    model_task_cost)


def _tasks(n=20, sizes=None):
    sizes = sizes if sizes is not None else [(i * 37) % 23 + 1
                                             for i in range(n)]
    return [Task(task_id=f"t{i:04d}", size_bytes=s, timestamp=i)
            for i, s in enumerate(sizes)]


# ---------------------------------------------------------------------------
# Registry / back-compat.
# ---------------------------------------------------------------------------

def test_policy_registry_names():
    assert set(POLICY_NAMES) == {"static", "fifo_selfsched", "sized_lpt",
                                 "adaptive_chunk", "shard_affinity"}
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_policy("nope")


def test_default_policy_is_bitwise_pre_refactor_static():
    """No policy argument == policy='static' == the historical fixed
    tasks_per_message organizer-order dispatch, batch for batch."""
    tasks = _tasks(23)
    logs = []
    for kw in ({}, {"policy": "static"}):
        core = SchedulerCore(tasks, tasks_per_message=3, **kw)
        log = []
        while not core.done:
            batch = core.next_batch("w0")
            log.append(tuple(t.task_id for t in batch))
            core.on_done("w0", [t.task_id for t in batch])
        logs.append(log)
    assert logs[0] == logs[1]
    # largest_first organizer order, fixed batches of 3
    assert all(len(b) == 3 for b in logs[0][:-1])


def test_manager_checkpoint_json_backcompat():
    # Pre-policy checkpoints (no "policy" key) load fine...
    old = json.dumps({"completed": ["t0001"], "pending": ["t0002"]})
    ck = ManagerCheckpoint.loads(old)
    assert ck.completed == {"t0001"} and ck.policy_state is None
    # ...and stateless policies keep emitting the old shape.
    core = SchedulerCore(_tasks(5), policy="static")
    doc = json.loads(core.checkpoint().dumps())
    assert "policy" not in doc


def test_run_job_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        run_job(_tasks(3), lambda t: 0, backend="threads", policy="bogus")


# ---------------------------------------------------------------------------
# Per-policy dispatch semantics.
# ---------------------------------------------------------------------------

def _drain_log(core, worker="w0"):
    log = []
    while not core.done:
        batch = core.next_batch(worker)
        log.append([t.task_id for t in batch])
        core.on_done(worker, [t.task_id for t in batch])
    return log


def test_fifo_selfsched_one_task_per_message_in_organizer_order():
    tasks = _tasks(9)
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=4, policy="fifo_selfsched")
    log = _drain_log(core)
    assert all(len(b) == 1 for b in log)
    assert [b[0] for b in log] == [t.task_id for t in tasks]


def test_sized_lpt_orders_by_cost_hint_over_bytes():
    # cpu hints reverse the size order: the estimator must win.
    tasks = [Task(task_id=f"t{i}", size_bytes=100 - i, timestamp=i,
                  cpu_cost_hint=float(i)) for i in range(5)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="sized_lpt")
    log = _drain_log(core)
    assert [b[0] for b in log] == ["t4", "t3", "t2", "t1", "t0"]


def test_adaptive_chunk_costs_budget_not_count():
    """A task costing more than the round budget travels ALONE; the
    cheap tail packs many-per-message; budgets shrink as the queue
    drains (cost-keyed factoring)."""
    giant = Task(task_id="giant", size_bytes=1, timestamp=0,
                 cpu_cost_hint=1000.0)
    minnows = [Task(task_id=f"m{i:03d}", size_bytes=1, timestamp=i + 1,
                    cpu_cost_hint=1.0) for i in range(64)]
    core = SchedulerCore([giant] + minnows, organization="chronological",
                         tasks_per_message=1, policy="adaptive_chunk",
                         n_workers=4)
    first = core.next_batch("w0")
    assert [t.task_id for t in first] == ["giant"]        # alone, first
    second = core.next_batch("w1")
    assert len(second) > 1                                # tail packs
    sizes = [len(core.next_batch("w2")) for _ in range(6)]
    sizes = [s for s in sizes if s]
    assert sizes == sorted(sizes, reverse=True)           # shrinking


def test_shard_affinity_keeps_worker_on_shard_and_steals_at_tail():
    uri = "store:///data/st#shard={}&rows={}:{}"
    tasks = []
    for s in range(3):
        for r in range(4):
            tasks.append(Task(
                task_id=f"store/s{s:05d}/r{r:05d}", size_bytes=10 - r,
                timestamp=r * 3 + s,
                payload=uri.format(f"s{s:05d}", r, r + 1)))
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=1, policy="shard_affinity",
                         n_workers=2)

    def take(w):
        batch = core.next_batch(w)
        core.on_done(w, [t.task_id for t in batch])
        return batch

    # Chronological order interleaves shards; affinity must NOT.
    w0_first, w1_first = take("w0")[0], take("w1")[0]
    k0, k1 = locality_key(w0_first), locality_key(w1_first)
    assert k0 != k1
    # Each worker stays on its shard for the shard's remaining ranges.
    for _ in range(3):
        assert locality_key(take("w0")[0]) == k0
        assert locality_key(take("w1")[0]) == k1
    # Both drained their shards; the third shard goes to whoever asks,
    # and a worker with nothing else left may steal from a bound run
    # rather than starve — nobody blocks while work remains.
    while not core.done:
        assert take("w0") or take("w1"), "affinity starved a worker"
    assert core.completed == {t.task_id for t in tasks}


def test_shard_affinity_requeues_dead_workers_tasks_into_their_run():
    tasks = [Task(task_id=f"g{i % 2}/t{i:04d}", size_bytes=5,
                  timestamp=i) for i in range(8)]
    core = SchedulerCore(tasks, organization="chronological",
                         tasks_per_message=2, policy="shard_affinity",
                         n_workers=2)
    b0 = core.next_batch("w0")
    assert {locality_key(t) for t in b0} == {"g0"}
    core.mark_dead("w0")                    # re-queues b0 into run g0
    # A new worker binding to g0 sees the re-queued tasks first.
    b1 = core.next_batch("w1")
    assert [t.task_id for t in b1] == [t.task_id for t in b0]


def test_locality_key_forms():
    t_shard = Task(task_id="x", payload="store:///r#rows=0:2&shard=s01")
    assert locality_key(t_shard) == "/r#shard=s01"
    t_track = Task(task_id="x", payload="store:///r#track=a/b.csv")
    assert locality_key(t_track) == "/r"
    t_dir = Task(task_id="fleet07/a123.zip")
    assert locality_key(t_dir) == "fleet07"
    t_flat = Task(task_id="plain")
    assert locality_key(t_flat) == "plain"


def test_explicit_policy_instance_keeps_its_tuning():
    pol = AdaptiveChunkPolicy(alpha=4.0, cost_fn=default_task_cost,
                              n_workers=2)
    resolved = get_policy(pol, tasks_per_message=3, n_workers=8)
    assert resolved is pol
    assert resolved.alpha == 4.0
    assert resolved.n_workers == 2          # constructor wins
    assert resolved.tasks_per_message == 3  # unset -> filled from job


# ---------------------------------------------------------------------------
# Cost estimates.
# ---------------------------------------------------------------------------

def test_task_seconds_monotone_and_hint_aware():
    m = PROCESS_PHASE
    xs = [m.task_seconds(s, nppn=8) for s in (0, 10**6, 10**8, 10**9)]
    assert xs == sorted(xs)
    hinted = m.task_seconds(10**6, nppn=8, cpu_cost_hint=500.0)
    assert hinted > m.task_seconds(10**6, nppn=8)


def test_model_task_cost_matches_task_seconds():
    cost = model_task_cost(PROCESS_PHASE, nppn=8, nodes=4)
    t = Task(task_id="a", size_bytes=5 * 10**6, cpu_cost_hint=3.0)
    assert cost(t) == PROCESS_PHASE.task_seconds(
        5 * 10**6, nppn=8, cpu_cost_hint=3.0, nodes=4)


def test_row_range_bytes_prorates_from_index(tmp_path):
    from repro.store.format import ShardRecord, StoreManifest, TrackRecord

    tracks = [TrackRecord(track_id=f"tr{r}", shard_id="s0", row=r,
                          n_obs=obs, icao24="a", seg_knots=(obs,),
                          seg_grid=(obs,))
              for r, obs in enumerate((10, 30, 60))]
    man = StoreManifest(
        shards=[ShardRecord(shard_id="s0", filename="shards/s0.shard",
                            n_tracks=3, n_points=100, size_bytes=1000,
                            sha256="x")],
        tracks=tracks)
    assert man.row_range_bytes("s0") == 1000
    assert man.row_range_bytes("s0", 0, 1) == 100      # 10/100 points
    assert man.row_range_bytes("s0", 1, 3) == 900
    with pytest.raises(ValueError):
        man.row_range_bytes("s0", 2, 5)

    # The rows-granularity task builder sizes tasks from exactly this
    # estimate, without any shard payload on disk.
    from repro.tracks.segments import segment_tasks_from_store
    man.save(str(tmp_path))
    tasks = segment_tasks_from_store(str(tmp_path), granularity="rows",
                                     rows_per_task=2)
    assert [t.task_id for t in tasks] == ["store/s0/r00000",
                                          "store/s0/r00002"]
    assert [t.size_bytes for t in tasks] == [400, 600]
    assert all(t.payload.startswith("store://") and "rows=" in t.payload
               for t in tasks)


# ---------------------------------------------------------------------------
# Wait attribution: worker take_wait_s -> DONE -> RunResult breakdown.
# ---------------------------------------------------------------------------

class _WaitingWorker:
    """Worker fn reporting 10 ms of feed wait per task via take_wait_s."""

    def __init__(self):
        self._local = threading.local()

    def __call__(self, task):
        time.sleep(0.002)
        self._local.wait = getattr(self._local, "wait", 0.0) + 0.01
        return task.size_bytes

    def take_wait_s(self):
        w = getattr(self._local, "wait", 0.0)
        self._local.wait = 0.0
        return w


def test_wait_seconds_surface_in_runresult_breakdown():
    tasks = _tasks(12)
    r = run_job(tasks, _WaitingWorker(), backend="threads", n_workers=2,
                poll_interval=0.002)
    assert abs(sum(r.worker_wait) - 0.12) < 1e-6
    rec = r.to_record()
    assert rec["wait_total_s"] == pytest.approx(0.12)
    assert set(rec["worker_breakdown"]) == {"w0", "w1"}
    for row in rec["worker_breakdown"].values():
        assert set(row) == {"tasks", "busy_s", "idle_s", "wait_s"}
    assert sum(row["wait_s"] for row in
               rec["worker_breakdown"].values()) == pytest.approx(0.12)
    assert rec["worker_wait_quantiles_s"]["p100"] > 0


def test_sim_fills_wait_with_io_phase_seconds():
    tasks = _tasks(30, sizes=[10**7] * 30)
    r = run_job(tasks, None, backend="sim", n_workers=4)
    assert sum(r.worker_wait) > 0
    for s in r.worker_stats.values():
        assert s.wait_seconds <= s.busy_seconds + 1e-9


def test_to_record_caps_breakdown_for_big_fleets():
    tasks = _tasks(80)
    r = run_job(tasks, None, backend="sim", n_workers=65)
    bd = r.to_record()["worker_breakdown"]
    assert bd["_dropped_workers"] == 1
    assert len(bd) == 65          # 64 busiest rows + the dropped count
    # The cap keeps the busiest workers: every kept row out-ranks the
    # dropped one (ties broken by id, so equality is allowed).
    kept = {k for k in bd if not k.startswith("_")}
    dropped_busy = min(s.busy_seconds for s in r.worker_stats.values()
                       if str(s.worker_id) not in kept)
    assert all(bd[k]["busy_s"] >= dropped_busy for k in kept)
    r = run_job(tasks, None, backend="sim", n_workers=64)
    bd = r.to_record()["worker_breakdown"]
    assert "_dropped_workers" not in bd and len(bd) == 64
    # max_workers is a documented knob: None lifts the cap entirely.
    full = r.worker_breakdown(max_workers=None)
    assert len(full) == 64 and "_dropped_workers" not in full
    assert len(r.worker_breakdown(max_workers=8)) == 9


# ---------------------------------------------------------------------------
# Policies behave across live backends through run_job.
# ---------------------------------------------------------------------------

def test_cost_aware_dispatch_identical_across_backends_with_hints():
    """Regression: run_job must resolve ONE cost estimator for every
    backend.  Tasks whose cpu-hint order disagrees with their byte-size
    order previously made sized_lpt dispatch differently on sim (model
    cost) vs threads (hint-or-bytes fallback)."""
    tasks = [
        Task(task_id="A", size_bytes=500_000_000, cpu_cost_hint=0.1),
        Task(task_id="B", size_bytes=1_000, cpu_cost_hint=50.0),
        Task(task_id="C", size_bytes=2_000, cpu_cost_hint=20.0),
    ]
    logs = {}
    for backend in ("threads", "sim"):
        r = run_job(tasks, _pickle_fn, backend=backend, n_workers=1,
                    organization="chronological", policy="sized_lpt",
                    poll_interval=0.002)
        logs[backend] = r.batches
    assert logs["threads"] == logs["sim"]


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_run_job_threads_all_policies_complete(policy):
    tasks = [Task(task_id=f"g{i % 3}/t{i:04d}", size_bytes=(i * 13) % 7 + 1,
                  timestamp=i) for i in range(25)]
    r = run_job(tasks, _pickle_fn, backend="threads", n_workers=3,
                tasks_per_message=2, policy=policy, poll_interval=0.002)
    assert r.completed_ids == {t.task_id for t in tasks}
    assert len(r.results) == len(tasks)


def _pickle_fn(task):
    return task.size_bytes


def test_workflow_policy_flag_threads_through(tmp_path):
    """TrackWorkflow(policy=...) validates and reaches run_job."""
    from repro.tracks.workflow import TrackWorkflow

    with pytest.raises(ValueError, match="unknown scheduling policy"):
        TrackWorkflow(str(tmp_path), policy="wat")
    wf = TrackWorkflow(str(tmp_path), policy="sized_lpt", n_workers=2)
    assert wf.policy == "sized_lpt"
