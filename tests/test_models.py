"""Per-architecture smoke tests (REDUCED configs, CPU): one forward /
train step asserting output shapes + no NaNs — deliverable (f) — plus
decode/prefill consistency and MoE behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_arch
from repro.models import model as M

ARCH_NAMES = all_arch_names()


def _batch(cfg, B=2, T=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    if cfg.frontend is not None:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)), jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(name):
    """Instantiate the reduced config, run one forward + one train step;
    assert logits shape and finite loss/grads (no NaNs)."""
    cfg = get_arch(name, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    B, T = batch["labels"].shape

    logits = M.forward(cfg, params, batch, remat=False)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_decode_shapes(name):
    """One-token decode against a cache: shapes + finiteness."""
    cfg = get_arch(name, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    cache = M.init_cache(cfg, B, S)
    if cfg.frontend is not None:
        step = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
    else:
        step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, new_cache = M.decode_step(cfg, params, cache, step)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("name", ["stablelm-12b", "rwkv6-3b",
                                  "jamba-v0.1-52b", "minicpm-2b"])
def test_prefill_decode_matches_forward(name):
    """prefill(T) then decode(T+1) == forward(T+1)'s last logits."""
    import dataclasses
    cfg = get_arch(name, reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no MoE drops
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    B, T = 2, 32
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)),
                       jnp.int32)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :T]},
                         cache_len=T + 4)
    dec, _ = M.decode_step(cfg, params, cache,
                           {"tokens": toks[:, T:T + 1]})
    full = M.forward(cfg, params, {"tokens": toks}, remat=False)
    a = np.asarray(dec[:, 0])
    b = np.asarray(full[:, T])
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert rel < 0.05, rel


def test_remat_matches_no_remat():
    cfg = get_arch("stablelm-12b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    batch = _batch(cfg)
    l1 = M.loss_fn(cfg, params, batch, remat=True)
    l2 = M.loss_fn(cfg, params, batch, remat=False)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_moe_capacity_drops_tokens():
    """Tiny capacity forces drops; residual path keeps outputs finite
    and the layer becomes closer to identity."""
    import dataclasses
    cfg = get_arch("qwen3-moe-30b-a3b", reduced=True)
    lo = dataclasses.replace(cfg, capacity_factor=0.05)
    hi = dataclasses.replace(cfg, capacity_factor=8.0)
    plo = M.init_params(lo, jax.random.PRNGKey(4))
    batch = _batch(lo)
    out_lo = M.forward(lo, plo, batch, remat=False)
    out_hi = M.forward(hi, plo, batch, remat=False)
    assert bool(jnp.isfinite(out_lo).all())
    assert not np.allclose(np.asarray(out_lo), np.asarray(out_hi))


def test_squared_relu_and_ungated_mlp():
    cfg = get_arch("nemotron-4-340b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    # ungated: wi has singleton gate dim
    wi = jax.tree_util.tree_leaves(
        {"w": params["blocks"]["s0"]["ffn"]["wi"]})[0]
    assert wi.shape[2] == 1
    out = M.forward(cfg, params, _batch(cfg), remat=False)
    assert bool(jnp.isfinite(out).all())


def test_tied_embeddings_minicpm():
    cfg = get_arch("minicpm-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    assert "unembed" not in params
    out = M.forward(cfg, params, _batch(cfg), remat=False)
    assert out.shape[-1] == cfg.vocab_size


def test_param_count_matches_init():
    for name in ("stablelm-12b", "rwkv6-3b", "qwen3-moe-30b-a3b"):
        cfg = get_arch(name, reduced=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(actual / predicted - 1) < 0.12, \
            (name, actual, predicted)
