"""Discrete-event simulator invariants (hypothesis)."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.cost_model import PhaseCostModel
from repro.core.messages import Task
from repro.core.simulator import (
    merge_tasks_per_message, simulate_self_scheduling, simulate_static)

MODEL = PhaseCostModel(
    name="t", r_process=1e6, b_node=8e6, b_global=64e6,
    cpu_rate=50e6, contention_alpha=0.001, task_overhead_s=0.01,
    msg_overhead_s=0.001)


def _tasks(sizes):
    return [Task(task_id=f"t{i:04d}", size_bytes=s, timestamp=i)
            for i, s in enumerate(sizes)]


@st.composite
def size_lists(draw):
    n = draw(st.integers(1, 60))
    return draw(st.lists(st.integers(1, 50_000_000),
                         min_size=n, max_size=n))


@given(size_lists(), st.integers(1, 32),
       st.sampled_from(["largest_first", "chronological", "random"]))
@settings(max_examples=25, deadline=None)
def test_selfsched_completes_all_and_bounds(sizes, n_workers, org):
    tasks = _tasks(sizes)
    r = simulate_self_scheduling(
        tasks, n_workers=n_workers, nodes=max(n_workers // 8, 1), nppn=8,
        model=MODEL, organization=org)
    assert len(r.task_records) == len(tasks)
    assert len({t.task_id for t in r.task_records}) == len(tasks)
    # lower bounds: serial work / workers, and the single longest task
    durations = [rec.end_s - rec.start_s for rec in r.task_records]
    assert r.job_seconds >= max(durations) - 1e-6
    total_busy = sum(r.worker_busy)
    assert r.job_seconds >= total_busy / n_workers - 1e-6
    # conservation: busy time == sum of task durations
    assert abs(total_busy - sum(durations)) < 1e-3 * max(total_busy, 1)


@given(size_lists(), st.integers(1, 16),
       st.sampled_from(["block", "cyclic"]))
@settings(max_examples=25, deadline=None)
def test_static_completes_all(sizes, n_workers, policy):
    tasks = _tasks(sizes)
    r = simulate_static(tasks, n_workers=n_workers,
                        nodes=max(n_workers // 8, 1), nppn=8,
                        model=MODEL, policy=policy)
    assert len(r.task_records) == len(tasks)


@given(size_lists())
@settings(max_examples=20, deadline=None)
def test_more_workers_never_slower_much(sizes):
    """Self-scheduling with more workers shouldn't get meaningfully
    slower (shared-I/O saturation can flatten it, not invert it)."""
    tasks = _tasks(sizes)
    r8 = simulate_self_scheduling(tasks, n_workers=8, nodes=1, nppn=8,
                                  model=MODEL)
    r32 = simulate_self_scheduling(tasks, n_workers=32, nodes=4, nppn=8,
                                   model=MODEL)
    assert r32.job_seconds <= r8.job_seconds * 1.10


def test_worker_death_recovers_all_tasks():
    tasks = _tasks([10_000_000] * 40)
    r = simulate_self_scheduling(
        tasks, n_workers=8, nodes=1, nppn=8, model=MODEL,
        worker_death={0: 5.0, 3: 20.0}, failure_timeout=2.0)
    assert len(r.task_records) == 40
    assert set(r.dead_workers) == {0, 3}
    assert r.reassigned_tasks >= 1
    # dead workers processed nothing after death
    for rec in r.task_records:
        if rec.worker in (0, 3):
            assert rec.end_s <= {0: 5.0, 3: 20.0}[rec.worker] + 1e-6


def test_static_death_reassigns():
    tasks = _tasks([5_000_000] * 24)
    r = simulate_static(tasks, n_workers=6, nodes=1, nppn=8, model=MODEL,
                        policy="cyclic", worker_death={1: 1.0},
                        failure_timeout=2.0)
    assert len(r.task_records) == 24


def test_merge_tasks_per_message():
    tasks = _tasks(range(1, 301))
    merged = merge_tasks_per_message(tasks, 300)
    assert len(merged) == 1
    assert merged[0].size_bytes == sum(range(1, 301))
    merged2 = merge_tasks_per_message(tasks, 100)
    assert len(merged2) == 3


def test_speculative_execution_exactly_once_and_helps():
    """Backup tasks (beyond-paper): exactly-once results, and makespan
    improves when stragglers hold the last big tasks."""
    tasks = _tasks([20_000_000] * 30)
    speed = [1.0] * 8
    speed[0] = speed[1] = 0.1            # two 10x-slow workers
    plain = simulate_self_scheduling(
        tasks, n_workers=8, nodes=1, nppn=8, model=MODEL,
        organization="largest_first", worker_speed=speed)
    spec = simulate_self_scheduling(
        tasks, n_workers=8, nodes=1, nppn=8, model=MODEL,
        organization="largest_first", worker_speed=speed,
        speculative=True)
    for r in (plain, spec):
        ids = [t.task_id for t in r.task_records]
        assert len(ids) == len(set(ids)) == 30
    assert spec.job_seconds < plain.job_seconds


def test_worker_speed_slows_job():
    tasks = _tasks([5_000_000] * 16)
    fast = simulate_self_scheduling(tasks, n_workers=4, nodes=1, nppn=4,
                                    model=MODEL)
    slow = simulate_self_scheduling(tasks, n_workers=4, nodes=1, nppn=4,
                                    model=MODEL,
                                    worker_speed=[0.5] * 4)
    assert slow.job_seconds > fast.job_seconds * 1.5


def test_poll_interval_adds_latency():
    tasks = _tasks([1_000_000] * 4)
    fast = simulate_self_scheduling(tasks, n_workers=4, nodes=1, nppn=4,
                                    model=MODEL, poll_interval=0.01)
    slow = simulate_self_scheduling(tasks, n_workers=4, nodes=1, nppn=4,
                                    model=MODEL, poll_interval=5.0)
    assert slow.job_seconds > fast.job_seconds
