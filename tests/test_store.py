"""Columnar track store: codec round-trips, writer determinism, reader
prefetch, store-vs-zip golden equivalence, workflow integration."""

import json
import os
import zipfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.messages import Task
from repro.store import (
    ShardChecksumError, ShardFormatError, StoreManifest, TrackStore,
    build_store, codec, make_store_uri, parse_store_uri)
from repro.store.writer import discover_sources, plan_shards
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import ScaledDatasetSpec, write_scaled_dataset
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import synthetic_registry
from repro.tracks.segments import (
    SegmentProcessor, read_observations, segment_tasks_from_archive_tree,
    segment_tasks_from_store, split_segments)

PLANE_FIELDS = ("times", "lat", "lon", "alt_msl_m", "alt_agl_m",
                "vrate_ms", "gspeed_ms", "heading_rad", "turn_rad_s")

_DTYPES = ("<f8", "<f4", "<i8", "<i4", "<i2", "<u4", "<u2", "<u1")


# ---------------------------------------------------------------------------
# Codec: property tests.
# ---------------------------------------------------------------------------

def _column(dtype: str, seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if dtype.startswith("<f"):
        return (rng.standard_normal(n) * 1e4).astype(dtype)
    info = np.iinfo(np.dtype(dtype))
    return rng.integers(info.min, info.max, size=n,
                        endpoint=True).astype(dtype)


@settings(max_examples=10)
@given(st.lists(st.tuples(st.sampled_from(_DTYPES),
                          st.integers(min_value=0, max_value=2000),
                          st.integers(min_value=0, max_value=10 ** 6)),
                min_size=1, max_size=5),
       st.sampled_from(["zlib", "none"]))
def test_codec_roundtrip_bitwise(cols_spec, compression):
    """Arbitrary lengths/dtypes -> encode -> decode bitwise-equal."""
    columns = {f"c{i}": _column(dt, seed, n)
               for i, (dt, n, seed) in enumerate(cols_spec)}
    meta = {"n": len(columns)}
    data = codec.encode_shard(columns, meta=meta,
                              compression=compression)
    # canonical encoding: same inputs -> same bytes
    assert data == codec.encode_shard(columns, meta=meta,
                                      compression=compression)
    decoded, meta2 = codec.decode_shard(data)
    assert meta2 == meta
    assert set(decoded) == set(columns)
    for name, arr in columns.items():
        out = decoded[name]
        assert out.dtype == arr.dtype
        assert out.shape == arr.shape
        assert out.tobytes() == arr.tobytes()      # bitwise


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_codec_corruption_rejected(seed):
    """Any single flipped payload byte must be detected."""
    rng = np.random.default_rng(seed)
    data = bytearray(codec.encode_shard(
        {"x": rng.standard_normal(64), "y": rng.integers(0, 99, 32)}))
    pos = int(rng.integers(0, len(data)))
    data[pos] ^= 0xFF
    with pytest.raises(ShardFormatError):
        codec.decode_shard(bytes(data))


def test_codec_truncation_and_magic_rejected():
    data = codec.encode_shard({"x": np.arange(10.0)})
    with pytest.raises(ShardFormatError):
        codec.decode_shard(data[:-3])
    with pytest.raises(ShardFormatError):
        codec.decode_shard(b"NOTASTORE" + data[9:])
    with pytest.raises(ShardChecksumError):
        codec.decode_shard(data[:40] + b"\x00" + data[41:])


def test_codec_column_subset_skips_payload():
    cols = {"big": np.arange(5000.0), "small": np.arange(4)}
    data = codec.encode_shard(cols)
    out, _ = codec.decode_shard(data, columns=["small"])
    assert list(out) == ["small"]
    np.testing.assert_array_equal(out["small"], cols["small"])
    with pytest.raises(KeyError):
        codec.decode_shard(data, columns=["absent"])


# ---------------------------------------------------------------------------
# Store URIs.
# ---------------------------------------------------------------------------

def test_store_uri_roundtrip():
    uri = make_store_uri("/tmp/st", shard="s00001", rows="0:8")
    root, sel = parse_store_uri(uri)
    assert root == "/tmp/st"
    assert sel == {"shard": "s00001", "rows": "0:8"}
    root2, sel2 = parse_store_uri(make_store_uri("/tmp/st"))
    assert (root2, sel2) == ("/tmp/st", {})
    with pytest.raises(ValueError):
        parse_store_uri("file:///tmp/st")
    with pytest.raises(ValueError):
        parse_store_uri("store:///tmp/st#bogus=1")
    with pytest.raises(ValueError):
        parse_store_uri("store:///tmp/st#rows=0:4")   # rows needs shard


# ---------------------------------------------------------------------------
# Golden end-to-end fixture: raw -> organize -> archive -> store.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden(tmp_path_factory):
    root = tmp_path_factory.mktemp("store_golden")
    raw, org, arc = (str(root / d) for d in ("raw", "org", "arc"))
    write_scaled_dataset(raw, ScaledDatasetSpec(name="g", n_files=4,
                                                scale=1e4))
    reg = synthetic_registry(n=2000, seed=13)
    organizer = Organizer(org, reg)
    for t in organize_tasks_from_dir(raw):
        organizer(t)
    archiver = Archiver(org, arc)
    for t in archive_tasks_from_tree(org):
        archiver(t)
    store_root = str(root / "store")
    manifest = build_store(arc, store_root, target_points=2048)
    return {"arc": arc, "store": store_root, "manifest": manifest,
            "root": str(root)}


def test_store_build_deterministic(golden):
    """Same-seed builds are byte-identical: manifest AND shard files."""
    rebuild = os.path.join(golden["root"], "store_rebuild")
    m2 = build_store(golden["arc"], rebuild, target_points=2048)
    assert golden["manifest"].canonical_bytes() == m2.canonical_bytes()
    for s in golden["manifest"].shards:
        with open(os.path.join(golden["store"], s.filename), "rb") as a, \
                open(os.path.join(rebuild, s.filename), "rb") as b:
            assert a.read() == b.read()


def test_manifest_index_matches_payload(golden):
    """seg_knots/seg_grid in the index == what a live parse computes."""
    from repro.tracks.segments import segment_shape
    store = TrackStore(golden["store"])
    for rec in golden["manifest"].tracks:
        obs = read_observations(
            os.path.join(golden["arc"], rec.track_id))
        assert rec.n_obs == len(obs["time"])
        shapes = [segment_shape(obs["time"], s)
                  for s in split_segments(obs["time"])]
        assert rec.seg_knots == tuple(n for n, _ in shapes)
        assert rec.seg_grid == tuple(m for _, m in shapes)
    # and the store-read payload is bitwise what the zip parse yields
    for rec in golden["manifest"].tracks[:3]:
        zip_obs = read_observations(
            os.path.join(golden["arc"], rec.track_id))
        st_obs = store.read_track(rec.track_id)
        for col in ("time", "lat", "lon", "alt"):
            assert np.array_equal(zip_obs[col], st_obs[col])
        assert [str(x) for x in zip_obs["icao24"]] == \
            [str(x) for x in st_obs["icao24"]]


def test_bucket_histogram_from_index(golden):
    """Index-driven bucket binning == the fused batcher's own binning."""
    from repro.tracks.segments import bucket_width
    proc = SegmentProcessor()
    widths: dict[int, int] = {}
    for rec in golden["manifest"].tracks:
        obs = read_observations(
            os.path.join(golden["arc"], rec.track_id))
        for r in proc._records([(obs, split_segments(obs["time"]))]):
            widths[r.width] = widths.get(r.width, 0) + 1
    assert golden["manifest"].bucket_histogram() == widths
    # plan() exposes the same histogram per shard, no payload touched
    plans = TrackStore(golden["store"]).plan()
    merged: dict[int, int] = {}
    for p in plans:
        for w, c in p.bucket_histogram.items():
            merged[w] = merged.get(w, 0) + c
    assert merged == widths
    assert all(w == bucket_width(w) for w in merged)


def test_store_vs_zip_process_batch_bitwise(golden):
    """THE golden gate: store-backed process_batch == zip-backed,
    bitwise, on every output plane."""
    ztasks = segment_tasks_from_archive_tree(golden["arc"])
    ttasks = segment_tasks_from_store(golden["store"],
                                      granularity="track")
    assert [t.task_id.replace(os.sep, "/") for t in ztasks] == \
        [t.task_id for t in ttasks]
    proc = SegmentProcessor()
    bz = proc.process_batch(ztasks)
    bs = proc.process_batch(ttasks)
    assert len(bz) == len(bs) == len(ztasks)
    for t in ztasks:
        rz, rs = bz[t.task_id], bs[t.task_id.replace(os.sep, "/")]
        assert rz.icao24 == rs.icao24
        assert rz.airspace == rs.airspace
        np.testing.assert_array_equal(rz.count, rs.count)
        for f in PLANE_FIELDS:
            np.testing.assert_array_equal(getattr(rz, f),
                                          getattr(rs, f), err_msg=f)


def test_shard_tasks_and_process_store_agree(golden):
    """Shard-granularity tasks and the prefetching process_store loop
    produce the same per-track results as track-granularity tasks."""
    proc = SegmentProcessor()
    per_track = proc.process_batch(
        segment_tasks_from_store(golden["store"], granularity="track"))
    via_shards: dict = {}
    for res in proc.process_batch(
            segment_tasks_from_store(golden["store"],
                                     granularity="shard")).values():
        via_shards.update(res)
    via_stream = proc.process_store(golden["store"], prefetch=2)
    assert set(per_track) == set(via_shards) == set(via_stream)
    for tid in per_track:
        for other in (via_shards[tid], via_stream[tid]):
            np.testing.assert_array_equal(per_track[tid].count,
                                          other.count)
            for f in PLANE_FIELDS:
                np.testing.assert_array_equal(
                    getattr(per_track[tid], f), getattr(other, f),
                    err_msg=f)


def test_iter_batches_prefetch_equivalence(golden):
    """prefetch=0 and prefetch=2 stream identical content/order."""
    store = TrackStore(golden["store"])
    sync = list(store.iter_batches(prefetch=0))
    pre = list(store.iter_batches(prefetch=2))
    assert [b.shard_id for b in sync] == [b.shard_id for b in pre]
    for a, b in zip(sync, pre):
        assert a.track_ids == b.track_ids
        for (obs_a, segs_a), (obs_b, segs_b) in zip(a.items, b.items):
            assert segs_a == segs_b
            for col in ("time", "lat", "lon", "alt"):
                assert np.array_equal(obs_a[col], obs_b[col])


def test_row_range_selection(golden):
    store = TrackStore(golden["store"])
    sid = golden["manifest"].shards[0].shard_id
    all_rows = store.read_selection({"shard": sid})
    part = store.read_selection({"shard": sid, "rows": "1:3"})
    assert [tid for tid, _, _ in part] == \
        [tid for tid, _, _ in all_rows][1:3]
    with pytest.raises(ValueError):
        store.read_selection({"shard": sid, "rows": "0:9999"})
    with pytest.raises(KeyError):
        store.read_selection({"shard": "nope"})


def test_prefetch_error_reaches_slow_consumer(golden):
    """A decode error in the prefetch thread must surface even when the
    consumer holds the (size-1) queue full — the producer retries the
    terminal event instead of dropping it (deadlock bug).  The slow
    consumer is driven by the reader's prefetch hooks, not sleeps: the
    test only resumes draining once the producer has verifiably blocked
    trying to enqueue the error, so the retry path runs on every
    machine, deterministically."""
    import threading
    root = os.path.join(golden["root"], "store_pershard")
    build_store(golden["arc"], root, target_points=1)
    manifest = StoreManifest.load(root)
    assert len(manifest.shards) >= 3
    path = os.path.join(root, manifest.shards[2].filename)
    blob = bytearray(open(path, "rb").read())
    blob[-1] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    store = TrackStore(root)
    err_blocked = threading.Event()
    store.prefetch_hooks = {
        "blocked": lambda kind: (err_blocked.set() if kind == "err"
                                 else None)}
    got = []
    with pytest.raises(ShardFormatError):
        it = store.iter_batches(store.plan(), prefetch=1)
        # Shard 0 in hand, shard 1 filling the size-1 queue; the
        # producer hits the corrupt shard 2 and must now retry the
        # "err" event against the full queue.
        got.append(next(it).shard_id)
        assert err_blocked.wait(timeout=30.0), \
            "producer never blocked on the terminal error event"
        for batch in it:
            got.append(batch.shard_id)
    assert got == [s.shard_id for s in manifest.shards[:2]]


def test_live_iter_batches_invalidates_warm_prefetch_on_append(golden):
    """Regression: a warm prefetch must not pin a live iteration to a
    stale manifest.  Appending a shard (``commit_shard``) and
    ``reload()``-ing mid-iteration advances the generation; the live
    iterator must drop in-flight buffers decoded under the old
    generation, re-plan from the fresh index, and still yield every
    shard — the appended one included — exactly once."""
    import threading
    from repro.store.writer import ShardBuilder, commit_shard

    sources = discover_sources(golden["arc"])
    plans = plan_shards(sources, target_points=1)
    assert len(plans) >= 3
    root = os.path.join(golden["root"], "store_live")
    build = ShardBuilder(root)
    results = [build(Task(task_id=p.shard_id, payload=p.dumps()))
               for p in plans]
    for r in results[:-1]:
        commit_shard(root, r, target_points=1)
    store = TrackStore(root)
    gen0 = store.generation
    assert gen0 == len(plans) - 1
    queued_next = threading.Event()
    store.prefetch_hooks = {
        "queued": lambda kind, sid: (queued_next.set()
                                     if kind == "ok"
                                     and sid != plans[0].shard_id
                                     else None)}
    seen = []
    appended = False
    for batch in store.iter_batches(prefetch=1):
        seen.append(batch.shard_id)
        if not appended:
            # A warm buffer is verifiably in flight; now append.
            assert queued_next.wait(timeout=30.0)
            commit_shard(root, results[-1], target_points=1)
            assert store.reload()
            appended = True
    assert store.generation == gen0 + 1
    assert sorted(seen) == [p.shard_id for p in plans]
    assert len(seen) == len(set(seen))
    assert store.stats["stale_drops"] >= 1
    # Explicit plans stay pinned: appends never leak into them.
    store2 = TrackStore(root)
    pinned = [b.shard_id
              for b in store2.iter_batches(store2.plan()[:1], prefetch=1)]
    assert pinned == [plans[0].shard_id]


class _TickClock:
    """Fake monotonic clock: advances one unit per reading."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def test_reader_stats_use_injected_clock(golden):
    """Exact decode_s/wait_s attribution under a fake monotonic clock —
    the timing stats must flow through the injected clock only, so
    tests assert exact values instead of flaky wall-time ratios."""
    clock = _TickClock()
    store = TrackStore(golden["store"], clock=clock)
    n = len(list(store.iter_batches(prefetch=0)))
    assert n == len(golden["manifest"].shards) > 0
    # one clock-step per decode, no consumer blocking measured
    assert store.stats["decode_s"] == pytest.approx(float(n))
    assert store.stats["wait_s"] == 0.0
    # frozen clock: every timing stat stays exactly zero, prefetch too
    frozen = TrackStore(golden["store"], clock=lambda: 0.0)
    assert len(list(frozen.iter_batches(prefetch=2))) == n
    assert frozen.stats["decode_s"] == 0.0
    assert frozen.stats["wait_s"] == 0.0


def test_corrupted_shard_detected_through_reader(golden):
    """Bit rot in a shard file surfaces as ShardChecksumError, also
    through the prefetch thread."""
    import shutil
    broken_root = os.path.join(golden["root"], "store_broken")
    shutil.copytree(golden["store"], broken_root)
    manifest = StoreManifest.load(broken_root)
    path = os.path.join(broken_root, manifest.shards[0].filename)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    store = TrackStore(broken_root)
    with pytest.raises(ShardFormatError):
        list(store.iter_batches(prefetch=0))
    with pytest.raises(ShardFormatError):
        list(store.iter_batches(prefetch=2))


def test_plan_shards_respects_target_and_order(golden):
    sources = discover_sources(golden["arc"])
    assert sources == sorted(sources, key=lambda s: s[0])
    plans = plan_shards(sources, target_points=1)   # one track per shard
    assert len(plans) == len(sources)
    assert [p.shard_id for p in plans] == \
        [f"s{i:05d}" for i in range(len(plans))]
    one = plan_shards(sources, target_points=10 ** 12)
    assert len(one) == 1
    assert [t for t, _ in one[0].sources] == [s[0] for s in sources]


# ---------------------------------------------------------------------------
# Workflow integration: the store-build phase.
# ---------------------------------------------------------------------------

def test_workflow_store_build_phase(tmp_path):
    from repro.tracks.workflow import TrackWorkflow
    wf = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003,
                       input="store", store_target_points=2048,
                       tasks_per_message=2)
    wf.generate_raw(n_files=3, scale=2e4)
    reports = wf.run()
    assert [r.phase for r in reports] == \
        ["organize", "archive", "store-build", "process"]
    assert all(r.tasks > 0 for r in reports)
    manifest = StoreManifest.load(wf.store_dir)
    assert manifest.tracks and manifest.shards
    # resume skips every completed phase
    wf2 = TrackWorkflow(str(tmp_path), n_workers=2, input="store")
    assert wf2.run() == []


def test_workflow_store_build_resumes_past_checkpointed_shards(tmp_path):
    """Shard tasks completed before a mid-phase kill are excluded from
    re-dispatch by the restored manager; finalize must still index them
    (regression: KeyError on every pre-kill shard)."""
    from repro.runtime import ManagerCheckpoint
    from repro.store.writer import ShardBuilder
    from repro.tracks.workflow import TrackWorkflow

    wfz = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003)
    wfz.generate_raw(n_files=3, scale=2e4)
    wfz.run()                      # organize + archive + (zip) process
    sources = discover_sources(wfz.archive_dir)
    plans = plan_shards(sources, target_points=1)
    assert len(plans) >= 2
    # shard 0 "completed before the kill": file committed, records lost
    store_dir = str(tmp_path / "store")
    done_task = Task(task_id=f"store/{plans[0].shard_id}",
                     payload=plans[0].dumps())
    ShardBuilder(store_dir)(done_task)
    with open(wfz.ckpt_path) as f:
        state = json.load(f)
    state["manager_phase"] = "store-build"
    state["manager"] = ManagerCheckpoint({done_task.task_id}, []).dumps()
    with open(wfz.ckpt_path, "w") as f:
        json.dump(state, f)

    wfs = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003,
                        input="store", store_target_points=1)
    reports = wfs.run()
    assert [r.phase for r in reports] == ["store-build"]
    manifest = StoreManifest.load(store_dir)
    assert [s.shard_id for s in manifest.shards] == \
        [p.shard_id for p in plans]


def test_commit_shard_recommit_is_idempotent(golden):
    """A worker killed between the per-shard manifest append and the
    manager checkpoint save is re-dispatched the same shard task on
    resume: the second ``commit_shard`` of the same shard_id must not
    duplicate the manifest row, orphan a shard file, or change bytes."""
    from repro.store.writer import (
        ShardBuilder, commit_shard, finalize_manifest)

    sources = discover_sources(golden["arc"])
    plans = plan_shards(sources, target_points=1)
    assert len(plans) >= 2
    store_dir = os.path.join(golden["root"], "store_recommit")
    build = ShardBuilder(store_dir)
    results = [build(Task(task_id=f"store/{p.shard_id}",
                          payload=p.dumps())) for p in plans]
    for r in results:
        commit_shard(store_dir, r, target_points=1)
    first = StoreManifest.load(store_dir)
    shard0 = os.path.join(store_dir, first.shards[0].filename)
    blob0 = open(shard0, "rb").read()
    # the re-dispatched task rebuilds AND re-commits shard 0
    commit_shard(store_dir, build(
        Task(task_id=f"store/{plans[0].shard_id}",
             payload=plans[0].dumps())), target_points=1)
    again = StoreManifest.load(store_dir)
    assert [s.shard_id for s in again.shards] == \
        [p.shard_id for p in plans]                  # no duplicate row
    assert open(shard0, "rb").read() == blob0        # no byte churn
    on_disk = sorted(
        os.path.relpath(os.path.join(d, f), store_dir).replace(os.sep, "/")
        for d, _dirs, files in os.walk(store_dir) for f in files)
    assert on_disk == sorted(
        ["store_manifest.json"] + [s.filename for s in again.shards])
    manifest = finalize_manifest(store_dir, target_points=1)
    clean = build_store(golden["arc"],
                        os.path.join(golden["root"], "store_clean1"),
                        target_points=1)
    assert [s.to_doc() for s in manifest.shards] == \
        [s.to_doc() for s in clean.shards]
    assert [t.to_doc() for t in manifest.tracks] == \
        [t.to_doc() for t in clean.tracks]


def test_dag_store_build_recommits_unckpted_shard(tmp_path):
    """Workflow-level twin of the recommit test: a shard file + partial
    manifest row exist on disk but the (lost) checkpoint never recorded
    the task, so the streaming DAG re-runs it end to end.  The sealed
    store must equal a clean single-shot build — no duplicated or
    orphaned shard."""
    from repro.store.writer import ShardBuilder, commit_shard
    from repro.tracks.workflow import TrackWorkflow

    wfz = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003)
    wfz.generate_raw(n_files=3, scale=2e4)
    wfz.run()                      # organize + archive + (zip) process
    sources = discover_sources(wfz.archive_dir)
    plans = plan_shards(sources, target_points=1)
    assert len(plans) >= 2
    store_dir = str(tmp_path / "store")
    commit_shard(store_dir, ShardBuilder(store_dir)(
        Task(task_id=f"store/{plans[0].shard_id}",
             payload=plans[0].dumps())), target_points=1)

    wfd = TrackWorkflow(str(tmp_path), n_workers=2, poll_interval=0.003,
                        input="store", store_target_points=1, mode="dag")
    reports = wfd.run()
    assert [r.phase for r in reports] == ["dag"]
    manifest = StoreManifest.load(store_dir)
    assert manifest.meta.get("partial") is None      # sealed
    clean = build_store(wfz.archive_dir, str(tmp_path / "store_clean"),
                        target_points=1)
    assert [s.to_doc() for s in manifest.shards] == \
        [s.to_doc() for s in clean.shards]
    assert [t.to_doc() for t in manifest.tracks] == \
        [t.to_doc() for t in clean.tracks]
    for s in manifest.shards:
        with open(os.path.join(store_dir, s.filename), "rb") as a, \
                open(os.path.join(str(tmp_path / "store_clean"),
                                  s.filename), "rb") as b:
            assert a.read() == b.read()


# ---------------------------------------------------------------------------
# Archiver crash-safety (satellite).
# ---------------------------------------------------------------------------

def test_archiver_cleans_orphaned_tmp(tmp_path):
    src_root = tmp_path / "org" / "2019" / "L2J" / "150" / "b0" / "abc123"
    src_root.mkdir(parents=True)
    (src_root / "abc123.csv").write_text("time,icao24\n1,abc123\n")
    arc_root = str(tmp_path / "arc")
    arch = Archiver(str(tmp_path / "org"), arc_root)
    rel = "2019/L2J/150/b0/abc123"
    # a killed worker's leftovers, both legacy and pid-suffixed
    parent = os.path.join(arc_root, "2019", "L2J", "150", "b0")
    os.makedirs(parent, exist_ok=True)
    zip_path = os.path.join(parent, "abc123.zip")
    for stale in (zip_path + ".tmp", zip_path + ".tmp.99999"):
        with open(stale, "w") as f:
            f.write("garbage from a dead worker")
    res = arch.archive_dir(rel)
    assert res.files == 1
    leftovers = [n for n in os.listdir(parent) if ".tmp" in n]
    assert leftovers == []
    with zipfile.ZipFile(zip_path) as zf:      # committed zip is valid
        assert zf.namelist() == ["abc123.csv"]


# ---------------------------------------------------------------------------
# Token shards on store primitives (satellite).
# ---------------------------------------------------------------------------

def test_token_shards_are_store_shards(tmp_path):
    from repro.data.pipeline import (
        SelfScheduledLoader, synthetic_token_shards,
        token_shard_manifests)
    shards = synthetic_token_shards(str(tmp_path), n_shards=4,
                                    tokens_per_shard_mean=4096, seed=3)
    # one shard-manifest implementation: the on-disk index IS a store
    # manifest, and reopening it yields the same loader views
    reopened = token_shard_manifests(str(tmp_path))
    assert reopened == shards
    cols, meta = codec.read_shard(shards[0].path)
    assert meta["shard_id"] == shards[0].shard_id
    assert cols["tokens"].dtype == np.int32
    assert len(cols["tokens"]) == shards[0].n_tokens
    loader = SelfScheduledLoader(shards, batch_size=2, seq_len=32,
                                 n_ingest_workers=2, poll_interval=0.003)
    batch = next(iter(loader))
    assert batch["tokens"].shape == (2, 32)
    # corruption fails the ingest job loudly
    blob = bytearray(open(shards[1].path, "rb").read())
    blob[-1] ^= 0xFF
    with open(shards[1].path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(RuntimeError, match="failed"):
        SelfScheduledLoader(shards, batch_size=2, seq_len=32,
                            n_ingest_workers=2, poll_interval=0.003)
