"""run_job failure paths: partial results, dead fleets, re-queue accounting.

The campaign engine runs every scenario with ``raise_on_failure=False``
so one bad cell can't abort a whole campaign — these tests pin the
contract that makes that safe: failures are *recorded* (RunResult.failures)
rather than silently dropped, and re-queue accounting stays exact.
"""

import time

import pytest

from repro.core.cost_model import PhaseCostModel
from repro.core.messages import Task
from repro.runtime import run_job

FAST = dict(poll_interval=0.002)

SIM_MODEL = PhaseCostModel(
    name="t", r_process=1e6, b_node=8e6, b_global=64e6,
    cpu_rate=50e6, contention_alpha=0.001, task_overhead_s=0.01,
    msg_overhead_s=0.001)


def _tasks(n, size=10_000_000):
    return [Task(task_id=f"t{i:04d}", size_bytes=size, timestamp=i)
            for i in range(n)]


def _fail_odd(task):
    i = int(task.task_id[1:])
    if i % 2:
        raise ValueError(f"bad task {task.task_id}")
    return i


def _slow20(task):
    time.sleep(0.02)
    return 1


# -- task failures: recorded, not raised ----------------------------------


def test_threads_partial_results_when_not_raising():
    tasks = _tasks(20)
    r = run_job(tasks, _fail_odd, backend="threads", n_workers=3,
                raise_on_failure=False, **FAST)
    evens = {f"t{i:04d}" for i in range(0, 20, 2)}
    odds = {f"t{i:04d}" for i in range(1, 20, 2)}
    assert r.completed_ids == evens
    assert set(r.failures) == odds
    assert all("ValueError" in e for e in r.failures.values())
    assert set(r.results) == evens          # partial results delivered
    assert r.failed_workers == []           # workers stayed alive


def test_threads_task_failure_raises_by_default():
    with pytest.raises(RuntimeError, match="failed"):
        run_job(_tasks(10), _fail_odd, backend="threads", n_workers=2,
                **FAST)


def test_failures_surface_in_bench_record():
    r = run_job(_tasks(20), _fail_odd, backend="threads", n_workers=3,
                raise_on_failure=False, **FAST)
    rec = r.to_record()
    assert rec["n_task_failures"] == 10
    assert rec["tasks_completed"] == 10


# -- sim: all workers dead ------------------------------------------------


def test_sim_all_workers_dead_partial_when_not_raising():
    tasks = _tasks(40)
    r = run_job(tasks, backend="sim", n_workers=4, nodes=1, nppn=4,
                cost_model=SIM_MODEL,
                worker_death={i: 1.0 for i in range(4)},
                failure_timeout=2.0, raise_on_failure=False)
    assert r.dead_workers == [0, 1, 2, 3]
    assert len(r.completed_ids) < len(tasks)    # genuinely partial
    # Whatever completed before the die-off is still exactly-once.
    assert len(r.completed_ids) == len({rec.task_id
                                        for rec in r.task_records})


def test_sim_all_workers_dead_raises_by_default():
    with pytest.raises(RuntimeError, match="incomplete"):
        run_job(_tasks(40), backend="sim", n_workers=4, nodes=1, nppn=4,
                cost_model=SIM_MODEL,
                worker_death={i: 1.0 for i in range(4)},
                failure_timeout=2.0)


def test_sim_mass_death_still_completes_with_survivors():
    """20 % of the fleet dies mid-job: every task still completes
    exactly once (regression test for the double-assign re-dispatch bug
    the campaign engine exposed)."""
    tasks = _tasks(120, size=5_000_000)
    deaths = {i: 2.0 + 0.25 * i for i in range(10)}   # 10 of 16 over time
    r = run_job(tasks, backend="sim", n_workers=16, nodes=2, nppn=8,
                cost_model=SIM_MODEL, worker_death=deaths,
                failure_timeout=1.0)
    assert r.completed_ids == {t.task_id for t in tasks}
    assert len({rec.task_id for rec in r.task_records}) == 120
    assert r.reassigned_tasks >= 1
    assert r.dead_workers == sorted(deaths)


# -- process backend: re-queue accounting ---------------------------------


def test_process_worker_fail_after_requeue_accounting():
    tasks = _tasks(30)
    r = run_job(tasks, _slow20, backend="processes", n_workers=3,
                tasks_per_message=4, failure_timeout=1.0,
                worker_fail_after={"w0": 2}, **FAST)
    assert r.completed_ids == {t.task_id for t in tasks}
    assert r.failed_workers == ["w0"]
    # w0 died mid-ASSIGN: everything in flight to it (at most one
    # 4-task message here) was re-queued, and nothing else was.
    assert 1 <= r.reassigned_tasks <= 4
    assert r.failures == {}           # a dead worker is not a failed task
    # Exactly-once across the re-queue: one result per task.
    assert len(r.results) == 30
