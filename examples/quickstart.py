"""Quickstart: the paper's technique in 60 lines.

1. Build a triples-mode resource request (nodes x NPPN x threads) and
   validate it under LLSC exclusive-mode rules.
2. Run a real self-scheduled job through the unified runtime
   (``run_job``) on the threads AND processes backends — same protocol
   core, interchangeable execution.
3. Simulate the same job at full 2048-core scale and compare orderings —
   the paper's Table II experiment in miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import (
    ORGANIZE_PHASE, Task, TriplesConfig, simulate_self_scheduling)
from repro.runtime import run_job
from repro.tracks.datasets import monday_manifest

def process(task: Task) -> int:
    time.sleep(task.size_bytes * 2e-5)          # pretend to parse a file
    return task.size_bytes


def main() -> None:
    # -- 1. triples-mode request (paper §II.C) -----------------------------
    triple = TriplesConfig(nodes=64, nppn=32, threads_per_process=1,
                           slots_per_process=2)
    print(f"triples request: {triple.nodes} nodes x NPPN={triple.nppn} "
          f"-> {triple.total_processes} processes, "
          f"{triple.allocated_cores} cores charged (exclusive mode), "
          f"{triple.gb_per_process:.0f} GB/process")

    # -- 2. real self-scheduled job (paper §II.D) --------------------------
    tasks = [Task(task_id=f"file{i:03d}", size_bytes=(i * 131) % 977 + 23,
                  timestamp=i) for i in range(64)]
    for backend in ("threads", "processes"):
        result = run_job(tasks, process, backend=backend, n_workers=8,
                         organization="largest_first", poll_interval=0.005)
        print(f"real run [{backend:9s}]: {len(result.results)} tasks on "
              f"8 workers in {result.job_seconds:.2f}s, "
              f"{result.messages_sent} messages")

    # -- 3. full-scale simulation (paper Table II) -------------------------
    manifest = monday_manifest()          # 2425 files, 714 GB (synthetic)
    for org in ("chronological", "largest_first"):
        sim = simulate_self_scheduling(
            manifest, n_workers=2047, nodes=64, nppn=32,
            model=ORGANIZE_PHASE, organization=org)
        print(f"simulated 2048-core organize, {org:14s}: "
              f"{sim.job_seconds:,.0f} s")
    print("=> largest-first wins, as in the paper's Tables I/II")


# The __main__ guard matters: the processes backend may use the spawn
# start method, which re-imports this module in every worker.
if __name__ == "__main__":
    main()
