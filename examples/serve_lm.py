"""Serve a small model with batched requests (continuous batching).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-moe-30b-a3b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import model as M
from repro.serving.server import BatchedServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)   # CPU-sized config
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=args.slots,
                           prompt_len=32, cache_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 30))),
                    max_new_tokens=int(rng.integers(4, args.max_new)))
            for i in range(args.requests)]
    t0 = time.time()
    server.serve(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.tokens_out) for r in reqs)
    print(f"{cfg.name}: {len(reqs)} requests / {tokens} tokens in "
          f"{dt:.2f}s — {tokens/dt:.1f} tok/s, {server.steps} engine "
          f"steps, {args.slots} slots (continuous batching)")
    for r in reqs[:3]:
        print(f"  req{r.request_id} ({len(r.prompt)} prompt toks) -> "
              f"{r.tokens_out}")


if __name__ == "__main__":
    main()
