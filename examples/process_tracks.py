"""End-to-end aircraft-track processing (the paper's workflow, §III.A).

Generates a scaled-down synthetic OpenSky-like dataset, then runs the
three phases — organize -> archive -> process/interpolate — under the
self-scheduling manager, with the Pallas kernels (interpret mode on CPU)
doing the interpolation / AGL / dynamic-rates math. Also generates the
aerodrome bounding-box queries (§III.B).

Run:  PYTHONPATH=src python examples/process_tracks.py [workdir]
"""

import sys
import tempfile

from repro.geometry import generate_queries, make_bounding_boxes
from repro.tracks.workflow import TrackWorkflow


def main() -> None:
    workdir = sys.argv[1] if len(sys.argv) > 1 else \
        tempfile.mkdtemp(prefix="repro_tracks_")
    print(f"workdir: {workdir}")

    # Aerodrome query generation (dataset #2's front half).
    boxes = make_bounding_boxes()
    queries = generate_queries(boxes, n_days=14)
    print(f"aerodrome queries: {len(boxes)} boxes (paper: 695) "
          f"-> {len(queries)} queries over 14 days")

    # The three-phase workflow at 1/10,000 scale.
    wf = TrackWorkflow(workdir, n_workers=6, poll_interval=0.005)
    n = wf.generate_raw(n_files=10, scale=2e4)
    print(f"raw: {n} hourly files")
    for report in wf.run():
        print(f"  {report.phase:9s}: {report.tasks:4d} tasks, "
              f"{report.workers} workers, {report.job_seconds:6.2f}s, "
              f"{report.messages} messages")
    print("done — organized/, archived/ and processed segments produced")


if __name__ == "__main__":
    main()
