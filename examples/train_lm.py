"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production path — self-scheduled shard ingestion, jitted
train_step with sharding rules, WSD schedule, async checkpoints — on a
CPU-sized model (stablelm-12b family scaled to ~100M params).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import SelfScheduledLoader, synthetic_token_shards
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def hundred_m_config():
    """stablelm family at ~100M params."""
    base = get_arch("stablelm-12b")
    return dataclasses.replace(
        base, name="stablelm-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32768)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.0f}M params")
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_100m_")

    shards = synthetic_token_shards(
        f"{workdir}/shards", n_shards=16, vocab_size=cfg.vocab_size,
        tokens_per_shard_mean=args.batch_size * (args.seq_len + 1) * 24)
    loader = SelfScheduledLoader(shards, batch_size=args.batch_size,
                                 seq_len=args.seq_len,
                                 poll_interval=0.003)
    print(f"ingest: {len(loader.job_result.results)} shards "
          f"(largest-first self-scheduling, "
          f"{loader.job_result.messages_sent} messages)")

    tcfg = TrainerConfig(workdir=workdir, total_steps=args.steps,
                         ckpt_every=100, log_every=25,
                         schedule="wsd", peak_lr=6e-4, warmup_steps=20)
    # WSD needs its own kwargs — rebuild the schedule explicitly.
    from repro.train.schedules import get_schedule
    trainer = Trainer(cfg, OptimizerConfig(weight_decay=0.05), tcfg)
    trainer.schedule = get_schedule(
        "wsd", peak=6e-4, warmup_steps=20,
        stable_steps=int(args.steps * 0.7),
        decay_steps=int(args.steps * 0.25))
    trainer._build(restore=True)

    log = trainer.run(loader.batches(args.steps), args.steps)
    trainer.close()
    first = np.mean([r["loss"] for r in log[:10]])
    last = np.mean([r["loss"] for r in log[-10:]])
    tput = args.batch_size * args.seq_len / np.median(
        [r["sec"] for r in log[5:]])
    print(f"loss {first:.3f} -> {last:.3f} over {len(log)} steps "
          f"({tput:,.0f} tok/s on CPU); checkpoints in {workdir}/ckpt")


if __name__ == "__main__":
    main()
