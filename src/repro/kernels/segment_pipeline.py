"""Fused, device-resident segment pipeline (one jit, zero host hops).

The per-task hot path of the track workflow used to be three separate
kernel launches with host numpy between them::

    track_interp -> np.asarray -> fi/fj index math (host) -> agl_lookup
                 -> np.asarray -> stack (host) -> dynamic_rates -> host

Every arrow is a host<->device transfer and a sync point.  This module
composes the three Pallas kernels plus the DEM fractional-index math and
the padding masks under ONE ``jax.jit``: inputs go up once, the nine
output planes come down once, and every intermediate (the resampled
grid, fi/fj, tile origins, rate stack) stays on device.

AGL tile fallback: tracks that span more than one DEM tile cannot use
the single-tile Pallas kernel.  The unfused path detects this from the
interpolated indices on the host (a forced device->host sync); here the
caller proves the single-tile property BEFORE launching — the interp
output is a convex combination of the raw knots, so knot extents bound
it — and tile-crossing buckets compile the oracle gather variant
(``agl_oracle=True``) while everything else compiles gather-free.  No
sync, no runtime branch, and the per-variant graphs stay bit-identical
to the standalone kernels (a runtime ``lax.cond``/``where`` mix would
let XLA contract the two sides differently at ulp level).

Ragged batching: callers bin segments into power-of-two width buckets
(:data:`repro.tracks.segments.BUCKET_SIZES`) and invoke this pipeline
once per bucket shape; jit caches one compilation per shape.  Widths
must be multiples of 128 (TPU lane width) — the wrapper pads if not.
The bucket shapes need not come from payload data at all: the columnar
track store (:mod:`repro.store`) records every segment's
(``seg_knots``, ``seg_grid``) pair in its manifest at ingest, via the
same :func:`repro.tracks.segments.segment_shape` helper the live
batcher uses, so ``StoreManifest.bucket_histogram`` /
``TrackStore.plan`` hand this pipeline its bucket plan from the index
while the shard payloads are still compressed on disk (and the store's
prefetcher decodes shard N+1 while this pipeline runs shard N).

On TPU the input buffers are donated (they are packing scratch, never
reused), letting XLA reuse them for intermediates; donation is skipped
on CPU where it is unsupported and only warns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.agl_lookup import TILE_H, TILE_W, agl_lookup_pallas
from repro.kernels.dynamic_rates import dynamic_rates_pallas
from repro.kernels.track_interp import track_interp_pallas

#: Output planes of the fused pipeline, in order.
FIELDS = ("times", "lat", "lon", "alt_msl", "alt_agl",
          "vrate", "gspeed", "heading", "turn")

_LANE = 128     # TPU lane width; all batched track axes pad to this


def _next_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pipeline(dem, t_in, v_in, count_in, t_out, count_out,
              *, grid: tuple, dt: float, interpret: bool,
              use_pallas: bool, agl_oracle: bool):
    """Traced body: interp -> fi/fj -> AGL -> rates -> masks, on device."""
    lat_min, lat_max, lon_min, lon_max, cells_per_deg = grid
    B, K = t_out.shape
    H, W = dem.shape

    # 1. Resample onto the uniform grid (MXU masked-matmul kernel).
    if use_pallas:
        block_m = min(256, K)
        interp = track_interp_pallas(t_in, v_in, count_in, t_out,
                                     block_m=block_m, interpret=interpret)
    else:
        interp = ref.track_interp_ref(t_in, v_in, count_in, t_out)
    # Stage-boundary barrier: the unfused path materializes the interp
    # result on the host before the AGL/rates stages consume it, so its
    # f32 roundings are those of the standalone ops.  Without the
    # barrier XLA may contract interp's epilogue into downstream FMAs
    # and drift the fused outputs an ulp off the unfused golden path.
    # (On TPU the stage is a pallas_call boundary anyway; this costs
    # nothing material and buys bit-stable fused==unfused numerics.)
    interp = jax.lax.optimization_barrier(interp)
    lat = interp[..., 0]
    lon = interp[..., 1]
    alt = interp[..., 2]

    # 2. DEM fractional indices from the affine grid — previously host
    #    numpy between two kernel launches; now VPU elementwise.  The
    #    optimization barrier pins the rounding at this former stage
    #    boundary: without it XLA may fuse the affine math into the AGL
    #    kernel's tile-local index FMAs and drift an ulp off the
    #    unfused path (amplified by the local terrain gradient).
    fi = (jnp.clip(lat, lat_min, lat_max) - lat_min) * cells_per_deg
    fj = (jnp.clip(lon, lon_min, lon_max) - lon_min) * cells_per_deg
    fi = jnp.clip(fi, 0.0, H - 1.001)
    fj = jnp.clip(fj, 0.0, W - 1.001)
    fi, fj = jax.lax.optimization_barrier((fi, fj))

    # 3. AGL = MSL - bilinear DEM elevation.  ``agl_oracle`` is decided
    #    STATICALLY by the caller (from raw knot extents — interp
    #    output is a convex combination of knots): a bucket proven to
    #    stay inside one DEM tile compiles the single-tile Pallas
    #    kernel and no gather at all; a bucket that may cross a tile
    #    border compiles the oracle gather for all of its rows.  A
    #    runtime per-row select (`lax.cond`/`where` mixing the two) is
    #    deliberately avoided: XLA contracts the mixed graphs
    #    differently and the selected values drift an ulp off the
    #    standalone kernels, breaking fused==unfused bit-equality.
    if use_pallas and not agl_oracle:
        dem_p = jnp.pad(dem, ((0, _next_mult(H, TILE_H) - H),
                              (0, _next_mult(W, TILE_W) - W)))
        oi = jnp.floor(jnp.min(fi, axis=1) / TILE_H).astype(jnp.int32)
        oj = jnp.floor(jnp.min(fj, axis=1) / TILE_W).astype(jnp.int32)
        oi = jnp.minimum(oi, dem_p.shape[0] // TILE_H - 1)
        oj = jnp.minimum(oj, dem_p.shape[1] // TILE_W - 1)
        agl = agl_lookup_pallas(dem_p, fi, fj, alt, oi, oj,
                                interpret=interpret)
    else:
        agl = ref.agl_lookup_ref(dem, fi, fj, alt)

    # 4. Dynamic rates over the resampled grid (VPU stencil kernel).
    v_grid = jnp.moveaxis(interp, 2, 1)                      # (B, 3, K)
    if use_pallas:
        rates = dynamic_rates_pallas(v_grid, count_out, dt,
                                     interpret=interpret)
    else:
        rates = ref.dynamic_rates_ref(v_grid, count_out, dt)

    # 5. Padding masks, still on device.
    mask = (jax.lax.broadcasted_iota(jnp.int32, (B, K), 1)
            < count_out[:, None]).astype(jnp.float32)
    return {
        "times": t_out * mask,
        "lat": lat * mask, "lon": lon * mask,
        "alt_msl": alt * mask, "alt_agl": agl * mask,
        "vrate": rates[:, 0] * mask, "gspeed": rates[:, 1] * mask,
        "heading": rates[:, 2] * mask, "turn": rates[:, 3] * mask,
    }


@functools.lru_cache(maxsize=None)
def _jitted(grid: tuple, dt: float, interpret: bool, use_pallas: bool,
            agl_oracle: bool, donate: bool):
    # ``grid`` is static (one DEM per processor): five fewer traced
    # scalars to ship per dispatch.
    fn = functools.partial(_pipeline, grid=grid, dt=dt,
                           interpret=interpret, use_pallas=use_pallas,
                           agl_oracle=agl_oracle)
    if donate:
        # t_in / v_in / t_out are packing scratch — donate on TPU.
        return jax.jit(fn, donate_argnums=(1, 2, 4))
    return jax.jit(fn)


def _pad_tracks(t_in, v_in, t_out):
    """Pad the track axes to the 128-lane multiple the kernels need.

    Knot padding is FINITE and increasing (last time + 1, 2, ...) so the
    masked interp weights are exactly zero (inf padding would produce
    0 * inf = nan inside the MXU mask product); values hold the last
    knot.  Query padding holds the last query (constant extrapolation,
    masked out afterwards).
    """
    N = t_in.shape[1]
    K = t_out.shape[1]
    Np, Kp = _next_mult(N, _LANE), _next_mult(K, _LANE)
    if Np != N:
        step = np.arange(1, Np - N + 1, dtype=np.float32)
        t_in = jnp.concatenate(
            [t_in, t_in[:, -1:] + step[None, :]], axis=1)
        v_in = jnp.concatenate(
            [v_in, jnp.broadcast_to(v_in[:, :, -1:],
                                    v_in.shape[:2] + (Np - N,))], axis=2)
    if Kp != K:
        t_out = jnp.concatenate(
            [t_out, jnp.broadcast_to(t_out[:, -1:],
                                     (t_out.shape[0], Kp - K))], axis=1)
    return t_in, v_in, t_out, K


def process_segments(dem, t_in, v_in, count_in, t_out, count_out, *,
                     grid, dt: float = 1.0, use_pallas: bool = True,
                     agl_oracle: bool = False,
                     interpret: bool = True, donate: bool = False):
    """Run the fused pipeline on one (B, K) bucket of segments.

    Args:
      dem: (H, W) f32 elevation grid (un-padded; padded inside the jit).
      t_in, v_in, count_in: (B, N), (B, 3, N) lat/lon/alt knots, (B,).
      t_out, count_out: (B, K) query grid + (B,) valid lengths.
      grid: (lat_min, lat_max, lon_min, lon_max, cells_per_deg) — the
        DEM affine transform, traced as scalars (no retrace per value).
      dt: uniform grid spacing (static).
      use_pallas: False composes the pure-jnp oracles instead (the
        correctness reference for tests).
      agl_oracle: True runs the oracle AGL gather for every row (the
        variant for tracks that may cross a DEM tile border — always
        correct, TPU-slow); False (default) runs the single-tile Pallas
        kernel, which clamps tile-crossing tracks to the tile border —
        callers must prove their tracks fit (segments.py proves it from
        raw knot extents).
      interpret: run Pallas in interpret mode (CPU).
      donate: donate the packing buffers (TPU only; CPU warns).

    Returns:
      dict of (B, K) f32 planes keyed by :data:`FIELDS`, all masked to
      ``count_out`` (device arrays; fetch with one ``jax.device_get``).
    """
    t_in = jnp.asarray(t_in, jnp.float32)
    v_in = jnp.asarray(v_in, jnp.float32)
    t_out = jnp.asarray(t_out, jnp.float32)
    t_in, v_in, t_out, K = _pad_tracks(t_in, v_in, t_out)
    fn = _jitted(tuple(float(g) for g in grid), float(dt),
                 bool(interpret), bool(use_pallas), bool(agl_oracle),
                 bool(donate))
    out = fn(jnp.asarray(dem, jnp.float32), t_in, v_in,
             jnp.asarray(count_in, jnp.int32), t_out,
             jnp.asarray(count_out, jnp.int32))
    if out["times"].shape[1] != K:
        out = {k: v[:, :K] for k, v in out.items()}
    return out
