"""Public jit'd wrappers for the track-processing kernels.

Each op pads inputs to kernel-friendly shapes, dispatches to the Pallas
kernel (interpret mode on CPU, compiled on TPU) or to the pure-jnp oracle
(``backend='ref'``), and unpads the result. The segments pipeline and the
benchmarks call these, never the kernels directly.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.agl_lookup import TILE_H, TILE_W, agl_lookup_pallas
from repro.kernels.dynamic_rates import dynamic_rates_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.track_interp import track_interp_pallas

Backend = Literal["pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int,
            value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def track_interp(t_in, v_in, count, t_out, *,
                 backend: Backend = "pallas", block_m: int = 256):
    """(B,N),(B,C,N),(B,),(B,M) -> (B,M,C). See ref.track_interp_ref."""
    if backend == "ref":
        return ref.track_interp_ref(t_in, v_in, count, t_out)
    M = t_out.shape[1]
    block_m = min(block_m, _next_mult(M, 128))
    t_out_p = _pad_to(jnp.asarray(t_out), 1, block_m)
    # Pad knot axis to 128 lanes with +inf times so padding never brackets.
    t_in_p = _pad_to(jnp.asarray(t_in, jnp.float32), 1, 128, value=np.inf)
    v_in_p = _pad_to(jnp.asarray(v_in, jnp.float32), 2, 128)
    out = track_interp_pallas(t_in_p, v_in_p, count, t_out_p,
                              block_m=block_m, interpret=not _on_tpu())
    return out[:, :M, :]


def dynamic_rates(v, count, dt, *, backend: Backend = "pallas"):
    """(B,3,M),(B,) -> (B,4,M). See ref.dynamic_rates_ref."""
    if backend == "ref":
        return ref.dynamic_rates_ref(v, count, dt)
    M = v.shape[2]
    v_p = _pad_to(jnp.asarray(v, jnp.float32), 2, 128)
    out = dynamic_rates_pallas(v_p, count, float(dt),
                               interpret=not _on_tpu())
    return out[:, :, :M]


def agl_lookup(dem, fi, fj, alt_msl, *, backend: Backend = "pallas"):
    """(H,W),(B,M),(B,M),(B,M) -> (B,M) AGL. See ref.agl_lookup_ref.

    Computes per-track tile origins on the host side; tracks that span
    more than one DEM tile fall back to the oracle (rare wide-area
    tracks — the paper's §V 'hundreds of nautical miles' case).
    """
    if backend == "ref":
        return ref.agl_lookup_ref(dem, fi, fj, alt_msl)
    dem = jnp.asarray(dem, jnp.float32)
    fi = jnp.asarray(fi, jnp.float32)
    fj = jnp.asarray(fj, jnp.float32)
    H, W = dem.shape
    fi_c = jnp.clip(fi, 0.0, H - 1.001)
    fj_c = jnp.clip(fj, 0.0, W - 1.001)
    # Host-side (concrete) origin/extent check.
    fi_np, fj_np = np.asarray(fi_c), np.asarray(fj_c)
    oi = (fi_np.min(axis=1) // TILE_H).astype(np.int32)
    oj = (fj_np.min(axis=1) // TILE_W).astype(np.int32)
    spans_i = (fi_np.max(axis=1) - oi * TILE_H) >= TILE_H - 1
    spans_j = (fj_np.max(axis=1) - oj * TILE_W) >= TILE_W - 1
    if bool(spans_i.any() or spans_j.any()):
        return ref.agl_lookup_ref(dem, fi, fj, alt_msl)
    dem_p = _pad_to(_pad_to(dem, 0, TILE_H), 1, TILE_W)
    # Keep origins inside the padded grid.
    oi = np.minimum(oi, dem_p.shape[0] // TILE_H - 1)
    oj = np.minimum(oj, dem_p.shape[1] // TILE_W - 1)
    M = fi.shape[1]
    fi_p = _pad_to(fi_c, 1, 128)
    fj_p = _pad_to(fj_c, 1, 128)
    alt_p = _pad_to(jnp.asarray(alt_msl, jnp.float32), 1, 128)
    out = agl_lookup_pallas(dem_p, fi_p, fj_p, alt_p,
                            jnp.asarray(oi), jnp.asarray(oj),
                            interpret=not _on_tpu())
    return out[:, :M]


def flash_attention(q, k, v, *, causal: bool = True,
                    backend: Backend = "pallas",
                    block_q: int = 128, block_k: int = 128):
    """Blocked online-softmax attention (GQA): q (B,H,T,hd),
    k/v (B,KV,S,hd) -> (B,H,T,hd). Pads T/S to block multiples.

    This is the real-TPU attention path (attention_impl='flash' on
    ArchConfig); the dry-run keeps stock-XLA attention so cost_analysis
    stays faithful (DESIGN.md §3)."""
    if backend == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    B, H, T, hd = q.shape
    S = k.shape[2]
    bq = min(block_q, _next_mult(T, 128))
    bk = min(block_k, _next_mult(S, 128))
    q_p = _pad_to(jnp.asarray(q), 2, bq)
    k_p = _pad_to(jnp.asarray(k), 2, bk)
    v_p = _pad_to(jnp.asarray(v), 2, bk)
    out = flash_attention_pallas(q_p, k_p, v_p, causal=causal,
                                 block_q=bq, block_k=bk,
                                 q_len=T, kv_len=S,
                                 interpret=not _on_tpu())
    return out[:, :, :T]


def _next_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
