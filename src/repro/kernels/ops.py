"""Public jit'd wrappers for the track-processing kernels.

Each op pads inputs to kernel-friendly shapes, dispatches to the Pallas
kernel (interpret mode on CPU, compiled on TPU) or to the pure-jnp oracle
(``backend='ref'``), and unpads the result. The segments pipeline and the
benchmarks call these, never the kernels directly.
"""

from __future__ import annotations

import functools
import threading
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref, segment_pipeline
from repro.kernels.agl_lookup import TILE_H, TILE_W, agl_lookup_pallas
from repro.kernels.dynamic_rates import dynamic_rates_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.track_interp import track_interp_pallas

Backend = Literal["pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Pipeline instrumentation (read by benchmarks/kernel_bench.py).
# ---------------------------------------------------------------------------

_STATS_LOCK = threading.Lock()
_STATS = {"intermediate_transfers": 0, "compile_hits": 0,
          "compile_misses": 0}
_SEEN_FUSED_SHAPES: set = set()


def reset_pipeline_stats(forget_shapes: bool = True) -> None:
    """Zero the transfer/compile counters.  ``forget_shapes=False``
    keeps the seen-shape set so already-compiled bucket shapes keep
    counting as cache hits (steady-state measurement)."""
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0
        if forget_shapes:
            _SEEN_FUSED_SHAPES.clear()


def get_pipeline_stats() -> dict:
    with _STATS_LOCK:
        return dict(_STATS)


def note_intermediate_transfer(n: int = 1) -> None:
    """Record a mid-pipeline host<->device hop (unfused path only)."""
    with _STATS_LOCK:
        _STATS["intermediate_transfers"] += n


def _pad_to(x: jax.Array, axis: int, multiple: int,
            value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def track_interp(t_in, v_in, count, t_out, *,
                 backend: Backend = "pallas", block_m: int = 256):
    """(B,N),(B,C,N),(B,),(B,M) -> (B,M,C). See ref.track_interp_ref."""
    if backend == "ref":
        return ref.track_interp_ref(t_in, v_in, count, t_out)
    M = t_out.shape[1]
    block_m = min(block_m, _next_mult(M, 128))
    t_out_p = _pad_to(jnp.asarray(t_out), 1, block_m)
    # Pad knot axis to 128 lanes with +inf times so padding never brackets.
    t_in_p = _pad_to(jnp.asarray(t_in, jnp.float32), 1, 128, value=np.inf)
    v_in_p = _pad_to(jnp.asarray(v_in, jnp.float32), 2, 128)
    out = track_interp_pallas(t_in_p, v_in_p, count, t_out_p,
                              block_m=block_m, interpret=not _on_tpu())
    return out[:, :M, :]


def dynamic_rates(v, count, dt, *, backend: Backend = "pallas"):
    """(B,3,M),(B,) -> (B,4,M). See ref.dynamic_rates_ref."""
    if backend == "ref":
        return ref.dynamic_rates_ref(v, count, dt)
    M = v.shape[2]
    v_p = _pad_to(jnp.asarray(v, jnp.float32), 2, 128)
    out = dynamic_rates_pallas(v_p, count, float(dt),
                               interpret=not _on_tpu())
    return out[:, :, :M]


# The spanning-row oracle fallback runs jitted so its f32 rounding
# matches the fused pipeline (which evaluates the same oracle under
# jit); eager op-by-op evaluation can drift an ulp and the golden
# fused-vs-unfused equivalence would inherit the noise.
_agl_lookup_ref_jit = jax.jit(ref.agl_lookup_ref)


def agl_lookup(dem, fi, fj, alt_msl, *, backend: Backend = "pallas",
               oracle_rows=None):
    """(H,W),(B,M),(B,M),(B,M) -> (B,M) AGL. See ref.agl_lookup_ref.

    Computes per-track tile origins on the host side; tracks that span
    more than one DEM tile (rare wide-area tracks — the paper's §V
    'hundreds of nautical miles' case) are routed — row by row, not
    whole-batch — to the oracle, while every other row stays on the
    Pallas tile path.  ``oracle_rows`` (a (B,) bool mask) forces extra
    rows onto the oracle — the unfused segments pipeline passes its
    conservative knot-extent mask so both pipelines route identically.
    The origin math runs in numpy on the caller's arrays, so host
    inputs (the common case) cost no device->host sync; the fully
    device-resident variant of this op is :func:`process_segments`.
    """
    if backend == "ref":
        return ref.agl_lookup_ref(dem, fi, fj, alt_msl)
    H, W = dem.shape
    # Host-side (concrete) clip + origin/extent math — numpy throughout,
    # so already-host inputs never bounce off the device first.
    fi_c = np.clip(np.asarray(fi, np.float32), 0.0,
                   np.float32(H - 1.001))
    fj_c = np.clip(np.asarray(fj, np.float32), 0.0,
                   np.float32(W - 1.001))
    alt_np = np.asarray(alt_msl, np.float32)
    oi = (fi_c.min(axis=1) // TILE_H).astype(np.int32)
    oj = (fj_c.min(axis=1) // TILE_W).astype(np.int32)
    spans = (((fi_c.max(axis=1) - oi * TILE_H) >= TILE_H - 1)
             | ((fj_c.max(axis=1) - oj * TILE_W) >= TILE_W - 1))
    if oracle_rows is not None:
        spans |= np.asarray(oracle_rows, bool)
    B, M = fi_c.shape
    dem = jnp.asarray(dem, jnp.float32)
    if bool(spans.all()):
        return _agl_lookup_ref_jit(dem, fi_c, fj_c, alt_np)

    fit = ~spans
    dem_p = _pad_to(_pad_to(dem, 0, TILE_H), 1, TILE_W)
    # Keep origins inside the padded grid.
    oi = np.minimum(oi[fit], dem_p.shape[0] // TILE_H - 1)
    oj = np.minimum(oj[fit], dem_p.shape[1] // TILE_W - 1)
    fi_p = _pad_to(jnp.asarray(fi_c[fit]), 1, 128)
    fj_p = _pad_to(jnp.asarray(fj_c[fit]), 1, 128)
    alt_p = _pad_to(jnp.asarray(alt_np[fit]), 1, 128)
    out_fit = agl_lookup_pallas(dem_p, fi_p, fj_p, alt_p,
                                jnp.asarray(oi), jnp.asarray(oj),
                                interpret=not _on_tpu())[:, :M]
    if not spans.any():
        return out_fit
    out_spanning = _agl_lookup_ref_jit(dem, fi_c[spans], fj_c[spans],
                                       alt_np[spans])
    out = jnp.zeros((B, M), jnp.float32)
    out = out.at[np.flatnonzero(fit)].set(out_fit)
    return out.at[np.flatnonzero(spans)].set(out_spanning)


def process_segments(dem, t_in, v_in, count_in, t_out, count_out, *,
                     grid, dt: float = 1.0, backend: Backend = "pallas",
                     agl_oracle: bool = False):
    """Fused on-device segment pipeline: interp + AGL + rates, one jit.

    Replaces the ``track_interp -> host numpy -> agl_lookup ->
    dynamic_rates`` sequence with a single compiled call: DEM
    fractional-index math, bilinear AGL lookup (with a per-row oracle
    fallback for tile-spanning tracks), rate estimation and the padding
    masks all execute on device; no intermediate ever crosses the
    host<->device boundary.  See :mod:`repro.kernels.segment_pipeline`.

    Args:
      dem: (H, W) elevation grid.
      t_in, v_in, count_in: (B, N) knot times, (B, 3, N) lat/lon/alt
        knots, (B,) valid knot counts.
      t_out, count_out: (B, K) query grid, (B,) valid output lengths.
      grid: (lat_min, lat_max, lon_min, lon_max, cells_per_deg) DEM
        affine transform.
      dt: uniform grid spacing in seconds.
      backend: 'pallas' fuses the Pallas kernels; 'ref' composes the
        pure-jnp oracles (the correctness reference).
      agl_oracle: True computes AGL with the oracle gather for every
        row (the always-correct variant for tracks that may cross a
        DEM tile border); False (default) uses the single-tile Pallas
        kernel — the caller must prove the tracks fit one tile
        (segments.py proves it from the raw knot extents).

    Returns:
      dict of (B, K) f32 device arrays keyed by
      :data:`segment_pipeline.FIELDS`, masked to ``count_out``.
    """
    use_pallas = backend != "ref"
    key = (np.shape(dem), np.shape(t_in), np.shape(t_out),
           tuple(float(g) for g in grid), float(dt), use_pallas,
           bool(agl_oracle))
    with _STATS_LOCK:
        if key in _SEEN_FUSED_SHAPES:
            _STATS["compile_hits"] += 1
        else:
            _SEEN_FUSED_SHAPES.add(key)
            _STATS["compile_misses"] += 1
    return segment_pipeline.process_segments(
        dem, t_in, v_in, count_in, t_out, count_out, grid=grid, dt=dt,
        use_pallas=use_pallas, agl_oracle=agl_oracle,
        interpret=not _on_tpu(), donate=_on_tpu())


def flash_attention(q, k, v, *, causal: bool = True,
                    backend: Backend = "pallas",
                    block_q: int = 128, block_k: int = 128):
    """Blocked online-softmax attention (GQA): q (B,H,T,hd),
    k/v (B,KV,S,hd) -> (B,H,T,hd). Pads T/S to block multiples.

    This is the real-TPU attention path (attention_impl='flash' on
    ArchConfig); the dry-run keeps stock-XLA attention so cost_analysis
    stays faithful (DESIGN.md §3)."""
    if backend == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    B, H, T, hd = q.shape
    S = k.shape[2]
    bq = min(block_q, _next_mult(T, 128))
    bk = min(block_k, _next_mult(S, 128))
    q_p = _pad_to(jnp.asarray(q), 2, bq)
    k_p = _pad_to(jnp.asarray(k), 2, bk)
    v_p = _pad_to(jnp.asarray(v), 2, bk)
    out = flash_attention_pallas(q_p, k_p, v_p, causal=causal,
                                 block_q=bq, block_k=bk,
                                 q_len=T, kv_len=S,
                                 interpret=not _on_tpu())
    return out[:, :, :T]


def _next_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
