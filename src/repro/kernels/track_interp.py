"""Pallas TPU kernel: piecewise-linear track resampling.

The workflow's hot loop interpolates raw, irregularly-sampled ADS-B/radar
observations onto a uniform time grid (paper §III.A step 3). On CPU/GPU
this is a searchsorted + gather. Neither maps well to the TPU: gathers
serialize on the VPU and searchsorted is branch-heavy.

TPU adaptation (DESIGN.md §2): reformulate interpolation as two masked
matmuls on the MXU. For output times t (M,) and input knots T (N,):

    cond[m, n] = 1 if t_m falls in segment [T_n, T_{n+1})          (M, N)
    WL = cond * (1 - w),  WR = cond * w,   w = (t - T_n)/(T_{n+1} - T_n)
    out = WL @ V^T + WR @ Vshift^T          -- V: (C, N) channel values

Both matmuls are MXU ops; cond/w are VPU elementwise. The O(M*N) FLOPs
are far cheaper than the memory stalls of a gather at these sizes
(N, M <= a few K), and the whole working set tiles cleanly into VMEM.

Block layout: grid (B, M/MB); per step we hold (N,), (C, N), (MB,) blocks
in VMEM — with N = 1024, C = 8, MB = 512 that is ~48 KB, well under the
~16 MB VMEM budget, leaving room for the (MB, N) mask intermediates
(512*1024*4 = 2 MB each).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_in_ref, v_in_ref, count_ref, t_out_ref, out_ref):
    # Blocks: t_in (1, N), v_in (1, C, N), count (1, 1), t_out (1, MB),
    # out (1, MB, C).
    t = t_in_ref[0, :]                       # (N,)
    v = v_in_ref[0, :, :]                    # (C, N)
    cnt = count_ref[0, 0]                    # scalar int32
    q = t_out_ref[0, :]                      # (MB,)
    N = t.shape[0]

    last = cnt - 1
    n_iota = jax.lax.broadcasted_iota(jnp.int32, (N,), 0)
    # Clamp queries into the valid time range (constant extrapolation).
    t_last = jnp.sum(jnp.where(n_iota == last, t, 0.0))
    q = jnp.clip(q, t[0], t_last)

    # Segment n is valid for n in [0, last-1]; its interval [T_n, T_{n+1}).
    t_next = jnp.concatenate([t[1:], t[-1:]], axis=0)       # (N,)
    seg_valid = n_iota < last                                # (N,)
    is_last_seg = n_iota == (last - 1)

    qm = q[:, None]                                          # (MB, 1)
    tn = t[None, :]
    tn1 = t_next[None, :]
    cond = (qm >= tn) & ((qm < tn1) | (is_last_seg[None, :] & (qm <= tn1)))
    cond = cond & seg_valid[None, :]                         # (MB, N)

    denom = jnp.where(tn1 > tn, tn1 - tn, 1.0)
    w = (qm - tn) / denom                                    # (MB, N)
    condf = cond.astype(jnp.float32)
    wl = condf * (1.0 - w)
    wr = condf * w

    v_shift = jnp.concatenate([v[:, 1:], v[:, -1:]], axis=1)  # (C, N)
    # MXU: (MB, N) @ (N, C) twice.
    out = jnp.dot(wl, v.T, preferred_element_type=jnp.float32)
    out += jnp.dot(wr, v_shift.T, preferred_element_type=jnp.float32)
    out_ref[0, :, :] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def track_interp_pallas(t_in: jax.Array, v_in: jax.Array, count: jax.Array,
                        t_out: jax.Array, *, block_m: int = 512,
                        interpret: bool = True) -> jax.Array:
    """Pallas version of ref.track_interp_ref (same signature + options).

    t_in (B, N) f32, v_in (B, C, N) f32, count (B,) i32, t_out (B, M) f32
    -> (B, M, C) f32. M must be a multiple of block_m (ops.py pads).
    """
    B, N = t_in.shape
    C = v_in.shape[1]
    M = t_out.shape[1]
    if M % block_m:
        raise ValueError(f"M={M} not a multiple of block_m={block_m}")
    count2 = count.reshape(B, 1).astype(jnp.int32)
    grid = (B, M // block_m)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N), lambda b, m: (b, 0)),
            pl.BlockSpec((1, C, N), lambda b, m: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, m: (b, 0)),
            pl.BlockSpec((1, block_m), lambda b, m: (b, m)),
        ],
        out_specs=pl.BlockSpec((1, block_m, C), lambda b, m: (b, m, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, C), jnp.float32),
        interpret=interpret,
    )(t_in.astype(jnp.float32), v_in.astype(jnp.float32), count2,
      t_out.astype(jnp.float32))
