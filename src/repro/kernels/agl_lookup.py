"""Pallas TPU kernel: AGL altitude via bilinear DEM lookup.

The paper's step 3 computes above-ground-level altitude for every
observation: AGL = MSL - DEM(lat, lon). On CPU/GPU this is a 4-point
gather from the elevation raster. Fine-grained gathers are the worst case
for the TPU memory system, so we adapt (DESIGN.md §2):

  1. *Spatial locality*: one aircraft track covers a tiny DEM window
     (§V: per-sensor tracks bound the DEM working set — the paper calls
     out wide-area OpenSky tracks as the expensive case). Per track we
     prefetch one (TH, TW) DEM tile into VMEM, selected by a per-track
     block origin carried as scalar-prefetch operands.
  2. *Gather -> matmul*: bilinear interpolation of M points from a VMEM
     tile is computed as  rowsum((A @ tile) * Ct)  where A (M, TH) holds
     the row weights (1-di, di) at columns (i0, i0+1) and Ct (M, TW) the
     column weights. One MXU matmul + one VPU reduction replace M
     scattered 4-point gathers.

Tracks wider than a tile are clamped to its border; ops.py routes such
tracks (rare, detected on host) to the jnp oracle instead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_H = 128
TILE_W = 256


def _kernel(oi_ref, oj_ref, fi_ref, fj_ref, alt_ref, dem_ref, out_ref):
    # Scalar prefetch: oi/oj (B,) block-origin indices (in tiles).
    b = pl.program_id(0)
    fi = fi_ref[0, :]                       # (M,) fractional rows (global)
    fj = fj_ref[0, :]
    alt = alt_ref[0, :]
    tile = dem_ref[...]                     # (TH, TW) VMEM tile

    # Tile-local coordinates, clamped inside the tile.
    fi_loc = jnp.clip(fi - oi_ref[b].astype(jnp.float32) * TILE_H,
                      0.0, TILE_H - 1.001)
    fj_loc = jnp.clip(fj - oj_ref[b].astype(jnp.float32) * TILE_W,
                      0.0, TILE_W - 1.001)
    i0 = jnp.floor(fi_loc).astype(jnp.int32)
    j0 = jnp.floor(fj_loc).astype(jnp.int32)
    di = fi_loc - i0.astype(jnp.float32)
    dj = fj_loc - j0.astype(jnp.float32)

    M = fi.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (M, TILE_H), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (M, TILE_W), 1)
    # Bilinear weights as sparse one-hot-pair matrices.
    A = (jnp.where(rows == i0[:, None], 1.0 - di[:, None], 0.0)
         + jnp.where(rows == i0[:, None] + 1, di[:, None], 0.0))
    Ct = (jnp.where(cols == j0[:, None], 1.0 - dj[:, None], 0.0)
          + jnp.where(cols == j0[:, None] + 1, dj[:, None], 0.0))
    # (M, TH) @ (TH, TW) -> (M, TW); weighted row-sum -> (M,)
    rowsel = jnp.dot(A, tile, preferred_element_type=jnp.float32)
    elev = jnp.sum(rowsel * Ct, axis=1)
    out_ref[0, :] = alt - elev


@functools.partial(jax.jit, static_argnames=("interpret",))
def agl_lookup_pallas(dem: jax.Array, fi: jax.Array, fj: jax.Array,
                      alt_msl: jax.Array, oi: jax.Array, oj: jax.Array,
                      *, interpret: bool = True) -> jax.Array:
    """AGL altitudes for B tracks of M points each.

    dem (H, W) f32 — H, W multiples of TILE_H/TILE_W (ops.py pads);
    fi/fj/alt_msl (B, M) f32 — global fractional DEM indices + MSL (m);
    oi/oj (B,) i32 — per-track tile origins, in tile units.
    Returns (B, M) f32 AGL (m).
    """
    B, M = fi.shape
    H, W = dem.shape
    if H % TILE_H or W % TILE_W:
        raise ValueError(f"dem {dem.shape} not tile-aligned")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, M), lambda b, oi, oj: (b, 0)),
            pl.BlockSpec((1, M), lambda b, oi, oj: (b, 0)),
            pl.BlockSpec((1, M), lambda b, oi, oj: (b, 0)),
            pl.BlockSpec((TILE_H, TILE_W), lambda b, oi, oj: (oi[b], oj[b])),
        ],
        out_specs=pl.BlockSpec((1, M), lambda b, oi, oj: (b, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(oi.astype(jnp.int32), oj.astype(jnp.int32),
      fi.astype(jnp.float32), fj.astype(jnp.float32),
      alt_msl.astype(jnp.float32), dem.astype(jnp.float32))
