"""Pallas TPU kernel: blocked causal flash attention (GQA-aware).

EXPERIMENTS.md §Roofline shows every *prefill* cell is memory-bound on
the materialized (T x S) logits/probs round-trips to HBM (e.g.
nemotron-4-340b prefill_32k: 78 s memory vs 20 s compute). The classic
fix is online-softmax blocking: stream K/V blocks through VMEM, keep
running (m, l, acc) statistics, and never write logits to HBM.

Kernel layout (canonical TPU flash):
  grid = (B, H, Tq/block_q, S/block_k) — the LAST axis iterates
  sequentially per (b, h, qi), accumulating into VMEM scratch:
    acc (block_q, hd) f32, m (block_q,) f32, l (block_q,) f32.
  Causal blocks with k_block > q_block are masked (their contribution is
  exactly zero); the output block is written once, on the final k step.
  GQA: the k/v BlockSpecs map query-head h -> kv head h // (H/KV).

VMEM working set: q (block_q, hd) + k/v (block_k, hd) + acc — at
block 128 x hd 192 that is < 300 KB, far under the ~16 MB budget.

NOTE (DESIGN.md §3): the dry-run keeps attention in stock-XLA form so
cost_analysis stays faithful — a pallas_call is an opaque custom-call
with zero accounted FLOPs. This kernel is the real-TPU serving/training
path (``attention_impl='flash'`` in ops.flash_attention), validated here
in interpret mode against the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUMemorySpace -> MemorySpace; support both.
_MemorySpace = getattr(pltpu, "MemorySpace",
                       getattr(pltpu, "TPUMemorySpace", None))

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            nk: int, offset: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _block():
        q = q_ref[0, 0].astype(F32) * scale            # (bq, hd)
        k = k_ref[0, 0].astype(F32)                    # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=F32)  # (bq, bk)
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = cols < kv_len                     # mask S padding
        if causal:
            # query t attends keys <= t + offset (offset = S - T aligns
            # the last query with the last key for chunked prefill)
            valid &= cols <= rows + offset
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])                # (bq, bk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(F32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jnp.dot(p, v, preferred_element_type=F32)
        m_ref[...] = m_new

    if causal:
        # whole block strictly above the diagonal contributes nothing
        @pl.when(ki * block_k <= qi * block_q + (block_q - 1) + offset)
        def _():
            _block()
    else:
        _block()

    @pl.when(ki == nk - 1)
    def _emit():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret", "q_len", "kv_len"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           q_len: int | None = None,
                           kv_len: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q (B, H, T, hd); k, v (B, KV, S, hd) -> (B, H, T, hd).

    T must divide block_q and S divide block_k (ops.py pads; pass the
    REAL q_len/kv_len so padding rows/cols are masked out).
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    if T % block_q or S % block_k:
        raise ValueError(f"T={T}/S={S} not multiples of blocks")
    if H % KV:
        raise ValueError("H must be a multiple of KV")
    q_len = q_len or T
    kv_len = kv_len or S
    g = H // KV
    nk = S // block_k
    scale = hd ** -0.5

    grid = (B, H, T // block_q, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, nk=nk, offset=kv_len - q_len, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=[
            _MemorySpace.VMEM((block_q, hd), F32),
            _MemorySpace.VMEM((block_q,), F32),
            _MemorySpace.VMEM((block_q,), F32),
        ],
        interpret=interpret,
    )(q, k, v)
