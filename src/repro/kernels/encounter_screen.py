"""Fused pairwise encounter screen: per-cell miss distances on device.

The screening workload (ROADMAP "encounter-screening workload") takes
the spatial-hash cells produced by :mod:`repro.geometry.gridhash` and,
within each cell, computes the pairwise horizontal/vertical separation
of every row pair over their time-aligned sample grids, emitting
*candidate encounters* — pairs that are simultaneously inside both
thresholds at some jointly valid instant.

Three numerically identical execution paths share one chunked pair
trace (:func:`_chunk_minima`):

  * ``backend="pallas"`` — the fused kernel: one program per
    (cell, 8-row block) streams the time axis in 128-sample chunks,
    keeping (rows, K) running minima in registers.  Interpret mode on
    CPU, compiled on TPU (same convention as :mod:`ops`).
  * ``backend="jit"`` — the same chunked trace XLA-compiled over the
    whole (C, K, T) batch; the production CPU path.
  * ``backend="ref"`` — :func:`repro.kernels.ref.encounter_screen_ref`
    vmapped over cells (full-broadcast oracle; tests and tiny cells).

Cells are batched with the ``segment_pipeline`` bucket machinery: rows
round to multiples of 8 (:func:`repro.tracks.segments._round_rows`),
time to 128-sample widths (:func:`repro.tracks.segments.bucket_width`
for spans inside ``MAX_SEG_POINTS``), so a handful of compiled shapes
cover arbitrary cell populations.  Empty and singleton cells never
reach the kernel at all (there is no pair to screen) — asserted by the
``cells_skipped`` / ``kernel_calls`` counters in
:func:`get_screen_stats`.

Candidate records are plain dicts, canonically ordered so every path
(grid vs. brute force, barrier vs. streaming DAG) yields byte-identical
serializations: ``{"a", "b", "t_s", "h_m", "v_m"}`` with ``a < b``
(row ids), deduplicated across the multiple cells a pair may share.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.geometry.gridhash import CellKey, GridSpec, bin_samples
from repro.kernels.ref import encounter_screen_ref
from repro.tracks.segments import BUCKET_SIZES, _round_rows, bucket_width

__all__ = [
    "ScreenConfig", "ScreenRow", "rows_from_track", "bin_screen_rows",
    "screen_aligned", "screen_cells", "screen_rows_grid",
    "brute_force_screen", "dedup_candidates",
    "get_screen_stats", "reset_screen_stats",
]

_BIG = np.float32(1e30)
_M_PER_DEG = 111_111.0
_T_CHUNK = 128                  # lane-width time chunks
_ROW_BLOCK = 8                  # f32 sublane tile: 8 pair rows per program
_C_CHUNK_BYTES = 64 << 20       # cap jnp-path (C, K, K, Tc) intermediates


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# shared chunk trace
# ---------------------------------------------------------------------------

def _chunk_minima(lat_i, lon_i, alt_i, val_i, lat_j, lon_j, alt_j, val_j,
                  tri, h_m: float, v_m: float):
    """Pair minima over one time chunk.

    ``*_i`` are (..., R, 1, Tc), ``*_j`` (..., 1, K, Tc), ``tri``
    (..., R, K, 1) bool.  Returns (hit, min_dh, argmin_dh, min_dv),
    each (..., R, K); minima are ``_BIG`` where the chunk has no hit.
    """
    m = jnp.float32(_M_PER_DEG)
    dn = (lat_i - lat_j) * m
    de = ((lon_i - lon_j) * m
          * jnp.cos(jnp.deg2rad(jnp.float32(0.5) * (lat_i + lat_j))))
    dh = jnp.sqrt(dn * dn + de * de)
    dv = jnp.abs(alt_i - alt_j)
    hit_t = ((val_i * val_j) > 0.5) & tri & (dh <= jnp.float32(h_m)) \
        & (dv <= jnp.float32(v_m))
    dh_m = jnp.where(hit_t, dh, _BIG)
    dv_m = jnp.where(hit_t, dv, _BIG)
    return (jnp.max(hit_t.astype(jnp.float32), axis=-1),
            jnp.min(dh_m, axis=-1),
            jnp.argmin(dh_m, axis=-1).astype(jnp.int32),
            jnp.min(dv_m, axis=-1))


def _fold_chunk(carry, chunk, t_base):
    """Fold one chunk's minima into the running (hit, dh, dv, ti) carry.

    Strict ``<`` on the running min keeps the *first* time index
    attaining the global minimum — bitwise-identical to the oracle's
    single ``argmin`` over the full time axis.
    """
    hit, mdh, mdv, tix = carry
    c_hit, c_dh, c_arg, c_dv = chunk
    better = c_dh < mdh
    return (jnp.maximum(hit, c_hit),
            jnp.where(better, c_dh, mdh),
            jnp.minimum(mdv, c_dv),
            jnp.where(better, (c_arg + t_base).astype(jnp.float32), tix))


# ---------------------------------------------------------------------------
# pallas kernel
# ---------------------------------------------------------------------------

def _screen_kernel(lat_ref, lon_ref, alt_ref, val_ref,
                   hit_ref, dh_ref, dv_ref, ti_ref, *,
                   h_m: float, v_m: float, rb: int, tc: int):
    ib = pl.program_id(1)
    lat = lat_ref[0]            # (K, T)
    lon = lon_ref[0]
    alt = alt_ref[0]
    val = val_ref[0]
    K, T = lat.shape
    i0 = ib * rb

    def rows(x):
        return jax.lax.dynamic_slice(x, (i0, 0), (rb, T))

    lat_i, lon_i, alt_i, val_i = rows(lat), rows(lon), rows(alt), rows(val)
    i_ids = i0 + jax.lax.broadcasted_iota(jnp.int32, (rb, K), 0)
    j_ids = jax.lax.broadcasted_iota(jnp.int32, (rb, K), 1)
    tri = (i_ids < j_ids)[:, :, None]

    def body(c, carry):
        t0 = c * tc

        def ci(x):      # (rb, 1, tc)
            return jax.lax.dynamic_slice(x, (0, t0), (rb, tc))[:, None, :]

        def cj(x):      # (1, K, tc)
            return jax.lax.dynamic_slice(x, (0, t0), (K, tc))[None, :, :]

        chunk = _chunk_minima(ci(lat_i), ci(lon_i), ci(alt_i), ci(val_i),
                              cj(lat), cj(lon), cj(alt), cj(val),
                              tri, h_m, v_m)
        return _fold_chunk(carry, chunk, t0)

    init = (jnp.zeros((rb, K), jnp.float32),
            jnp.full((rb, K), _BIG, jnp.float32),
            jnp.full((rb, K), _BIG, jnp.float32),
            jnp.zeros((rb, K), jnp.float32))
    hit, mdh, mdv, tix = jax.lax.fori_loop(0, T // tc, body, init)
    hit_ref[0] = hit
    dh_ref[0] = mdh
    dv_ref[0] = mdv
    ti_ref[0] = tix


def _screen_batch_pallas(lat, lon, alt, val, *, h_m, v_m, interpret):
    C, K, T = lat.shape
    rb, tc = _ROW_BLOCK, min(_T_CHUNK, T)
    n_i = K // rb
    in_spec = pl.BlockSpec((1, K, T), lambda c, i: (c, 0, 0))
    out_spec = pl.BlockSpec((1, rb, K), lambda c, i: (c, i, 0))
    shape = jax.ShapeDtypeStruct((C, K, K), jnp.float32)
    return pl.pallas_call(
        functools.partial(_screen_kernel, h_m=h_m, v_m=v_m, rb=rb, tc=tc),
        grid=(C, n_i),
        in_specs=[in_spec] * 4,
        out_specs=[out_spec] * 4,
        out_shape=[shape] * 4,
        interpret=interpret,
    )(lat, lon, alt, val)


# ---------------------------------------------------------------------------
# jnp (XLA) path — same chunked trace over the whole batch
# ---------------------------------------------------------------------------

def _screen_batch_jnp(lat, lon, alt, val, *, h_m, v_m):
    C, K, T = lat.shape
    tc = min(_T_CHUNK, T)
    tri = (jnp.arange(K)[:, None] < jnp.arange(K)[None, :])[None, :, :, None]

    def body(c, carry):
        t0 = c * tc

        def sl(x):
            return jax.lax.dynamic_slice_in_dim(x, t0, tc, axis=2)

        la, lo, al, va = sl(lat), sl(lon), sl(alt), sl(val)
        chunk = _chunk_minima(
            la[:, :, None, :], lo[:, :, None, :], al[:, :, None, :],
            va[:, :, None, :], la[:, None, :, :], lo[:, None, :, :],
            al[:, None, :, :], va[:, None, :, :], tri, h_m, v_m)
        return _fold_chunk(carry, chunk, t0)

    init = (jnp.zeros((C, K, K), jnp.float32),
            jnp.full((C, K, K), _BIG, jnp.float32),
            jnp.full((C, K, K), _BIG, jnp.float32),
            jnp.zeros((C, K, K), jnp.float32))
    return jax.lax.fori_loop(0, T // tc, body, init)


def _screen_batch_ref(lat, lon, alt, val, *, h_m, v_m):
    fn = functools.partial(encounter_screen_ref,
                           h_thresh_m=h_m, v_thresh_m=v_m)
    return jax.vmap(fn)(lat, lon, alt, val)


@functools.lru_cache(maxsize=None)
def _jitted(C: int, K: int, T: int, h_m: float, v_m: float,
            backend: str, interpret: bool):
    """One compiled screen per padded batch shape + thresholds."""
    if backend == "pallas":
        fn = functools.partial(_screen_batch_pallas, h_m=h_m, v_m=v_m,
                               interpret=interpret)
    elif backend == "jit":
        fn = functools.partial(_screen_batch_jnp, h_m=h_m, v_m=v_m)
    elif backend == "ref":
        fn = functools.partial(_screen_batch_ref, h_m=h_m, v_m=v_m)
    else:
        raise ValueError(f"unknown screen backend {backend!r}")
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

_STATS: Dict[str, float] = {}


def reset_screen_stats() -> None:
    _STATS.clear()
    _STATS.update(kernel_calls=0, cells_screened=0, cells_skipped=0,
                  pairs_screened=0, padded_cells=0)


def get_screen_stats() -> dict:
    if not _STATS:
        reset_screen_stats()
    return dict(_STATS)


reset_screen_stats()


# ---------------------------------------------------------------------------
# batched screening over padded (C, K, T) arrays
# ---------------------------------------------------------------------------

def screen_aligned(lat, lon, alt, valid, *, h_thresh_m: float,
                   v_thresh_m: float, backend: str = "jit",
                   interpret: Optional[bool] = None) -> dict:
    """Screen a (C, K, T) batch of time-aligned cells.

    Pads rows to the 8-row tile, time to 128-sample chunks, and the
    cell axis to a bounded set of bucket sizes, then dispatches to the
    requested backend.  Returns ``{"hit", "min_dh", "min_dv", "t_idx"}``
    as (C, K, K) float32 numpy arrays (strict upper triangle).
    """
    lat = np.asarray(lat, np.float32)
    C, K, T = lat.shape
    Kp = max(_ROW_BLOCK, _round_rows(K))
    Tp = -(-T // _T_CHUNK) * _T_CHUNK
    interp = (not _on_tpu()) if interpret is None else interpret

    def pad(x, fill=0.0):
        out = np.full((C, Kp, Tp), fill, np.float32)
        out[:, :K, :T] = np.asarray(x, np.float32)
        return out

    latp, lonp = pad(lat), pad(lon)
    altp, valp = pad(alt), pad(valid)

    c_max = max(1, _C_CHUNK_BYTES // (Kp * Kp * min(_T_CHUNK, Tp) * 4))
    outs = [np.empty((C, Kp, Kp), np.float32) for _ in range(4)]
    done = 0
    while done < C:
        n = min(c_max, C - done)
        Cp = min(max(1, _round_rows(n)), c_max)
        sl = slice(done, done + n)

        def cpad(x):
            if Cp == n:
                return jnp.asarray(x[sl])
            out = np.zeros((Cp, Kp, Tp), np.float32)
            out[:n] = x[sl]
            return jnp.asarray(out)

        fn = _jitted(Cp, Kp, Tp, float(h_thresh_m), float(v_thresh_m),
                     backend, interp)
        res = fn(cpad(latp), cpad(lonp), cpad(altp), cpad(valp))
        for dst, arr in zip(outs, res):
            dst[sl] = np.asarray(arr)[:n]
        _STATS["kernel_calls"] += 1
        _STATS["padded_cells"] += Cp - n
        done += n
    hit, mdh, mdv, tix = (o[:, :K, :K] for o in outs)
    return {"hit": hit, "min_dh": mdh, "min_dv": mdv, "t_idx": tix}


# ---------------------------------------------------------------------------
# rows, binning, cell screening
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScreenConfig:
    """Encounter-screen thresholds and execution knobs."""

    h_thresh_m: float = 926.0   # 0.5 NM horizontal
    v_thresh_m: float = 152.4   # 500 ft vertical
    dt_s: float = 1.0           # sample grid spacing (RESAMPLE_DT_S)
    backend: str = "jit"        # pallas | jit | ref

    def __post_init__(self) -> None:
        if self.h_thresh_m <= 0 or self.v_thresh_m <= 0 or self.dt_s <= 0:
            raise ValueError("ScreenConfig values must be positive")
        if self.backend not in ("pallas", "jit", "ref"):
            raise ValueError(f"unknown screen backend {self.backend!r}")


@dataclasses.dataclass
class ScreenRow:
    """One resampled segment, anchored at an absolute start time.

    Samples sit on a uniform ``dt_s`` grid starting at ``t0``; rows
    from the same aircraft share a ``group`` and are never paired
    against each other.
    """
    row_id: str
    group: str
    t0: float
    lat: np.ndarray
    lon: np.ndarray
    alt: np.ndarray
    dt_s: float = 1.0

    def __len__(self) -> int:
        return len(self.lat)

    @property
    def times(self) -> np.ndarray:
        return self.t0 + np.arange(len(self.lat)) * self.dt_s


def rows_from_track(track_id: str, obs: dict, segs: Sequence[slice],
                    processed) -> List[ScreenRow]:
    """ProcessedSegments planes + raw observation times -> ScreenRows.

    ``processed.times`` grids are segment-relative (they start at 0);
    the absolute anchor is the raw first-observation time of each
    segment, which is what places rows on the shared screening grid.
    """
    rows = []
    for k, s in enumerate(segs):
        if k >= len(processed):
            break
        m = int(processed.count[k])
        rows.append(ScreenRow(
            row_id=f"{track_id}#s{k:03d}", group=track_id,
            t0=float(obs["time"][s.start]),
            lat=np.asarray(processed.lat[k, :m], np.float32),
            lon=np.asarray(processed.lon[k, :m], np.float32),
            alt=np.asarray(processed.alt_msl_m[k, :m], np.float32)))
    return rows


def bin_screen_rows(rows: Sequence[ScreenRow], *, grid: GridSpec,
                    config: ScreenConfig) -> Dict[CellKey, List[str]]:
    """Halo-padded cell membership (cell -> row ids) for screen rows."""
    return bin_samples(
        [(r.row_id, r.times, r.lat, r.lon, r.alt) for r in rows],
        spec=grid, h_pad_m=config.h_thresh_m, v_pad_m=config.v_thresh_m)


def _pack_cell(rows: Sequence[ScreenRow], dt: float):
    """-> (t0_cell, T, lat, lon, alt, valid) on the cell's union grid."""
    t0c = min(r.t0 for r in rows)
    starts = [int(round((r.t0 - t0c) / dt)) for r in rows]
    T = max(s + len(r) for s, r in zip(starts, rows))
    K = len(rows)
    lat = np.zeros((K, T), np.float32)
    lon = np.zeros((K, T), np.float32)
    alt = np.zeros((K, T), np.float32)
    val = np.zeros((K, T), np.float32)
    for k, (s, r) in enumerate(zip(starts, rows)):
        m = len(r)
        lat[k, s:s + m] = r.lat
        lon[k, s:s + m] = r.lon
        alt[k, s:s + m] = r.alt
        val[k, s:s + m] = 1.0
    return t0c, T, lat, lon, alt, val


def dedup_candidates(cands: Iterable[dict]) -> List[dict]:
    """Canonical candidate list: unique pairs, sorted by (a, b).

    A pair screened in several cells (or several streaming generations)
    produces identical records — the pair trace depends only on the two
    rows' absolute-time samples — so keeping the first is exact."""
    seen: Set[Tuple[str, str]] = set()
    out = []
    for c in sorted(cands, key=lambda c: (c["a"], c["b"])):
        key = (c["a"], c["b"])
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def screen_cells(cells: Dict[CellKey, Sequence[ScreenRow]], *,
                 config: ScreenConfig,
                 new_ids: Optional[Dict[CellKey, Set[str]]] = None,
                 dedup: bool = True):
    """Screen binned cells -> (candidates, stats).

    Cells are length-bucketed — (padded rows, padded time span) — and
    batched so one kernel launch covers many same-shape cells.  Empty
    and singleton cells are skipped before any batching.  With
    ``new_ids`` (streaming-DAG generations) only pairs touching a new
    row are emitted, so unioning generations never double-screens.
    """
    dt = config.dt_s
    skipped = screened = pairs = 0
    buckets: Dict[Tuple[int, int], list] = {}
    occ_max = 0
    for key in sorted(cells):
        rows = sorted(cells[key], key=lambda r: r.row_id)
        occ_max = max(occ_max, len(rows))
        if len(rows) < 2:
            skipped += 1
            continue
        screened += 1
        pairs += len(rows) * (len(rows) - 1) // 2
        t0c, T, *planes = _pack_cell(rows, dt)
        Kp = max(_ROW_BLOCK, _round_rows(len(rows)))
        Tp = (bucket_width(T) if T <= BUCKET_SIZES[-1]
              else -(-T // _T_CHUNK) * _T_CHUNK)
        buckets.setdefault((Kp, Tp), []).append((key, rows, t0c, T, planes))

    _STATS["cells_screened"] += screened
    _STATS["cells_skipped"] += skipped
    _STATS["pairs_screened"] += pairs

    cands: List[dict] = []
    for (Kp, Tp), items in sorted(buckets.items()):
        C = len(items)
        lat = np.zeros((C, Kp, Tp), np.float32)
        lon = np.zeros((C, Kp, Tp), np.float32)
        alt = np.zeros((C, Kp, Tp), np.float32)
        val = np.zeros((C, Kp, Tp), np.float32)
        for c, (_, rows, _, T, planes) in enumerate(items):
            K = len(rows)
            lat[c, :K, :T], lon[c, :K, :T] = planes[0], planes[1]
            alt[c, :K, :T], val[c, :K, :T] = planes[2], planes[3]
        res = screen_aligned(lat, lon, alt, val,
                             h_thresh_m=config.h_thresh_m,
                             v_thresh_m=config.v_thresh_m,
                             backend=config.backend)
        for c, (key, rows, t0c, _, _) in enumerate(items):
            fresh = None if new_ids is None else new_ids.get(key, set())
            ii, jj = np.nonzero(res["hit"][c] > 0.5)
            for i, j in zip(ii.tolist(), jj.tolist()):
                if i >= len(rows) or j >= len(rows):
                    continue
                a, b = rows[i], rows[j]
                if a.group == b.group:
                    continue
                if fresh is not None and a.row_id not in fresh \
                        and b.row_id not in fresh:
                    continue
                cands.append({
                    "a": a.row_id, "b": b.row_id,
                    "t_s": float(t0c + float(res["t_idx"][c, i, j]) * dt),
                    "h_m": float(res["min_dh"][c, i, j]),
                    "v_m": float(res["min_dv"][c, i, j]),
                })
    stats = {
        "cells": screened + skipped,
        "cells_screened": screened,
        "cells_skipped": skipped,
        "pairs_screened": pairs,
        "max_occupancy": occ_max,
        "candidates_raw": len(cands),
    }
    if dedup:
        cands = dedup_candidates(cands)
    stats["candidates"] = len(cands)
    return cands, stats


def screen_rows_grid(rows: Sequence[ScreenRow], *, grid: GridSpec,
                     config: ScreenConfig):
    """Bin rows into the spatial hash and screen every multi-row cell."""
    by_id = {r.row_id: r for r in rows}
    bins = bin_screen_rows(rows, grid=grid, config=config)
    cells = {key: [by_id[i] for i in ids] for key, ids in bins.items()}
    return screen_cells(cells, config=config)


# ---------------------------------------------------------------------------
# numpy brute-force reference (the baseline the kernel must beat)
# ---------------------------------------------------------------------------

def brute_force_screen(rows: Sequence[ScreenRow], *,
                       config: ScreenConfig) -> List[dict]:
    """All-pairs numpy screen on one global time grid — O(N^2 * T).

    No spatial pruning, no device: this is both the exactness reference
    (the grid + kernel path must emit the identical candidate set) and
    the speedup baseline in ``repro.bench.encounters``.
    """
    rows = sorted(rows, key=lambda r: r.row_id)
    if len(rows) < 2:
        return []
    dt = config.dt_s
    t0g = min(r.t0 for r in rows)
    starts = [int(round((r.t0 - t0g) / dt)) for r in rows]
    T = max(s + len(r) for s, r in zip(starts, rows))
    N = len(rows)
    lat = np.zeros((N, T), np.float32)
    lon = np.zeros((N, T), np.float32)
    alt = np.zeros((N, T), np.float32)
    val = np.zeros((N, T), bool)
    for k, (s, r) in enumerate(zip(starts, rows)):
        m = len(r)
        lat[k, s:s + m] = r.lat
        lon[k, s:s + m] = r.lon
        alt[k, s:s + m] = r.alt
        val[k, s:s + m] = True
    groups = np.array([r.group for r in rows])
    m_per_deg = np.float32(_M_PER_DEG)
    h_t = np.float32(config.h_thresh_m)
    v_t = np.float32(config.v_thresh_m)
    out = []
    for i in range(N - 1):
        lj = lat[i + 1:]
        dn = (lat[i][None, :] - lj) * m_per_deg
        de = ((lon[i][None, :] - lon[i + 1:]) * m_per_deg
              * np.cos(np.deg2rad(np.float32(0.5) * (lat[i][None, :] + lj))))
        dh = np.sqrt(dn * dn + de * de)
        dv = np.abs(alt[i][None, :] - alt[i + 1:])
        hit_t = (val[i][None, :] & val[i + 1:]
                 & (dh <= h_t) & (dv <= v_t)
                 & (groups[i + 1:] != groups[i])[:, None])
        js = np.nonzero(hit_t.any(axis=1))[0]
        for j in js.tolist():
            dh_m = np.where(hit_t[j], dh[j], _BIG)
            dv_m = np.where(hit_t[j], dv[j], _BIG)
            ti = int(np.argmin(dh_m))
            out.append({
                "a": rows[i].row_id, "b": rows[i + 1 + j].row_id,
                "t_s": float(t0g + ti * dt),
                "h_m": float(dh_m[ti]),
                "v_m": float(np.min(dv_m)),
            })
    return dedup_candidates(out)
