"""Pallas TPU kernel: dynamic-rate estimation over resampled tracks.

Computes vertical rate, ground speed, heading and turn rate with central
differences (paper §III.A step 3: "estimating dynamic rates (e.g.
vertical rate)"). Pure VPU stencil work: shifts + transcendentals, fused
in one pass over VMEM so each track is read once (the unfused jnp oracle
materializes ~10 intermediates in HBM).

Layout: channel-major (B, 3, M) so the track axis M sits in the 128-wide
lane dimension; shifts are lane rotations. Grid over B; each step holds a
(3, M) block and writes a (4, M) block — at M = 4096 that is 112 KB of
VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

M_PER_DEG = 111_111.0


def _make_central(M: int, cnt: jax.Array, dt: float):
    """Clamped-neighbor derivative: central inside [0, cnt), one-sided at
    both track ends. Shift + select, no gathers."""
    idx = jax.lax.broadcasted_iota(jnp.int32, (M,), 0)
    last = cnt - 1
    denom = (jnp.minimum(idx + 1, jnp.maximum(last, 0))
             - jnp.maximum(idx - 1, 0))
    denom = jnp.maximum(denom, 1).astype(jnp.float32) * dt

    def central(x: jax.Array) -> jax.Array:
        x_l = jnp.concatenate([x[0:1], x[:-1]], axis=0)    # x[i-1]
        x_r = jnp.concatenate([x[1:], x[-1:]], axis=0)     # x[i+1]
        left = jnp.where(idx == 0, x, x_l)
        right = jnp.where(idx >= last, x, x_r)
        return (right - left) / denom

    return central, idx


def _kernel(v_ref, count_ref, out_ref, *, dt: float):
    lat = v_ref[0, 0, :]
    lon = v_ref[0, 1, :]
    alt = v_ref[0, 2, :]
    cnt = count_ref[0, 0]
    M = lat.shape[0]
    central, idx = _make_central(M, cnt, dt)

    vrate = central(alt)
    dn = central(lat) * M_PER_DEG
    de = central(lon) * M_PER_DEG * jnp.cos(jnp.deg2rad(lat))
    gspeed = jnp.sqrt(dn * dn + de * de)
    heading = jnp.arctan2(de, dn)
    dh = central(heading) * dt
    dh = (dh + jnp.pi) % (2.0 * jnp.pi) - jnp.pi
    turn = dh / dt

    valid = idx < cnt
    out = jnp.stack([vrate, gspeed, heading, turn], axis=0)   # (4, M)
    out_ref[0, :, :] = jnp.where(valid[None, :], out, 0.0)


@functools.partial(jax.jit, static_argnames=("dt", "interpret"))
def dynamic_rates_pallas(v: jax.Array, count: jax.Array, dt: float,
                         *, interpret: bool = True) -> jax.Array:
    """Pallas version of ref.dynamic_rates_ref.

    v (B, 3, M) f32, count (B,) i32 -> (B, 4, M) f32.
    """
    B, C, M = v.shape
    assert C == 3, v.shape
    count2 = count.reshape(B, 1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, dt=float(dt)),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 3, M), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4, M), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 4, M), jnp.float32),
        interpret=interpret,
    )(v.astype(jnp.float32), count2)
