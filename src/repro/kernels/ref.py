"""Pure-jnp oracles for every Pallas kernel (the correctness reference).

These are straightforward, unfused implementations; tests sweep shapes
and dtypes asserting the Pallas kernels (interpret mode on CPU) match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def track_interp_ref(t_in: jax.Array, v_in: jax.Array, count: jax.Array,
                     t_out: jax.Array) -> jax.Array:
    """Piecewise-linear resample of tracks onto a new time grid.

    Args:
      t_in:  (B, N) sorted observation times (padding after ``count``).
      v_in:  (B, C, N) channel values at t_in.
      count: (B,) int32 — number of valid observations per track (>= 2).
      t_out: (B, M) query times.
    Returns:
      (B, M, C) linearly interpolated values; t_out clamped to the valid
      time range of each track (constant extrapolation at the ends).
    """
    B, N = t_in.shape
    C = v_in.shape[1]
    M = t_out.shape[1]

    def one(tb, vb, cb, qb):
        last = cb - 1
        t0 = tb[0]
        tl = tb[last]
        q = jnp.clip(qb, t0, tl)
        # Right bracketing index in [1, last].
        idx = jnp.searchsorted(tb[:], q, side="right")
        idx = jnp.clip(idx, 1, last)
        tj = tb[idx - 1]
        tj1 = tb[idx]
        w = jnp.where(tj1 > tj, (q - tj) / jnp.where(tj1 > tj, tj1 - tj, 1.0),
                      0.0)
        vl = vb[:, idx - 1]     # (C, M)
        vr = vb[:, idx]
        return ((1.0 - w)[None, :] * vl + w[None, :] * vr).T   # (M, C)

    return jax.vmap(one)(t_in, v_in, count, t_out)


def dynamic_rates_ref(v: jax.Array, count: jax.Array,
                      dt: float) -> jax.Array:
    """Dynamic rates from a uniformly resampled track (paper §III.A).

    Args:
      v: (B, 3, M) — lat (deg), lon (deg), altitude (m) on a uniform grid.
      count: (B,) int32 valid lengths.
      dt: grid spacing in seconds.
    Returns:
      (B, 4, M): vertical rate (m/s), ground speed (m/s), heading (rad,
      from north, clockwise), turn rate (rad/s). Positions >= count are 0.
    """
    B, _, M = v.shape
    lat, lon, alt = v[:, 0], v[:, 1], v[:, 2]
    m_per_deg = 111_111.0
    idx = jnp.arange(M)[None, :]
    last = (count - 1)[:, None]
    li = jnp.maximum(idx - 1, 0)
    ri = jnp.clip(idx + 1, 0, jnp.maximum(last, 0))
    denom = jnp.maximum(ri - li, 1).astype(jnp.float32) * dt

    def central(x):
        # difference between clamped neighbors: central inside the valid
        # range, one-sided at both track ends.
        return (jnp.take_along_axis(x, ri, axis=1)
                - jnp.take_along_axis(x, li, axis=1)) / denom

    vrate = central(alt)
    dn = central(lat) * m_per_deg                       # north velocity m/s
    de = central(lon) * m_per_deg * jnp.cos(jnp.deg2rad(lat))
    gspeed = jnp.sqrt(dn * dn + de * de)
    heading = jnp.arctan2(de, dn)
    dh = central(heading) * dt                          # un-normalized diff
    dh = (dh + jnp.pi) % (2.0 * jnp.pi) - jnp.pi        # wrap to (-pi, pi]
    turn = dh / dt
    out = jnp.stack([vrate, gspeed, heading, turn], axis=1)
    return jnp.where(idx[:, None, :] < count[:, None, None], out, 0.0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Plain-softmax GQA attention oracle for the flash kernel.

    q (B, H, T, hd); k, v (B, KV, S, hd) -> (B, H, T, hd) f32. Causal
    alignment: query t attends keys <= t + (S - T).
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vv.astype(jnp.float32))


_SCREEN_BIG = jnp.float32(1e30)


def encounter_screen_ref(lat: jax.Array, lon: jax.Array, alt: jax.Array,
                         valid: jax.Array, *, h_thresh_m: float,
                         v_thresh_m: float):
    """Pairwise miss-distance screen over time-aligned rows (one cell).

    Args:
      lat, lon, alt: (K, T) f32 — samples on a common 1-sample grid.
      valid: (K, T) f32 0/1 — sample presence mask.
      h_thresh_m / v_thresh_m: candidate thresholds (meters).
    Returns:
      ``(hit, min_dh, min_dv, t_idx)``, each (K, K) f32, populated on
      the strict upper triangle (i < j) only.  ``hit[i, j]`` is 1.0
      when rows i and j are simultaneously within *both* thresholds at
      some jointly valid instant; ``min_dh``/``min_dv`` are the minima
      of horizontal/vertical separation over those hit instants (1e30
      where no hit); ``t_idx`` is the first time index attaining
      ``min_dh``.  Local-tangent metric: 1 deg = 111_111 m, east
      meters scaled by cos of the pair's mean latitude — matching
      :func:`dynamic_rates_ref`.
    """
    K, T = lat.shape
    m_per_deg = jnp.float32(111_111.0)
    li, lj = lat[:, None, :], lat[None, :, :]
    dn = (li - lj) * m_per_deg
    de = ((lon[:, None, :] - lon[None, :, :]) * m_per_deg
          * jnp.cos(jnp.deg2rad(jnp.float32(0.5) * (li + lj))))
    dh = jnp.sqrt(dn * dn + de * de)
    dv = jnp.abs(alt[:, None, :] - alt[None, :, :])
    both = (valid[:, None, :] * valid[None, :, :]) > 0.5
    tri = (jnp.arange(K)[:, None] < jnp.arange(K)[None, :])[:, :, None]
    hit_t = both & tri & (dh <= jnp.float32(h_thresh_m)) \
        & (dv <= jnp.float32(v_thresh_m))
    dh_m = jnp.where(hit_t, dh, _SCREEN_BIG)
    dv_m = jnp.where(hit_t, dv, _SCREEN_BIG)
    hit = jnp.max(hit_t.astype(jnp.float32), axis=-1)
    min_dh = jnp.min(dh_m, axis=-1)
    min_dv = jnp.min(dv_m, axis=-1)
    t_idx = jnp.argmin(dh_m, axis=-1).astype(jnp.float32)
    return hit, min_dh, min_dv, t_idx


def agl_lookup_ref(dem: jax.Array, fi: jax.Array, fj: jax.Array,
                   alt_msl: jax.Array) -> jax.Array:
    """AGL altitude: MSL altitude minus bilinear DEM elevation.

    Args:
      dem: (H, W) elevation grid (m).
      fi, fj: (B, M) fractional row/col indices into dem.
      alt_msl: (B, M) MSL altitudes (m).
    Returns:
      (B, M) AGL altitudes (m).
    """
    H, W = dem.shape
    fi = jnp.clip(fi, 0.0, H - 1.000001)
    fj = jnp.clip(fj, 0.0, W - 1.000001)
    i0 = jnp.floor(fi).astype(jnp.int32)
    j0 = jnp.floor(fj).astype(jnp.int32)
    di = fi - i0
    dj = fj - j0
    z00 = dem[i0, j0]
    z01 = dem[i0, j0 + 1]
    z10 = dem[i0 + 1, j0]
    z11 = dem[i0 + 1, j0 + 1]
    elev = ((1 - di) * (1 - dj) * z00 + (1 - di) * dj * z01
            + di * (1 - dj) * z10 + di * dj * z11)
    return alt_msl - elev
