"""Data pipeline: self-scheduled shard ingestion (the paper's technique
applied to the training input layer)."""

from repro.data.pipeline import (
    ShardManifest, SelfScheduledLoader, synthetic_token_shards)

__all__ = ["ShardManifest", "SelfScheduledLoader", "synthetic_token_shards"]
