"""Self-scheduled training-data ingestion (DESIGN.md §4).

The paper's manager/worker loop applied to the input layer: training
shards are tasks, ingest hosts are workers.  The manager hands out shards
largest-first; a straggling host simply claims fewer shards, and a dead
host's in-flight shards are re-queued — the same straggler story as
§IV.A, now protecting the training input pipeline.

Ingest runs on the unified runtime (:func:`repro.runtime.run_job`), so
the 'hosts' can be threads (default) or real OS processes.  Workers no
longer mutate a shared buffer: each shard's sequences return to the
manager inside the DONE message, which is what makes the process backend
(and a real fleet's control plane) work unchanged.  The loader exposes a
per-step iterator of fixed-shape (tokens, labels) batches, which the
trainer device_puts against the mesh.

Shard files and the on-disk manifest come from :mod:`repro.store` — the
same checksummed columnar codec and :class:`~repro.store.StoreManifest`
index the track store uses — so there is exactly one shard-manifest
implementation in the repo; :class:`ShardManifest` here is just the
loader-facing view of a store :class:`~repro.store.ShardRecord`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import deque
from typing import Iterator

import numpy as np

from repro.core.messages import Task
from repro.runtime import run_job
from repro.store import codec
from repro.store.format import ShardRecord, StoreManifest

TOKEN_SHARD_SUFFIX = ".shard"


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    """Loader view of one token shard (see module docstring)."""

    shard_id: str
    path: str
    n_tokens: int
    size_bytes: int

    @classmethod
    def from_record(cls, root: str, rec: ShardRecord) -> "ShardManifest":
        return cls(shard_id=rec.shard_id,
                   path=os.path.join(root, rec.filename),
                   n_tokens=rec.n_points, size_bytes=rec.size_bytes)


def token_shard_manifests(root: str) -> list[ShardManifest]:
    """Loader views for every shard in a token store directory."""
    manifest = StoreManifest.load(root)
    return [ShardManifest.from_record(root, rec)
            for rec in manifest.shards]


def synthetic_token_shards(root: str, *, n_shards: int = 16,
                           vocab_size: int = 512,
                           tokens_per_shard_mean: int = 65536,
                           seed: int = 0) -> list[ShardManifest]:
    """Heavy-tailed shard sizes (like the aerodrome dataset's Fig 3).

    Written as a :mod:`repro.store` store: checksummed codec shards plus
    a ``store_manifest.json`` index (re-openable later with
    :func:`token_shard_manifests`)."""
    rng = np.random.default_rng(seed)
    records = []
    w = rng.pareto(1.5, size=n_shards) + 0.2
    w = w / w.mean()
    for i in range(n_shards):
        shard_id = f"shard_{i:05d}"
        n = max(int(tokens_per_shard_mean * w[i]), 2048)
        toks = rng.integers(0, vocab_size, size=n, dtype=np.int32)
        data = codec.encode_shard({"tokens": toks},
                                  meta={"shard_id": shard_id,
                                        "vocab_size": vocab_size})
        filename = f"{shard_id}{TOKEN_SHARD_SUFFIX}"
        path = os.path.join(root, filename)
        os.makedirs(root, exist_ok=True)
        with open(path, "wb") as f:
            f.write(data)
        records.append(ShardRecord(
            shard_id=shard_id, filename=filename, n_tracks=1,
            n_points=n, size_bytes=len(data),
            sha256=hashlib.sha256(data).hexdigest()))
    StoreManifest(compression="zlib", shards=records,
                  meta={"kind": "token-shards",
                        "vocab_size": vocab_size}).save(root)
    return [ShardManifest.from_record(root, rec) for rec in records]


class SelfScheduledLoader:
    """Batches from shards claimed via largest-first self-scheduling."""

    def __init__(self, shards: list[ShardManifest], *,
                 batch_size: int, seq_len: int,
                 n_ingest_workers: int = 4,
                 organization: str = "largest_first",
                 poll_interval: float = 0.005,
                 exec_backend: str = "threads",
                 seed: int = 0):
        self.shards = shards
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.n_ingest_workers = n_ingest_workers
        self.organization = organization
        self.poll_interval = poll_interval
        self.exec_backend = exec_backend
        self.rng = np.random.default_rng(seed)
        self._buf: deque[np.ndarray] = deque()
        self._ingested_tokens = 0
        self._run_ingest()

    # -- ingest phase (the paper's protocol) -------------------------------

    def _ingest_shard(self, task: Task) -> np.ndarray:
        """Worker fn: shard file -> (n_seq, seq_len+1) sequence array,
        returned to the manager in the DONE message.  Store-codec shards
        decode through the checksummed reader, so a corrupted shard
        fails the task loudly instead of training on garbage; bare
        ``.npy`` paths keep working for hand-rolled fixtures."""
        if task.payload.endswith(".npy"):
            toks = np.load(task.payload)
        else:
            cols, _meta = codec.read_shard(task.payload,
                                           columns=["tokens"])
            toks = cols["tokens"]
        L = self.seq_len + 1
        n_seq = len(toks) // L
        if n_seq == 0:
            return np.zeros((0, L), np.int32)
        return toks[: n_seq * L].reshape(n_seq, L).astype(np.int32)

    def _run_ingest(self) -> None:
        tasks = [Task(task_id=s.shard_id, size_bytes=s.size_bytes,
                      payload=s.path) for s in self.shards]
        self.job_result = run_job(
            tasks, self._ingest_shard,
            backend=self.exec_backend,
            n_workers=self.n_ingest_workers,
            organization=self.organization,
            poll_interval=self.poll_interval)
        # Deterministic buffer order regardless of DONE arrival order.
        for tid in sorted(self.job_result.results):
            seqs = self.job_result.results[tid]
            for s in seqs:
                self._buf.append(s)
            self._ingested_tokens += int(seqs.size)

    # -- batch iterator ----------------------------------------------------

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        order = self.rng.permutation(len(self._buf))
        seqs = list(self._buf)
        bs = self.batch_size
        for i in range(0, len(order) - bs + 1, bs):
            chunk = np.stack([seqs[j] for j in order[i:i + bs]])
            yield {"tokens": chunk[:, :-1].astype(np.int32),
                   "labels": chunk[:, 1:].astype(np.int32)}

    def batches(self, n: int) -> Iterator[dict[str, np.ndarray]]:
        """Infinite-ish batch stream (reshuffles each epoch)."""
        count = 0
        while count < n:
            for b in self:
                yield b
                count += 1
                if count >= n:
                    return
