"""Self-scheduled training-data ingestion (DESIGN.md §4).

The paper's manager/worker loop applied to the input layer: training
shards are tasks, ingest hosts are workers. The manager hands out shards
largest-first; a straggling host simply claims fewer shards, and a dead
host's in-flight shards are re-queued — the same straggler story as
§IV.A, now protecting the training input pipeline.

On this single-host container the 'hosts' are threads; on a real fleet
the Manager runs on host 0 and messages ride the existing control plane.
The loader exposes a per-step iterator of fixed-shape (tokens, labels)
batches, which the trainer device_puts against the mesh.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from collections import deque
from typing import Iterator, Optional

import numpy as np

from repro.core.messages import Task
from repro.core.selfsched import Manager


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    shard_id: str
    path: str
    n_tokens: int
    size_bytes: int


def synthetic_token_shards(root: str, *, n_shards: int = 16,
                           vocab_size: int = 512,
                           tokens_per_shard_mean: int = 65536,
                           seed: int = 0) -> list[ShardManifest]:
    """Heavy-tailed shard sizes (like the aerodrome dataset's Fig 3)."""
    rng = np.random.default_rng(seed)
    os.makedirs(root, exist_ok=True)
    out = []
    w = rng.pareto(1.5, size=n_shards) + 0.2
    w = w / w.mean()
    for i in range(n_shards):
        n = max(int(tokens_per_shard_mean * w[i]), 2048)
        toks = rng.integers(0, vocab_size, size=n, dtype=np.int32)
        path = os.path.join(root, f"shard_{i:05d}.npy")
        np.save(path, toks)
        out.append(ShardManifest(f"shard_{i:05d}", path, n,
                                 int(toks.nbytes)))
    return out


class SelfScheduledLoader:
    """Batches from shards claimed via largest-first self-scheduling."""

    def __init__(self, shards: list[ShardManifest], *,
                 batch_size: int, seq_len: int,
                 n_ingest_workers: int = 4,
                 organization: str = "largest_first",
                 poll_interval: float = 0.005,
                 seed: int = 0):
        self.shards = shards
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.n_ingest_workers = n_ingest_workers
        self.organization = organization
        self.poll_interval = poll_interval
        self.rng = np.random.default_rng(seed)
        self._buf: deque[np.ndarray] = deque()
        self._lock = threading.Lock()
        self._ingested_tokens = 0
        self._run_ingest()

    # -- ingest phase (the paper's protocol) -------------------------------

    def _ingest_shard(self, task: Task) -> int:
        toks = np.load(task.payload)
        L = self.seq_len + 1
        n_seq = len(toks) // L
        if n_seq == 0:
            return 0
        seqs = toks[: n_seq * L].reshape(n_seq, L)
        with self._lock:
            for s in seqs:
                self._buf.append(s)
            self._ingested_tokens += int(seqs.size)
        return n_seq

    def _run_ingest(self) -> None:
        tasks = [Task(task_id=s.shard_id, size_bytes=s.size_bytes,
                      payload=s.path) for s in self.shards]
        mgr = Manager(tasks, self.n_ingest_workers, self._ingest_shard,
                      organization=self.organization,
                      poll_interval=self.poll_interval)
        self.job_result = mgr.run()

    # -- batch iterator ----------------------------------------------------

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        order = self.rng.permutation(len(self._buf))
        seqs = list(self._buf)
        bs = self.batch_size
        for i in range(0, len(order) - bs + 1, bs):
            chunk = np.stack([seqs[j] for j in order[i:i + bs]])
            yield {"tokens": chunk[:, :-1].astype(np.int32),
                   "labels": chunk[:, 1:].astype(np.int32)}

    def batches(self, n: int) -> Iterator[dict[str, np.ndarray]]:
        """Infinite-ish batch stream (reshuffles each epoch)."""
        count = 0
        while count < n:
            for b in self:
                yield b
                count += 1
                if count >= n:
                    return
