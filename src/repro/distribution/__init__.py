"""Sharding rules + mesh-aware partitioning for the production meshes."""

from repro.distribution.sharding import (
    batch_spec, cache_shardings, make_spec, opt_state_shardings,
    param_shardings)

__all__ = ["batch_spec", "cache_shardings", "make_spec",
           "opt_state_shardings", "param_shardings"]
