"""Sharding rules: FSDP ('data') x TP/EP ('model') x pure-DP ('pod').

Design (DESIGN.md §6):
  * 'pod' is pure data-parallel: params replicated across pods, gradients
    all-reduced across the inter-pod links once per step.
  * 'data' is the FSDP axis: params sharded along a non-TP dim, gathered
    per scanned superblock under remat.
  * 'model' is tensor/expert parallel: attention q-heads, MLP hidden,
    Mamba inner channels, MoE experts.

Every rule is a priority list of axis groups per tensor dim; the engine
assigns the first group whose product divides the dim (so kv=1 MQA or
E=16 MoE never produce invalid shardings — they just fall back to
replication, recorded by the caller if needed).
"""

from __future__ import annotations

import math
import re
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = ("data",)
DP = ("pod", "data")      # batch axes (pod first so 2x16 folds cleanly)
TP = ("model",)

# Per-dim candidate axis groups, in priority order.
Rule = Sequence[Optional[Sequence[Sequence[str]]]]

# (name-pattern, rank) -> rule (one entry per trailing dim; leading stack
# dims of scanned params are handled by the caller). First match with the
# right rank wins.
_PARAM_RULES: list[tuple[str, Rule]] = [
    # embeddings
    (r"\bembed$", [[TP], [FSDP]]),
    (r"\bunembed$", [[FSDP], [TP]]),
    # attention (rank 3)
    (r"mixer/wq$", [[FSDP], [TP], None]),
    # Perf iteration B1 (EXPERIMENTS.md §Perf): shard KV heads when they
    # divide TP, else REPLICATE — never shard head_dim. hd-sharding made
    # RoPE's rotate-half split cross shard boundaries, forcing XLA into
    # "involuntary full rematerialization" reshards every layer.
    (r"mixer/wk$|mixer/wv$", [[FSDP], [TP], None]),
    (r"mixer/wo$", [[TP], None, [FSDP]]),
    # rwkv time mix (rank 2: D x D)
    (r"mixer/w[rkvgo]$", [[FSDP], [TP]]),
    (r"mixer/lora_a_\w+$", [[FSDP], None]),
    (r"mixer/lora_b_\w+$", [None, [TP]]),
    (r"mixer/u$", [[TP], None]),
    # dense mlp / rwkv channel mix
    (r"ffn/wi$", [[FSDP], None, [TP]]),                   # (D, g, F)
    (r"ffn/wo$", [[TP], [FSDP]]),                         # (F, D)
    (r"ffn/wr$", [[FSDP], [TP]]),                         # rwkv channel
    (r"ffn/wk$", [[FSDP], [TP]]),                         # (D, F)
    (r"ffn/wv$", [[TP], [FSDP]]),                         # (F, D)
    # moe router
    (r"ffn/router$", [None, None]),
    # mamba
    (r"mixer/in_proj$", [[FSDP], [TP]]),
    (r"mixer/conv_w$", [[TP], None]),
    (r"mixer/conv_b$", [[TP]]),
    (r"mixer/x_proj$", [[TP], None]),
    (r"mixer/dt_proj$", [None, [TP]]),
    (r"mixer/dt_bias$", [[TP]]),
    (r"mixer/A_log$", [[TP], None]),
    (r"mixer/D$", [[TP]]),
    (r"mixer/out_proj$", [[TP], [FSDP]]),
]


def _leaf_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_spec(rule: Rule, shape: Sequence[int], mesh: Mesh) -> P:
    """Greedy assignment: first divisible axis-group per dim wins."""
    used: set[str] = set()
    out: list[Any] = []
    rule = list(rule) + [None] * (len(shape) - len(rule))
    for dim_size, candidates in zip(shape, rule):
        chosen = None
        for group in candidates or []:
            axes = tuple(a for a in group
                         if a in mesh.axis_names and a not in used)
            if not axes:
                continue
            n = math.prod(mesh.shape[a] for a in axes)
            if n > 1 and dim_size % n == 0:
                chosen = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        out.append(chosen)
    return P(*out)


def _rule_for(path_str: str, rank: int, is_moe_leaf: bool) -> Rule:
    if is_moe_leaf:
        # EP over 'model'. (Perf iteration A3 — EP over the data axis —
        # was tried and REFUTED: XLA gathered the full expert weights
        # across data every layer, 1050 GB/chip. See EXPERIMENTS.md §Perf.)
        if path_str.endswith("wi"):
            return [[TP], [FSDP], None, None]              # (E, D, g, F)
        if path_str.endswith("wo"):
            return [[TP], None, [FSDP]]                    # (E, F, D)
    for pat, rule in _PARAM_RULES:
        if re.search(pat, path_str) and len(rule) == rank:
            return rule
    return [None] * rank


def param_shardings(params_tree, mesh: Mesh):
    """NamedSharding tree matching params (works on ShapeDtypeStructs).

    Leaves under 'blocks' have a leading stacked-layer dim (never
    sharded). MoE leaves are recognized by rank (wi rank 4+stack / wo
    rank 3+stack under ffn with expert dim first).
    """
    def one(path, leaf):
        ps = _leaf_path_str(path)
        shape = list(leaf.shape)
        stacked = ps.startswith("blocks")
        core = shape[1:] if stacked else shape
        is_moe = ("ffn" in ps and
                  ((ps.endswith("wi") and len(core) == 4)
                   or (ps.endswith("wo") and len(core) == 3)))
        rule = _rule_for(ps, len(core), is_moe)
        spec = make_spec(rule, core, mesh)
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_spec(mesh: Mesh, batch_size: int, extra_dims: int = 1) -> P:
    """Shard the batch dim over as many DP axes as divide it."""
    used: list[str] = []
    n = 1
    for a in DP:
        if a in mesh.axis_names:
            m = mesh.shape[a]
            if batch_size % (n * m) == 0:
                used.append(a)
                n *= m
    lead = tuple(used) if len(used) > 1 else (used[0] if used else None)
    return P(lead, *([None] * extra_dims))


def batch_shardings(mesh: Mesh, batch_tree):
    def one(leaf):
        return NamedSharding(
            mesh, batch_spec(mesh, leaf.shape[0], len(leaf.shape) - 1))
    return jax.tree_util.tree_map(one, batch_tree)


_CACHE_RULES: list[tuple[str, Rule]] = [
    # Perf iteration C1 (EXPERIMENTS.md §Perf): KV cache (B, S, KV, hd)
    # sharded on SEQUENCE over TP (batch over DP). Decode attention then
    # keeps logits (B, H, S/tp) shard-local and only psums the softmax
    # stats + the (B, H, hd) output partials — the hd-sharded layout
    # psum'd (B, H, S) logits (~805 MB/layer for granite decode_32k).
    (r"\bk$|\bv$", [[DP, FSDP], [TP, FSDP], None, None]),
    (r"\bpos$", [None]),
    # mamba: conv (B, K-1, Di), ssm (B, Di, S)
    (r"\bconv$", [[DP, FSDP], None, [TP]]),
    (r"\bssm$", [[DP, FSDP], [TP], None]),
    # rwkv: shift (B, 1, D), wkv (B, H, dk, dv)
    (r"\bshift$", [[DP, FSDP], None, None]),
    (r"\bwkv$", [[DP, FSDP], [TP], None, None]),
]


def cache_shardings(cache_tree, mesh: Mesh):
    """Shardings for the stacked decode cache (leading superblock dim)."""
    def one(path, leaf):
        ps = _leaf_path_str(path)
        core = list(leaf.shape)[1:]          # drop stacked superblock dim
        rule = [None] * len(core)
        for pat, r in _CACHE_RULES:
            if re.search(pat, ps):
                rule = r
                break
        spec = make_spec(rule, core, mesh)
        return NamedSharding(mesh, P(None, *spec))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_state_shardings(opt_state_tree, params_tree, params_shardings,
                        mesh: Mesh):
    """Adam m/v shard exactly like their params. Row-wise int8 moments
    keep the parameter's shape ('q') so they inherit the SAME sharding;
    their per-block scales drop the last-axis entry. (Perf iteration A4:
    the earlier flat 256-way quant layout forced a full m/v reshard
    every optimizer step — ~600 GB/chip for qwen3 train_4k.)"""
    leaves_sh, treedef = jax.tree_util.tree_flatten(params_shardings)

    def shard_moment_tree(tree):
        leaves = treedef.flatten_up_to(tree)
        out = []
        for leaf, psh in zip(leaves, leaves_sh):
            if isinstance(leaf, dict):   # {'q': param-shape, 'scale': ...}
                pspec = tuple(psh.spec)
                pspec = pspec + (None,) * (leaf["q"].ndim - len(pspec))
                nblk = leaf["scale"].shape[-1] if leaf["scale"].ndim else 1
                last = pspec[-1] if pspec else None
                # keep last-axis sharding on the scale only if it divides
                scale_last = None
                if last is not None:
                    n = math.prod(
                        mesh.shape[a] for a in
                        (last if isinstance(last, tuple) else (last,)))
                    if nblk % n == 0:
                        scale_last = last
                sspec = pspec[:-1] + (scale_last,) if pspec else ()
                out.append({
                    "q": NamedSharding(mesh, P(*pspec)),
                    "scale": NamedSharding(mesh, P(*sspec)),
                })
            else:
                out.append(psh)
        return jax.tree_util.tree_unflatten(treedef, out)

    result: dict = {"count": NamedSharding(mesh, P())}
    for key in ("m", "v"):
        if key in opt_state_tree:
            result[key] = shard_moment_tree(opt_state_tree[key])
    return result
