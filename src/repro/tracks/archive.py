"""Workflow step 2: archive organized leaf directories (paper §III.A).

Many small files => massive random I/O when thousands of parallel
processes touch them (and Lustre's 1 MB block size wastes space). The fix:
one zip archive per *bottom* directory, replicating the first three tiers
of the hierarchy in a new parent directory.

One Task per aircraft directory; runs under a self-scheduled Manager or a
static cyclic distribution (the paper's §IV.B result: cyclic >90 % faster
than block here).
"""

from __future__ import annotations

import dataclasses
import os
import zipfile

from repro.core.messages import Task

LUSTRE_BLOCK_BYTES = 1_000_000   # every file occupies >= 1 MB on Lustre


@dataclasses.dataclass
class ArchiveResult:
    src_dir: str
    zip_path: str
    files: int
    bytes_in: int
    bytes_out: int
    lustre_blocks_saved: int


class Archiver:
    """Zips one aircraft directory into the mirrored archive tree."""

    def __init__(self, organized_root: str, archive_root: str,
                 compression: int = zipfile.ZIP_STORED):
        self.organized_root = organized_root
        self.archive_root = archive_root
        self.compression = compression

    def __call__(self, task: Task) -> ArchiveResult:
        return self.archive_dir(task.payload or task.task_id)

    def archive_dir(self, rel_dir: str) -> ArchiveResult:
        """rel_dir: '<year>/<type>/<seats>/<bucket>/<icao24>'."""
        src = os.path.join(self.organized_root, rel_dir)
        parts = rel_dir.split("/")
        # Replicate the first three tiers; the leaf becomes '<icao>.zip'.
        parent = os.path.join(self.archive_root, *parts[:-1])
        os.makedirs(parent, exist_ok=True)
        zip_path = os.path.join(parent, parts[-1] + ".zip")
        files = 0
        bytes_in = 0
        tmp = zip_path + ".tmp"
        with zipfile.ZipFile(tmp, "w", self.compression) as zf:
            for name in sorted(os.listdir(src)):
                p = os.path.join(src, name)
                if os.path.isfile(p):
                    zf.write(p, arcname=name)
                    files += 1
                    bytes_in += os.path.getsize(p)
        os.replace(tmp, zip_path)   # atomic commit
        bytes_out = os.path.getsize(zip_path)
        saved = max(files - 1, 0) * LUSTRE_BLOCK_BYTES
        return ArchiveResult(
            src_dir=src, zip_path=zip_path, files=files,
            bytes_in=bytes_in, bytes_out=bytes_out,
            lustre_blocks_saved=saved)


def archive_tasks_from_tree(organized_root: str) -> list[Task]:
    """One Task per aircraft dir. Sorted by path => filename order, the
    LLMapReduce default that makes block distribution pathological."""
    tasks = []
    for dirpath, dirnames, filenames in os.walk(organized_root):
        if filenames and not dirnames:
            rel = os.path.relpath(dirpath, organized_root)
            size = sum(os.path.getsize(os.path.join(dirpath, f))
                       for f in filenames)
            tasks.append(Task(task_id=rel.replace(os.sep, "/"),
                              size_bytes=size, timestamp=0.0,
                              payload=rel.replace(os.sep, "/")))
    tasks.sort(key=lambda t: t.task_id)
    return tasks
