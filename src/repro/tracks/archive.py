"""Workflow step 2: archive organized leaf directories (paper §III.A).

Many small files => massive random I/O when thousands of parallel
processes touch them (and Lustre's 1 MB block size wastes space). The fix:
one zip archive per *bottom* directory, replicating the first three tiers
of the hierarchy in a new parent directory.

One Task per aircraft directory; runs under a self-scheduled Manager or a
static cyclic distribution (the paper's §IV.B result: cyclic >90 % faster
than block here).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import zipfile

from repro.core.messages import Task
# One crash-safe-commit implementation repo-wide; the zip is written
# incrementally so only the rename-durability half is shared here.
from repro.store.format import fsync_dir as _fsync_dir

LUSTRE_BLOCK_BYTES = 1_000_000   # every file occupies >= 1 MB on Lustre


@dataclasses.dataclass
class ArchiveResult:
    src_dir: str
    zip_path: str
    files: int
    bytes_in: int
    bytes_out: int
    lustre_blocks_saved: int


class Archiver:
    """Zips one aircraft directory into the mirrored archive tree."""

    @staticmethod
    def _clean_orphans(zip_path: str) -> None:
        """Remove stale ``<zip>.tmp*`` files left by killed workers."""
        parent = os.path.dirname(zip_path)
        prefix = os.path.basename(zip_path) + ".tmp"
        try:
            names = os.listdir(parent)
        except OSError:
            return
        for name in names:
            if name.startswith(prefix):
                try:
                    os.remove(os.path.join(parent, name))
                except OSError:
                    pass        # another cleaner won the race

    def __init__(self, organized_root: str, archive_root: str,
                 compression: int = zipfile.ZIP_STORED):
        self.organized_root = organized_root
        self.archive_root = archive_root
        self.compression = compression

    def __call__(self, task: Task) -> ArchiveResult:
        return self.archive_dir(task.payload or task.task_id)

    def archive_dir(self, rel_dir: str) -> ArchiveResult:
        """rel_dir: '<year>/<type>/<seats>/<bucket>/<icao24>'."""
        src = os.path.join(self.organized_root, rel_dir)
        parts = rel_dir.split("/")
        # Replicate the first three tiers; the leaf becomes '<icao>.zip'.
        parent = os.path.join(self.archive_root, *parts[:-1])
        os.makedirs(parent, exist_ok=True)
        zip_path = os.path.join(parent, parts[-1] + ".zip")
        # Crash safety (the paper's worker-death experiments reach this
        # path): tmp names carry the writer's pid AND thread id so a
        # re-dispatched task — or a speculative backup copy racing the
        # primary on the threads backend, where both share a pid — never
        # collides with another writer's in-progress bytes, and any
        # orphaned .tmp for this archive is removed up front.  If the
        # presumed-dead writer is actually alive, deleting its tmp makes
        # its final rename fail — the correct outcome, since its DONE
        # would be a duplicate of ours.
        self._clean_orphans(zip_path)
        files = 0
        bytes_in = 0
        tmp = f"{zip_path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with zipfile.ZipFile(tmp, "w", self.compression) as zf:
            for name in sorted(os.listdir(src)):
                p = os.path.join(src, name)
                if os.path.isfile(p):
                    zf.write(p, arcname=name)
                    files += 1
                    bytes_in += os.path.getsize(p)
        # fsync BEFORE the rename: os.replace is atomic in the namespace
        # but says nothing about data blocks; a crash right after an
        # unsynced rename can leave a committed name with torn contents.
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        os.replace(tmp, zip_path)   # atomic commit
        _fsync_dir(parent)          # persist the rename itself
        bytes_out = os.path.getsize(zip_path)
        saved = max(files - 1, 0) * LUSTRE_BLOCK_BYTES
        return ArchiveResult(
            src_dir=src, zip_path=zip_path, files=files,
            bytes_in=bytes_in, bytes_out=bytes_out,
            lustre_blocks_saved=saved)


def archive_tasks_from_tree(organized_root: str) -> list[Task]:
    """One Task per aircraft dir. Sorted by path => filename order, the
    LLMapReduce default that makes block distribution pathological."""
    tasks = []
    for dirpath, dirnames, filenames in os.walk(organized_root):
        if filenames and not dirnames:
            rel = os.path.relpath(dirpath, organized_root)
            size = sum(os.path.getsize(os.path.join(dirpath, f))
                       for f in filenames)
            tasks.append(Task(task_id=rel.replace(os.sep, "/"),
                              size_bytes=size, timestamp=0.0,
                              payload=rel.replace(os.sep, "/")))
    tasks.sort(key=lambda t: t.task_id)
    return tasks
