"""Workflow step 1: parse + organize raw observation files (paper §III.A).

Each task parses one raw hourly/query CSV, groups rows by ICAO 24-bit
address, and appends them to per-aircraft CSVs inside the 4-tier
hierarchy. This creates many small files — which is why step 2 (archive)
exists.

Designed to run as the ``fn`` of a self-scheduled Manager: one Task per
raw file, task.payload = the file path.
"""

from __future__ import annotations

import dataclasses
import os
from collections import defaultdict
from typing import Optional

from repro.core.messages import Task
from repro.tracks.registry import HierarchySpec, RegistryEntry


@dataclasses.dataclass
class OrganizeResult:
    raw_file: str
    rows: int
    aircraft: int
    files_written: int
    bytes_written: int


class Organizer:
    """Parses raw state CSVs into the per-aircraft hierarchy."""

    def __init__(self, out_root: str,
                 registry: dict[str, RegistryEntry],
                 hierarchy: Optional[HierarchySpec] = None,
                 year: int = 2019):
        self.out_root = out_root
        self.registry = registry
        self.hierarchy = hierarchy or HierarchySpec()
        self.year = year

    def __call__(self, task: Task) -> OrganizeResult:
        return self.organize_file(task.payload or task.task_id)

    def organize_file(self, raw_path: str) -> OrganizeResult:
        by_aircraft: dict[str, list[str]] = defaultdict(list)
        rows = 0
        with open(raw_path) as f:
            header = f.readline().rstrip("\n")
            icao_col = header.split(",").index("icao24")
            for line in f:
                line = line.rstrip("\n")
                if not line:
                    continue
                icao = line.split(",", icao_col + 2)[icao_col]
                by_aircraft[icao].append(line)
                rows += 1
        files = 0
        nbytes = 0
        for icao, lines in by_aircraft.items():
            entry = self.registry.get(icao)
            d = os.path.join(
                self.out_root,
                self.hierarchy.aircraft_dir(self.year, entry, icao))
            os.makedirs(d, exist_ok=True)
            path = os.path.join(d, f"{icao}.csv")
            is_new = not os.path.exists(path)
            with open(path, "a") as f:
                if is_new:
                    f.write(header + "\n")
                    nbytes += len(header) + 1
                payload = "\n".join(lines) + "\n"
                f.write(payload)
                nbytes += len(payload)
            files += 1
        return OrganizeResult(
            raw_file=raw_path, rows=rows, aircraft=len(by_aircraft),
            files_written=files, bytes_written=nbytes)


def organize_tasks_from_dir(raw_dir: str) -> list[Task]:
    """One Task per raw file; size = file size, timestamp = mtime order."""
    tasks = []
    for name in sorted(os.listdir(raw_dir)):
        if not name.endswith(".csv"):
            continue
        p = os.path.join(raw_dir, name)
        st = os.stat(p)
        tasks.append(Task(task_id=name, size_bytes=st.st_size,
                          timestamp=st.st_mtime, payload=p))
    return tasks
