"""Aviation substrate: datasets, hierarchy, organize/archive/process workflow."""
