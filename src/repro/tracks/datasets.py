"""Synthetic stand-ins for the paper's three datasets.

No network access is available, so we synthesize datasets that match the
paper's *described statistics* (§III.B-C, §V):

  Dataset #1 "Mondays"   : 104 Mondays (2018-02-05 .. 2020-11-16), 24 hourly
                           files/day with gaps => 2425 files, 714 GB total.
                           Fig 3: roughly Gaussian size distribution —
                           diurnal pattern because files are per-UTC-hour.
  Dataset #2 "Aerodromes": 136,884 query-result files over 695 bounding
                           boxes x 196 days, 847 GB. Fig 3: heavy-tailed
                           ("sloping") — activity is not uniform across
                           locations; many small files.
  Radar (§V)             : 13,190,700 deidentified ids across 18 radars,
                           Jan-Sep 2015; tasks are small and uniform;
                           allocated 300 tasks/message => 43,969 messages.

Two products per dataset:
  * a *manifest* of (task_id, size_bytes, timestamp) at FULL scale — drives
    the discrete-event simulator benchmarks; and
  * real, scaled-down CSV files on disk (synthetic ADS-B/radar
    observations) — drive the real workflow end to end.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional

import numpy as np

from repro.core.messages import Task

MB = 1_000_000
GB = 1_000_000_000

# Paper constants.
MONDAY_FILE_COUNT = 2425
MONDAY_TOTAL_BYTES = 714 * GB
MONDAY_COUNT = 104
AERODROME_FILE_COUNT = 136_884
AERODROME_TOTAL_BYTES = 847 * GB
AERODROME_BBOX_COUNT = 695
AERODROME_DAY_COUNT = 196
RADAR_ID_COUNT = 13_190_700
RADAR_TASKS_PER_MESSAGE = 300
RADAR_MESSAGE_COUNT = 43_969   # ceil(13_190_700 / 300)

RADARS = ["ATL", "DEN", "DFW", "FLL", "HPN", "JFK", "LAS", "LAX", "LAXN",
          "MOD", "OAK", "ORDA", "PDX", "PHL", "PHX", "SDF", "SEA", "STL"]


# ---------------------------------------------------------------------------
# Full-scale manifests (for the simulator).
# ---------------------------------------------------------------------------

def monday_manifest(seed: int = 0) -> list[Task]:
    """2425 hourly files with a diurnal (Gaussian-looking, Fig 3) size mix."""
    rng = np.random.default_rng(seed)
    # 104 Mondays x 24 hours = 2496 slots; drop 71 at random (availability
    # is not guaranteed) to hit exactly 2425 files.
    slots = [(d, h) for d in range(MONDAY_COUNT) for h in range(24)]
    drop = rng.choice(len(slots), size=len(slots) - MONDAY_FILE_COUNT,
                      replace=False)
    keep = sorted(set(range(len(slots))) - set(drop.tolist()))
    # Diurnal weight: global ADS-B volume peaks around 14:00 UTC (EU+US
    # daytime overlap). Multiplicative lognormal noise keeps sizes positive.
    days = np.array([slots[i][0] for i in keep])
    hours = np.array([slots[i][1] for i in keep])
    w = 0.35 + 0.65 * 0.5 * (1.0 + np.cos(2.0 * np.pi * (hours - 14) / 24.0))
    w = w * rng.lognormal(mean=0.0, sigma=0.18, size=len(keep))
    sizes = w / w.sum() * MONDAY_TOTAL_BYTES
    ts = days * 86400.0 * 7 + hours * 3600.0
    return [Task(task_id=f"monday/d{d:03d}/h{h:02d}.csv",
                 size_bytes=int(s), timestamp=float(t))
            for d, h, s, t in zip(days, hours, sizes, ts)]


def aerodrome_manifest(seed: int = 1) -> list[Task]:
    """136,884 query files; heavy-tailed sizes ('sloping', Fig 3)."""
    rng = np.random.default_rng(seed)
    n = AERODROME_FILE_COUNT
    # Location 'popularity' is heavy-tailed (Zipf-ish over bounding boxes),
    # compounded with per-day lognormal noise.
    bbox = rng.integers(0, AERODROME_BBOX_COUNT, size=n)
    popularity = rng.pareto(1.2, size=AERODROME_BBOX_COUNT) + 0.05
    w = popularity[bbox] * rng.lognormal(0.0, 0.8, size=n)
    sizes = w / w.sum() * AERODROME_TOTAL_BYTES
    day = rng.integers(0, AERODROME_DAY_COUNT, size=n)
    return [Task(task_id=f"aero/b{b:03d}/d{d:03d}_{i:06d}.csv",
                 size_bytes=int(s), timestamp=float(d) * 86400.0)
            for i, (b, d, s) in enumerate(zip(bbox, day, sizes))]


def radar_message_manifest(seed: int = 2,
                           n_messages: int = RADAR_MESSAGE_COUNT) -> list[Task]:
    """Radar job at MESSAGE granularity (300 ids each, §V).

    Per-message CPU hint: 300 small uniform tasks. Calibrated so the median
    worker busy time lands near the paper's 24.34 h with 1023 workers:
    total ~= 1023 * 87,633 s => ~6.8 s/task average (SQL query + organize +
    interpolate for ONE sensor-contiguous track).
    """
    rng = np.random.default_rng(seed)
    # Each message sums 300 i.i.d. gamma(8) task costs => gamma(2400) per
    # message; per-message relative sd ~2 %, matching the paper's tight
    # 1.12 h span across 24.34 h median worker times.
    per_msg_cpu = rng.gamma(shape=2400.0, scale=6.3 / 8.0,
                            size=n_messages) * (RADAR_TASKS_PER_MESSAGE / 300.0)
    sizes = rng.lognormal(math.log(1.2 * MB), 0.5, size=n_messages) \
        * RADAR_TASKS_PER_MESSAGE
    return [Task(task_id=f"radar/m{i:06d}",
                 size_bytes=int(s), timestamp=float(i),
                 cpu_cost_hint=float(c))
            for i, (s, c) in enumerate(zip(sizes, per_msg_cpu))]


def aircraft_archive_manifest(n_aircraft: int = 30_000,
                              seed: int = 7) -> list[Task]:
    """Leaf-directory archive tasks (§IV.B): one per aircraft.

    Filename-sorted task ids cluster a well-observed aircraft's files
    consecutively; sizes are heavy-tailed AND autocorrelated along the
    sorted order (commercial fleets share registry prefixes), which is the
    precondition for the block-distribution pathology.

    Fleet blocks of ~30 consecutive registrations match one worker's block
    size at 1023 workers, so a hot fleet lands on a single worker under
    block distribution — reproducing the paper's '2 % of processes account
    for >95 % of job time' pathology and the >90 % cyclic win.
    """
    rng = np.random.default_rng(seed)
    fleet_size = 30
    n_blocks = n_aircraft // fleet_size
    block_level = rng.pareto(0.9, size=n_blocks) + 0.01
    blocks = np.repeat(np.arange(n_blocks), fleet_size)[:n_aircraft]
    w = block_level[blocks] * rng.lognormal(0.0, 0.4, size=n_aircraft)
    sizes = w / w.sum() * MONDAY_TOTAL_BYTES
    return [Task(task_id=f"archive/{i:08d}", size_bytes=int(s),
                 timestamp=0.0)
            for i, s in enumerate(sizes)]


def processing_manifest(n_aircraft: int = 40_000, seed: int = 4) -> list[Task]:
    """Track-processing tasks (§IV.C): one per aircraft archive.

    CPU cost scales super-linearly with the aircraft's observation volume
    and with its spatial extent (wide-area tracks load more DEM tiles —
    §V attributes the OpenSky imbalance to exactly this). Calibrated to the
    paper's dataset #2 worker statistics: median 13.1 h, all done in
    29.6 h, 17.3 h fastest-to-slowest span, on 1023 workers.
    """
    rng = np.random.default_rng(seed)
    # The 4-tier hierarchy sorts by year/type/seats/icao24, so a filename
    # sort clusters aircraft of the same TYPE — and types differ hugely in
    # activity (commercial jets vs gliders). That autocorrelation is what
    # block distribution trips over (§IV.B applies to processing too: the
    # paper's predecessor needed >7 days with batch/block).
    n_fleets = 160
    fleet_level = rng.pareto(1.0, size=n_fleets) + 0.02
    fleet = np.sort(rng.integers(0, n_fleets, size=n_aircraft))
    w = fleet_level[fleet] * rng.lognormal(0.0, 0.45, size=n_aircraft)
    sizes = w / w.sum() * AERODROME_TOTAL_BYTES
    extent = rng.lognormal(0.0, 0.4, size=n_aircraft)    # DEM working set
    # CPU grows sublinearly with archive size (dedup/seek amortization) but
    # is inflated by spatial extent. Scale chosen so total work / 1023
    # workers ~= the paper's 13.1 h median; the sublinear exponent tames
    # the Pareto tail so 99.1 % of workers finish within 18 h.
    rel = (sizes / sizes.mean()) ** 0.45 * extent
    # mean 1206 s/task: 40,000 tasks / 1023 workers => ~13.1 h median busy.
    cpu = rel / rel.mean() * 1206.0                      # seconds
    # A handful of continental ferry flights: tracks spanning multiple
    # states load DEM tiles far beyond the norm (§V blames exactly these).
    # They stretch the slowest workers toward the paper's 29.6 h max
    # without moving the 99.1 % quantile.
    k = max(n_aircraft // 2500, 1)
    idx = rng.choice(n_aircraft, size=k, replace=False)
    cpu[idx] += rng.uniform(8.0 * 3600, 15.0 * 3600, size=k)
    return [Task(task_id=f"proc/f{f:03d}/{i:08d}", size_bytes=int(s),
                 timestamp=0.0, cpu_cost_hint=float(c))
            for i, (f, s, c) in enumerate(zip(fleet, sizes, cpu))]


def smoke_manifest(n: int = 200, seed: int = 0) -> list[Task]:
    """Tiny fixed-seed workload for live-backend smoke scenarios.

    Sizes follow the same deterministic pattern the old ad-hoc smoke jobs
    used (``(i * 37) % 23 + 1`` bytes), so a smoke task costs microseconds
    on the threads/processes backends while still exercising batching,
    ordering, and exactly-once accounting.  ``seed`` offsets the pattern so
    distinct smoke scenarios don't share task ids.
    """
    return [Task(task_id=f"smoke{seed}/t{i:04d}",
                 size_bytes=((i + seed) * 37) % 23 + 1, timestamp=float(i))
            for i in range(n)]


def heavy_tail_manifest(n: int = 20_000, seed: int = 5) -> list[Task]:
    """Many small tasks under a heavy Pareto tail (beyond-paper).

    The scheduling-policy bench's acceptance dataset: the §V radar
    regime (so many sub-second-to-seconds tasks that per-message
    overhead and the manager's serial send matter at
    ``tasks_per_message=1``) crossed with the aerodrome datasets'
    heavy-tailed size mix (Fig 3 "sloping": a few tasks hundreds of
    times the median).  Pareto(1.6) compute hints put the largest task
    near ``total/P`` for the bench's worker counts, which is the regime
    where dispatch ORDER (sized_lpt) and cost-budgeted chunking
    (adaptive_chunk) each separate from naive FIFO dispatch — exactly
    the gap the companion 2020 HPC paper measured behind stragglers.
    Task order is shuffled (timestamps are a random permutation), so
    chronological organization models an arrival stream with no
    helpful accidental ordering.
    """
    rng = np.random.default_rng(seed)
    cpu = 0.35 + rng.pareto(1.6, size=n) * 1.9           # seconds
    sizes = (cpu / cpu.mean()) * 260_000                  # bytes ~ cpu
    order = rng.permutation(n)
    return [Task(task_id=f"ht/t{i:06d}", size_bytes=max(int(s), 1_000),
                 timestamp=float(order[i]), cpu_cost_hint=float(c))
            for i, (s, c) in enumerate(zip(sizes, cpu))]


def tiny_task_manifest(n: int = 131_400, seed: int = 0) -> list[Task]:
    """Radar-like tiny-uniform tasks at reduced count (beyond-paper).

    The §V regime — so many sub-second tasks that the manager's serial
    send loop is the constraint — scaled to 131,400 tasks so sweeps over
    tasks-per-message stay simulable in seconds.
    """
    rng = np.random.default_rng(seed)
    return [Task(task_id=f"tiny/t{i:06d}", size_bytes=400_000,
                 timestamp=float(i),
                 cpu_cost_hint=float(rng.gamma(8.0, 0.25 / 8)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# Encounter-screening density manifests (beyond-paper).
# ---------------------------------------------------------------------------

#: Modeled store re-read bytes per cell row (one resampled segment's
#: lat/lon/alt planes).  Screen-cell task sizes are
#: ``occupancy * SCREEN_ROW_BYTES``, so goldens and cost models can
#: recover occupancy from ``size_bytes`` exactly.
SCREEN_ROW_BYTES = 12_000

_SCREEN_REGION = (24.0, 48.0, -125.0, -67.0)       # lat/lon box (CONUS)

# Eight busy terminal areas; the paper's dataset #2 is aerodrome-anchored
# bounding-box queries, so density concentrates at a handful of hotspots.
_SCREEN_HOTSPOTS = [
    (33.64, -84.43), (32.90, -97.04), (39.86, -104.67), (41.98, -87.90),
    (33.94, -118.41), (40.64, -73.78), (37.62, -122.38), (47.45, -122.31),
]


#: Screen-trail sample spacing (seconds).  Trail start times snap to
#: this grid so pair placement on a shared time grid is independent of
#: the grid anchor (cell minimum vs global minimum) — the property the
#: grid-vs-brute-force exactness gate in ``repro.bench.encounters``
#: relies on.
SCREEN_TRAIL_DT_S = 15.0


def screen_density_trails(kind: str, n_aircraft: int, seed: int, *,
                          cell_t_s: float = 3600.0) -> list[tuple]:
    """Synthetic aircraft sample trails for the screening manifests.

    Each aircraft contributes one short straight trail (8 samples at
    ``SCREEN_TRAIL_DT_S``): ``(aircraft_id, times, lat, lon, alt)``.
    ``kind='dense'`` concentrates traffic at eight terminal hotspots
    plus inter-hotspot corridors at low altitude; ``kind='sparse'``
    spreads cruise-altitude overflights across the whole region.
    """
    rng = np.random.default_rng(seed)
    lat_lo, lat_hi, lon_lo, lon_hi = _SCREEN_REGION
    hot = np.array(_SCREEN_HOTSPOTS)
    rows = []
    for i in range(n_aircraft):
        if kind == "dense":
            if rng.random() < 0.7:      # terminal-area traffic
                c = hot[rng.integers(len(hot))]
                lat0 = c[0] + rng.normal(0.0, 0.05)
                lon0 = c[1] + rng.normal(0.0, 0.05)
            else:                        # inter-hotspot corridor
                a, b = hot[rng.choice(len(hot), 2, replace=False)]
                f = rng.random()
                lat0 = a[0] + f * (b[0] - a[0]) + rng.normal(0.0, 0.03)
                lon0 = a[1] + f * (b[1] - a[1]) + rng.normal(0.0, 0.03)
            alt0 = float(rng.lognormal(np.log(450.0), 0.5))
            speed = rng.uniform(60.0, 120.0)
        else:                            # "sparse": en-route overflights
            a = np.array([rng.uniform(lat_lo, lat_hi),
                          rng.uniform(lon_lo, lon_hi)])
            b = np.array([rng.uniform(lat_lo, lat_hi),
                          rng.uniform(lon_lo, lon_hi)])
            f = rng.random()
            lat0, lon0 = a + f * (b - a) + rng.normal(0.0, 0.15, 2)
            alt0 = rng.uniform(7_000.0, 12_000.0)
            speed = rng.uniform(180.0, 260.0)
        hdg = rng.uniform(0.0, 2.0 * np.pi)
        ns, dt = 8, SCREEN_TRAIL_DT_S
        t0 = round(float(rng.uniform(0.0, cell_t_s / 2)) / dt) * dt
        ts = t0 + np.arange(ns) * dt
        step = speed * dt / 111_111.0
        la = lat0 + np.cos(hdg) * step * np.arange(ns)
        lo = lon0 + np.sin(hdg) * step * np.arange(ns) \
            / max(np.cos(np.deg2rad(lat0)), 0.2)
        al = np.full(ns, alt0) + rng.normal(0.0, 5.0, ns).cumsum()
        rows.append((f"a{i:05d}", ts, la, lo, al))
    return rows


def _density_screen_tasks(kind: str, n_aircraft: int, seed: int, *,
                          cell_deg: float = 0.25, cell_alt_m: float = 300.0,
                          cell_t_s: float = 3600.0) -> list[Task]:
    """Screen-cell tasks from a real spatial-hash binning of the
    :func:`screen_density_trails` trails.

    Trails are binned through
    :func:`repro.geometry.gridhash.bin_samples` with the default
    screening-threshold halo, and every multi-occupancy cell becomes
    one task (singleton cells never reach the kernel, so they are not
    workload).  ``cpu_cost_hint = cell_cost(occupancy)`` — quadratic —
    and timestamps are a random permutation, so chronological arrival
    models an unordered cell stream.
    """
    from repro.geometry import gridhash
    rng = np.random.default_rng(seed + 101)
    spec = gridhash.GridSpec(cell_deg=cell_deg, cell_alt_m=cell_alt_m,
                             cell_t_s=cell_t_s)
    rows = screen_density_trails(kind, n_aircraft, seed,
                                 cell_t_s=cell_t_s)
    bins = gridhash.bin_samples(rows, spec=spec, h_pad_m=926.0,
                                v_pad_m=152.4)
    cells = sorted((key, len(ids)) for key, ids in bins.items()
                   if len(ids) >= 2)
    order = rng.permutation(len(cells))
    return [Task(task_id=f"screen/{kind}/{gridhash.cell_id(key)}",
                 size_bytes=occ * SCREEN_ROW_BYTES,
                 timestamp=float(order[k]),
                 cpu_cost_hint=gridhash.cell_cost(occ))
            for k, (key, occ) in enumerate(cells)]


def aerodrome_dense_manifest(n_aircraft: int = 3000,
                             seed: int = 11) -> list[Task]:
    """Aerodrome-dense screening cells (paper dataset #2 regime).

    Traffic concentrates at eight terminal hotspots plus the corridors
    between them, so a few cells hold hundreds of rows while the bulk
    hold a handful — with quadratic per-cell cost, the resulting skew
    is far beyond any size-linear manifest and is the acceptance
    workload for ``sized_lpt``/``adaptive_chunk`` in
    ``repro.bench.encounters``.
    """
    return _density_screen_tasks("dense", n_aircraft, seed)


def enroute_sparse_manifest(n_aircraft: int = 900,
                            seed: int = 12) -> list[Task]:
    """En-route-sparse screening cells (paper dataset #1 regime).

    Overflights spread across the whole region at cruise altitudes:
    almost every occupied cell holds one or two rows, so max-cell
    occupancy stays an order of magnitude below the aerodrome-dense
    manifest (asserted by the dataset goldens) and screening cost is
    dominated by per-task overhead, not pair count.
    """
    return _density_screen_tasks("sparse", n_aircraft, seed)


# ---------------------------------------------------------------------------
# Manifest registry — the declarative handle the bench subsystem uses.
# ---------------------------------------------------------------------------

MANIFESTS = {
    "monday": monday_manifest,
    "aerodrome": aerodrome_manifest,
    "radar_messages": radar_message_manifest,
    "archive": aircraft_archive_manifest,
    "processing": processing_manifest,
    "smoke": smoke_manifest,
    "heavy_tail": heavy_tail_manifest,
    "tiny": tiny_task_manifest,
    "aerodrome_dense": aerodrome_dense_manifest,
    "enroute_sparse": enroute_sparse_manifest,
}

_manifest_cache: dict[tuple, list[Task]] = {}


def get_manifest(name: str, *, limit: Optional[int] = None,
                 **kwargs) -> list[Task]:
    """Build (and memoize) a named manifest.

    ``limit`` truncates AFTER generation so a scaled scenario sees a prefix
    of the exact full-scale task population.  Returns a fresh list each
    call; the cached copy is never handed out for mutation.
    """
    if name not in MANIFESTS:
        raise KeyError(f"unknown manifest {name!r}; "
                       f"choose from {sorted(MANIFESTS)}")
    key = (name, tuple(sorted(kwargs.items())))
    if key not in _manifest_cache:
        _manifest_cache[key] = MANIFESTS[name](**kwargs)
    tasks = _manifest_cache[key]
    return list(tasks if limit is None else tasks[:limit])


def manifest_stats(tasks: list[Task]) -> dict:
    """Distribution summary used by golden tests and BENCH artifacts."""
    sizes = np.array([t.size_bytes for t in tasks], dtype=float)
    total = float(sizes.sum())
    srt = np.sort(sizes)
    top1 = max(len(tasks) // 100, 1)
    return {
        "count": len(tasks),
        "total_bytes": int(total),
        "mean_bytes": float(sizes.mean()) if len(tasks) else 0.0,
        "median_over_mean": (float(np.median(sizes) / sizes.mean())
                             if total else 0.0),
        "cv": float(sizes.std() / sizes.mean()) if total else 0.0,
        "top1pct_share": float(srt[-top1:].sum() / total) if total else 0.0,
    }


# ---------------------------------------------------------------------------
# Real scaled-down observation files (for the actual workflow).
# ---------------------------------------------------------------------------

STATE_COLUMNS = ["time", "icao24", "lat", "lon", "velocity", "heading",
                 "vertrate", "baroaltitude", "geoaltitude", "onground"]


@dataclasses.dataclass(frozen=True)
class ScaledDatasetSpec:
    """A scaled-down real dataset written to disk.

    ``scale`` divides file sizes; e.g. scale=1e6 turns 714 GB into ~714 KB
    of actual CSV. Observation counts follow from bytes/row (~80 B)."""
    name: str
    n_files: int
    scale: float
    seed: int = 0
    update_period_s: float = 10.0    # dataset #1: >=10 s between obs


def _synth_track_points(rng: np.random.Generator, n: int, icao24: str,
                        t0: float, period_s: float) -> list[str]:
    """One aircraft's observation rows: a smooth random flight."""
    t = t0 + np.arange(n) * period_s
    lat0 = rng.uniform(25.0, 48.0)
    lon0 = rng.uniform(-124.0, -67.0)
    heading = rng.uniform(0, 360)
    speed = rng.uniform(30.0, 220.0)          # m/s
    turn = rng.normal(0.0, 0.3, size=n).cumsum()
    hdg = np.deg2rad(heading + turn)
    dlat = speed * np.cos(hdg) * period_s / 111_111.0
    dlon = speed * np.sin(hdg) * period_s / (111_111.0 *
                                             np.cos(np.deg2rad(lat0)))
    lat = lat0 + np.concatenate([[0.0], dlat[:-1]]).cumsum()
    lon = lon0 + np.concatenate([[0.0], dlon[:-1]]).cumsum()
    alt0 = rng.uniform(300.0, 3000.0)
    vr = rng.normal(0.0, 2.0, size=n)
    alt = np.maximum(alt0 + (vr * period_s).cumsum(), 10.0)
    rows = []
    for i in range(n):
        rows.append(
            f"{t[i]:.0f},{icao24},{lat[i]:.5f},{lon[i]:.5f},"
            f"{speed:.1f},{np.rad2deg(hdg[i]) % 360:.1f},{vr[i]:.2f},"
            f"{alt[i]:.1f},{alt[i] + rng.normal(0, 8):.1f},0")
    return rows


def write_scaled_dataset(root: str, spec: ScaledDatasetSpec,
                         manifest: Optional[list[Task]] = None) -> list[str]:
    """Write real CSV files whose sizes follow ``manifest`` / ``scale``.

    Returns the list of file paths. Each file holds whole synthetic tracks
    (multiple aircraft), like an OpenSky hourly state file.
    """
    rng = np.random.default_rng(spec.seed)
    if manifest is None:
        manifest = monday_manifest(spec.seed)[: spec.n_files]
    manifest = manifest[: spec.n_files]
    os.makedirs(root, exist_ok=True)
    paths = []
    header = ",".join(STATE_COLUMNS)
    for task in manifest:
        target_bytes = max(int(task.size_bytes / spec.scale), 400)
        path = os.path.join(root, task.task_id.replace("/", "_"))
        if not path.endswith(".csv"):
            path += ".csv"
        rows: list[str] = []
        nbytes = len(header) + 1
        while nbytes < target_bytes:
            # US registry block (matches tracks.registry.synthetic_registry)
            icao24 = f"{rng.integers(0xA00000, 0xB00000):06x}"
            n = int(rng.integers(12, 120))
            chunk = _synth_track_points(
                rng, n, icao24, task.timestamp, spec.update_period_s)
            rows.extend(chunk)
            nbytes += sum(len(r) + 1 for r in chunk)
        with open(path, "w") as f:
            f.write(header + "\n")
            f.write("\n".join(rows) + "\n")
        paths.append(path)
    return paths
