"""Synthetic aircraft registries + the 4-tier directory hierarchy.

Paper §III.A: national aircraft registries give each aircraft's type,
registration expiration, and ICAO 24-bit address. The hierarchy is::

    <year>/<aircraft type>/<number of seats>/<icao24 bucket>/

with no more than 1000 directories per level (LLSC recommendation), deep
and wide enough for efficient parallel I/O across the whole structure.

The radar dataset (§V) uses year/radar/month-range/unique-id instead; both
layouts share HierarchySpec.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

AIRCRAFT_TYPES = [
    "FixedWingSingleEngine", "FixedWingMultiEngine", "Rotorcraft",
    "Glider", "Balloon", "Unknown",
]
# Seat buckets keep tier 3 under 1000 dirs.
SEAT_BUCKETS = ["1-4", "5-9", "10-19", "20-99", "100+", "NA"]


@dataclasses.dataclass(frozen=True)
class RegistryEntry:
    icao24: str            # 6-hex-digit transponder address
    aircraft_type: str
    seats: int
    expiration_year: int

    @property
    def seat_bucket(self) -> str:
        if self.seats <= 0:
            return "NA"
        if self.seats <= 4:
            return "1-4"
        if self.seats <= 9:
            return "5-9"
        if self.seats <= 19:
            return "10-19"
        if self.seats <= 99:
            return "20-99"
        return "100+"


def synthetic_registry(n: int = 5000, seed: int = 13) -> dict[str, RegistryEntry]:
    """Synthetic union of national registries keyed by icao24."""
    rng = np.random.default_rng(seed)
    out: dict[str, RegistryEntry] = {}
    type_p = [0.45, 0.25, 0.12, 0.08, 0.02, 0.08]
    while len(out) < n:
        icao = f"{rng.integers(0xA00000, 0xAFFFFF):06x}"  # US block
        if icao in out:
            continue
        at = AIRCRAFT_TYPES[int(rng.choice(len(AIRCRAFT_TYPES), p=type_p))]
        seats = {
            "FixedWingSingleEngine": int(rng.integers(1, 7)),
            "FixedWingMultiEngine": int(rng.choice(
                [6, 9, 19, 50, 150, 220], p=[.2, .2, .2, .15, .15, .1])),
            "Rotorcraft": int(rng.integers(1, 15)),
            "Glider": int(rng.integers(1, 3)),
            "Balloon": int(rng.integers(1, 9)),
            "Unknown": 0,
        }[at]
        out[icao] = RegistryEntry(
            icao24=icao, aircraft_type=at, seats=seats,
            expiration_year=int(rng.integers(2019, 2026)))
    return out


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """4-tier hierarchy with <=1000 dirs per level."""
    max_dirs_per_level: int = 1000
    icao_bucket_hex_digits: int = 2   # 256 buckets at the icao24 level

    def leaf_dir(self, year: int, entry: Optional[RegistryEntry],
                 icao24: str) -> str:
        at = entry.aircraft_type if entry else "Unknown"
        sb = entry.seat_bucket if entry else "NA"
        bucket = icao24[: self.icao_bucket_hex_digits]
        return f"{year}/{at}/{sb}/{bucket}"

    def aircraft_dir(self, year: int, entry: Optional[RegistryEntry],
                     icao24: str) -> str:
        return f"{self.leaf_dir(year, entry, icao24)}/{icao24}"

    def radar_dir(self, year: int, radar: str, month_range: str,
                  unique_id: str) -> str:
        """§V layout: year/radar/month-range/unique-id."""
        return f"{year}/{radar}/{month_range}/{unique_id}"

    def validate_fanout(self, paths: list[str]) -> bool:
        """No level exceeds max_dirs_per_level children."""
        children: dict[str, set[str]] = {}
        for p in paths:
            parts = p.split("/")
            for i in range(len(parts)):
                parent = "/".join(parts[:i])
                children.setdefault(parent, set()).add(parts[i])
        return all(len(v) <= self.max_dirs_per_level
                   for v in children.values())
