"""Workflow step 3: process + interpolate into track segments (§III.A).

Per aircraft archive:
  1. split raw observations into segments on time gaps;
  2. drop segments with fewer than ten observations (paper rule);
  3. resample each segment onto a uniform grid  -> kernels.track_interp;
  4. AGL altitude = MSL - DEM elevation         -> kernels.agl_lookup;
  5. dynamic rates (vrate/speed/heading/turn)   -> kernels.dynamic_rates;
  6. airspace class tag (nearest aerodrome within the terminal cylinder).

Steps 3-5 run through the fused device-resident pipeline
(:func:`repro.kernels.ops.process_segments`): one jit'd call per length
bucket, no intermediate host<->device transfers.  Segments are binned
into power-of-two width buckets (:data:`BUCKET_SIZES`) instead of one
global (B, 1024) tile, bounding padding waste to <2x for any segment at
least half a bucket long (the old fixed tile wasted ~100x on a
10-observation segment); one compilation is cached per bucket shape.
``pipeline='unfused'`` keeps the historical three-launch host-hop path
as the benchmark baseline (``benchmarks/kernel_bench.py`` measures one
against the other).

Input is either the PR-0 zip/CSV path (text re-parsed per run) or the
columnar track store (:mod:`repro.store`): ``store://`` task payloads
select tracks, shards, or row ranges, and
:meth:`SegmentProcessor.process_store` streams whole shards through the
fused pipeline behind the store's async prefetcher.
"""

from __future__ import annotations

import dataclasses
import os
import zipfile
from typing import Optional, Sequence

import numpy as np

from repro.core.messages import Task
from repro.geometry.aerodromes import Aerodrome
from repro.geometry.dem import SyntheticGlobeDEM
from repro.geometry.queries import RADIUS_DEG
from repro.kernels import ops

MIN_OBS_PER_SEGMENT = 10       # paper: remove segments with <10 observations
SEGMENT_GAP_S = 120.0          # new segment after a 2-minute gap
RESAMPLE_DT_S = 1.0            # uniform 1 Hz grid
MAX_SEG_POINTS = 1024          # widest tile (pad/truncate ceiling)
BUCKET_SIZES = (128, 256, 512, 1024)   # ragged-batch width buckets


def bucket_width(n: int) -> int:
    """Smallest bucket that holds an ``n``-point segment (capped)."""
    for k in BUCKET_SIZES:
        if n <= k:
            return k
    return BUCKET_SIZES[-1]


def segment_shape(times: np.ndarray, s: slice) -> tuple[int, int]:
    """One segment's fused-pipeline shape: (raw knots n, grid points m).

    The single source of truth for shard ingest (``repro.store.writer``
    records these in the manifest index) and for live batching
    (:meth:`SegmentProcessor._records`), so index-driven bucket plans
    agree exactly with what the pipeline would compute from payloads.
    """
    n = min(s.stop - s.start, MAX_SEG_POINTS)
    t = times[s.start:s.start + n]
    m = min(int((t[-1] - t[0]) / RESAMPLE_DT_S) + 1, MAX_SEG_POINTS)
    return n, m


def read_observations(path: str) -> dict[str, np.ndarray]:
    """Read a per-aircraft CSV (possibly inside a .zip archive).

    The parse is vectorized: one ``np.loadtxt`` over the decoded payload
    per column group instead of a Python ``split(',')`` loop per line
    (the loop dominated small-archive task cost).  This text decode is
    what the columnar store (:mod:`repro.store`) pays exactly once, at
    ingest."""
    if path.endswith(".zip"):
        with zipfile.ZipFile(path) as zf:
            text = zf.read(zf.namelist()[0]).decode()
    else:
        with open(path) as f:
            text = f.read()
    nl = text.find("\n")
    if nl < 0 or not text[nl:].strip():
        return {}
    cols = {c: i for i, c in enumerate(text[:nl].strip().split(","))}
    lines = [ln for ln in text[nl + 1:].split("\n") if ln.strip()]
    num = np.loadtxt(lines, delimiter=",", ndmin=2,
                     usecols=[cols[c] for c in
                              ("time", "lat", "lon", "geoaltitude")])
    icao = np.loadtxt(lines, delimiter=",", dtype=str,
                      usecols=cols["icao24"], ndmin=1)
    t = num[:, 0]
    order = np.argsort(t, kind="stable")
    return {
        "time": t[order],
        "lat": num[order, 1],
        "lon": num[order, 2],
        "alt": num[order, 3],
        "icao24": icao[order],
    }


def _round_rows(b: int) -> int:
    """Round a bucket's row count up: powers of two below 8, multiples
    of 8 after — at most 7 padded rows, and far fewer compiled batch
    shapes per bucket width than one per distinct segment count."""
    p = 1
    while p < b and p < 8:
        p *= 2
    return p if b <= 8 else -(-b // 8) * 8


@dataclasses.dataclass
class ProcessedSegments:
    """One archive's processed segments as (B, W) planes; ``W`` is the
    archive's widest bucket (<= MAX_SEG_POINTS), ``count`` masks rows."""
    icao24: list[str]
    times: np.ndarray       # (B, W) uniform grid times
    lat: np.ndarray         # (B, W)
    lon: np.ndarray         # (B, W)
    alt_msl_m: np.ndarray   # (B, W)
    alt_agl_m: np.ndarray   # (B, W)
    vrate_ms: np.ndarray    # (B, W)
    gspeed_ms: np.ndarray   # (B, W)
    heading_rad: np.ndarray  # (B, W)
    turn_rad_s: np.ndarray  # (B, W)
    count: np.ndarray       # (B,)
    airspace: list[str]

    def __len__(self) -> int:
        return len(self.count)


# Field name mapping: fused-pipeline plane -> ProcessedSegments attribute.
_PLANE_ATTRS = (("times", "times"), ("lat", "lat"), ("lon", "lon"),
                ("alt_msl", "alt_msl_m"), ("alt_agl", "alt_agl_m"),
                ("vrate", "vrate_ms"), ("gspeed", "gspeed_ms"),
                ("heading", "heading_rad"), ("turn", "turn_rad_s"))


def split_segments(times: np.ndarray, gap_s: float = SEGMENT_GAP_S,
                   min_obs: int = MIN_OBS_PER_SEGMENT) -> list[slice]:
    """Split a sorted time vector into gap-delimited segments, dropping
    those shorter than ``min_obs`` (the paper's ten-observation rule)."""
    if len(times) == 0:
        return []
    breaks = np.flatnonzero(np.diff(times) > gap_s) + 1
    out = []
    for s, e in zip(np.r_[0, breaks], np.r_[breaks, len(times)]):
        if e - s >= min_obs:
            out.append(slice(int(s), int(e)))
    return out


def _is_store_uri(path) -> bool:
    """Lazy delegate to :mod:`repro.store.reader` (one URI definition)."""
    from repro.store.reader import is_store_uri
    return is_store_uri(path)


def _parse_store_uri(uri: str):
    from repro.store.reader import parse_store_uri
    return parse_store_uri(uri)


@dataclasses.dataclass
class _SegRecord:
    """One segment, flattened out of its archive for bucketed batching."""
    arch: int               # archive index in the _process_many items
    name: str
    t: np.ndarray           # raw times, truncated to MAX_SEG_POINTS
    lat: np.ndarray
    lon: np.ndarray
    alt: np.ndarray
    n: int                  # valid knots
    m: int                  # valid output grid points
    width: int              # bucket width (>= max(n, m))
    may_span: bool          # track may cross a DEM tile border


class SegmentProcessor:
    """Processes one organized/archived aircraft file into segments."""

    def __init__(self, dem: Optional[SyntheticGlobeDEM] = None,
                 aerodromes: Optional[Sequence[Aerodrome]] = None,
                 backend: str = "pallas", pipeline: str = "fused"):
        if pipeline not in ("fused", "unfused"):
            raise ValueError(f"unknown pipeline {pipeline!r}")
        self.dem = dem or SyntheticGlobeDEM()
        self.aerodromes = list(aerodromes or [])
        self.backend = backend
        self.pipeline = pipeline
        self._stores: dict = {}          # store root -> TrackStore
        self._dem_f32 = self.dem.elevation_m.astype(np.float32)
        self._dem_grid = (self.dem.lat_min, self.dem.lat_max,
                          self.dem.lon_min, self.dem.lon_max,
                          float(self.dem.cells_per_deg))
        self.last_stats: dict = {}
        if self.aerodromes:
            self._aero_lat = np.array([a.lat for a in self.aerodromes])
            self._aero_lon = np.array([a.lon for a in self.aerodromes])
            self._aero_cls = [a.airspace_class for a in self.aerodromes]

    # -- io -------------------------------------------------------------

    def __call__(self, task: Task):
        return self.process_file(task.payload or task.task_id)

    def read_observations(self, path: str) -> dict[str, np.ndarray]:
        """One source -> observation dict.  Accepts a CSV path, a PR-0
        zip archive, or a single-track ``store://`` URI (columnar-store
        reads skip the text parse entirely)."""
        if _is_store_uri(path):
            root, sel = _parse_store_uri(path)
            if "track" not in sel:
                raise ValueError(
                    f"read_observations needs a single track; {path!r} "
                    f"selects a shard (use process_file/process_batch)")
            return self._store_read(
                root, lambda st: st.read_track(sel["track"]))
        return read_observations(path)

    # -- store-backed input ----------------------------------------------

    def _store(self, root: str):
        """One cached TrackStore per store root (index parsed once)."""
        store = self._stores.get(root)
        if store is None:
            from repro.store.reader import TrackStore
            store = self._stores[root] = TrackStore(root)
        return store

    def _store_read(self, root: str, fn):
        """Run one read against the cached store, retrying once after a
        manifest reload on a missed track/shard — a streaming-DAG store
        grows while it is being processed, so a worker's index snapshot
        can predate the shard its task names."""
        store = self._store(root)
        try:
            return fn(store)
        except KeyError:
            store.reload()
            return fn(store)

    def _store_items(self, uri: str) -> list[tuple[str, dict, list[slice]]]:
        """store:// URI -> [(track_id, obs, segs)] for its selection."""
        root, sel = _parse_store_uri(uri)
        return self._store_read(root, lambda st: st.read_selection(sel))

    def process_store(self, root: str, *, prefetch: int = 1,
                      plans=None) -> dict[str, "ProcessedSegments"]:
        """Stream the whole store (or ``plans``) through the fused
        pipeline: the async prefetcher decodes shard N+1 while the
        device processes shard N.  Returns {track_id: ProcessedSegments}.
        """
        store = self._store(root)
        out: dict[str, ProcessedSegments] = {}
        for batch in store.iter_batches(plans, prefetch=prefetch):
            out.update(self._process_triples(
                [(tid, obs, segs) for tid, (obs, segs)
                 in zip(batch.track_ids, batch.items)]))
        return out

    # -- processing -------------------------------------------------------

    def process_file(self, path: str):
        """One source -> ProcessedSegments; a multi-track ``store://``
        selection (shard / row range / whole store) -> a dict keyed by
        track_id."""
        if _is_store_uri(path):
            _root, sel = _parse_store_uri(path)
            if "track" not in sel:
                return self._process_selection(path)
        obs = self.read_observations(path)
        if not obs:
            return _empty()
        segs = split_segments(obs["time"])
        if not segs:
            return _empty()
        return self.process_arrays(obs, segs)

    def _process_selection(self, uri: str) -> dict:
        return self._process_triples(self._store_items(uri))

    def _process_triples(self, triples: list) -> dict:
        """[(track_id, obs, segs)] -> {track_id: ProcessedSegments},
        ONE fused pass over the non-empty items — the single merge
        helper behind store selections AND store streaming."""
        out = {tid: _empty() for tid, _obs, segs in triples if not segs}
        work = [(tid, (obs, segs)) for tid, obs, segs in triples if segs]
        if work:
            for (tid, _), ps in zip(
                    work, self._process_many([it for _, it in work])):
                out[tid] = ps
        return out

    def process_arrays(self, obs: dict[str, np.ndarray],
                       segs: list[slice]) -> ProcessedSegments:
        return self._process_many([(obs, segs)])[0]

    def process_batch(self, tasks: Sequence[Task]) -> dict:
        """Runtime batch hook: one multi-task ASSIGN message -> bucketed
        fused pipeline calls over every segment of every source in the
        batch, instead of per-task Python dispatch.  Returns
        ``{task_id: result}`` (what the worker reports DONE): a
        ProcessedSegments per zip/CSV/single-track task, a
        ``{track_id: ProcessedSegments}`` dict per multi-track
        ``store://`` task — with ONE fused pipeline pass over all of it.
        """
        out: dict[str, object] = {}
        items: list[tuple[dict, list[slice]]] = []
        # (task_id, track_key or None, item index); key None = the
        # task's result IS the ProcessedSegments, else it lands in the
        # task's per-track dict under that key.
        slots: list[tuple[str, Optional[str], int]] = []
        for task in tasks:
            path = task.payload or task.task_id
            if _is_store_uri(path):
                _root, sel = _parse_store_uri(path)
                single = "track" in sel
                if not single:
                    out[task.task_id] = {}
                for tid, obs, segs in self._store_items(path):
                    key = None if single else tid
                    if segs:
                        slots.append((task.task_id, key, len(items)))
                        items.append((obs, segs))
                    elif single:
                        out[task.task_id] = _empty()
                    else:
                        out[task.task_id][tid] = _empty()
                continue
            obs = self.read_observations(path)
            segs = split_segments(obs["time"]) if obs else []
            if segs:
                slots.append((task.task_id, None, len(items)))
                items.append((obs, segs))
            else:
                out[task.task_id] = _empty()
        if items:
            processed = self._process_many(items)
            for task_id, key, idx in slots:
                if key is None:
                    out[task_id] = processed[idx]
                else:
                    out[task_id][key] = processed[idx]
        return out

    def _process_many(self, items: list[tuple[dict, list[slice]]]
                      ) -> list[ProcessedSegments]:
        if self.pipeline == "unfused":
            return self._process_many_unfused(items)
        return self._process_many_fused(items)

    # -- fused, length-bucketed path --------------------------------------

    # Conservative guard band (in DEM cells) added to the host-side
    # tile-span check: the device predicate works on f32 interp output,
    # the host bound on f64 raw knots — the margin absorbs the rounding.
    _SPAN_MARGIN = 0.5

    def _may_span(self, lat: np.ndarray, lon: np.ndarray) -> bool:
        """Can this track's DEM window cross a tile border?  Interp
        output is a convex combination of the knots, so knot extents
        bound it; False proves the fused op needs no oracle fallback."""
        lat_min, lat_max, lon_min, lon_max, cpd = self._dem_grid
        H, W = self._dem_f32.shape

        def axis_spans(v, lo, hi, cells, tile):
            f0 = (min(max(float(v.min()), lo), hi) - lo) * cpd
            f1 = (min(max(float(v.max()), lo), hi) - lo) * cpd
            f0 = min(max(f0, 0.0), cells - 1.001)
            f1 = min(max(f1, 0.0), cells - 1.001)
            origin = (f0 // tile) * tile
            return (f1 - origin) >= tile - 1 - self._SPAN_MARGIN

        return (axis_spans(lat, lat_min, lat_max, H, ops.TILE_H)
                or axis_spans(lon, lon_min, lon_max, W, ops.TILE_W))

    def _records(self, items: list[tuple[dict, list[slice]]]
                 ) -> list[_SegRecord]:
        records: list[_SegRecord] = []
        for ai, (obs, segs) in enumerate(items):
            for s in segs:
                n, m = segment_shape(obs["time"], s)
                sl = slice(s.start, s.start + n)
                t = obs["time"][sl]
                lat, lon = obs["lat"][sl], obs["lon"][sl]
                records.append(_SegRecord(
                    arch=ai, name=str(obs["icao24"][s.start]), t=t,
                    lat=lat, lon=lon, alt=obs["alt"][sl], n=n, m=m,
                    width=bucket_width(max(n, m)),
                    may_span=self._may_span(lat, lon)))
        return records

    def _process_many_fused(self, items: list[tuple[dict, list[slice]]]
                            ) -> list[ProcessedSegments]:
        """Bucketed ragged batching: flatten every archive's segments,
        bin them by power-of-two width, run ONE fused device call per
        bucket (cached compilation per shape), then reassemble rows into
        per-archive planes."""
        records = self._records(items)
        # Bucket key includes the fallback flag: a segment's compiled
        # graph variant must be a function of the segment alone, or
        # per-archive outputs could drift an ulp depending on which
        # other segments share its batch (XLA fuses the fallback and
        # no-fallback graphs differently).
        buckets: dict[tuple[int, bool], list[int]] = {}
        for gi, rec in enumerate(records):
            buckets.setdefault((rec.width, rec.may_span), []).append(gi)

        planes: dict[int, dict[str, np.ndarray]] = {}   # gi -> field rows
        allocated = 0
        for width, may_span in sorted(buckets):
            idxs = buckets[(width, may_span)]
            bk = len(idxs)
            bp = _round_rows(bk)
            allocated += bp * width
            # The knot axis gets its own (smaller) 128-multiple width:
            # raw observations are ~5-8x sparser than the 1 Hz output
            # grid, so tying knots to the output bucket would waste most
            # of the interp kernel's mask matmul.
            kn = -(-max(records[gi].n for gi in idxs) // 128) * 128
            t_in = np.zeros((bp, kn), np.float32)
            v_in = np.zeros((bp, 3, kn), np.float32)
            count_in = np.full((bp,), 2, np.int32)
            t_out = np.zeros((bp, width), np.float32)
            count_out = np.ones((bp,), np.int32)
            # Benign padding rows: strictly increasing knots, zero values.
            t_in[bk:] = np.arange(kn, dtype=np.float32)[None, :]
            for r, gi in enumerate(idxs):
                rec = records[gi]
                n, m = rec.n, rec.m
                t0 = rec.t[0]
                t_in[r, :n] = rec.t - t0
                t_in[r, n:] = (rec.t[-1] - t0) + np.arange(1, kn - n + 1)
                v_in[r, 0, :n] = rec.lat
                v_in[r, 1, :n] = rec.lon
                v_in[r, 2, :n] = rec.alt
                # hold last value through padding (keeps interp defined)
                v_in[r, :, n:] = v_in[r, :, n - 1:n]
                count_in[r] = n
                t_out[r, :m] = np.arange(m) * RESAMPLE_DT_S
                t_out[r, m:] = t_out[r, m - 1]
                count_out[r] = m
            out = ops.process_segments(
                self._dem_f32, t_in, v_in, count_in, t_out, count_out,
                grid=self._dem_grid, dt=RESAMPLE_DT_S,
                backend=self.backend, agl_oracle=may_span)
            # ONE device->host fetch per bucket — the pipeline's only
            # downward transfer.
            host = {k: np.asarray(v) for k, v in out.items()}
            for r, gi in enumerate(idxs):
                planes[gi] = {k: v[r] for k, v in host.items()}

        # Airspace class for every segment in one vectorized query.
        lat0 = np.array([planes[gi]["lat"][0] for gi in range(len(records))])
        lon0 = np.array([planes[gi]["lon"][0] for gi in range(len(records))])
        airspace = self._airspace_classes(lat0, lon0)

        valid = sum(rec.m for rec in records)
        bucket_rows: dict[int, int] = {}
        for (width, _), ix in buckets.items():
            bucket_rows[int(width)] = bucket_rows.get(int(width), 0) \
                + len(ix)
        self.last_stats = _pipeline_stats(
            "fused", self.backend, len(records), int(valid),
            int(allocated), bucket_rows, len(buckets))

        out_list: list[ProcessedSegments] = []
        gi = 0
        for ai, (_, segs) in enumerate(items):
            rows = list(range(gi, gi + len(segs)))
            gi += len(segs)
            if not rows:
                out_list.append(_empty())
                continue
            wmax = max(records[r].width for r in rows)
            fields = {attr: np.zeros((len(rows), wmax), np.float32)
                      for _, attr in _PLANE_ATTRS}
            for b, r in enumerate(rows):
                w = records[r].width
                for plane, attr in _PLANE_ATTRS:
                    fields[attr][b, :w] = planes[r][plane]
            out_list.append(ProcessedSegments(
                icao24=[records[r].name for r in rows],
                count=np.array([records[r].m for r in rows], np.int32),
                airspace=[airspace[r] for r in rows],
                **fields))
        return out_list

    # -- unfused baseline (three launches + host hops) --------------------

    def _process_many_unfused(self, items: list[tuple[dict, list[slice]]]
                              ) -> list[ProcessedSegments]:
        """The historical path: one fixed (B, 1024) tile padded to the
        global max length, three separate kernel launches with host
        numpy in between.  Kept as the measured baseline for
        ``benchmarks/kernel_bench.py``."""
        B = sum(len(segs) for _, segs in items)
        N = max(s.stop - s.start for _, segs in items for s in segs)
        N = min(max(N, MIN_OBS_PER_SEGMENT), MAX_SEG_POINTS)
        M = MAX_SEG_POINTS
        t_in = np.zeros((B, N), np.float32)
        v_in = np.zeros((B, 3, N), np.float32)
        count_in = np.zeros((B,), np.int32)
        t_out = np.zeros((B, M), np.float32)
        count_out = np.zeros((B,), np.int32)
        names = []
        oracle_rows = np.zeros((B,), bool)
        b = 0
        for obs, segs in items:
            for s in segs:
                t = obs["time"][s][:N]
                n = len(t)
                t0 = t[0]
                t_in[b, :n] = t - t0
                t_in[b, n:] = (t[-1] - t0) + np.arange(1, N - n + 1)
                v_in[b, 0, :n] = obs["lat"][s][:N]
                v_in[b, 1, :n] = obs["lon"][s][:N]
                v_in[b, 2, :n] = obs["alt"][s][:N]
                # hold last value through padding (keeps interp well-defined)
                v_in[b, :, n:] = v_in[b, :, n - 1:n]
                count_in[b] = n
                dur = t[-1] - t0
                m = min(int(dur / RESAMPLE_DT_S) + 1, M)
                t_out[b, :m] = np.arange(m) * RESAMPLE_DT_S
                t_out[b, m:] = t_out[b, m - 1]
                count_out[b] = m
                names.append(str(obs["icao24"][s.start]))
                oracle_rows[b] = self._may_span(obs["lat"][s][:N],
                                                obs["lon"][s][:N])
                b += 1

        interp = np.asarray(ops.track_interp(
            t_in, v_in, count_in, t_out, backend=self.backend))
        ops.note_intermediate_transfer()          # device->host: interp
        lat, lon, alt = interp[:, :, 0], interp[:, :, 1], interp[:, :, 2]

        # AGL via DEM (fractional indices from the DEM's affine grid).
        fi = (np.clip(lat, self.dem.lat_min, self.dem.lat_max)
              - self.dem.lat_min) * self.dem.cells_per_deg
        fj = (np.clip(lon, self.dem.lon_min, self.dem.lon_max)
              - self.dem.lon_min) * self.dem.cells_per_deg
        ops.note_intermediate_transfer()          # host->device: fi/fj/alt
        agl = np.asarray(ops.agl_lookup(
            self._dem_f32, fi, fj, alt, backend=self.backend,
            oracle_rows=oracle_rows))
        ops.note_intermediate_transfer()          # device->host: agl

        v_grid = np.stack([lat, lon, alt], axis=1).astype(np.float32)
        rates = np.asarray(ops.dynamic_rates(
            v_grid, count_out, RESAMPLE_DT_S, backend=self.backend))
        ops.note_intermediate_transfer()          # device->host: rates

        airspace = self._airspace_classes(lat[:, 0], lon[:, 0])
        mask = (np.arange(M)[None, :] < count_out[:, None])
        times = t_out * mask
        lat_m, lon_m, alt_m, agl_m = (lat * mask, lon * mask, alt * mask,
                                      agl * mask)
        vr, gs, hd, tr = (rates[:, 0] * mask, rates[:, 1] * mask,
                          rates[:, 2] * mask, rates[:, 3] * mask)

        self.last_stats = _pipeline_stats(
            "unfused", self.backend, B, int(count_out.sum()), int(B * M),
            {M: B}, 3)

        out: list[ProcessedSegments] = []
        off = 0
        for _, segs in items:
            sl = slice(off, off + len(segs))
            out.append(ProcessedSegments(
                icao24=names[sl],
                times=times[sl],
                lat=lat_m[sl], lon=lon_m[sl],
                alt_msl_m=alt_m[sl], alt_agl_m=agl_m[sl],
                vrate_ms=vr[sl], gspeed_ms=gs[sl],
                heading_rad=hd[sl], turn_rad_s=tr[sl],
                count=count_out[sl], airspace=airspace[sl]))
            off += len(segs)
        return out

    # -- airspace ---------------------------------------------------------

    def _airspace_classes(self, lat0: np.ndarray,
                          lon0: np.ndarray) -> list[str]:
        """Class of the nearest aerodrome within the terminal radius for
        every segment at once (one (B, A) argmin), else 'G' (uncontrolled,
        below Class E floors — good enough a proxy)."""
        lat0 = np.atleast_1d(np.asarray(lat0, np.float64))
        lon0 = np.atleast_1d(np.asarray(lon0, np.float64))
        if not self.aerodromes:
            return ["G"] * len(lat0)
        d2 = ((self._aero_lat[None, :] - lat0[:, None]) ** 2
              + ((self._aero_lon[None, :] - lon0[:, None])
                 * np.cos(np.deg2rad(lat0))[:, None]) ** 2)
        nearest = np.argmin(d2, axis=1)
        best = d2[np.arange(len(lat0)), nearest]
        return [self._aero_cls[i] if b <= RADIUS_DEG ** 2 else "G"
                for i, b in zip(nearest, best)]

    def _airspace_class(self, lat: float, lon: float) -> str:
        return self._airspace_classes(np.array([lat]), np.array([lon]))[0]


def _pipeline_stats(pipeline: str, backend: str, n_segments: int,
                    valid: int, allocated: int, bucket_rows: dict,
                    pipeline_calls: int) -> dict:
    """Padding accounting for one ``_process_many`` batch.

    ``padded_fraction`` is the padding-to-payload ratio — padded output
    elements per *valid* output element (0 = no padding; this is the
    quantity that multiplies wasted kernel compute).  ``padded_share``
    is the share of the allocated tile that is padding (in [0, 1))."""
    padded = allocated - valid
    return {
        "pipeline": pipeline, "backend": backend,
        "n_segments": n_segments, "valid_points": valid,
        "allocated_points": allocated,
        "padded_fraction": padded / valid if valid else 0.0,
        "padded_share": padded / allocated if allocated else 0.0,
        "bucket_rows": bucket_rows,
        "pipeline_calls": pipeline_calls,
    }


def _empty() -> ProcessedSegments:
    z = np.zeros((0, BUCKET_SIZES[0]), np.float32)
    return ProcessedSegments(
        icao24=[], times=z, lat=z, lon=z, alt_msl_m=z, alt_agl_m=z,
        vrate_ms=z, gspeed_ms=z, heading_rad=z, turn_rad_s=z,
        count=np.zeros((0,), np.int32), airspace=[])


def segment_tasks_from_archive_tree(archive_root: str) -> list[Task]:
    """One Task per aircraft .zip archive."""
    tasks = []
    for dirpath, _dirnames, filenames in os.walk(archive_root):
        for f in filenames:
            if f.endswith(".zip"):
                p = os.path.join(dirpath, f)
                tasks.append(Task(
                    task_id=os.path.relpath(p, archive_root),
                    size_bytes=os.path.getsize(p),
                    payload=p))
    tasks.sort(key=lambda t: t.task_id)
    return tasks


#: Index bytes per stored observation point (4 f64 columns + codes);
#: sizes store-backed tasks for largest-first organization.
_STORE_BYTES_PER_POINT = 36


def segment_tasks_from_store(store_root: str,
                             granularity: str = "shard",
                             rows_per_task: int = 4) -> list[Task]:
    """Store-backed processing tasks, sized from the index alone.

    ``granularity='shard'``: one Task per shard — a worker's ASSIGN
    batch maps 1:1 onto shard reads, so the prefetching reader streams
    whole shards to the fused pipeline.  ``granularity='track'``: one
    Task per track — drop-in parity with
    :func:`segment_tasks_from_archive_tree` task ids (the golden
    store-vs-zip equivalence tests rely on that).
    ``granularity='rows'``: one Task per ``rows_per_task`` consecutive
    rows of a shard (``store://...#shard=<id>&rows=a:b`` payloads),
    sized via :meth:`repro.store.format.StoreManifest.row_range_bytes`
    — the grain the ``shard_affinity`` scheduling policy groups by, so
    one worker streams a shard's ranges back-to-back off one decode.
    """
    from repro.store.format import StoreManifest
    from repro.store.reader import make_store_uri

    if granularity not in ("shard", "track", "rows"):
        raise ValueError(f"unknown granularity {granularity!r}")
    manifest = StoreManifest.load(store_root)
    tasks = []
    if granularity == "shard":
        for s in manifest.shards:
            tasks.append(Task(
                task_id=f"store/{s.shard_id}",
                size_bytes=s.n_points * _STORE_BYTES_PER_POINT,
                payload=make_store_uri(store_root, shard=s.shard_id)))
    elif granularity == "rows":
        if rows_per_task < 1:
            raise ValueError("rows_per_task must be >= 1")
        for s in manifest.shards:
            n_rows = len(manifest.tracks_in(s.shard_id))
            for a in range(0, n_rows, rows_per_task):
                b = min(a + rows_per_task, n_rows)
                tasks.append(Task(
                    task_id=f"store/{s.shard_id}/r{a:05d}",
                    size_bytes=manifest.row_range_bytes(s.shard_id, a, b),
                    payload=make_store_uri(store_root, shard=s.shard_id,
                                           rows=f"{a}:{b}")))
    else:
        for t in manifest.tracks:
            tasks.append(Task(
                task_id=t.track_id,
                size_bytes=t.n_obs * _STORE_BYTES_PER_POINT,
                payload=make_store_uri(store_root, track=t.track_id)))
    tasks.sort(key=lambda t: t.task_id)
    return tasks
