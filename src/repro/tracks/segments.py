"""Workflow step 3: process + interpolate into track segments (§III.A).

Per aircraft archive:
  1. split raw observations into segments on time gaps;
  2. drop segments with fewer than ten observations (paper rule);
  3. resample each segment onto a uniform grid  -> kernels.track_interp;
  4. AGL altitude = MSL - DEM elevation         -> kernels.agl_lookup;
  5. dynamic rates (vrate/speed/heading/turn)   -> kernels.dynamic_rates;
  6. airspace class tag (nearest aerodrome within the terminal cylinder).

Segments are batched to fixed (B, M) tiles so one jit/pallas compilation
serves every archive (count arrays mask the padding).
"""

from __future__ import annotations

import dataclasses
import io
import os
import zipfile
from typing import Optional, Sequence

import numpy as np

from repro.core.messages import Task
from repro.geometry.aerodromes import Aerodrome
from repro.geometry.dem import SyntheticGlobeDEM
from repro.kernels import ops

MIN_OBS_PER_SEGMENT = 10       # paper: remove segments with <10 observations
SEGMENT_GAP_S = 120.0          # new segment after a 2-minute gap
RESAMPLE_DT_S = 1.0            # uniform 1 Hz grid
MAX_SEG_POINTS = 1024          # fixed tile width (pad/truncate)


@dataclasses.dataclass
class ProcessedSegments:
    """Fixed-shape batch of processed segments for one archive."""
    icao24: list[str]
    times: np.ndarray       # (B, M) uniform grid times
    lat: np.ndarray         # (B, M)
    lon: np.ndarray         # (B, M)
    alt_msl_m: np.ndarray   # (B, M)
    alt_agl_m: np.ndarray   # (B, M)
    vrate_ms: np.ndarray    # (B, M)
    gspeed_ms: np.ndarray   # (B, M)
    heading_rad: np.ndarray  # (B, M)
    turn_rad_s: np.ndarray  # (B, M)
    count: np.ndarray       # (B,)
    airspace: list[str]

    def __len__(self) -> int:
        return len(self.count)


def split_segments(times: np.ndarray, gap_s: float = SEGMENT_GAP_S,
                   min_obs: int = MIN_OBS_PER_SEGMENT) -> list[slice]:
    """Split a sorted time vector into gap-delimited segments, dropping
    those shorter than ``min_obs`` (the paper's ten-observation rule)."""
    if len(times) == 0:
        return []
    breaks = np.flatnonzero(np.diff(times) > gap_s) + 1
    out = []
    for s, e in zip(np.r_[0, breaks], np.r_[breaks, len(times)]):
        if e - s >= min_obs:
            out.append(slice(int(s), int(e)))
    return out


class SegmentProcessor:
    """Processes one organized/archived aircraft file into segments."""

    def __init__(self, dem: Optional[SyntheticGlobeDEM] = None,
                 aerodromes: Optional[Sequence[Aerodrome]] = None,
                 backend: str = "pallas"):
        self.dem = dem or SyntheticGlobeDEM()
        self.aerodromes = list(aerodromes or [])
        self.backend = backend
        if self.aerodromes:
            self._aero_lat = np.array([a.lat for a in self.aerodromes])
            self._aero_lon = np.array([a.lon for a in self.aerodromes])
            self._aero_cls = [a.airspace_class for a in self.aerodromes]

    # -- io -------------------------------------------------------------

    def __call__(self, task: Task):
        return self.process_file(task.payload or task.task_id)

    def read_observations(self, path: str) -> dict[str, np.ndarray]:
        """Read a per-aircraft CSV (possibly inside a .zip archive)."""
        if path.endswith(".zip"):
            with zipfile.ZipFile(path) as zf:
                name = zf.namelist()[0]
                raw = io.StringIO(zf.read(name).decode())
        else:
            raw = open(path)
        try:
            header = raw.readline().strip().split(",")
            cols = {c: i for i, c in enumerate(header)}
            rows = [ln.strip().split(",") for ln in raw if ln.strip()]
        finally:
            if hasattr(raw, "close"):
                raw.close()
        if not rows:
            return {}
        arr = np.array(rows, dtype=object)

        def col(name, dtype=np.float64):
            return arr[:, cols[name]].astype(dtype)

        t = col("time")
        order = np.argsort(t, kind="stable")
        return {
            "time": t[order],
            "lat": col("lat")[order],
            "lon": col("lon")[order],
            "alt": col("geoaltitude")[order],
            "icao24": arr[order, cols["icao24"]],
        }

    # -- processing -------------------------------------------------------

    def process_file(self, path: str) -> ProcessedSegments:
        obs = self.read_observations(path)
        if not obs:
            return _empty()
        segs = split_segments(obs["time"])
        if not segs:
            return _empty()
        return self.process_arrays(obs, segs)

    def process_arrays(self, obs: dict[str, np.ndarray],
                       segs: list[slice]) -> ProcessedSegments:
        return self._process_many([(obs, segs)])[0]

    def process_batch(self, tasks: Sequence[Task]) -> dict:
        """Runtime batch hook: one multi-task ASSIGN message -> ONE
        vectorized pallas call over every segment of every archive in the
        batch, instead of per-task Python dispatch.  Returns
        ``{task_id: ProcessedSegments}`` (what the worker reports DONE)."""
        out: dict[str, ProcessedSegments] = {}
        work: list[tuple[str, dict, list[slice]]] = []
        for task in tasks:
            path = task.payload or task.task_id
            obs = self.read_observations(path)
            segs = split_segments(obs["time"]) if obs else []
            if segs:
                work.append((task.task_id, obs, segs))
            else:
                out[task.task_id] = _empty()
        if work:
            processed = self._process_many(
                [(obs, segs) for _, obs, segs in work])
            for (tid, _, _), ps in zip(work, processed):
                out[tid] = ps
        return out

    def _process_many(self, items: list[tuple[dict, list[slice]]]
                      ) -> list[ProcessedSegments]:
        """Process the segments of several archives in one fixed-shape
        tile batch: a single track_interp / agl_lookup / dynamic_rates
        invocation covers all of them; rows are sliced back per archive."""
        B = sum(len(segs) for _, segs in items)
        N = max(s.stop - s.start for _, segs in items for s in segs)
        N = min(max(N, MIN_OBS_PER_SEGMENT), MAX_SEG_POINTS)
        M = MAX_SEG_POINTS
        t_in = np.zeros((B, N), np.float32)
        v_in = np.zeros((B, 3, N), np.float32)
        count_in = np.zeros((B,), np.int32)
        t_out = np.zeros((B, M), np.float32)
        count_out = np.zeros((B,), np.int32)
        names = []
        b = 0
        for obs, segs in items:
            for s in segs:
                t = obs["time"][s][:N]
                n = len(t)
                t0 = t[0]
                t_in[b, :n] = t - t0
                t_in[b, n:] = (t[-1] - t0) + np.arange(1, N - n + 1)
                v_in[b, 0, :n] = obs["lat"][s][:N]
                v_in[b, 1, :n] = obs["lon"][s][:N]
                v_in[b, 2, :n] = obs["alt"][s][:N]
                # hold last value through padding (keeps interp well-defined)
                v_in[b, :, n:] = v_in[b, :, n - 1:n]
                count_in[b] = n
                dur = t[-1] - t0
                m = min(int(dur / RESAMPLE_DT_S) + 1, M)
                t_out[b, :m] = np.arange(m) * RESAMPLE_DT_S
                t_out[b, m:] = t_out[b, m - 1]
                count_out[b] = m
                names.append(str(obs["icao24"][s.start]))
                b += 1

        interp = np.asarray(ops.track_interp(
            t_in, v_in, count_in, t_out, backend=self.backend))
        lat, lon, alt = interp[:, :, 0], interp[:, :, 1], interp[:, :, 2]

        # AGL via DEM (fractional indices from the DEM's affine grid).
        fi = (np.clip(lat, self.dem.lat_min, self.dem.lat_max)
              - self.dem.lat_min) * self.dem.cells_per_deg
        fj = (np.clip(lon, self.dem.lon_min, self.dem.lon_max)
              - self.dem.lon_min) * self.dem.cells_per_deg
        agl = np.asarray(ops.agl_lookup(
            self.dem.elevation_m.astype(np.float32), fi, fj, alt,
            backend=self.backend))

        v_grid = np.stack([lat, lon, alt], axis=1).astype(np.float32)
        rates = np.asarray(ops.dynamic_rates(
            v_grid, count_out, RESAMPLE_DT_S, backend=self.backend))

        airspace = [self._airspace_class(lat[b, 0], lon[b, 0])
                    for b in range(B)]
        mask = (np.arange(M)[None, :] < count_out[:, None])
        times = t_out * mask
        lat_m, lon_m, alt_m, agl_m = (lat * mask, lon * mask, alt * mask,
                                      agl * mask)
        vr, gs, hd, tr = (rates[:, 0] * mask, rates[:, 1] * mask,
                          rates[:, 2] * mask, rates[:, 3] * mask)

        out: list[ProcessedSegments] = []
        off = 0
        for _, segs in items:
            sl = slice(off, off + len(segs))
            out.append(ProcessedSegments(
                icao24=names[sl],
                times=times[sl],
                lat=lat_m[sl], lon=lon_m[sl],
                alt_msl_m=alt_m[sl], alt_agl_m=agl_m[sl],
                vrate_ms=vr[sl], gspeed_ms=gs[sl],
                heading_rad=hd[sl], turn_rad_s=tr[sl],
                count=count_out[sl], airspace=airspace[sl]))
            off += len(segs)
        return out

    def _airspace_class(self, lat: float, lon: float) -> str:
        """Class of the nearest aerodrome within the terminal radius, else
        'G' (uncontrolled, below Class E floors — good enough a proxy)."""
        if not self.aerodromes:
            return "G"
        d2 = ((self._aero_lat - lat) ** 2
              + ((self._aero_lon - lon) * np.cos(np.deg2rad(lat))) ** 2)
        i = int(np.argmin(d2))
        from repro.geometry.queries import RADIUS_DEG
        return self._aero_cls[i] if d2[i] <= RADIUS_DEG ** 2 else "G"


def _empty() -> ProcessedSegments:
    z = np.zeros((0, MAX_SEG_POINTS), np.float32)
    return ProcessedSegments(
        icao24=[], times=z, lat=z, lon=z, alt_msl_m=z, alt_agl_m=z,
        vrate_ms=z, gspeed_ms=z, heading_rad=z, turn_rad_s=z,
        count=np.zeros((0,), np.int32), airspace=[])


def segment_tasks_from_archive_tree(archive_root: str) -> list[Task]:
    """One Task per aircraft .zip archive."""
    tasks = []
    for dirpath, _dirnames, filenames in os.walk(archive_root):
        for f in filenames:
            if f.endswith(".zip"):
                p = os.path.join(dirpath, f)
                tasks.append(Task(
                    task_id=os.path.relpath(p, archive_root),
                    size_bytes=os.path.getsize(p),
                    payload=p))
    tasks.sort(key=lambda t: t.task_id)
    return tasks
