"""End-to-end track-processing workflow driver (paper §III.A).

Glues the three phases — organize -> archive -> process — behind the
unified self-scheduling runtime (:func:`repro.runtime.run_job`), with a
JSON phase checkpoint so a killed job resumes where it left off.  The
execution backend is pluggable: ``threads`` (default) or ``processes``
(real NPPN-style process isolation); periodic *mid-phase* manager
checkpoints mean a kill-and-restart resumes inside a phase, not just at
phase boundaries.  This is the real (scaled-down) counterpart of the
simulated full-scale benchmarks.

CLI:  PYTHONPATH=src python -m repro.tracks.workflow --backend processes
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional

from repro.core.triples import TriplesConfig
from repro.geometry.aerodromes import synthetic_aerodromes
from repro.geometry.dem import SyntheticGlobeDEM
from repro.runtime import ManagerCheckpoint, RunResult, run_job
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import ScaledDatasetSpec, write_scaled_dataset
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import synthetic_registry
from repro.tracks.segments import (
    SegmentProcessor, segment_tasks_from_archive_tree)


@dataclasses.dataclass
class PhaseReport:
    phase: str
    job_seconds: float
    tasks: int
    workers: int
    messages: int

    @classmethod
    def from_job(cls, phase: str, r: RunResult, tasks: int,
                 workers: int) -> "PhaseReport":
        return cls(phase=phase, job_seconds=r.job_seconds, tasks=tasks,
                   workers=workers, messages=r.messages_sent)


class TrackWorkflow:
    """organize -> archive -> process with self-scheduling + checkpoints."""

    def __init__(self, root: str, n_workers: int = 8,
                 organization: str = "largest_first",
                 poll_interval: float = 0.01,
                 backend: str = "pallas",
                 pipeline: str = "fused",
                 exec_backend: str = "threads",
                 tasks_per_message: int = 1,
                 checkpoint_interval_s: float = 0.5,
                 triple: Optional[TriplesConfig] = None,
                 seed: int = 0):
        if exec_backend not in ("threads", "processes"):
            raise ValueError(
                "workflow phases do real work; exec_backend must be "
                "'threads' or 'processes' (use benchmarks/run.py "
                "--backend sim for simulated timing)")
        self.root = root
        self.raw_dir = os.path.join(root, "raw")
        self.organized_dir = os.path.join(root, "organized")
        self.archive_dir = os.path.join(root, "archived")
        self.ckpt_path = os.path.join(root, "workflow_ckpt.json")
        self.n_workers = (max(triple.worker_processes, 1)
                          if triple is not None else n_workers)
        self.organization = organization
        self.poll_interval = poll_interval
        self.backend = backend
        self.pipeline = pipeline
        self.exec_backend = exec_backend
        self.tasks_per_message = tasks_per_message
        self.checkpoint_interval_s = checkpoint_interval_s
        self.seed = seed
        self.registry = synthetic_registry(n=2000, seed=seed + 13)
        self.reports: list[PhaseReport] = []

    # -- checkpointing ----------------------------------------------------

    def _load_ckpt(self) -> dict:
        if os.path.exists(self.ckpt_path):
            with open(self.ckpt_path) as f:
                return json.load(f)
        return {"phases_done": [], "manager": None}

    def _save_ckpt(self, state: dict) -> None:
        tmp = self.ckpt_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, self.ckpt_path)

    # -- phases -----------------------------------------------------------

    def generate_raw(self, n_files: int = 12, scale: float = 1e4) -> int:
        spec = ScaledDatasetSpec(name="monday-scaled", n_files=n_files,
                                 scale=scale, seed=self.seed)
        paths = write_scaled_dataset(self.raw_dir, spec)
        return len(paths)

    def _run_phase(self, phase: str, tasks, fn,
                   organization: Optional[str] = None,
                   tasks_per_message: Optional[int] = None) -> RunResult:
        state = self._load_ckpt()
        ck = None
        if state.get("manager") and state.get("manager_phase") == phase:
            ck = ManagerCheckpoint.loads(state["manager"])

        def save_mid_phase(c: ManagerCheckpoint) -> None:
            # Persist the manager's ledger periodically so a kill mid-phase
            # resumes from the last checkpoint instead of re-running the
            # whole phase.
            mid = dict(state)
            mid["manager"] = c.dumps()
            mid["manager_phase"] = phase
            self._save_ckpt(mid)

        result = run_job(
            tasks, fn,
            backend=self.exec_backend,
            n_workers=self.n_workers,
            organization=organization or self.organization,
            tasks_per_message=(tasks_per_message
                               if tasks_per_message is not None
                               else self.tasks_per_message),
            poll_interval=self.poll_interval,
            checkpoint=ck,
            on_checkpoint=save_mid_phase,
            checkpoint_interval_s=self.checkpoint_interval_s)
        state["phases_done"].append(phase)
        state["manager"] = None
        state["manager_phase"] = None
        self._save_ckpt(state)
        self.reports.append(PhaseReport.from_job(
            phase, result, len(tasks), self.n_workers))
        return result

    def run(self) -> list[PhaseReport]:
        state = self._load_ckpt()
        done = set(state["phases_done"])
        if "organize" not in done:
            org = Organizer(self.organized_dir, self.registry)
            tasks = organize_tasks_from_dir(self.raw_dir)
            self._run_phase("organize", tasks, org)
        if "archive" not in done:
            arch = Archiver(self.organized_dir, self.archive_dir)
            tasks = archive_tasks_from_tree(self.organized_dir)
            # §IV.B: cyclic beats block for this phase; self-scheduling
            # subsumes both — keep largest_first.
            self._run_phase("archive", tasks, arch)
        if "process" not in done:
            proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline)
            tasks = segment_tasks_from_archive_tree(self.archive_dir)
            # §IV.C: random organization for processing.  A multi-task
            # ASSIGN executes as bucketed fused pipeline calls via
            # SegmentProcessor.process_batch.
            self._run_phase("process", tasks, proc, organization="random")
        return self.reports


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run the organize->archive->process track workflow "
                    "on a chosen execution backend.")
    ap.add_argument("--root", default="experiments/trackwf")
    ap.add_argument("--backend", default="threads",
                    choices=["threads", "processes"],
                    help="execution backend for the self-scheduled phases")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=None,
                    help="triples-mode nodes (overrides --workers)")
    ap.add_argument("--nppn", type=int, default=None,
                    help="triples-mode processes per node")
    ap.add_argument("--files", type=int, default=8)
    ap.add_argument("--scale", type=float, default=2e4)
    ap.add_argument("--tasks-per-message", type=int, default=4)
    ap.add_argument("--pipeline", default="fused",
                    choices=["fused", "unfused"],
                    help="segment hot path: fused device-resident "
                         "bucketed pipeline, or the legacy three-launch "
                         "baseline")
    args = ap.parse_args()

    triple = None
    if args.nodes is not None:
        triple = TriplesConfig(nodes=args.nodes, nppn=args.nppn or 8)
    wf = TrackWorkflow(args.root, n_workers=args.workers,
                       exec_backend=args.backend,
                       pipeline=args.pipeline,
                       tasks_per_message=args.tasks_per_message,
                       poll_interval=0.005, triple=triple)
    if not os.path.isdir(wf.raw_dir):
        n = wf.generate_raw(n_files=args.files, scale=args.scale)
        print(f"generated {n} raw files under {wf.raw_dir}")
    for r in wf.run():
        print(f"{r.phase:10s}: {r.tasks:5d} tasks on {r.workers} "
              f"{args.backend} workers in {r.job_seconds:.2f}s "
              f"({r.messages} messages)")


if __name__ == "__main__":
    main()
