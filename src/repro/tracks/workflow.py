"""End-to-end track-processing workflow driver (paper §III.A).

Glues the three phases — organize -> archive -> process — behind the
self-scheduling Manager, with a JSON phase checkpoint so a killed job
resumes where it left off. This is the real (scaled-down) counterpart of
the simulated full-scale benchmarks.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

from repro.core.selfsched import JobResult, Manager, ManagerCheckpoint
from repro.geometry.aerodromes import synthetic_aerodromes
from repro.geometry.dem import SyntheticGlobeDEM
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import ScaledDatasetSpec, write_scaled_dataset
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import synthetic_registry
from repro.tracks.segments import (
    SegmentProcessor, segment_tasks_from_archive_tree)


@dataclasses.dataclass
class PhaseReport:
    phase: str
    job_seconds: float
    tasks: int
    workers: int
    messages: int

    @classmethod
    def from_job(cls, phase: str, r: JobResult, tasks: int,
                 workers: int) -> "PhaseReport":
        return cls(phase=phase, job_seconds=r.job_seconds, tasks=tasks,
                   workers=workers, messages=r.messages_sent)


class TrackWorkflow:
    """organize -> archive -> process with self-scheduling + checkpoints."""

    def __init__(self, root: str, n_workers: int = 8,
                 organization: str = "largest_first",
                 poll_interval: float = 0.01,
                 backend: str = "pallas",
                 seed: int = 0):
        self.root = root
        self.raw_dir = os.path.join(root, "raw")
        self.organized_dir = os.path.join(root, "organized")
        self.archive_dir = os.path.join(root, "archived")
        self.ckpt_path = os.path.join(root, "workflow_ckpt.json")
        self.n_workers = n_workers
        self.organization = organization
        self.poll_interval = poll_interval
        self.backend = backend
        self.seed = seed
        self.registry = synthetic_registry(n=2000, seed=seed + 13)
        self.reports: list[PhaseReport] = []

    # -- checkpointing ----------------------------------------------------

    def _load_ckpt(self) -> dict:
        if os.path.exists(self.ckpt_path):
            with open(self.ckpt_path) as f:
                return json.load(f)
        return {"phases_done": [], "manager": None}

    def _save_ckpt(self, state: dict) -> None:
        tmp = self.ckpt_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, self.ckpt_path)

    # -- phases -----------------------------------------------------------

    def generate_raw(self, n_files: int = 12, scale: float = 1e4) -> int:
        spec = ScaledDatasetSpec(name="monday-scaled", n_files=n_files,
                                 scale=scale, seed=self.seed)
        paths = write_scaled_dataset(self.raw_dir, spec)
        return len(paths)

    def _run_phase(self, phase: str, tasks, fn,
                   organization: Optional[str] = None) -> JobResult:
        state = self._load_ckpt()
        ck = None
        if state.get("manager") and state.get("manager_phase") == phase:
            ck = ManagerCheckpoint.loads(state["manager"])
        mgr = Manager(tasks, self.n_workers, fn,
                      organization=organization or self.organization,
                      poll_interval=self.poll_interval,
                      checkpoint=ck)
        result = mgr.run()
        state["phases_done"].append(phase)
        state["manager"] = None
        state["manager_phase"] = None
        self._save_ckpt(state)
        self.reports.append(PhaseReport.from_job(
            phase, result, len(tasks), self.n_workers))
        return result

    def run(self) -> list[PhaseReport]:
        state = self._load_ckpt()
        done = set(state["phases_done"])
        if "organize" not in done:
            org = Organizer(self.organized_dir, self.registry)
            tasks = organize_tasks_from_dir(self.raw_dir)
            self._run_phase("organize", tasks, org)
        if "archive" not in done:
            arch = Archiver(self.organized_dir, self.archive_dir)
            tasks = archive_tasks_from_tree(self.organized_dir)
            # §IV.B: cyclic beats block for this phase; self-scheduling
            # subsumes both — keep largest_first.
            self._run_phase("archive", tasks, arch)
        if "process" not in done:
            proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend)
            tasks = segment_tasks_from_archive_tree(self.archive_dir)
            # §IV.C: random organization for processing.
            self._run_phase("process", tasks, proc, organization="random")
        return self.reports
