"""End-to-end track-processing workflow driver (paper §III.A).

Glues the phases — organize -> archive [-> store-build] -> process —
behind the unified self-scheduling runtime
(:func:`repro.runtime.run_job`), with a JSON phase checkpoint so a
killed job resumes where it left off.  The execution backend is
pluggable: ``threads`` (default) or ``processes`` (real NPPN-style
process isolation); periodic *mid-phase* manager checkpoints mean a
kill-and-restart resumes inside a phase, not just at phase boundaries.
This is the real (scaled-down) counterpart of the simulated full-scale
benchmarks.

With ``--input store`` the workflow inserts a ``store-build`` phase
(one self-scheduled task per shard, :class:`repro.store.ShardBuilder`
as the worker fn) that ingests the zip archives into the columnar track
store, and the process phase then reads ``store://`` shard tasks
through the prefetching :class:`repro.store.TrackStore` instead of
re-parsing CSV text out of zip members.

CLI:  PYTHONPATH=src python -m repro.tracks.workflow --backend processes
      PYTHONPATH=src python -m repro.tracks.workflow --input store
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional

from repro.core.triples import TriplesConfig
from repro.geometry.aerodromes import synthetic_aerodromes
from repro.geometry.dem import SyntheticGlobeDEM
from repro.runtime import ManagerCheckpoint, RunResult, run_job
from repro.store.format import MANIFEST_NAME
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import ScaledDatasetSpec, write_scaled_dataset
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import synthetic_registry
from repro.tracks.segments import (
    SegmentProcessor, segment_tasks_from_archive_tree,
    segment_tasks_from_store)


@dataclasses.dataclass
class PhaseReport:
    phase: str
    job_seconds: float
    tasks: int
    workers: int
    messages: int

    @classmethod
    def from_job(cls, phase: str, r: RunResult, tasks: int,
                 workers: int) -> "PhaseReport":
        return cls(phase=phase, job_seconds=r.job_seconds, tasks=tasks,
                   workers=workers, messages=r.messages_sent)


class TrackWorkflow:
    """organize -> archive -> process with self-scheduling + checkpoints."""

    def __init__(self, root: str, n_workers: int = 8,
                 organization: str = "largest_first",
                 poll_interval: float = 0.01,
                 backend: str = "pallas",
                 pipeline: str = "fused",
                 exec_backend: str = "threads",
                 tasks_per_message: int = 1,
                 policy: str = "static",
                 checkpoint_interval_s: float = 0.5,
                 triple: Optional[TriplesConfig] = None,
                 input: str = "zip",
                 store_target_points: Optional[int] = None,
                 seed: int = 0):
        if exec_backend not in ("threads", "processes"):
            raise ValueError(
                "workflow phases do real work; exec_backend must be "
                "'threads' or 'processes' (use benchmarks/run.py "
                "--backend sim for simulated timing)")
        if input not in ("zip", "store"):
            raise ValueError(f"unknown input {input!r}; 'zip' processes "
                             f"archives directly, 'store' inserts a "
                             f"store-build phase")
        from repro.runtime.policies import POLICY_NAMES
        if policy not in POLICY_NAMES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"choose from {list(POLICY_NAMES)}")
        self.root = root
        self.raw_dir = os.path.join(root, "raw")
        self.organized_dir = os.path.join(root, "organized")
        self.archive_dir = os.path.join(root, "archived")
        self.store_dir = os.path.join(root, "store")
        self.input = input
        self.store_target_points = store_target_points
        self.ckpt_path = os.path.join(root, "workflow_ckpt.json")
        self.n_workers = (max(triple.worker_processes, 1)
                          if triple is not None else n_workers)
        self.organization = organization
        self.poll_interval = poll_interval
        self.backend = backend
        self.pipeline = pipeline
        self.exec_backend = exec_backend
        self.tasks_per_message = tasks_per_message
        self.policy = policy
        self.checkpoint_interval_s = checkpoint_interval_s
        self.seed = seed
        self.registry = synthetic_registry(n=2000, seed=seed + 13)
        self.reports: list[PhaseReport] = []

    # -- checkpointing ----------------------------------------------------

    def _load_ckpt(self) -> dict:
        if os.path.exists(self.ckpt_path):
            with open(self.ckpt_path) as f:
                return json.load(f)
        return {"phases_done": [], "manager": None}

    def _save_ckpt(self, state: dict) -> None:
        tmp = self.ckpt_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, self.ckpt_path)

    # -- phases -----------------------------------------------------------

    def generate_raw(self, n_files: int = 12, scale: float = 1e4) -> int:
        spec = ScaledDatasetSpec(name="monday-scaled", n_files=n_files,
                                 scale=scale, seed=self.seed)
        paths = write_scaled_dataset(self.raw_dir, spec)
        return len(paths)

    def _run_phase(self, phase: str, tasks, fn,
                   organization: Optional[str] = None,
                   tasks_per_message: Optional[int] = None) -> RunResult:
        state = self._load_ckpt()
        ck = None
        if state.get("manager") and state.get("manager_phase") == phase:
            ck = ManagerCheckpoint.loads(state["manager"])

        def save_mid_phase(c: ManagerCheckpoint) -> None:
            # Persist the manager's ledger periodically so a kill mid-phase
            # resumes from the last checkpoint instead of re-running the
            # whole phase.
            mid = dict(state)
            mid["manager"] = c.dumps()
            mid["manager_phase"] = phase
            self._save_ckpt(mid)

        # One scheduling policy drives every phase; the mid-phase
        # checkpoint carries its state (e.g. adaptive_chunk's open
        # round), so a kill-and-restart resumes the chunk schedule.
        result = run_job(
            tasks, fn,
            backend=self.exec_backend,
            n_workers=self.n_workers,
            organization=organization or self.organization,
            tasks_per_message=(tasks_per_message
                               if tasks_per_message is not None
                               else self.tasks_per_message),
            policy=self.policy,
            poll_interval=self.poll_interval,
            checkpoint=ck,
            on_checkpoint=save_mid_phase,
            checkpoint_interval_s=self.checkpoint_interval_s)
        state["phases_done"].append(phase)
        state["manager"] = None
        state["manager_phase"] = None
        self._save_ckpt(state)
        self.reports.append(PhaseReport.from_job(
            phase, result, len(tasks), self.n_workers))
        return result

    def _run_store_build(self) -> None:
        """Self-scheduled shard ingest: archives -> columnar store."""
        from repro.store import writer as store_writer
        from repro.core.messages import Task

        sources = store_writer.discover_sources(self.archive_dir)
        sizes = {track_id: size for track_id, _p, size in sources}
        target = (self.store_target_points
                  or store_writer.DEFAULT_TARGET_POINTS)
        plans = store_writer.plan_shards(sources, target_points=target)
        tasks = [Task(task_id=f"store/{p.shard_id}",
                      size_bytes=sum(sizes[t] for t, _ in p.sources),
                      payload=p.dumps())
                 for p in plans]
        builder = store_writer.ShardBuilder(self.store_dir)
        result = self._run_phase("store-build", tasks, builder)
        results = []
        for task in tasks:
            doc = result.results.get(task.task_id)
            if doc is None:
                # Completed before a mid-phase checkpoint kill: the
                # restored manager never re-dispatches the task, so its
                # records died with the worker.  Shard builds are
                # deterministic and atomically committed — just redo it.
                doc = builder(task)
            results.append(doc)
        store_writer.finalize_store(
            self.store_dir, results, target_points=target,
            meta={"source_root": os.path.abspath(self.archive_dir)})

    def run(self) -> list[PhaseReport]:
        state = self._load_ckpt()
        done = set(state["phases_done"])
        if self.input == "store" and "store-build" in done and \
                not os.path.exists(os.path.join(self.store_dir,
                                                MANIFEST_NAME)):
            # Killed between phase completion and the manifest commit:
            # shard builds are idempotent, so just redo the phase.
            done.discard("store-build")
        if "organize" not in done:
            org = Organizer(self.organized_dir, self.registry)
            tasks = organize_tasks_from_dir(self.raw_dir)
            self._run_phase("organize", tasks, org)
        if "archive" not in done:
            arch = Archiver(self.organized_dir, self.archive_dir)
            tasks = archive_tasks_from_tree(self.organized_dir)
            # §IV.B: cyclic beats block for this phase; self-scheduling
            # subsumes both — keep largest_first.
            self._run_phase("archive", tasks, arch)
        if self.input == "store" and "store-build" not in done:
            self._run_store_build()
        if "process" not in done:
            proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline)
            if self.input == "store":
                tasks = segment_tasks_from_store(self.store_dir,
                                                 granularity="shard")
            else:
                tasks = segment_tasks_from_archive_tree(self.archive_dir)
            # §IV.C: random organization for processing.  A multi-task
            # ASSIGN executes as bucketed fused pipeline calls via
            # SegmentProcessor.process_batch (store:// shard payloads
            # stream through the TrackStore reader).
            self._run_phase("process", tasks, proc, organization="random")
        return self.reports


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run the organize->archive->process track workflow "
                    "on a chosen execution backend.")
    ap.add_argument("--root", default="experiments/trackwf")
    ap.add_argument("--backend", default="threads",
                    choices=["threads", "processes"],
                    help="execution backend for the self-scheduled phases")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=None,
                    help="triples-mode nodes (overrides --workers)")
    ap.add_argument("--nppn", type=int, default=None,
                    help="triples-mode processes per node")
    ap.add_argument("--files", type=int, default=8)
    ap.add_argument("--scale", type=float, default=2e4)
    ap.add_argument("--tasks-per-message", type=int, default=4)
    ap.add_argument("--policy", default="static",
                    help="scheduling policy for every self-scheduled "
                         "phase (static | fifo_selfsched | sized_lpt | "
                         "adaptive_chunk | shard_affinity)")
    ap.add_argument("--pipeline", default="fused",
                    choices=["fused", "unfused"],
                    help="segment hot path: fused device-resident "
                         "bucketed pipeline, or the legacy three-launch "
                         "baseline")
    ap.add_argument("--input", default="zip", choices=["zip", "store"],
                    help="process-phase input: re-parse CSV text from "
                         "zip archives, or insert a store-build phase "
                         "and stream shards from the columnar store")
    ap.add_argument("--store-target-points", type=int, default=None,
                    help="observation points per store shard (store "
                         "input only)")
    args = ap.parse_args()

    triple = None
    if args.nodes is not None:
        triple = TriplesConfig(nodes=args.nodes, nppn=args.nppn or 8)
    wf = TrackWorkflow(args.root, n_workers=args.workers,
                       exec_backend=args.backend,
                       pipeline=args.pipeline,
                       tasks_per_message=args.tasks_per_message,
                       policy=args.policy,
                       poll_interval=0.005, triple=triple,
                       input=args.input,
                       store_target_points=args.store_target_points)
    if not os.path.isdir(wf.raw_dir):
        n = wf.generate_raw(n_files=args.files, scale=args.scale)
        print(f"generated {n} raw files under {wf.raw_dir}")
    for r in wf.run():
        print(f"{r.phase:10s}: {r.tasks:5d} tasks on {r.workers} "
              f"{args.backend} workers in {r.job_seconds:.2f}s "
              f"({r.messages} messages)")


if __name__ == "__main__":
    main()
