"""End-to-end track-processing workflow driver (paper §III.A).

Glues the phases — organize -> archive [-> store-build] -> process —
behind the unified self-scheduling runtime
(:func:`repro.runtime.run_job`), with a JSON phase checkpoint so a
killed job resumes where it left off.  The execution backend is
pluggable: ``threads`` (default) or ``processes`` (real NPPN-style
process isolation); periodic *mid-phase* manager checkpoints mean a
kill-and-restart resumes inside a phase, not just at phase boundaries.
This is the real (scaled-down) counterpart of the simulated full-scale
benchmarks.

With ``--input store`` the workflow inserts a ``store-build`` phase
(one self-scheduled task per shard, :class:`repro.store.ShardBuilder`
as the worker fn) that ingests the zip archives into the columnar track
store, and the process phase then reads ``store://`` shard tasks
through the prefetching :class:`repro.store.TrackStore` instead of
re-parsing CSV text out of zip members.

``--pipeline dag`` replaces the barrier sequence with the streaming
phase DAG (:func:`repro.runtime.run_dag`): each completed archive feeds
the shard planner (:class:`_ShardPlanEmitter`), which cuts a
store-build task the moment enough consecutive archives exist; each
committed shard (:class:`_ShardCommitEmitter` appends it to the
manifest incrementally) immediately emits its process task.  No phase
waits for the slowest task of the previous one, and the final store is
byte-identical to a barrier run.  ``--manager-shards N`` splits the
coordinator into N shard queues (paper §V's message-rate wall).

``--screen`` (requires ``--input store``) appends an encounter-screen
phase: processed segment rows are binned into a halo-padded spatial
hash (:mod:`repro.geometry.gridhash`) and every multi-row cell becomes
a self-scheduled task running the fused pairwise miss-distance kernel
(:mod:`repro.kernels.encounter_screen`), with the deduplicated
candidate encounters written canonically to ``candidates.json``.
Under ``--pipeline dag`` the process -> screen edge streams: cells
admit incremental *generations* as the shards feeding them commit
(:class:`_CellBinEmitter`), and the candidate file is byte-identical
to the barrier run's.

``--serve`` switches from batch to continuous-ingest mode
(:func:`run_serve`): a synthetic live feed lands observation files in a
watch directory, :class:`repro.serving.IngestService` tails it through
the open-node service DAG (:func:`repro.runtime.run_service`),
appending store shards as they cut, and a
:class:`repro.serving.StoreFrontEnd` answers live ``nearest`` and
snapshot queries against the growing store before sealing it.

CLI:  PYTHONPATH=src python -m repro.tracks.workflow --backend processes
      PYTHONPATH=src python -m repro.tracks.workflow --input store
      PYTHONPATH=src python -m repro.tracks.workflow --pipeline dag
      PYTHONPATH=src python -m repro.tracks.workflow --serve --files 12
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional

from repro.core.messages import Task
from repro.core.triples import TriplesConfig
from repro.geometry.aerodromes import synthetic_aerodromes
from repro.geometry.dem import SyntheticGlobeDEM
from repro.geometry.gridhash import GridSpec, cell_cost, cell_id
from repro.kernels.encounter_screen import (
    ScreenConfig, bin_screen_rows, dedup_candidates, rows_from_track,
    screen_cells)
from repro.runtime import (
    EdgeEmitter, ManagerCheckpoint, RunResult, StreamingDAG, run_dag,
    run_job)
from repro.store import writer as store_writer
from repro.store.format import MANIFEST_NAME
from repro.store.reader import make_store_uri
from repro.tracks.archive import Archiver, archive_tasks_from_tree
from repro.tracks.datasets import (
    SCREEN_ROW_BYTES, ScaledDatasetSpec, write_scaled_dataset)
from repro.tracks.organize import Organizer, organize_tasks_from_dir
from repro.tracks.registry import synthetic_registry
from repro.tracks.segments import (
    SegmentProcessor, segment_tasks_from_archive_tree,
    segment_tasks_from_store, split_segments)


@dataclasses.dataclass
class PhaseReport:
    phase: str
    job_seconds: float
    tasks: int
    workers: int
    messages: int

    @classmethod
    def from_job(cls, phase: str, r: RunResult, tasks: int,
                 workers: int) -> "PhaseReport":
        return cls(phase=phase, job_seconds=r.job_seconds, tasks=tasks,
                   workers=workers, messages=r.messages_sent)


class _ShardPlanEmitter(EdgeEmitter):
    """archive -> store-build streaming edge: cut shard plans as soon as
    enough *consecutive* archives exist.

    :func:`repro.store.writer.plan_shards` assigns tracks to shards in
    sorted-id order, so the plan for shard k depends only on the sizes
    of the first tracks in that order.  The emitter is primed with the
    archive node's task ids (the expected zip set), buffers sizes as
    archives complete out of order, and consumes the contiguous sorted
    prefix through the same greedy cut — the resulting partition (and
    shard numbering) is identical to the barrier build's, it just
    doesn't wait for the last archive before planning the first shard.
    """

    def __init__(self, archive_root: str, target_points: int):
        self.archive_root = archive_root
        self.target_points = target_points
        self.expected: list[str] = []       # sorted zip ids, set by prime
        self.idx = 0                        # consumed contiguous prefix
        self.sizes: dict[str, int] = {}     # zip id -> bytes (fed)
        self.cur: list[str] = []            # open shard's zip ids
        self.cur_points = 0
        self.n_shards = 0

    def prime(self, src_task_ids) -> None:
        # Archive task id '<y>/<t>/<s>/<b>/<icao>' -> zip id '<...>.zip',
        # the same root-relative id discover_sources would assign.
        self.expected = sorted(f"{tid}.zip" for tid in src_task_ids)

    def _cut(self) -> Task:
        plan = store_writer.ShardPlan(
            f"s{self.n_shards:05d}",
            tuple((rel, os.path.join(self.archive_root, rel))
                  for rel in self.cur))
        self.n_shards += 1
        size = sum(self.sizes.pop(rel) for rel in self.cur)
        self.cur, self.cur_points = [], 0
        return Task(task_id=f"store/{plan.shard_id}", size_bytes=size,
                    payload=plan.dumps())

    def _drain(self, skip_missing: bool = False) -> list[Task]:
        out: list[Task] = []
        while self.idx < len(self.expected):
            rel = self.expected[self.idx]
            if rel not in self.sizes:
                if not skip_missing:
                    break
                # Failed archive: leave the hole, store what exists.
                self.idx += 1
                continue
            est = max(self.sizes[rel] // store_writer.EST_BYTES_PER_OBS, 1)
            if self.cur and self.cur_points + est > self.target_points:
                out.append(self._cut())
            self.cur.append(rel)
            self.cur_points += est
            self.idx += 1
        return out

    def feed(self, task: Task, result) -> list[Task]:
        rel = f"{task.task_id}.zip"
        size = getattr(result, "bytes_out", None)
        if size is None and isinstance(result, dict):
            size = result.get("bytes_out")
        if size is None:
            # Resumed/sim completion without a live result doc: the zip
            # is on disk (archives commit atomically), measure it.
            path = os.path.join(self.archive_root, rel)
            try:
                size = os.path.getsize(path)
            except OSError:
                size = max(task.size_bytes, 1)
        self.sizes[rel] = int(size)
        return self._drain()

    def finish(self) -> list[Task]:
        out = self._drain(skip_missing=True)
        if self.cur:
            out.append(self._cut())
        return out

    def state(self) -> dict:
        return {"expected": self.expected, "idx": self.idx,
                "sizes": self.sizes, "cur": self.cur,
                "cur_points": self.cur_points, "n_shards": self.n_shards}

    def restore(self, state: dict) -> None:
        self.expected = list(state["expected"])
        self.idx = int(state["idx"])
        self.sizes = {k: int(v) for k, v in state["sizes"].items()}
        self.cur = list(state["cur"])
        self.cur_points = int(state["cur_points"])
        self.n_shards = int(state["n_shards"])


class _ShardCommitEmitter(EdgeEmitter):
    """store-build -> process streaming edge: append each built shard to
    the manifest (:func:`repro.store.writer.commit_shard`, idempotent by
    shard id) and immediately emit its process task — the same id /
    size / ``store://`` payload :func:`segment_tasks_from_store` would
    produce, so processing starts while later shards are still building.
    Stateless: the manifest on disk IS the commit ledger, and a kill
    between manifest append and manager checkpoint just re-commits
    (no-op) on the re-run.
    """

    def __init__(self, store_dir: str, target_points: int):
        self.store_dir = store_dir
        self.target_points = target_points

    def feed(self, task: Task, result) -> list[Task]:
        from repro.tracks.segments import _STORE_BYTES_PER_POINT
        if result is None:
            # DONE without a result doc (e.g. resumed completion whose
            # records died with a worker): shard builds are
            # deterministic and atomically committed — redo it here.
            result = store_writer.ShardBuilder(self.store_dir)(task)
        rec = store_writer.commit_shard(self.store_dir, result,
                                        target_points=self.target_points)
        return [Task(task_id=f"store/{rec.shard_id}",
                     size_bytes=rec.n_points * _STORE_BYTES_PER_POINT,
                     payload=make_store_uri(self.store_dir,
                                            shard=rec.shard_id))]


def _screen_rows_for_uri(proc: SegmentProcessor, uri: str) -> list:
    """Multi-track ``store://`` selection -> ScreenRows, via the same
    fused segment pipeline the process phase runs (so screening sees
    byte-identical resampled planes)."""
    items = proc._store_items(uri)
    procd = proc._process_triples(items)
    rows = []
    for tid, obs, segs in items:
        if segs:
            rows.extend(rows_from_track(tid, obs, segs, procd[tid]))
    return rows


class ScreenWorker:
    """Self-scheduled encounter-screen task: one spatial-hash cell.

    The task payload is a JSON doc ``{"cell", "all", "new"}`` naming the
    cell and its member row ids.  The worker re-reads each member track
    from the columnar store (``store://...#track=<id>``), re-derives its
    ScreenRows through the fused segment pipeline (deterministic, so
    recomputation after a checkpoint kill is exact), screens the single
    cell with the fused kernel, and returns the candidate dicts.  With
    ``new != all`` (a streaming-DAG generation) only pairs touching a
    new row are emitted.  Picklable for the processes backend; the
    SegmentProcessor is built lazily per process.
    """

    def __init__(self, store_dir: str, *, h_thresh_m: float,
                 v_thresh_m: float, backend: str = "pallas",
                 pipeline: str = "fused"):
        self.store_dir = store_dir
        self.h_thresh_m = h_thresh_m
        self.v_thresh_m = v_thresh_m
        self.backend = backend
        self.pipeline = pipeline
        self._proc: Optional[SegmentProcessor] = None

    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        state["_proc"] = None
        return state

    def _processor(self) -> SegmentProcessor:
        if self._proc is None:
            self._proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline)
        return self._proc

    def _config(self) -> ScreenConfig:
        return ScreenConfig(h_thresh_m=self.h_thresh_m,
                            v_thresh_m=self.v_thresh_m)

    def __call__(self, task: Task) -> dict:
        doc = json.loads(task.payload)
        wanted = set(doc["all"])
        tracks = sorted({rid.rsplit("#", 1)[0] for rid in wanted})
        proc = self._processor()
        rows = []
        for tid in tracks:
            uri = make_store_uri(self.store_dir, track=tid)
            obs = proc.read_observations(uri)
            segs = split_segments(obs["time"])
            if not segs:
                continue
            ps = proc.process_arrays(obs, segs)
            rows.extend(r for r in rows_from_track(tid, obs, segs, ps)
                        if r.row_id in wanted)
        new = set(doc["new"])
        cands, stats = screen_cells(
            {doc["cell"]: rows}, config=self._config(),
            new_ids=None if new >= wanted else {doc["cell"]: new})
        return {"candidates": cands, "stats": stats}


class _CellBinEmitter(EdgeEmitter):
    """process -> screen streaming edge: admit screen cells as upstream
    shards commit.

    Each completed process task covers one committed store shard; the
    emitter re-derives that shard's ScreenRows from the store (never
    from the in-flight result object, so live runs, sim runs, and
    post-checkpoint resumes all emit identical tasks), bins them into
    the halo-padded spatial hash, and — whenever a cell holds >= 2 rows
    with unscreened members — cuts a *generation* task
    ``screen/<cell>/g<n>`` carrying the cell's full membership plus the
    newly-arrived rows.  Workers screen only pairs touching a new row,
    so the union over generations is exactly the barrier run's pair set
    (each track lives in exactly one shard, so a row arrives once).
    ``cpu_cost_hint`` uses the incremental quadratic cost
    :func:`repro.geometry.gridhash.cell_cost`, giving sized_lpt /
    adaptive_chunk real occupancy skew to schedule against.
    """

    def __init__(self, store_dir: str, grid: GridSpec,
                 config: ScreenConfig, *, backend: str = "pallas",
                 pipeline: str = "fused"):
        self.store_dir = store_dir
        self.grid = grid
        self.config = config
        self.backend = backend
        self.pipeline = pipeline
        self.members: dict[str, list[str]] = {}   # cell -> all row ids
        self.pending: dict[str, list[str]] = {}   # cell -> unscreened ids
        self.gen: dict[str, int] = {}             # cell -> generations cut
        self._proc: Optional[SegmentProcessor] = None

    def _processor(self) -> SegmentProcessor:
        if self._proc is None:
            self._proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline)
        return self._proc

    def feed(self, task: Task, result) -> list[Task]:
        rows = _screen_rows_for_uri(self._processor(), task.payload)
        bins = bin_screen_rows(rows, grid=self.grid, config=self.config)
        out: list[Task] = []
        for key in sorted(bins):
            cid = cell_id(key)
            arrived = sorted(bins[key])
            self.members.setdefault(cid, []).extend(arrived)
            self.pending.setdefault(cid, []).extend(arrived)
            if len(self.members[cid]) < 2 or not self.pending[cid]:
                continue
            g = self.gen.get(cid, 0) + 1
            self.gen[cid] = g
            all_ids = sorted(self.members[cid])
            new_ids = sorted(self.pending[cid])
            self.pending[cid] = []
            out.append(Task(
                task_id=f"screen/{cid}/g{g}",
                size_bytes=len(all_ids) * SCREEN_ROW_BYTES,
                payload=json.dumps({"cell": cid, "all": all_ids,
                                    "new": new_ids}, sort_keys=True),
                cpu_cost_hint=cell_cost(len(all_ids), len(new_ids))))
        return out

    def state(self) -> dict:
        return {"members": self.members, "pending": self.pending,
                "gen": self.gen}

    def restore(self, state: dict) -> None:
        self.members = {k: list(v) for k, v in state["members"].items()}
        self.pending = {k: list(v) for k, v in state["pending"].items()}
        self.gen = {k: int(v) for k, v in state["gen"].items()}


class TrackWorkflow:
    """organize -> archive -> process with self-scheduling + checkpoints."""

    def __init__(self, root: str, n_workers: int = 8,
                 organization: str = "largest_first",
                 poll_interval: float = 0.01,
                 backend: str = "pallas",
                 pipeline: str = "fused",
                 exec_backend: str = "threads",
                 tasks_per_message: int = 1,
                 policy: str = "static",
                 checkpoint_interval_s: float = 0.5,
                 triple: Optional[TriplesConfig] = None,
                 input: str = "zip",
                 store_target_points: Optional[int] = None,
                 mode: str = "barrier",
                 n_manager_shards: int = 1,
                 screen: bool = False,
                 screen_h_m: float = 926.0,
                 screen_v_m: float = 152.4,
                 screen_cell_deg: float = 0.25,
                 speculative: bool = False,
                 elastic: bool = False,
                 seed: int = 0,
                 tracer=None):
        if exec_backend not in ("threads", "processes"):
            raise ValueError(
                "workflow phases do real work; exec_backend must be "
                "'threads' or 'processes' (use benchmarks/run.py "
                "--backend sim for simulated timing)")
        if input not in ("zip", "store"):
            raise ValueError(f"unknown input {input!r}; 'zip' processes "
                             f"archives directly, 'store' inserts a "
                             f"store-build phase")
        if mode not in ("barrier", "dag"):
            raise ValueError(f"unknown pipeline mode {mode!r}; 'barrier' "
                             f"runs the phases sequentially, 'dag' "
                             f"streams tasks between them")
        if n_manager_shards < 1:
            raise ValueError("n_manager_shards must be >= 1")
        if screen and input != "store":
            raise ValueError("--screen needs --input store: screening "
                             "re-reads segment rows from the columnar "
                             "store (store:// track selections)")
        from repro.runtime.policies import POLICY_NAMES
        if policy not in POLICY_NAMES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"choose from {list(POLICY_NAMES)}")
        if elastic:
            if exec_backend != "threads":
                raise ValueError("--elastic needs exec_backend='threads' "
                                 "(processes cannot spawn workers mid-run)")
            if n_manager_shards > 1:
                raise ValueError("--elastic needs n_manager_shards=1")
        self.root = root
        self.raw_dir = os.path.join(root, "raw")
        self.organized_dir = os.path.join(root, "organized")
        self.archive_dir = os.path.join(root, "archived")
        self.store_dir = os.path.join(root, "store")
        self.input = input
        self.store_target_points = store_target_points
        self.mode = mode
        self.n_manager_shards = n_manager_shards
        self.ckpt_path = os.path.join(root, "workflow_ckpt.json")
        self.screen = screen
        self.screen_grid = GridSpec(cell_deg=screen_cell_deg)
        self.screen_config = ScreenConfig(h_thresh_m=screen_h_m,
                                          v_thresh_m=screen_v_m)
        self.candidates_path = os.path.join(root, "candidates.json")
        self.n_workers = (max(triple.worker_processes, 1)
                          if triple is not None else n_workers)
        self.organization = organization
        self.poll_interval = poll_interval
        self.backend = backend
        self.pipeline = pipeline
        self.exec_backend = exec_backend
        self.tasks_per_message = tasks_per_message
        self.policy = policy
        self.speculative = speculative
        self.elastic = elastic
        self.checkpoint_interval_s = checkpoint_interval_s
        self.seed = seed
        #: Optional :class:`repro.obs.Tracer`, threaded through every
        #: phase run (barrier and dag): one trace covers the whole
        #: workflow, with task ids namespaced per phase.
        self.tracer = tracer
        self.registry = synthetic_registry(n=2000, seed=seed + 13)
        self.reports: list[PhaseReport] = []

    # -- checkpointing ----------------------------------------------------

    def _load_ckpt(self) -> dict:
        if os.path.exists(self.ckpt_path):
            with open(self.ckpt_path) as f:
                return json.load(f)
        return {"phases_done": [], "manager": None}

    def _save_ckpt(self, state: dict) -> None:
        tmp = self.ckpt_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, self.ckpt_path)

    # -- phases -----------------------------------------------------------

    def generate_raw(self, n_files: int = 12, scale: float = 1e4) -> int:
        spec = ScaledDatasetSpec(name="monday-scaled", n_files=n_files,
                                 scale=scale, seed=self.seed)
        paths = write_scaled_dataset(self.raw_dir, spec)
        return len(paths)

    def _run_phase(self, phase: str, tasks, fn,
                   organization: Optional[str] = None,
                   tasks_per_message: Optional[int] = None) -> RunResult:
        state = self._load_ckpt()
        ck = None
        if state.get("manager") and state.get("manager_phase") == phase:
            ck = ManagerCheckpoint.loads(state["manager"])

        def save_mid_phase(c: ManagerCheckpoint) -> None:
            # Persist the manager's ledger periodically so a kill mid-phase
            # resumes from the last checkpoint instead of re-running the
            # whole phase.
            mid = dict(state)
            mid["manager"] = c.dumps()
            mid["manager_phase"] = phase
            self._save_ckpt(mid)

        # One scheduling policy drives every phase; the mid-phase
        # checkpoint carries its state (e.g. adaptive_chunk's open
        # round), so a kill-and-restart resumes the chunk schedule.
        result = run_job(
            tasks, fn,
            backend=self.exec_backend,
            n_workers=self.n_workers,
            organization=organization or self.organization,
            tasks_per_message=(tasks_per_message
                               if tasks_per_message is not None
                               else self.tasks_per_message),
            policy=self.policy,
            speculative=self.speculative,
            elastic=self.elastic,
            poll_interval=self.poll_interval,
            checkpoint=ck,
            on_checkpoint=save_mid_phase,
            checkpoint_interval_s=self.checkpoint_interval_s,
            tracer=self.tracer)
        state["phases_done"].append(phase)
        state["manager"] = None
        state["manager_phase"] = None
        self._save_ckpt(state)
        self.reports.append(PhaseReport.from_job(
            phase, result, len(tasks), self.n_workers))
        return result

    def _run_store_build(self) -> None:
        """Self-scheduled shard ingest: archives -> columnar store."""
        sources = store_writer.discover_sources(self.archive_dir)
        sizes = {track_id: size for track_id, _p, size in sources}
        target = (self.store_target_points
                  or store_writer.DEFAULT_TARGET_POINTS)
        plans = store_writer.plan_shards(sources, target_points=target)
        tasks = [Task(task_id=f"store/{p.shard_id}",
                      size_bytes=sum(sizes[t] for t, _ in p.sources),
                      payload=p.dumps())
                 for p in plans]
        builder = store_writer.ShardBuilder(self.store_dir)
        result = self._run_phase("store-build", tasks, builder)
        results = []
        for task in tasks:
            doc = result.results.get(task.task_id)
            if doc is None:
                # Completed before a mid-phase checkpoint kill: the
                # restored manager never re-dispatches the task, so its
                # records died with the worker.  Shard builds are
                # deterministic and atomically committed — just redo it.
                doc = builder(task)
            results.append(doc)
        store_writer.finalize_store(
            self.store_dir, results, target_points=target,
            meta={"source_root": os.path.abspath(self.archive_dir)})

    # -- encounter screening ---------------------------------------------

    def _screen_worker(self) -> ScreenWorker:
        return ScreenWorker(self.store_dir,
                            h_thresh_m=self.screen_config.h_thresh_m,
                            v_thresh_m=self.screen_config.v_thresh_m,
                            backend=self.backend, pipeline=self.pipeline)

    def _screen_tasks_full(self) -> list[Task]:
        """One task per multi-row cell over the *finished* store — the
        barrier screen plan (``new == all``: every pair screened)."""
        proc = SegmentProcessor(
            dem=SyntheticGlobeDEM(),
            aerodromes=synthetic_aerodromes(n=64),
            backend=self.backend, pipeline=self.pipeline)
        rows = []
        for t in segment_tasks_from_store(self.store_dir,
                                          granularity="shard"):
            rows.extend(_screen_rows_for_uri(proc, t.payload))
        bins = bin_screen_rows(rows, grid=self.screen_grid,
                               config=self.screen_config)
        tasks = []
        for key in sorted(bins):
            ids = sorted(bins[key])
            if len(ids) < 2:
                continue
            cid = cell_id(key)
            tasks.append(Task(
                task_id=f"screen/{cid}/g1",
                size_bytes=len(ids) * SCREEN_ROW_BYTES,
                payload=json.dumps({"cell": cid, "all": ids, "new": ids},
                                   sort_keys=True),
                cpu_cost_hint=cell_cost(len(ids))))
        return tasks

    def _write_candidates(self, cands) -> str:
        """Canonical candidate file: deduped, (a, b)-sorted, sorted
        keys — byte-identical across barrier and DAG runs."""
        doc = {
            "schema": "repro.encounters/v1",
            "thresholds": {"h_m": self.screen_config.h_thresh_m,
                           "v_m": self.screen_config.v_thresh_m},
            "grid": {"cell_deg": self.screen_grid.cell_deg,
                     "cell_alt_m": self.screen_grid.cell_alt_m,
                     "cell_t_s": self.screen_grid.cell_t_s},
            "candidates": dedup_candidates(cands),
        }
        tmp = self.candidates_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, sort_keys=True, indent=1)
            f.write("\n")
        os.replace(tmp, self.candidates_path)
        return self.candidates_path

    def _run_screen_barrier(self) -> None:
        tasks = self._screen_tasks_full()
        worker = self._screen_worker()
        cands: list = []
        if tasks:
            result = self._run_phase("screen", tasks, worker)
            for task in tasks:
                doc = result.results.get(task.task_id)
                if doc is None:
                    # Completed before a mid-phase checkpoint kill;
                    # screening is deterministic — just redo the cell.
                    doc = worker(task)
                cands.extend(doc["candidates"])
        else:
            state = self._load_ckpt()
            state["phases_done"].append("screen")
            self._save_ckpt(state)
        self._write_candidates(cands)

    def _run_dag(self) -> None:
        """Streaming-DAG pipeline (``mode='dag'``): one coordinator, no
        phase barriers — archive completions cut shard plans, shard
        commits emit process tasks (see the emitters above).  The DAG
        frontier rides the same workflow checkpoint as the barrier
        phases, so a mid-stream kill resumes mid-stream."""
        state = self._load_ckpt()
        ck = None
        if state.get("manager") and state.get("manager_phase") == "dag":
            ck = ManagerCheckpoint.loads(state["manager"])

        # Phases a previous run (barrier OR dag) already completed stay
        # done: re-running the append-mode Organizer over an organized
        # tree would double every track, so completed phases are simply
        # absent from the node graph.
        done = set(state["phases_done"])
        if self.input == "store" and "store-build" in done and \
                not os.path.exists(os.path.join(self.store_dir,
                                                MANIFEST_NAME)):
            done.discard("store-build")
        if self.screen and "screen" in done and \
                not os.path.exists(self.candidates_path):
            done.discard("screen")
        run_organize = "organize" not in done
        run_archive = "archive" not in done
        run_store = self.input == "store" and "store-build" not in done
        run_process = "process" not in done
        run_screen = self.screen and "screen" not in done

        target = (self.store_target_points
                  or store_writer.DEFAULT_TARGET_POINTS)
        dag = StreamingDAG()
        if run_organize:
            dag.add_node("organize",
                         fn=Organizer(self.organized_dir, self.registry),
                         tasks=organize_tasks_from_dir(self.raw_dir))
        if run_archive:
            arch = Archiver(self.organized_dir, self.archive_dir)
            if run_organize:
                dag.add_node("archive", fn=arch)
                # Barrier edge: archive-task discovery scans the
                # organized tree, which is only final once every
                # organize task has landed.
                dag.add_edge("organize", "archive",
                             on_complete=lambda: archive_tasks_from_tree(
                                 self.organized_dir))
            else:
                dag.add_node("archive", fn=arch,
                             tasks=archive_tasks_from_tree(
                                 self.organized_dir))
        if run_process:
            process_tasks = None
            if not run_store and not run_archive:
                process_tasks = (
                    segment_tasks_from_store(self.store_dir,
                                             granularity="shard")
                    if self.input == "store" else
                    segment_tasks_from_archive_tree(self.archive_dir))
            dag.add_node("process", fn=SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline),
                tasks=process_tasks)
        store_tasks = None
        if run_store:
            if run_archive:
                dag.add_node("store-build",
                             fn=store_writer.ShardBuilder(self.store_dir))
                dag.add_edge("archive", "store-build",
                             emitter=_ShardPlanEmitter(self.archive_dir,
                                                       target))
            else:
                # Archives already on disk — plan the shards up front,
                # exactly like the barrier store-build phase.
                sources = store_writer.discover_sources(self.archive_dir)
                sizes = {tid: size for tid, _p, size in sources}
                plans = store_writer.plan_shards(sources,
                                                 target_points=target)
                store_tasks = [
                    Task(task_id=f"store/{p.shard_id}",
                         size_bytes=sum(sizes[t] for t, _ in p.sources),
                         payload=p.dumps())
                    for p in plans]
                dag.add_node(
                    "store-build",
                    fn=store_writer.ShardBuilder(self.store_dir),
                    tasks=store_tasks)
            if run_process:
                dag.add_edge("store-build", "process",
                             emitter=_ShardCommitEmitter(self.store_dir,
                                                         target))
        screen_tasks = None
        screen_emitter = None
        if run_screen:
            if run_process:
                # Streaming edge: cells admit generations as the shards
                # feeding them commit and process.
                screen_emitter = _CellBinEmitter(
                    self.store_dir, self.screen_grid, self.screen_config,
                    backend=self.backend, pipeline=self.pipeline)
                dag.add_node("screen", fn=self._screen_worker())
                dag.add_edge("process", "screen", emitter=screen_emitter)
            else:
                # Store already processed by a prior run: plan the cells
                # up front, exactly like the barrier screen phase.
                screen_tasks = self._screen_tasks_full()
                dag.add_node("screen", fn=self._screen_worker(),
                             tasks=screen_tasks)
        if self.input != "store" and run_process and run_archive:
            archive_root = self.archive_dir

            def zip_process_task(task: Task, result) -> list[Task]:
                # 1:1 expansion matching segment_tasks_from_archive_tree.
                rel = f"{task.task_id}.zip"
                path = os.path.join(archive_root, rel)
                size = getattr(result, "bytes_out", None)
                if size is None:
                    size = (os.path.getsize(path)
                            if os.path.exists(path) else task.size_bytes)
                return [Task(task_id=rel, size_bytes=int(size),
                             payload=path)]

            dag.add_edge("archive", "process", expand=zip_process_task)

        if not dag.nodes:
            state["phases_done"].append("dag")
            self._save_ckpt(state)
            return

        def save_mid_stream(c: ManagerCheckpoint) -> None:
            mid = dict(state)
            mid["manager"] = c.dumps()
            mid["manager_phase"] = "dag"
            self._save_ckpt(mid)

        result = run_dag(
            dag,
            backend=self.exec_backend,
            n_workers=self.n_workers,
            n_manager_shards=self.n_manager_shards,
            organization=self.organization,
            tasks_per_message=self.tasks_per_message,
            policy=self.policy,
            poll_interval=self.poll_interval,
            checkpoint=ck,
            on_checkpoint=save_mid_stream,
            checkpoint_interval_s=self.checkpoint_interval_s,
            speculative=self.speculative,
            elastic=self.elastic,
            tracer=self.tracer)
        if run_store:
            if store_tasks is not None:
                # No process edge to stream commits through (a prior run
                # already processed): commit the built shards here.
                # commit_shard is idempotent, and builds completed before
                # a checkpoint kill are deterministic — just redo them.
                builder = store_writer.ShardBuilder(self.store_dir)
                docs = result.node_results.get("store-build", {})
                for task in store_tasks:
                    doc = docs.get(task.task_id)
                    if doc is None:
                        doc = builder(task)
                    store_writer.commit_shard(self.store_dir, doc,
                                              target_points=target)
            # Seal the incrementally-committed manifest; byte-identical
            # to the barrier build's finalize_store output.
            store_writer.finalize_manifest(
                self.store_dir, target_points=target,
                meta={"source_root": os.path.abspath(self.archive_dir)})
        if run_screen:
            worker = self._screen_worker()
            docs = result.node_results.get("screen", {})
            by_id = {t.task_id: t for t in (screen_tasks or [])}
            cands: list = []
            for tid in sorted(result.node_completed.get("screen", [])):
                doc = docs.get(tid)
                if doc is None:
                    # Completed before a checkpoint kill: rebuild the
                    # task.  Emitter-cut generations rebuild from the
                    # (restored + re-fed) full cell membership — a
                    # superset of the lost generation's pairs, which
                    # the canonical dedup collapses back exactly.
                    task = by_id.get(tid)
                    if task is None:
                        cid = tid.split("/")[1]
                        ids = sorted(screen_emitter.members.get(cid, []))
                        task = Task(task_id=tid,
                                    payload=json.dumps(
                                        {"cell": cid, "all": ids,
                                         "new": ids}, sort_keys=True))
                    doc = worker(task)
                cands.extend(doc["candidates"])
            self._write_candidates(cands)
        # Node names double as the barrier-phase names: record them so
        # switching back to mode="barrier" later never re-runs them.
        state["phases_done"].extend(dag.nodes)
        state["phases_done"].append("dag")
        state["manager"] = None
        state["manager_phase"] = None
        self._save_ckpt(state)
        n_tasks = sum(len(c) for c in result.node_completed.values())
        self.reports.append(PhaseReport(
            phase="dag", job_seconds=result.job_seconds, tasks=n_tasks,
            workers=self.n_workers, messages=result.run.messages_sent))

    def run(self) -> list[PhaseReport]:
        if self.mode == "dag":
            state = self._load_ckpt()
            done = set(state["phases_done"])
            if "dag" not in done or (self.screen and (
                    "screen" not in done
                    or not os.path.exists(self.candidates_path))):
                self._run_dag()
            return self.reports
        state = self._load_ckpt()
        done = set(state["phases_done"])
        if self.input == "store" and "store-build" in done and \
                not os.path.exists(os.path.join(self.store_dir,
                                                MANIFEST_NAME)):
            # Killed between phase completion and the manifest commit:
            # shard builds are idempotent, so just redo the phase.
            done.discard("store-build")
        if self.screen and "screen" in done and \
                not os.path.exists(self.candidates_path):
            # Killed between phase completion and the candidate write:
            # cell screens are deterministic, so just redo the phase.
            done.discard("screen")
        if "organize" not in done:
            org = Organizer(self.organized_dir, self.registry)
            tasks = organize_tasks_from_dir(self.raw_dir)
            self._run_phase("organize", tasks, org)
        if "archive" not in done:
            arch = Archiver(self.organized_dir, self.archive_dir)
            tasks = archive_tasks_from_tree(self.organized_dir)
            # §IV.B: cyclic beats block for this phase; self-scheduling
            # subsumes both — keep largest_first.
            self._run_phase("archive", tasks, arch)
        if self.input == "store" and "store-build" not in done:
            self._run_store_build()
        if "process" not in done:
            proc = SegmentProcessor(
                dem=SyntheticGlobeDEM(),
                aerodromes=synthetic_aerodromes(n=64),
                backend=self.backend, pipeline=self.pipeline)
            if self.input == "store":
                tasks = segment_tasks_from_store(self.store_dir,
                                                 granularity="shard")
            else:
                tasks = segment_tasks_from_archive_tree(self.archive_dir)
            # §IV.C: random organization for processing.  A multi-task
            # ASSIGN executes as bucketed fused pipeline calls via
            # SegmentProcessor.process_batch (store:// shard payloads
            # stream through the TrackStore reader).
            self._run_phase("process", tasks, proc, organization="random")
        if self.screen and "screen" not in done:
            self._run_screen_barrier()
        return self.reports


def run_serve(root: str, *, n_files: int = 12, obs_per_file: int = 64,
              seed: int = 0, n_workers: int = 4,
              target_points: int = 2048, backend: str = "threads",
              feed_batch: int = 3, tracer=None) -> dict:
    """Continuous-ingest serving demo: live feed -> service DAG ->
    queries -> sealed store.  Returns a JSON-able summary (also the CI
    smoke surface).  ``tracer`` captures the full serving telemetry:
    ingest lifecycle, DAG admissions, build/commit spans, and front-end
    query spans on one timeline."""
    from repro.serving import (
        FeedSpec, IngestService, Query, StoreFrontEnd, SyntheticFeed)

    feed_dir = os.path.join(root, "feed")
    store_dir = os.path.join(root, "store_live")
    os.makedirs(feed_dir, exist_ok=True)
    feed = SyntheticFeed(feed_dir, FeedSpec(
        n_files=n_files, obs_per_file=obs_per_file, seed=seed))
    svc = IngestService(feed_dir, store_dir, target_points=target_points,
                        tracer=tracer)

    def stop_when() -> bool:
        if not feed.exhausted:
            feed.emit(feed_batch)
            return False
        return not svc.scan()

    result = svc.run_service(backend=backend, n_workers=n_workers,
                             stop_when=stop_when)
    front = StoreFrontEnd(svc)
    queries = [Query(1, "nearest", {"lat": 39.0, "lon": -98.0}),
               Query(2, "snapshot", {"digest": True})]
    done = {q.query_id: q for q in front.serve(queries)}
    return {
        "files_ingested": svc.stats["files_accepted"],
        "shards_committed": svc.stats["shards_committed"],
        "points_ingested": svc.stats["points_ingested"],
        "generation": svc.generation,
        "retained_tracks": len(svc.retained),
        "nearest_track": (done[1].result or {}).get("track_id"),
        "snapshot": done[2].result,
        "job_seconds": result.job_seconds,
    }


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run the organize->archive->process track workflow "
                    "on a chosen execution backend.")
    ap.add_argument("--root", default="experiments/trackwf")
    ap.add_argument("--backend", default="threads",
                    choices=["threads", "processes"],
                    help="execution backend for the self-scheduled phases")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=None,
                    help="triples-mode nodes (overrides --workers)")
    ap.add_argument("--nppn", type=int, default=None,
                    help="triples-mode processes per node")
    ap.add_argument("--files", type=int, default=8)
    ap.add_argument("--scale", type=float, default=2e4)
    ap.add_argument("--tasks-per-message", type=int, default=4)
    ap.add_argument("--policy", default="static",
                    help="scheduling policy for every self-scheduled "
                         "phase (static | fifo_selfsched | sized_lpt | "
                         "adaptive_chunk | shard_affinity)")
    ap.add_argument("--pipeline", default="barrier",
                    choices=["barrier", "dag"],
                    help="phase pipelining: 'barrier' runs organize/"
                         "archive/store-build/process as sequential "
                         "self-scheduled phases; 'dag' streams tasks "
                         "between phases as dependencies resolve "
                         "(run_dag)")
    ap.add_argument("--kernel-pipeline", default="fused",
                    choices=["fused", "unfused"],
                    help="segment hot path: fused device-resident "
                         "bucketed pipeline, or the legacy three-launch "
                         "baseline")
    ap.add_argument("--manager-shards", type=int, default=1,
                    help="coordinator shards for --pipeline dag (>1 "
                         "splits the pending queue by locality and "
                         "work-steals at the tail)")
    ap.add_argument("--input", default="zip", choices=["zip", "store"],
                    help="process-phase input: re-parse CSV text from "
                         "zip archives, or insert a store-build phase "
                         "and stream shards from the columnar store")
    ap.add_argument("--store-target-points", type=int, default=None,
                    help="observation points per store shard (store "
                         "input only)")
    ap.add_argument("--screen", action="store_true",
                    help="append an encounter-screen phase (requires "
                         "--input store): spatial-hash cell tasks over "
                         "the processed segment rows, fused pairwise "
                         "miss-distance kernel, candidates.json output")
    ap.add_argument("--screen-h-m", type=float, default=926.0,
                    help="horizontal candidate threshold (meters)")
    ap.add_argument("--screen-v-m", type=float, default=152.4,
                    help="vertical candidate threshold (meters)")
    ap.add_argument("--screen-cell-deg", type=float, default=0.25,
                    help="spatial-hash cell width (degrees; must divide "
                         "360)")
    ap.add_argument("--speculative", action="store_true",
                    help="re-issue the longest-running in-flight task to "
                         "idle workers at the tail (backup copies; "
                         "first DONE wins)")
    ap.add_argument("--elastic", action="store_true",
                    help="threshold-driven fleet autoscaler: grow on "
                         "queue backlog, retire idle workers "
                         "(threads backend, single manager shard)")
    ap.add_argument("--serve", action="store_true",
                    help="continuous-ingest mode: tail a synthetic live "
                         "feed into the store via the service DAG and "
                         "answer queries against the growing store")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write observability artifacts to DIR: "
                         "trace.json (Chrome/Perfetto trace of every "
                         "phase, store read, and serving event) and "
                         "TRACE_summary.json (canonical repro.obs/v1 "
                         "summary; feed either file to "
                         "`python -m repro.obs.report`)")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()

    def _write_trace(label: str) -> None:
        if tracer is None:
            return
        from repro.obs import write_trace_files
        paths = write_trace_files(tracer, args.trace, label=label)
        print(f"trace: {len(tracer)} events -> {paths['trace']}, "
              f"summary -> {paths['summary']}")

    if args.serve:
        summary = run_serve(args.root, n_files=args.files,
                            n_workers=args.workers,
                            backend=args.backend,
                            target_points=(args.store_target_points
                                           or 2048),
                            tracer=tracer)
        print(f"serve: ingested {summary['files_ingested']} files into "
              f"{summary['shards_committed']} shards "
              f"({summary['points_ingested']} points, generation "
              f"{summary['generation']}) in "
              f"{summary['job_seconds']:.2f}s; "
              f"{summary['retained_tracks']} tracks retained")
        print(f"serve: nearest(39,-98) -> {summary['nearest_track']}, "
              f"snapshot digest {summary['snapshot']['digest'][:16]}... "
              f"({summary['snapshot']['n_tracks']} tracks)")
        _write_trace("serve")
        return

    triple = None
    if args.nodes is not None:
        triple = TriplesConfig(nodes=args.nodes, nppn=args.nppn or 8)
    wf = TrackWorkflow(args.root, n_workers=args.workers,
                       exec_backend=args.backend,
                       pipeline=args.kernel_pipeline,
                       tasks_per_message=args.tasks_per_message,
                       policy=args.policy,
                       poll_interval=0.005, triple=triple,
                       input=args.input,
                       store_target_points=args.store_target_points,
                       mode=args.pipeline,
                       n_manager_shards=args.manager_shards,
                       screen=args.screen,
                       screen_h_m=args.screen_h_m,
                       screen_v_m=args.screen_v_m,
                       screen_cell_deg=args.screen_cell_deg,
                       speculative=args.speculative,
                       elastic=args.elastic,
                       tracer=tracer)
    if not os.path.isdir(wf.raw_dir):
        n = wf.generate_raw(n_files=args.files, scale=args.scale)
        print(f"generated {n} raw files under {wf.raw_dir}")
    for r in wf.run():
        print(f"{r.phase:10s}: {r.tasks:5d} tasks on {r.workers} "
              f"{args.backend} workers in {r.job_seconds:.2f}s "
              f"({r.messages} messages)")
    if args.screen and os.path.exists(wf.candidates_path):
        with open(wf.candidates_path) as f:
            n = len(json.load(f)["candidates"])
        print(f"screen    : {n} candidate encounters -> "
              f"{wf.candidates_path}")
    _write_trace(args.pipeline)


if __name__ == "__main__":
    main()
