"""Synthetic NOAA GLOBE-like digital elevation model.

The paper uses the NOAA GLOBE DEM (30-arc-second, ~1 km) to estimate the
min/max elevation of each bounding box, converting a desired AGL range
into the MSL range Impala can filter on. We synthesize smooth continental
terrain (sum of long-wavelength sinusoids + ridged noise, flat coasts)
deterministic in the seed, sampled on the same grid the rasterizer uses.

Also provides the bilinear lookup used by the AGL-altitude kernel's oracle
(kernels/agl_lookup/ref.py delegates here for the pure-numpy path).
"""

from __future__ import annotations

import numpy as np

FT_PER_M = 3.280839895


class SyntheticGlobeDEM:
    """Deterministic synthetic terrain over the continental US."""

    def __init__(self, lat_min: float = 24.0, lat_max: float = 50.0,
                 lon_min: float = -125.0, lon_max: float = -66.0,
                 cells_per_deg: int = 8, seed: int = 5):
        self.lat_min, self.lat_max = lat_min, lat_max
        self.lon_min, self.lon_max = lon_min, lon_max
        self.cells_per_deg = cells_per_deg
        nlat = int(round((lat_max - lat_min) * cells_per_deg)) + 1
        nlon = int(round((lon_max - lon_min) * cells_per_deg)) + 1
        self.lats = np.linspace(lat_min, lat_max, nlat)
        self.lons = np.linspace(lon_min, lon_max, nlon)
        rng = np.random.default_rng(seed)
        glat, glon = np.meshgrid(self.lats, self.lons, indexing="ij")
        z = np.zeros_like(glat)
        # Long-wavelength continental shape + Rockies/Appalachians ridges.
        for _ in range(12):
            fx, fy = rng.uniform(0.02, 0.45, size=2)
            ph1, ph2 = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(80, 420)
            z += amp * np.sin(fx * glon + ph1) * np.sin(fy * glat + ph2)
        # Rockies: strong meridional ridge near -110..-105.
        z += 2200.0 * np.exp(-((glon + 107.5) / 6.0) ** 2)
        # Appalachians: weaker ridge near -80.
        z += 600.0 * np.exp(-((glon + 80.0) / 3.5) ** 2)
        # Coastal taper.
        z *= np.clip((glat - 23.0) / 4.0, 0.2, 1.0)
        self.elevation_m = np.maximum(z, 0.0)

    # -- queries ------------------------------------------------------------

    def minmax_in_box(self, lat0: float, lat1: float,
                      lon0: float, lon1: float) -> tuple[float, float]:
        """Min/max elevation (meters MSL) inside a lat/lon box."""
        i0 = int(np.searchsorted(self.lats, lat0, "left"))
        i1 = max(int(np.searchsorted(self.lats, lat1, "right")), i0 + 1)
        j0 = int(np.searchsorted(self.lons, lon0, "left"))
        j1 = max(int(np.searchsorted(self.lons, lon1, "right")), j0 + 1)
        i1 = min(i1, len(self.lats))
        j1 = min(j1, len(self.lons))
        i0 = min(i0, i1 - 1)
        j0 = min(j0, j1 - 1)
        patch = self.elevation_m[i0:i1, j0:j1]
        return float(patch.min()), float(patch.max())

    def bilinear(self, lat: np.ndarray, lon: np.ndarray) -> np.ndarray:
        """Bilinear elevation interpolation (meters), vectorized."""
        fi = (np.clip(lat, self.lat_min, self.lat_max) - self.lat_min) \
            * self.cells_per_deg
        fj = (np.clip(lon, self.lon_min, self.lon_max) - self.lon_min) \
            * self.cells_per_deg
        i = np.clip(fi.astype(np.int64), 0, len(self.lats) - 2)
        j = np.clip(fj.astype(np.int64), 0, len(self.lons) - 2)
        di = fi - i
        dj = fj - j
        z = self.elevation_m
        return ((1 - di) * (1 - dj) * z[i, j]
                + (1 - di) * dj * z[i, j + 1]
                + di * (1 - dj) * z[i + 1, j]
                + di * dj * z[i + 1, j + 1])
