"""Rectilinear polygon machinery (paper §III.B, Fig 1).

The paper's pipeline: circles around aerodromes -> union into (possibly
non-convex, overlapping) polygons -> a set of DISCRETE, NON-OVERLAPPING,
RECTILINEAR polygons -> iteratively joined / divided into simple
non-overlapping rectangular bounding boxes.

We implement this on a raster: circles are rasterized onto a lat/lon grid
(the union is then exact on the grid), connected components give the
discrete rectilinear polygons, and a row-run sweep decomposes each
component into maximal non-overlapping rectangles (merging vertically
adjacent runs with identical column extents — the 'iteratively joined'
step). Oversized rectangles are recursively split (the 'iteratively
divided' step).

Everything returns cell-index rectangles [r0, r1) x [c0, c1); queries.py
maps them back to lat/lon.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

Rect = tuple[int, int, int, int]   # (r0, c0, r1, c1), half-open


def rasterize_circles(lats: np.ndarray, lons: np.ndarray, radius_deg: float,
                      grid_lat: np.ndarray, grid_lon: np.ndarray,
                      lon_scale: bool = True) -> np.ndarray:
    """Boolean mask of the union of circles on the grid.

    ``radius_deg`` is the radius in latitude degrees; the longitude extent
    is stretched by 1/cos(lat) when ``lon_scale`` (8 nm is ~0.133 deg lat).
    """
    mask = np.zeros((len(grid_lat), len(grid_lon)), dtype=bool)
    glat = grid_lat[:, None]
    glon = grid_lon[None, :]
    for lat0, lon0 in zip(lats, lons):
        coslat = max(np.cos(np.deg2rad(lat0)), 0.2) if lon_scale else 1.0
        d2 = ((glat - lat0) ** 2
              + ((glon - lon0) * coslat) ** 2)
        mask |= d2 <= radius_deg ** 2
    return mask


def connected_components(mask: np.ndarray) -> list[np.ndarray]:
    """4-connected components of a boolean mask, as boolean masks.

    Iterative flood fill (stack-based) — no scipy dependency.
    """
    visited = np.zeros_like(mask, dtype=bool)
    comps: list[np.ndarray] = []
    rows, cols = mask.shape
    for r0 in range(rows):
        for c0 in range(cols):
            if mask[r0, c0] and not visited[r0, c0]:
                comp = np.zeros_like(mask, dtype=bool)
                stack = [(r0, c0)]
                visited[r0, c0] = True
                while stack:
                    r, c = stack.pop()
                    comp[r, c] = True
                    for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        rr, cc = r + dr, c + dc
                        if (0 <= rr < rows and 0 <= cc < cols
                                and mask[rr, cc] and not visited[rr, cc]):
                            visited[rr, cc] = True
                            stack.append((rr, cc))
                comps.append(comp)
    return comps


def decompose_mask_into_rectangles(mask: np.ndarray) -> list[Rect]:
    """Exact cover of a boolean mask by non-overlapping rectangles.

    Row-run sweep: each row decomposes into maximal horizontal runs; runs
    with identical column extent merge with the row above ('iteratively
    joined'). Produces a small rectangle count for rectilinear unions of
    circles while guaranteeing exact, overlap-free coverage.
    """
    rows, cols = mask.shape
    open_runs: dict[tuple[int, int], int] = {}   # (c0, c1) -> r_start
    rects: list[Rect] = []
    for r in range(rows + 1):
        runs: set[tuple[int, int]] = set()
        if r < rows:
            row = mask[r]
            c = 0
            while c < cols:
                if row[c]:
                    c0 = c
                    while c < cols and row[c]:
                        c += 1
                    runs.add((c0, c))
                else:
                    c += 1
        # Close runs that don't continue with the same extent.
        for extent in list(open_runs):
            if extent not in runs:
                r_start = open_runs.pop(extent)
                rects.append((r_start, extent[0], r, extent[1]))
        # Open new runs.
        for extent in runs:
            if extent not in open_runs:
                open_runs[extent] = r
    return rects


def split_large_rectangles(rects: Sequence[Rect],
                           max_cells: int) -> list[Rect]:
    """Recursively halve rectangles larger than ``max_cells`` cells
    (paper: 'For large rectangles, they are iteratively divided into
    smaller boxes')."""
    out: list[Rect] = []
    stack = list(rects)
    while stack:
        r0, c0, r1, c1 = stack.pop()
        h, w = r1 - r0, c1 - c0
        if h * w <= max_cells or (h <= 1 and w <= 1):
            out.append((r0, c0, r1, c1))
        elif h >= w:
            mid = r0 + h // 2
            stack.append((r0, c0, mid, c1))
            stack.append((mid, c0, r1, c1))
        else:
            mid = c0 + w // 2
            stack.append((r0, c0, r1, mid))
            stack.append((r0, mid, r1, c1))
    return out


def rectangles_cover_mask(rects: Sequence[Rect], mask: np.ndarray) -> bool:
    """Validation helper: rectangles exactly tile the mask, no overlap."""
    acc = np.zeros_like(mask, dtype=np.int32)
    for r0, c0, r1, c1 in rects:
        acc[r0:r1, c0:c1] += 1
    return bool(np.all((acc == 1) == mask) and np.all(acc <= 1))
