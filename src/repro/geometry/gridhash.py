"""Spatial-hash grid binning for encounter screening (lat/lon/alt/time).

The all-pairs proximity screen over N tracks is O(N^2) and intractable
at fleet scale; binning track rows into a 4-D grid (latitude band x
longitude band x altitude layer x time window) prunes it to within-cell
pairs.  Correctness hinges on one invariant:

  **halo padding** — a row's membership is its *home* cells plus every
  cell within the screening thresholds of any of its samples.  Two
  rows that ever come within ``h_thresh_m`` horizontally *and*
  ``v_thresh_m`` vertically at a common instant are then guaranteed to
  share at least one cell (the home cell of either sample is inside the
  other's padded membership), so within-cell screening misses nothing.

Longitude indices live on a ring of ``n_lon = round(360 / cell_deg)``
cells: the antimeridian is just another cell boundary and padded ranges
wrap modulo ``n_lon``.  Latitude/altitude indices are plain floors, so
equator/hemisphere boundaries need no special casing — padding spills
into the adjacent (possibly negative) index.

Cell *cost* is quadratic in occupancy — a cell with k rows screens
k*(k-1)/2 pairs — which is exactly the skew ``PhaseCostModel.
task_seconds`` exposes to the scheduling policies via ``cpu_cost_hint``
(see :func:`cell_cost`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "GridSpec", "CellKey", "cell_id", "wrap_lon",
    "cells_for_samples", "bin_samples", "occupancy_stats", "cell_cost",
    "SCREEN_COST_PER_PAIR_S",
]

#: Modeled CPU seconds per screened pair (one pairwise miss-distance
#: trace over a bucketed time window).  Calibrated so a 256-row cell
#: (~32k pairs) costs ~8 s — the same order as the heaviest tasks in
#: the archive-phase manifests, keeping sim makespans comparable.
SCREEN_COST_PER_PAIR_S = 2.5e-4

#: (time index, altitude index, latitude index, longitude index)
CellKey = Tuple[int, int, int, int]

_M_PER_DEG = 111_111.0          # matches kernels/ref.py distance model
_MIN_COS_LAT = 0.2              # clamp: lon padding stays finite at poles


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Cell dimensions of the 4-D screening grid.

    ``cell_deg`` must divide 360 to an integer number of longitude
    cells so the ring wraps cleanly at the antimeridian.
    """

    cell_deg: float = 0.25      # lat/lon cell edge (degrees)
    cell_alt_m: float = 300.0   # altitude layer thickness (meters)
    cell_t_s: float = 3600.0    # time window (seconds)

    def __post_init__(self) -> None:
        if self.cell_deg <= 0 or self.cell_alt_m <= 0 or self.cell_t_s <= 0:
            raise ValueError("GridSpec dimensions must be positive")
        n_lon = 360.0 / self.cell_deg
        if abs(n_lon - round(n_lon)) > 1e-9:
            raise ValueError(
                f"cell_deg={self.cell_deg} does not divide 360 evenly; "
                f"the longitude ring would not close at the antimeridian")

    @property
    def n_lon(self) -> int:
        return int(round(360.0 / self.cell_deg))


def wrap_lon(lon):
    """Wrap longitudes into [-180, 180)."""
    return (np.asarray(lon, dtype=np.float64) + 180.0) % 360.0 - 180.0


def cell_id(key: CellKey) -> str:
    """Stable, sortable-enough string id for a cell key."""
    ti, ai, yi, xi = key
    return f"t{ti}_a{ai}_y{yi}_x{xi}"


def _parse_cell_id(cid: str) -> CellKey:
    ti, ai, yi, xi = (int(p[1:]) for p in cid.split("_"))
    return (ti, ai, yi, xi)


def cells_for_samples(times, lat, lon, alt, *, spec: GridSpec,
                      h_pad_m: float = 0.0,
                      v_pad_m: float = 0.0) -> List[CellKey]:
    """All cells a sampled trajectory touches, halo-padded.

    Args:
      times, lat, lon, alt: 1-D sample arrays (seconds, deg, deg, m).
      spec: grid dimensions.
      h_pad_m / v_pad_m: halo radii — normally the screening
        thresholds, so any trajectory within threshold of a sample
        shares a cell with it.  Longitude padding scales by
        1/cos(lat) (clamped near the poles) so the halo is a true
        metric radius at every latitude.

    Returns a sorted list of unique :data:`CellKey` tuples.  Time is
    never padded: two rows can only conflict at a *common* instant, and
    that instant lands in the same time window for both.
    """
    t = np.asarray(times, dtype=np.float64)
    la = np.asarray(lat, dtype=np.float64)
    lo = wrap_lon(lon)
    al = np.asarray(alt, dtype=np.float64)
    if t.size == 0:
        return []

    ti = np.floor(t / spec.cell_t_s).astype(np.int64)

    pad_lat = h_pad_m / _M_PER_DEG
    cos_lat = np.maximum(np.cos(np.deg2rad(la)), _MIN_COS_LAT)
    pad_lon = h_pad_m / (_M_PER_DEG * cos_lat)

    def _rng(vals, pad, width):
        lo_i = np.floor((vals - pad) / width).astype(np.int64)
        hi_i = np.floor((vals + pad) / width).astype(np.int64)
        return lo_i, hi_i

    la_lo, la_hi = _rng(la, pad_lat, spec.cell_deg)
    lo_lo, lo_hi = _rng(lo, pad_lon, spec.cell_deg)
    al_lo, al_hi = _rng(al, v_pad_m, spec.cell_alt_m)

    n_lon = spec.n_lon
    keys = set()
    ti_l = ti.tolist()
    # Halo spans are tiny (<= 2 cells/dim when pad <= cell size), so
    # iterating offset combinations costs O(samples * ~8).  The set
    # dedups tuples directly: rows are short, so python-level inserts
    # beat an np.unique(axis=0) round trip per combination by ~10x.
    for da in range(int((la_hi - la_lo).max()) + 1):
        ai_l = np.minimum(la_lo + da, la_hi).tolist()
        for do in range(int((lo_hi - lo_lo).max()) + 1):
            oi_l = (np.minimum(lo_lo + do, lo_hi) % n_lon).tolist()
            for dz in range(int((al_hi - al_lo).max()) + 1):
                zi_l = np.minimum(al_lo + dz, al_hi).tolist()
                keys.update(zip(ti_l, zi_l, ai_l, oi_l))
    return sorted(keys)


def bin_samples(rows: Sequence[Tuple[str, np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]],
                *, spec: GridSpec, h_pad_m: float = 0.0,
                v_pad_m: float = 0.0) -> Dict[CellKey, List[str]]:
    """Bin ``(row_id, times, lat, lon, alt)`` rows -> cell -> row ids.

    Row ids keep their first-seen order within each cell; callers that
    need canonical cell contents sort the lists themselves.
    """
    bins: Dict[CellKey, List[str]] = {}
    for row_id, times, lat, lon, alt in rows:
        for key in cells_for_samples(times, lat, lon, alt, spec=spec,
                                     h_pad_m=h_pad_m, v_pad_m=v_pad_m):
            bins.setdefault(key, []).append(row_id)
    return bins


def occupancy_stats(bins: Dict[CellKey, Iterable[str]]) -> dict:
    """Occupancy summary of a binning: totals, max, pair counts."""
    occ = [len(list(v)) for v in bins.values()]
    pairs = sum(k * (k - 1) // 2 for k in occ)
    return {
        "cells": len(occ),
        "max_occupancy": max(occ) if occ else 0,
        "mean_occupancy": (sum(occ) / len(occ)) if occ else 0.0,
        "multi_cells": sum(1 for k in occ if k >= 2),
        "pairs": pairs,
    }


def cell_cost(n_all: int, n_new: int | None = None, *,
              per_pair_s: float = SCREEN_COST_PER_PAIR_S) -> float:
    """Modeled CPU seconds to screen one cell — quadratic in occupancy.

    A full-cell screen walks all n*(n-1)/2 pairs; an incremental screen
    (streaming DAG generations) walks only pairs touching the ``n_new``
    newly admitted rows: n_new * (n_all - n_new) + n_new*(n_new-1)/2.
    """
    n_all = int(n_all)
    if n_new is None:
        pairs = n_all * (n_all - 1) // 2
    else:
        n_new = int(n_new)
        n_old = n_all - n_new
        pairs = n_new * n_old + n_new * (n_new - 1) // 2
    return float(pairs) * per_pair_s
