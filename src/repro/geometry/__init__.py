"""Aerodrome query-generation geometry (paper §III.B, Figs 1-2)."""

from repro.geometry.aerodromes import (
    Aerodrome, synthetic_aerodromes)
from repro.geometry.dem import SyntheticGlobeDEM
from repro.geometry.queries import (
    BoundingBox, Query, generate_queries, make_bounding_boxes)
from repro.geometry.rectilinear import (
    decompose_mask_into_rectangles, rasterize_circles, split_large_rectangles)

__all__ = [
    "Aerodrome", "synthetic_aerodromes",
    "SyntheticGlobeDEM",
    "BoundingBox", "Query", "generate_queries", "make_bounding_boxes",
    "decompose_mask_into_rectangles", "rasterize_circles",
    "split_large_rectangles",
]
