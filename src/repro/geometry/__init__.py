"""Aerodrome query-generation geometry (paper §III.B, Figs 1-2)."""

from repro.geometry.aerodromes import (
    Aerodrome, synthetic_aerodromes)
from repro.geometry.dem import SyntheticGlobeDEM
from repro.geometry.gridhash import (
    GridSpec, bin_samples, cell_cost, cell_id, cells_for_samples,
    occupancy_stats, wrap_lon)
from repro.geometry.queries import (
    BoundingBox, Query, generate_queries, make_bounding_boxes)
from repro.geometry.rectilinear import (
    decompose_mask_into_rectangles, rasterize_circles, split_large_rectangles)

__all__ = [
    "Aerodrome", "synthetic_aerodromes",
    "SyntheticGlobeDEM",
    "GridSpec", "bin_samples", "cell_cost", "cell_id",
    "cells_for_samples", "occupancy_stats", "wrap_lon",
    "BoundingBox", "Query", "generate_queries", "make_bounding_boxes",
    "decompose_mask_into_rectangles", "rasterize_circles",
    "split_large_rectangles",
]
