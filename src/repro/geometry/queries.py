"""Bounding-box query generation (paper §III.B, Fig 2; software [16]).

Pipeline (mirrors em-download-opensky):
  1. circles of TERMINAL_RADIUS_NM around every aerodrome;
  2. union -> discrete non-overlapping rectilinear polygons (raster);
  3. decompose into simple non-overlapping rectangles; split large ones;
  4. drop boxes not within the desired airspace classes / distance;
  5. DEM min/max elevation per box -> MSL range for the desired AGL range
     (default 0..5,100 ft AGL, hard ceiling 12,500 ft MSL);
  6. meridian-based timezone per box;
  7. one query per (box, local day), assigned to a load-balancing group.

The Impala shell supports only axis-aligned range predicates (no geometric
types), which is why everything must become rectangles.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.geometry.aerodromes import (
    Aerodrome, NM_TO_M, TERMINAL_RADIUS_NM, synthetic_aerodromes)
from repro.geometry.dem import FT_PER_M, SyntheticGlobeDEM
from repro.geometry.rectilinear import (
    connected_components, decompose_mask_into_rectangles, rasterize_circles,
    split_large_rectangles)

DEFAULT_AGL_CEILING_FT = 5100.0
HARD_MSL_CEILING_FT = 12500.0
# 8 nm in latitude degrees: 8 * 1852 m / 111,111 m/deg.
RADIUS_DEG = TERMINAL_RADIUS_NM * NM_TO_M / 111_111.0


@dataclasses.dataclass(frozen=True)
class BoundingBox:
    box_id: int
    lat_min: float
    lat_max: float
    lon_min: float
    lon_max: float
    elev_min_ft: float
    elev_max_ft: float
    msl_min_ft: float
    msl_max_ft: float
    timezone_offset_h: int
    airspace_classes: tuple[str, ...]

    @property
    def area_deg2(self) -> float:
        return (self.lat_max - self.lat_min) * (self.lon_max - self.lon_min)


@dataclasses.dataclass(frozen=True)
class Query:
    query_id: int
    box_id: int
    day_index: int
    group: int
    sql: str


def make_bounding_boxes(
        aerodromes: Optional[Sequence[Aerodrome]] = None,
        *,
        cells_per_deg: int = 8,
        max_cells: int = 12,
        agl_ceiling_ft: float = DEFAULT_AGL_CEILING_FT,
        classes: tuple[str, ...] = ("B", "C", "D"),
        dem: Optional[SyntheticGlobeDEM] = None) -> list[BoundingBox]:
    """Steps 1-6: aerodrome circles -> filtered, annotated boxes."""
    if aerodromes is None:
        aerodromes = synthetic_aerodromes()
    if dem is None:
        dem = SyntheticGlobeDEM(cells_per_deg=cells_per_deg)
    aero = [a for a in aerodromes if a.airspace_class in classes]
    lats = np.array([a.lat for a in aero])
    lons = np.array([a.lon for a in aero])

    grid_lat, grid_lon = dem.lats, dem.lons
    # Conservative rasterization: a grid point marks the CELL extent
    # [point, point+1/cpd)^2, so inflate the radius by the half-cell
    # diagonal — every point of the union is then inside some marked cell
    # (bounding boxes are supersets, exactly like the paper's).
    half_diag = 0.5 * (2 ** 0.5) / cells_per_deg
    mask = rasterize_circles(lats, lons, RADIUS_DEG + half_diag,
                             grid_lat, grid_lon)

    rects: list[tuple[int, int, int, int]] = []
    for comp in connected_components(mask):
        rects.extend(decompose_mask_into_rectangles(comp))
    rects = split_large_rectangles(rects, max_cells=max_cells)

    boxes: list[BoundingBox] = []
    cell_lat = (grid_lat[-1] - grid_lat[0]) / (len(grid_lat) - 1)
    cell_lon = (grid_lon[-1] - grid_lon[0]) / (len(grid_lon) - 1)
    for bid, (r0, c0, r1, c1) in enumerate(sorted(rects)):
        lat0 = grid_lat[0] + r0 * cell_lat
        lat1 = grid_lat[0] + r1 * cell_lat
        lon0 = grid_lon[0] + c0 * cell_lon
        lon1 = grid_lon[0] + c1 * cell_lon
        # Step 4: keep boxes within 1.5 radii of some in-class aerodrome
        # (nearest-point distance, so a box containing an aerodrome at its
        # corner is never dropped).
        clat, clon = 0.5 * (lat0 + lat1), 0.5 * (lon0 + lon1)
        nlat = np.clip(lats, lat0, lat1)
        nlon = np.clip(lons, lon0, lon1)
        d2 = (lats - nlat) ** 2 + ((lons - nlon)
                                   * np.cos(np.deg2rad(clat))) ** 2
        near = d2 <= (1.5 * RADIUS_DEG) ** 2
        if not near.any():
            continue
        near_classes = tuple(sorted({aero[i].airspace_class
                                     for i in np.flatnonzero(near)}))
        # Step 5: DEM -> MSL range.
        emin_m, emax_m = dem.minmax_in_box(lat0, lat1, lon0, lon1)
        emin_ft, emax_ft = emin_m * FT_PER_M, emax_m * FT_PER_M
        msl_min = emin_ft                       # AGL 0 at the lowest point
        msl_max = min(emax_ft + agl_ceiling_ft, HARD_MSL_CEILING_FT)
        # Step 6: meridian-based timezone.
        tz = int(np.round(clon / 15.0))
        boxes.append(BoundingBox(
            box_id=len(boxes),
            lat_min=float(lat0), lat_max=float(lat1),
            lon_min=float(lon0), lon_max=float(lon1),
            elev_min_ft=float(emin_ft), elev_max_ft=float(emax_ft),
            msl_min_ft=float(msl_min), msl_max_ft=float(msl_max),
            timezone_offset_h=tz,
            airspace_classes=near_classes))
    return boxes


def generate_queries(boxes: Sequence[BoundingBox],
                     n_days: int = 196,
                     n_groups: int = 64) -> list[Query]:
    """Step 7: one query per (box, local day); groups balance total area.

    The paper generated 136,884 queries for 196 days across 695 boxes.
    Groups facilitate load balancing and storage optimization: we assign
    boxes to groups greedily by descending area (largest-first into the
    least-loaded group — the same insight as task organization by size).
    """
    order = sorted(boxes, key=lambda b: -b.area_deg2)
    load = [0.0] * n_groups
    group_of: dict[int, int] = {}
    for b in order:
        g = min(range(n_groups), key=load.__getitem__)
        group_of[b.box_id] = g
        load[g] += b.area_deg2

    queries: list[Query] = []
    qid = 0
    for b in boxes:
        for d in range(n_days):
            # Local midnight-to-midnight day window, expressed in UTC via
            # the meridian timezone (the Impala table is hour-partitioned).
            utc_start = d * 24 - b.timezone_offset_h
            sql = (
                "SELECT * FROM state_vectors_data4 WHERE "
                f"lat BETWEEN {b.lat_min:.4f} AND {b.lat_max:.4f} AND "
                f"lon BETWEEN {b.lon_min:.4f} AND {b.lon_max:.4f} AND "
                f"baroaltitude BETWEEN {b.msl_min_ft / FT_PER_M:.1f} "
                f"AND {b.msl_max_ft / FT_PER_M:.1f} AND "
                f"hour >= {utc_start * 3600} AND hour < {(utc_start + 24) * 3600}"
            )
            queries.append(Query(
                query_id=qid, box_id=b.box_id, day_index=d,
                group=group_of[b.box_id], sql=sql))
            qid += 1
    return queries
