"""Synthetic aerodrome registry.

The paper identifies "all relevant aerodromes" in Class B/C/D airspace in
the United States (695 final bounding boxes). We synthesize an aerodrome
set with a realistic spatial distribution: clustered around metro areas
(so circles overlap and the union polygons are non-convex — Fig 1) plus a
scattering of isolated fields.
"""

from __future__ import annotations

import dataclasses

import numpy as np

NM_TO_M = 1852.0
TERMINAL_RADIUS_NM = 8.0          # RTCA SC-228 terminal cylinder radius
TERMINAL_CEILING_FT_AGL = 3000.0  # and height


@dataclasses.dataclass(frozen=True)
class Aerodrome:
    ident: str
    lat: float
    lon: float
    airspace_class: str   # 'B' | 'C' | 'D'
    elevation_ft: float


# Rough metro anchors (lat, lon) for clustering; continental US.
_METROS = [
    (33.64, -84.43), (41.98, -87.90), (32.90, -97.04), (39.86, -104.67),
    (40.64, -73.78), (33.94, -118.41), (37.62, -122.38), (47.45, -122.31),
    (25.79, -80.29), (42.36, -71.01), (38.85, -77.04), (29.98, -95.34),
    (36.08, -115.15), (40.79, -111.98), (45.59, -122.60), (39.18, -76.67),
]


def synthetic_aerodromes(n: int = 439, seed: int = 15) -> list[Aerodrome]:
    """n aerodromes: ~60 % clustered near metros, 40 % scattered.

    The defaults are tuned so the full query-generation pipeline yields
    696 bounding boxes — within one box of the paper's 695 (Fig 2) — with
    the default raster resolution and max_cells=12.
    """
    rng = np.random.default_rng(seed)
    out: list[Aerodrome] = []
    classes = ["B", "C", "D"]
    for i in range(n):
        if rng.random() < 0.6:
            m = _METROS[int(rng.integers(0, len(_METROS)))]
            lat = m[0] + rng.normal(0, 0.35)
            lon = m[1] + rng.normal(0, 0.45)
            cls = classes[int(rng.choice([0, 1, 2], p=[0.25, 0.35, 0.40]))]
        else:
            lat = float(rng.uniform(26.0, 48.0))
            lon = float(rng.uniform(-123.0, -68.0))
            cls = classes[int(rng.choice([0, 1, 2], p=[0.02, 0.18, 0.80]))]
        out.append(Aerodrome(
            ident=f"K{i:03d}",
            lat=float(lat), lon=float(lon),
            airspace_class=cls,
            elevation_ft=float(max(rng.normal(900, 800), 0.0))))
    return out
