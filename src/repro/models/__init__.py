"""Model stack: GQA/MoE/Mamba/RWKV-6 decoder architectures in pure JAX."""
