"""Core layers: RMSNorm, RoPE, GQA attention (train + cached decode),
MLPs, and capacity-based MoE.

Pure-JAX by design: the dense transformer math is left to XLA so the
dry-run's cost_analysis stays faithful (DESIGN.md §3). Einsums accumulate
in f32 via preferred_element_type.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32)).astype(x.dtype)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                      # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(F32) * freqs        # (B, T, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_train(x: jax.Array, p: dict, *, n_heads: int, n_kv: int,
                    head_dim: int, theta: float,
                    window: Optional[int] = None,
                    impl: str = "xla") -> jax.Array:
    """Full causal (optionally sliding-window) attention.

    x: (B, T, D). p: {'wq','wk','wv','wo'} with
      wq (D, H, hd), wk/wv (D, KV, hd), wo (H, hd, D).
    impl='flash' routes through the Pallas blocked online-softmax kernel
    (no sliding-window support there; falls back to 'xla' if windowed).
    """
    B, T, D = x.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    # Perf iteration B2 (EXPERIMENTS.md §Perf): projection outputs in the
    # activation dtype — TPU MXUs accumulate in f32 internally either
    # way, but f32 OUTPUTS double every cross-chip psum / grad
    # reduce-scatter that flows through them.
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"],
                   preferred_element_type=x.dtype)
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"],
                   preferred_element_type=x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"],
                   preferred_element_type=x.dtype)
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)

    if impl == "flash" and window is None:
        from repro.kernels import ops as kops
        o = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True)
        o = o.transpose(0, 2, 1, 3).astype(x.dtype)
        return jnp.einsum("bthk,hkd->btd", o, p["wo"],
                          preferred_element_type=x.dtype)

    g = n_heads // n_kv
    q = q.reshape(B, T, n_kv, g, head_dim)
    scale = head_dim ** -0.5
    logits = jnp.einsum("bqhgk,bshk->bhgqs", q, k,
                        preferred_element_type=F32) * scale
    # logits: (B, KV, g, T, T)
    qi = jnp.arange(T)[:, None]
    ki = jnp.arange(T)[None, :]
    mask = ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgqs,bshk->bqhgk", probs, v,
                   preferred_element_type=F32)
    o = o.reshape(B, T, n_heads, head_dim).astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"],
                      preferred_element_type=x.dtype)


def attention_decode(x: jax.Array, cache: dict, p: dict, *, n_heads: int,
                     n_kv: int, head_dim: int, theta: float,
                     window: Optional[int] = None) -> tuple[jax.Array, dict]:
    """One-token decode against a KV cache.

    x: (B, 1, D); cache: {'k','v': (B, S, KV, hd), 'pos': (B,) int32}.
    The cache is a ring buffer when ``window`` is set (hybrid long ctx).
    """
    B, _, D = x.shape
    S = cache["k"].shape[1]
    pos = cache["pos"]                                  # (B,)
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"], preferred_element_type=F32)
    q = apply_rope(q.astype(x.dtype), pos[:, None], theta)
    k = apply_rope(k.astype(x.dtype), pos[:, None], theta)

    slot = (pos % S).astype(jnp.int32)                  # ring slot
    oh = jax.nn.one_hot(slot, S, dtype=k.dtype)         # (B, S)
    k_cache = cache["k"] * (1.0 - oh)[..., None, None] \
        + oh[..., None, None] * k[:, 0][:, None]
    v_cache = cache["v"] * (1.0 - oh)[..., None, None] \
        + oh[..., None, None] * v[:, 0][:, None]

    g = n_heads // n_kv
    qh = q.reshape(B, n_kv, g, head_dim)
    # (Perf iteration C2 — replicating q + pinning logits S-sharded via
    # with_sharding_constraint — was REFUTED: 159 -> 248 ms collective.
    # Same lesson as A2/A7: this XLA SPMD version answers in-body pins
    # with replication; the rule-level layouts are the lever that works.)
    scale = head_dim ** -0.5
    logits = jnp.einsum("bhgk,bshk->bhgs", qh, k_cache,
                        preferred_element_type=F32) * scale
    sidx = jnp.arange(S)[None, :]                       # (1, S)
    # Absolute position currently held by each ring slot: the largest
    # q <= pos with q % S == slot (negative => never written).
    qpos = pos[:, None] - ((pos[:, None] - sidx) % S)
    valid = qpos >= 0
    if window is not None:
        valid &= (pos[:, None] - qpos) < window
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgs,bshk->bhgk", probs, v_cache,
                   preferred_element_type=F32)
    o = o.reshape(B, 1, n_heads, head_dim).astype(x.dtype)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"],
                     preferred_element_type=F32).astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (gated) + MoE
# ---------------------------------------------------------------------------

def mlp(x: jax.Array, p: dict, activation: str) -> jax.Array:
    """MLP. Gated (wi: (D,2,F)): act(x@wi0) * (x@wi1) @ wo.
    Plain (wi: (D,1,F)): act(x@wi0) @ wo — nemotron/granite/musicgen."""
    act = activation_fn(activation)
    h = jnp.einsum("btd,dcf->btcf", x, p["wi"],
                   preferred_element_type=F32)      # f32 into the gate
    if p["wi"].shape[1] == 2:
        h = act(h[:, :, 0]) * h[:, :, 1]
    else:
        h = act(h[:, :, 0])
    return jnp.einsum("btf,fd->btd", h.astype(x.dtype), p["wo"],
                      preferred_element_type=x.dtype)   # B2: bf16 psum


def _largest_divisor_leq(n: int, cap: int) -> int:
    d = min(cap, n)
    while n % d:
        d -= 1
    return d


def _constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint iff the ambient mesh has these axes
    (no-op for single-device smoke tests)."""
    mesh = jax.sharding.get_abstract_mesh()
    names = set(getattr(mesh, "axis_names", ()) or ())
    if not names:
        return x
    clean = []
    for axes in spec:
        if axes is None:
            clean.append(None)
            continue
        tup = axes if isinstance(axes, tuple) else (axes,)
        tup = tuple(a for a in tup if a in names)
        clean.append(tup if len(tup) > 1 else (tup[0] if tup else None))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*clean))


def moe(x: jax.Array, p: dict, *, n_experts: int, top_k: int,
        activation: str, capacity_factor: float = 1.25,
        group_size: int = 2048) -> jax.Array:
    """Capacity-based top-k MoE with GROUPED dispatch (EP-shardable).

    p: {'router': (D, E), 'wi': (E, D, 2|1, F), 'wo': (E, F, D)}.
    Tokens over capacity are dropped (residual passes through).

    Grouping (GShard-style): the dispatch one-hot matmuls cost
    2*S_g*(cf*K*S_g)*D FLOPs per group — quadratic in group size — so
    tokens are routed within groups of ``group_size``. A single global
    group at S=1M tokens costs ~500x the expert compute itself (measured:
    the pre-fix qwen3 train cell burned 99.7 % of its FLOPs in dispatch);
    at 2048 it is ~1.1x expert compute for qwen3's top-8/128e.
    """
    B, T, D = x.shape
    E = n_experts
    S = B * T
    gs = _largest_divisor_leq(S, group_size)
    G = S // gs
    xg = x.reshape(G, gs, D)
    gate_logits = jnp.einsum("gsd,de->gse", xg.astype(F32),
                             p["router"].astype(F32))
    probs = jax.nn.softmax(gate_logits, axis=-1)               # (G, Sg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (G, Sg, K)

    cap = max(int(capacity_factor * top_k * gs / E), 1)
    # Position of each (token, k) within its expert queue, per group.
    # Perf iteration A5 (EXPERIMENTS.md §Perf): sort-based ranking in
    # O(Sg*K) memory — the classic cumsum-over-(Sg*K, E) materializes an
    # int32 tensor E times larger (~60 GB/chip/layer of HBM traffic for
    # qwen3's 128 experts).
    SK = gs * top_k
    eid = gate_idx.reshape(G, SK)                              # (G, SK)

    def rank_in_expert(e):
        order = jnp.argsort(e, stable=True)
        e_sorted = e[order]
        start = jnp.searchsorted(e_sorted, e_sorted, side="left")
        pos_sorted = jnp.arange(SK, dtype=jnp.int32) \
            - start.astype(jnp.int32)
        return jnp.zeros((SK,), jnp.int32).at[order].set(pos_sorted)

    pos = jax.vmap(rank_in_expert)(eid).reshape(G, gs, top_k)
    keep = pos < cap
    gate_vals = gate_vals * keep
    onehot = jax.nn.one_hot(gate_idx, E, dtype=x.dtype)        # (G,Sg,K,E)

    # Dispatch/combine one-hot einsums. Perf iterations A2/A6/A7 all
    # tried to improve this further (explicit all-to-all pins, scatter/
    # gather dispatch, E-dim pinning) and were each REFUTED by
    # measurement — XLA's SPMD partitioner answered every pin with
    # replication + all-reduce. See EXPERIMENTS.md §Perf for the log.
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=x.dtype)[..., :cap]          # (G,Sg,K,cap)
    disp = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    comb = jnp.einsum("gsec,gsk,gske->gsec", disp,
                      gate_vals.astype(x.dtype), onehot)

    xin = jnp.einsum("gsec,gsd->gecd", disp, xg,
                     preferred_element_type=F32).astype(x.dtype)
    # Expert FFN as 3-D batched matmuls over (E, G*cap, ...) — the form
    # both the MXU and the CPU executor handle natively.
    z = p["wi"].shape[2]
    F = p["wi"].shape[3]
    xe = xin.transpose(1, 0, 2, 3).reshape(E, G * cap, D)
    wi = p["wi"].reshape(E, D, z * F)
    h = jnp.einsum("ecd,edf->ecf", xe, wi, preferred_element_type=F32)
    h = h.reshape(E, G * cap, z, F)
    act = activation_fn(activation)
    if z == 2:
        h = act(h[:, :, 0]) * h[:, :, 1]
    else:
        h = act(h[:, :, 0])
    eout = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), p["wo"],
                      preferred_element_type=F32).astype(x.dtype)
    eout = eout.reshape(E, G, cap, D).transpose(1, 0, 2, 3)    # (G,E,c,D)
    yf = jnp.einsum("gsec,gecd->gsd", comb, eout,
                    preferred_element_type=F32).astype(x.dtype)
    return yf.reshape(B, T, D)
