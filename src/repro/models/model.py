"""Decoder LM assembled from the ArchConfig block pattern.

Layer stacking uses jax.lax.scan over *superblocks* (one period of the
block pattern) with optional rematerialization — the production choice
for 96-layer models. Cost accounting note (DESIGN.md §7): XLA's
cost_analysis counts a while-loop body once, so roofline.py composes
full-graph cost + (n_superblocks - 1) x single-superblock cost; this
module exposes ``superblock_apply`` for exactly that purpose.

Public API:
  init_params(cfg, key)                     -> params pytree
  forward(cfg, params, batch)               -> logits (train/prefill path)
  loss_fn(cfg, params, batch)               -> scalar loss
  init_cache(cfg, B, cache_len, dtype)      -> decode cache pytree
  prefill(cfg, params, batch, cache_len)    -> logits, cache
  decode_step(cfg, params, cache, batch)    -> logits, cache
  superblock_apply(cfg, block_params, x, sb_index=0) -> x
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S

F32 = jnp.float32


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def _adtype(cfg: ArchConfig):
    return jnp.dtype(cfg.activation_dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init(key, shape, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (shape[0] ** -0.5 if shape else 0.02)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def _init_ffn(cfg: ArchConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    g = 2 if cfg.gated_mlp else 1
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    return {"wi": _init(k1, (d, g, f), dt), "wo": _init(k2, (f, d), dt)}


def _init_moe(cfg: ArchConfig, key) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    g = 2 if cfg.gated_mlp else 1
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {"router": _init(k1, (d, e), F32),
            "wi": _init(k2, (e, d, g, f), dt),
            "wo": _init(k3, (e, f, d), dt)}


def _init_attn(cfg: ArchConfig, key) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {"wq": _init(ks[0], (d, h, hd), dt),
            "wk": _init(ks[1], (d, kv, hd), dt),
            "wv": _init(ks[2], (d, kv, hd), dt),
            "wo": _init(ks[3], (h, hd, d), dt, scale=(h * hd) ** -0.5)}


def _init_mamba(cfg: ArchConfig, key) -> dict:
    d, di, ds, K = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    R = max(d // 16, 1)
    ks = jax.random.split(key, 7)
    dt = _dtype(cfg)
    return {
        "in_proj": _init(ks[0], (d, 2 * di), dt),
        "conv_w": _init(ks[1], (di, K), dt, scale=0.3),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": _init(ks[2], (di, R + 2 * ds), dt),
        "dt_proj": _init(ks[3], (R, di), dt),
        "dt_bias": jnp.full((di,), -2.0, dt),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=F32), (di, ds))),
        "D": jnp.ones((di,), F32),
        "out_proj": _init(ks[4], (di, d), dt),
    }


def _init_rwkv_time(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    R = max(d // 32, 8)
    ks = jax.random.split(key, 20)
    dt = _dtype(cfg)
    p: dict[str, Any] = {}
    for i, nm in enumerate(("r", "k", "v", "w", "g")):
        p[f"mu_{nm}"] = jnp.full((d,), 0.5, F32)
        p[f"lora_a_{nm}"] = _init(ks[2 * i], (d, R), dt)
        p[f"lora_b_{nm}"] = jnp.zeros((R, d), dt)
    p["w0"] = jnp.full((d,), -2.0, F32)
    p["lora_a_w2"] = _init(ks[10], (d, R), dt)
    p["lora_b_w2"] = jnp.zeros((R, d), dt)
    for i, nm in enumerate(("wr", "wk", "wv", "wg", "wo")):
        p[nm] = _init(ks[11 + i], (d, d), dt)
    p["u"] = jnp.zeros((H, hd), F32)
    p["ln_scale"] = jnp.ones((d,), F32)
    return p


def _init_rwkv_channel(cfg: ArchConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {"mu_r": jnp.full((d,), 0.5, F32),
            "mu_k": jnp.full((d,), 0.5, F32),
            "wr": _init(ks[0], (d, d), dt),
            "wk": _init(ks[1], (d, f), dt),
            "wv": _init(ks[2], (f, d), dt)}


def init_sublayer_params(cfg: ArchConfig, key, layer_idx: int) -> dict:
    kind = cfg.layer_kind(layer_idx)
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": jnp.ones((d,), F32),
                         "norm2": jnp.ones((d,), F32)}
    if kind == "attn":
        p["mixer"] = _init_attn(cfg, k1)
    elif kind == "mamba":
        p["mixer"] = _init_mamba(cfg, k1)
    elif kind == "rwkv6":
        p["mixer"] = _init_rwkv_time(cfg, k1)
    else:
        raise ValueError(kind)
    if kind == "rwkv6":
        p["ffn"] = _init_rwkv_channel(cfg, k2)
    elif cfg.is_moe_layer(layer_idx):
        p["ffn"] = _init_moe(cfg, k2)
    else:
        p["ffn"] = _init_ffn(cfg, k2)
    return p


def init_superblock_params(cfg: ArchConfig, key, sb: int = 0) -> dict:
    keys = jax.random.split(key, cfg.pattern_period)
    return {f"s{i}": init_sublayer_params(cfg, keys[i],
                                          sb * cfg.pattern_period + i)
            for i in range(cfg.pattern_period)}


def init_params(cfg: ArchConfig, key) -> dict:
    kE, kU, kB = jax.random.split(key, 3)
    dt = _dtype(cfg)
    params: dict[str, Any] = {
        "embed": _init(kE, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), F32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(kU, (cfg.d_model, cfg.vocab_size), dt)
    # Stacked superblocks (leading axis scanned over).
    keys = jax.random.split(kB, cfg.n_superblocks)
    blocks = [init_superblock_params(cfg, keys[i], i)
              for i in range(cfg.n_superblocks)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _apply_sublayer(cfg: ArchConfig, kind: str, is_moe: bool, p: dict,
                    x: jax.Array) -> jax.Array:
    h = L.rms_norm(x, p["norm1"], cfg.rms_eps)
    if kind == "attn":
        h = L.attention_train(h, p["mixer"], n_heads=cfg.n_heads,
                              n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                              theta=cfg.rope_theta,
                              window=cfg.sliding_window,
                              impl=cfg.attention_impl)
    elif kind == "mamba":
        h = S.mamba_train(h, p["mixer"], d_state=cfg.d_state)
    elif kind == "rwkv6":
        h = S.rwkv6_time_mix(h, p["mixer"], head_dim=cfg.rwkv_head_dim)
    x = x + h
    h = L.rms_norm(x, p["norm2"], cfg.rms_eps)
    if kind == "rwkv6":
        h = S.rwkv6_channel_mix(h, p["ffn"])
    elif is_moe:
        h = L.moe(h, p["ffn"], n_experts=cfg.n_experts, top_k=cfg.top_k,
                  activation=cfg.activation,
                  capacity_factor=cfg.capacity_factor,
                  group_size=cfg.moe_group_size)
    else:
        h = L.mlp(h, p["ffn"], cfg.activation)
    return x + h


def superblock_apply(cfg: ArchConfig, block_params: dict,
                     x: jax.Array, sb_index: int = 0) -> jax.Array:
    """One period of the block pattern (used standalone by roofline.py)."""
    for i, kind in enumerate(cfg.block_pattern):
        layer_idx = sb_index * cfg.pattern_period + i
        x = _apply_sublayer(cfg, kind, cfg.is_moe_layer(layer_idx),
                            block_params[f"s{i}"], x)
    return x


REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots_no_batch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def forward_trunk(cfg: ArchConfig, params: dict, x: jax.Array,
                  remat: bool = True,
                  remat_policy: str = "nothing") -> jax.Array:
    def body(carry, block_p):
        return superblock_apply(cfg, block_p, carry), None
    if remat:
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat_policy])
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


def encode_inputs(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    """Token embedding, or the stubbed modality frontend's embeddings."""
    if cfg.frontend is not None:
        return batch["embeds"].astype(_adtype(cfg))
    return params["embed"][batch["tokens"]].astype(_adtype(cfg))


def forward(cfg: ArchConfig, params: dict, batch: dict,
            remat: bool = True, remat_policy: str = "nothing") -> jax.Array:
    x = encode_inputs(cfg, params, batch)
    x = forward_trunk(cfg, params, x, remat=remat,
                      remat_policy=remat_policy)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    return jnp.einsum("btd,dv->btv", x, unembed,
                      preferred_element_type=F32)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict,
            z_loss: float = 1e-4, remat: bool = True,
            remat_policy: str = "nothing") -> jax.Array:
    logits = forward(cfg, params, batch, remat=remat,
                     remat_policy=remat_policy)              # (B, T, V) f32
    labels = batch["labels"]
    valid = (labels >= 0).astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    zl = z_loss * jnp.square(lse) * valid
    return (nll.sum() + zl.sum()) / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + cached decode
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ArchConfig, cache_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def init_sublayer_cache(cfg: ArchConfig, kind: str, B: int, cache_len: int,
                        dtype) -> dict:
    if kind == "attn":
        s = _attn_cache_len(cfg, cache_len)
        return {"k": jnp.zeros((B, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
                "v": jnp.zeros((B, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
                "pos": jnp.zeros((B,), jnp.int32)}
    if kind == "mamba":
        return S.mamba_init_state(cfg.d_inner, cfg.d_state, cfg.d_conv, B,
                                  dtype)
    if kind == "rwkv6":
        return S.rwkv6_init_state(B, cfg.d_model, cfg.rwkv_head_dim, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, B: int, cache_len: int,
               fill: int = 0) -> dict:
    """Stacked per-superblock caches (scanned alongside the params)."""
    dtype = _adtype(cfg)
    one = {f"s{i}": init_sublayer_cache(cfg, kind, B, cache_len, dtype)
           for i, kind in enumerate(cfg.block_pattern)}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_superblocks,) + x.shape).copy(),
        one)
    if fill:
        stacked = jax.tree.map(
            lambda x: (jnp.full_like(x, fill) if x.dtype == jnp.int32
                       and x.ndim == 2 else x), stacked)
    return stacked


def _apply_sublayer_decode(cfg: ArchConfig, kind: str, is_moe: bool,
                           p: dict, cache: dict, x: jax.Array):
    h = L.rms_norm(x, p["norm1"], cfg.rms_eps)
    if kind == "attn":
        h, new_cache = L.attention_decode(
            h, cache, p["mixer"], n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, theta=cfg.rope_theta,
            window=cfg.sliding_window)
    elif kind == "mamba":
        h, new_cache = S.mamba_decode(h, cache, p["mixer"],
                                      d_state=cfg.d_state)
    elif kind == "rwkv6":
        h, tstate = S.rwkv6_time_mix_decode(h, cache["time"], p["mixer"],
                                            head_dim=cfg.rwkv_head_dim)
        new_cache = {"time": tstate, "channel": cache["channel"]}
    x = x + h
    h = L.rms_norm(x, p["norm2"], cfg.rms_eps)
    if kind == "rwkv6":
        h, cstate = S.rwkv6_channel_mix(h, p["ffn"], state=cache["channel"],
                                        return_state=True)
        new_cache = {"time": new_cache["time"], "channel": cstate}
    elif is_moe:
        h = L.moe(h, p["ffn"], n_experts=cfg.n_experts, top_k=cfg.top_k,
                  activation=cfg.activation,
                  capacity_factor=cfg.capacity_factor,
                  group_size=cfg.moe_group_size)
    else:
        h = L.mlp(h, p["ffn"], cfg.activation)
    return x + h, new_cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                batch: dict) -> tuple[jax.Array, dict]:
    """One-token decode. batch: {'tokens': (B,1)} or {'embeds': (B,1,D)}."""
    x = encode_inputs(cfg, params, batch)

    def body(carry, pc):
        block_p, blk_cache = pc
        h = carry
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            h, nc = _apply_sublayer_decode(
                cfg, kind, cfg.is_moe_layer(i), block_p[f"s{i}"],
                blk_cache[f"s{i}"], h)
            new_caches[f"s{i}"] = nc
        return h, new_caches

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = jnp.einsum("btd,dv->btv", x, unembed,
                        preferred_element_type=F32)
    return logits, new_cache


def prefill(cfg: ArchConfig, params: dict, batch: dict,
            cache_len: Optional[int] = None) -> tuple[jax.Array, dict]:
    """Process a full prompt, returning logits and a primed cache."""
    x = encode_inputs(cfg, params, batch)
    B, T = x.shape[0], x.shape[1]
    cache_len = cache_len or T
    dtype = _adtype(cfg)

    def body(carry, block_p):
        h = carry
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            p = block_p[f"s{i}"]
            hn = L.rms_norm(h, p["norm1"], cfg.rms_eps)
            if kind == "attn":
                s = _attn_cache_len(cfg, cache_len)
                hm = L.attention_train(hn, p["mixer"], n_heads=cfg.n_heads,
                                       n_kv=cfg.n_kv_heads,
                                       head_dim=cfg.head_dim_,
                                       theta=cfg.rope_theta,
                                       window=cfg.sliding_window)
                k = jnp.einsum("btd,dhk->bthk", hn, p["mixer"]["wk"],
                               preferred_element_type=F32).astype(dtype)
                v = jnp.einsum("btd,dhk->bthk", hn, p["mixer"]["wv"],
                               preferred_element_type=F32).astype(dtype)
                pos = jnp.broadcast_to(
                    jnp.arange(T, dtype=jnp.int32)[None], (B, T))
                k = L.apply_rope(k, pos, cfg.rope_theta)
                if s >= T:
                    pad = s - T
                    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                else:   # keep the last s positions (ring layout: slot=pos%s)
                    tail_k = k[:, T - s:]
                    tail_v = v[:, T - s:]
                    roll = (T - s) % s
                    kc = jnp.roll(tail_k, shift=roll, axis=1)
                    vc = jnp.roll(tail_v, shift=roll, axis=1)
                nc = {"k": kc, "v": vc,
                      "pos": jnp.full((B,), T, jnp.int32)}
            elif kind == "mamba":
                hm, nc = _mamba_prefill(cfg, hn, p["mixer"])
            elif kind == "rwkv6":
                hm, tstate = S.rwkv6_time_mix(hn, p["mixer"],
                                              head_dim=cfg.rwkv_head_dim,
                                              return_state=True)
                nc = {"time": tstate}
            h = h + hm
            hn = L.rms_norm(h, p["norm2"], cfg.rms_eps)
            if kind == "rwkv6":
                hf, cstate = S.rwkv6_channel_mix(hn, p["ffn"],
                                                 return_state=True)
                nc["channel"] = cstate
            elif cfg.is_moe_layer(i):
                hf = L.moe(hn, p["ffn"], n_experts=cfg.n_experts,
                           top_k=cfg.top_k, activation=cfg.activation,
                           capacity_factor=cfg.capacity_factor,
                           group_size=cfg.moe_group_size)
            else:
                hf = L.mlp(hn, p["ffn"], cfg.activation)
            h = h + hf
            new_caches[f"s{i}"] = nc
        return h, new_caches

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = jnp.einsum("btd,dv->btv", x, unembed,
                        preferred_element_type=F32)
    return logits, cache


def _mamba_prefill(cfg: ArchConfig, x: jax.Array, p: dict):
    """Mamba over the prompt + final state for decode (single pass)."""
    return S.mamba_train(x, p, d_state=cfg.d_state, return_state=True)
