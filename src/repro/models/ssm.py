"""State-space + linear-attention mixers: Mamba (Jamba) and RWKV-6.

Both are implemented with O(T) parallel forms suitable for TPU:
  * Mamba: selective scan via chunked associative scan (jax.lax) — the
    CUDA selective-scan kernel has no TPU analogue; the associative-scan
    formulation maps to the VPU and keeps the (B, T, d_inner, d_state)
    working set bounded by chunking (DESIGN.md §2 hardware adaptation).
  * RWKV-6 (Finch): data-dependent per-channel decay. Training/prefill
    use a chunked scan (carry = (H, dk, dv) state per chunk); decode is a
    single-step recurrence.

Decode paths carry explicit state pytrees (the SSM equivalent of a KV
cache): conv tail + ssm state for Mamba; token-shift + wkv state for
RWKV-6.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _chunks_of(T: int, chunk: int) -> tuple[int, int]:
    """(n_chunks, chunk_len) with chunk_len the largest divisor of T that
    is <= chunk (power-of-2 T gives exactly ``chunk``)."""
    ck = min(chunk, T)
    while T % ck:
        ck -= 1
    return T // ck, ck


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B, T, Di), w (Di, K), b (Di,)."""
    K = w.shape[1]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=F32)
    for j in range(K):                       # K is tiny (4): unrolled taps
        out = out + pads[:, j:j + x.shape[1]].astype(F32) * w[:, j].astype(F32)
    return (out + b.astype(F32)).astype(x.dtype)


def mamba_train(x: jax.Array, p: dict, *, d_state: int,
                chunk: int = 256, return_state: bool = False):
    """Mamba mixer over a full sequence.

    p: in_proj (D, 2*Di), conv_w (Di, K), conv_b (Di,),
       x_proj (Di, R+2*S), dt_proj (R, Di), dt_bias (Di,),
       A_log (Di, S), D (Di,), out_proj (Di, D).
    """
    B, T, _ = x.shape
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"],
                    preferred_element_type=F32).astype(x.dtype)
    x1_raw, z = jnp.split(xz, 2, axis=-1)                   # (B, T, Di)
    x1 = jax.nn.silu(
        _causal_conv1d(x1_raw, p["conv_w"], p["conv_b"]).astype(F32)
    ).astype(x.dtype)
    R = p["dt_proj"].shape[0]
    xdb = jnp.einsum("bti,ie->bte", x1, p["x_proj"],
                     preferred_element_type=F32)             # (B,T,R+2S)
    dt_r, B_ssm, C_ssm = jnp.split(xdb, [R, R + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,ri->bti", dt_r, p["dt_proj"],
                   preferred_element_type=F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))                     # (Di, S)

    a = jnp.exp(dt[..., None] * A[None, None])               # (B,T,Di,S)
    bx = (dt * x1.astype(F32))[..., None] * B_ssm[:, :, None, :]

    n_chunks, ck = _chunks_of(T, chunk)
    a_c = a.reshape(B, n_chunks, ck, *a.shape[2:])
    bx_c = bx.reshape(B, n_chunks, ck, *bx.shape[2:])

    def outer(h0, inputs):
        a_i, bx_i = inputs                                   # (B,ck,Di,S)
        # within-chunk associative scan; fold in the carried state
        def combine(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])
        aa, hh = jax.lax.associative_scan(combine, (a_i, bx_i), axis=1)
        hh = hh + aa * h0[:, None]
        return hh[:, -1], hh

    h0 = jnp.zeros((B, a.shape[2], d_state), F32)
    h_last, hs = jax.lax.scan(outer, h0,
                              (a_c.transpose(1, 0, 2, 3, 4),
                               bx_c.transpose(1, 0, 2, 3, 4)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, T, -1, d_state)
    y = jnp.einsum("btis,bts->bti", h, C_ssm,
                   preferred_element_type=F32)
    y = y + p["D"].astype(F32) * x1.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = jnp.einsum("bti,id->btd", y, p["out_proj"],
                     preferred_element_type=F32).astype(x.dtype)
    if return_state:
        K = p["conv_w"].shape[1]
        tail = x1_raw[:, T - (K - 1):] if T >= K - 1 else jnp.pad(
            x1_raw, ((0, 0), (K - 1 - T, 0), (0, 0)))
        return out, {"conv": tail, "ssm": h_last}
    return out


def mamba_init_state(cfg_d_inner: int, d_state: int, d_conv: int, B: int,
                     dtype) -> dict:
    return {
        "conv": jnp.zeros((B, d_conv - 1, cfg_d_inner), dtype),
        "ssm": jnp.zeros((B, cfg_d_inner, d_state), F32),
    }


def mamba_decode(x: jax.Array, state: dict, p: dict, *,
                 d_state: int) -> tuple[jax.Array, dict]:
    """One-token Mamba step. x (B, 1, D)."""
    B = x.shape[0]
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"],
                    preferred_element_type=F32).astype(x.dtype)
    x1, z = jnp.split(xz[:, 0], 2, axis=-1)                  # (B, Di)
    # conv over [state, new]
    window = jnp.concatenate([state["conv"], x1[:, None]], axis=1)  # (B,K,Di)
    w = p["conv_w"].astype(F32)                              # (Di, K)
    x1c = jnp.einsum("bki,ik->bi", window.astype(F32), w) \
        + p["conv_b"].astype(F32)
    x1c = jax.nn.silu(x1c).astype(x.dtype)
    R = p["dt_proj"].shape[0]
    xdb = jnp.einsum("bi,ie->be", x1c, p["x_proj"],
                     preferred_element_type=F32)
    dt_r, B_ssm, C_ssm = jnp.split(xdb, [R, R + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,ri->bi", dt_r, p["dt_proj"],
                   preferred_element_type=F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))
    a = jnp.exp(dt[..., None] * A[None])                     # (B,Di,S)
    bx = (dt * x1c.astype(F32))[..., None] * B_ssm[:, None, :]
    h = a * state["ssm"] + bx
    y = jnp.einsum("bis,bs->bi", h, C_ssm, preferred_element_type=F32)
    y = y + p["D"].astype(F32) * x1c.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"],
                     preferred_element_type=F32).astype(x.dtype)
    new_state = {"conv": window[:, 1:], "ssm": h}
    return out[:, None], new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """xx[t] = x[t-1] (zeros or carried state at t=0). x (B,T,D)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(x, xx, mu, lora_a, lora_b):
    """Data-dependent token-shift interpolation (RWKV-6 ddlerp)."""
    base = x + (xx - x) * mu.astype(x.dtype)
    m = jnp.einsum("btd,dr->btr", base, lora_a, preferred_element_type=F32)
    m = jnp.einsum("btr,rd->btd", jnp.tanh(m), lora_b,
                   preferred_element_type=F32).astype(x.dtype)
    return x + (xx - x) * (mu.astype(x.dtype) + m)


def rwkv6_time_mix(x: jax.Array, p: dict, *, head_dim: int,
                   chunk: int = 32,
                   state: dict | None = None,
                   return_state: bool = False):
    """RWKV-6 time mixing over a sequence (chunked recurrence).

    p: mu_{r,k,v,w,g} (D,), lora_a_* (D,R), lora_b_* (R,D),
       w0 (D,), wr/wk/wv/wg (D,D), wo (D,D), u (H, dk),
       ln_scale (D,) — per-head group norm scale.
    """
    B, T, D = x.shape
    H = D // head_dim
    prev = state["shift"] if state is not None else None
    xx = _token_shift(x, prev)

    xr = _ddlerp(x, xx, p["mu_r"], p["lora_a_r"], p["lora_b_r"])
    xk = _ddlerp(x, xx, p["mu_k"], p["lora_a_k"], p["lora_b_k"])
    xv = _ddlerp(x, xx, p["mu_v"], p["lora_a_v"], p["lora_b_v"])
    xw = _ddlerp(x, xx, p["mu_w"], p["lora_a_w"], p["lora_b_w"])
    xg = _ddlerp(x, xx, p["mu_g"], p["lora_a_g"], p["lora_b_g"])

    r = jnp.einsum("btd,de->bte", xr, p["wr"], preferred_element_type=F32)
    k = jnp.einsum("btd,de->bte", xk, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("btd,de->bte", xv, p["wv"], preferred_element_type=F32)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"],
                               preferred_element_type=F32))
    # data-dependent decay (per channel), kept in log space
    lw = p["w0"].astype(F32) + jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["lora_a_w2"],
                            preferred_element_type=F32)),
        p["lora_b_w2"], preferred_element_type=F32)
    # Clamp so exp(-cumsum(logw)) stays inside f32 range for chunk<=32
    # (the chunked form divides by within-chunk decay; see DESIGN.md §9).
    logw = -jnp.exp(jnp.clip(lw, -8.0, 1.0))                # log decay < 0

    r = r.reshape(B, T, H, head_dim)
    k = k.reshape(B, T, H, head_dim)
    v = v.reshape(B, T, H, head_dim)
    logw = logw.reshape(B, T, H, head_dim)
    u = p["u"].astype(F32)                                   # (H, dk)

    n_chunks, ck = _chunks_of(T, chunk)
    rc = r.reshape(B, n_chunks, ck, H, head_dim).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, n_chunks, ck, H, head_dim).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, ck, H, head_dim).transpose(1, 0, 2, 3, 4)
    wc = logw.reshape(B, n_chunks, ck, H, head_dim).transpose(1, 0, 2, 3, 4)

    def outer(S, inputs):
        rr, kk, vv, ww = inputs                 # (B, ck, H, dk)
        cw = jnp.cumsum(ww, axis=1)             # inclusive log-decay prefix
        # inter-chunk: o_t += (r_t * exp(cw_t - w_t ... )) hmm: state S is
        # pre-chunk; decay from chunk start to t inclusive of w_t is cw_t.
        # Contribution of S to o_t: r_t . (diag(exp(cw_{t-1})) S) where
        # cw_{t-1} = cw_t - ww_t.
        decay_in = jnp.exp(cw - ww)             # (B, ck, H, dk)
        o_inter = jnp.einsum("bthk,bhkv->bthv", rr.astype(F32) * decay_in, S,
                             preferred_element_type=F32)
        # intra-chunk: pairwise decays exp(cw_{t-1} - cw_s) for s < t,
        # bonus u at s == t.
        qd = rr.astype(F32) * decay_in          # (B,t,H,dk)
        kd = kk.astype(F32) * jnp.exp(-cw)      # (B,s,H,dk)
        att = jnp.einsum("bthk,bshk->bhts", qd, kd,
                         preferred_element_type=F32)
        ti = jnp.arange(ck)[:, None]
        si = jnp.arange(ck)[None, :]
        att = jnp.where((si < ti)[None, None], att, 0.0)
        bonus = jnp.einsum("bthk,bthk->bth", rr.astype(F32),
                           u[None, None] * kk.astype(F32))
        o_intra = jnp.einsum("bhts,bshv->bthv", att, vv.astype(F32),
                             preferred_element_type=F32)
        o_intra = o_intra + bonus[..., None] * vv.astype(F32)
        # state update to end of chunk: S' = diag(exp(cw_L)) S +
        #   sum_s exp(cw_L - cw_s) k_s v_s^T
        cw_last = cw[:, -1:]                     # (B,1,H,dk)
        S_new = jnp.exp(cw_last[:, 0])[..., None] * S \
            + jnp.einsum("bshk,bshv->bhkv",
                         kk.astype(F32) * jnp.exp(cw_last - cw),
                         vv.astype(F32), preferred_element_type=F32)
        return S_new, o_inter + o_intra

    S0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, head_dim, head_dim), F32))
    S_final, os_ = jax.lax.scan(outer, S0, (rc, kc, vc, wc))
    o = os_.transpose(1, 0, 2, 3, 4).reshape(B, T, H, head_dim)

    # per-head group norm, then gate and output projection
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 64e-5)
    o = o.reshape(B, T, D) * p["ln_scale"].astype(F32)
    o = (o * g).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", o, p["wo"],
                     preferred_element_type=F32).astype(x.dtype)
    if return_state:
        return out, {"shift": x[:, -1:], "wkv": S_final}
    return out


def rwkv6_time_mix_decode(x: jax.Array, state: dict, p: dict, *,
                          head_dim: int) -> tuple[jax.Array, dict]:
    """Single-token RWKV-6 step (recurrent form). x (B, 1, D)."""
    out, new_state = rwkv6_time_mix(x, p, head_dim=head_dim, chunk=1,
                                    state=state, return_state=True)
    return out, new_state


def rwkv6_channel_mix(x: jax.Array, p: dict,
                      state: dict | None = None,
                      return_state: bool = False):
    """RWKV-6 channel mix: r = sigmoid(Wr xr); k = relu(Wk xk)^2;
    out = r * (Wv k). p: mu_r, mu_k (D,), wr (D,D), wk (D,F), wv (F,D)."""
    prev = state["shift"] if state is not None else None
    xx = _token_shift(x, prev)
    xr = x + (xx - x) * p["mu_r"].astype(x.dtype)
    xk = x + (xx - x) * p["mu_k"].astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"],
                                  preferred_element_type=F32))
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["wk"],
                                          preferred_element_type=F32)))
    out = r * jnp.einsum("btf,fd->btd", k.astype(x.dtype), p["wv"],
                         preferred_element_type=F32)
    out = out.astype(x.dtype)
    if return_state:
        return out, {"shift": x[:, -1:]}
    return out


def rwkv6_init_state(B: int, D: int, head_dim: int, dtype) -> dict:
    H = D // head_dim
    return {
        "time": {"shift": jnp.zeros((B, 1, D), dtype),
                 "wkv": jnp.zeros((B, H, head_dim, head_dim), F32)},
        "channel": {"shift": jnp.zeros((B, 1, D), dtype)},
    }
