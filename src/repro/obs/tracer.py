"""`Tracer`: the low-overhead structured event ring every layer emits into.

One tracer instance is threaded through a whole run — scheduler core,
execution backend, store reader, serving loop — and collects *events*:
fixed-shape tuples appended to a bounded ring buffer.  Two event kinds
share one shape:

  * **spans** carry a start timestamp and a duration (``dur >= 0``) —
    task executions, shard decodes, query lifetimes;
  * **instants** mark a point in time (``dur == INSTANT``) — task
    lifecycle transitions (``queued``/``assigned``/``done``/``failed``/
    ``requeued``), DAG admissions, ingest commits.

Event tuple layout (:data:`EVENT_FIELDS`)::

    (ts, dur, name, cat, track, task_id, extra)

``ts``/``dur`` are seconds in the tracer's *clock domain*; ``cat`` is one
of :data:`CATEGORIES`; ``track`` names the timeline row the event
belongs to (a worker id, a manager shard, a service stream); ``task_id``
/``extra`` are optional correlation payload (``extra`` stays a scalar on
hot paths).

Design constraints, in order:

  1. **Cheap when attached.**  ``emit`` is one counter bump plus one
     ``deque.append`` of a tuple — no dict construction, no string
     formatting, no locking (``deque.append`` is atomic under the GIL,
     so the store prefetch thread and the driver loop share one tracer
     safely).  Ring overflow is handled by the deque's own ``maxlen``
     eviction; :attr:`Tracer.dropped` is *derived*
     (``emitted - len(ring)``) so the hot path never compares against
     capacity.  Per-task loops go one step further through the
     sanctioned raw fast path — append pre-built tuples via
     :attr:`Tracer.raw`, then settle the count once per batch with
     ``tracer.emitted += n`` — which skips the ``emit`` call frame
     entirely (~10x cheaper per event).  The ≤5 % makespan gate on the
     heavy_tail sim (``benchmarks/obs_bench.py``) holds the line.
  2. **Free when absent.**  Every instrumentation site guards with
     ``if tracer is not None`` — an untraced run pays one attribute
     load per site.
  3. **Clock-agnostic.**  The default clock is ``time.monotonic``; the
     discrete-event sim rebinds it to its virtual clock
     (:meth:`Tracer.set_clock`), so simulated and live runs emit through
     the same API and render identically.

The ring is bounded (``capacity`` events); overflow evicts the oldest
event and counts it in :attr:`Tracer.dropped` — a saturated trace is
explicitly marked, never silently wrong.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Optional

__all__ = ["INSTANT", "EVENT_FIELDS", "CATEGORIES", "DEFAULT_CAPACITY",
           "Tracer"]

#: Sentinel duration marking an instant event (a point, not a range).
INSTANT = -1.0

#: Positional meaning of each slot in an event tuple.
EVENT_FIELDS = ("ts", "dur", "name", "cat", "track", "task_id", "extra")

#: Known event categories (one per instrumented layer).
CATEGORIES = ("task", "sched", "store", "dag", "serving")

#: Default ring size: a 12k-task sim emits ~5 events per task, so the
#: default holds two orders of magnitude more than the standard bench
#: workload before eviction starts.
DEFAULT_CAPACITY = 1_000_000


class Tracer:
    """Bounded event ring with a swappable clock (see module docstring)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events: collections.deque = collections.deque(maxlen=capacity)
        #: Sanctioned hot-loop fast path: the ring's bound
        #: ``deque.append``.  Append fully-built 7-slot event tuples
        #: directly, then settle accounting once per batch with
        #: ``tracer.emitted += n`` (eviction is the deque's own
        #: ``maxlen``; :attr:`dropped` is derived from ``emitted``).
        self.raw: Callable[[tuple], None] = self._events.append
        #: Total events ever appended (raw appends included — their
        #: callers bump this).
        self.emitted = 0
        #: Current time source — call directly (``tracer.clock()``) on
        #: hot paths; :meth:`now` is the same thing one frame slower.
        self.clock: Callable[[], float] = (clock if clock is not None
                                           else time.monotonic)

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Rebind the time source (the sim binds its virtual clock)."""
        self.clock = clock

    def now(self) -> float:
        """Current time in the tracer's clock domain."""
        return self.clock()

    @property
    def dropped(self) -> int:
        """Events evicted by ring overflow (oldest-first)."""
        return self.emitted - len(self._events)

    # -- hot path ----------------------------------------------------------

    def emit(self, ts: float, dur: float, name: str, cat: str, track,
             task_id=None, extra=None) -> None:
        """Append one raw event tuple; ``dur=INSTANT`` marks an instant."""
        self.emitted += 1
        self.raw((ts, dur, name, cat, track, task_id, extra))

    def instant(self, name: str, cat: str, track, *, ts: Optional[float]
                = None, task_id=None, extra=None) -> None:
        """Point event at ``ts`` (default: now)."""
        self.emit(self.clock() if ts is None else ts, INSTANT,
                  name, cat, track, task_id, extra)

    def span(self, name: str, cat: str, track, start: float, end: float,
             *, task_id=None, extra=None) -> None:
        """Range event covering ``[start, end]``."""
        self.emit(start, end - start, name, cat, track, task_id, extra)

    # -- read side ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> list[tuple]:
        """Snapshot of the ring contents (oldest first)."""
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.emitted = 0
