"""End-to-end tracing & metrics for the track-processing machine.

The layer the paper's §IV–§V performance story needs: structured,
low-overhead span events for every task lifecycle transition, store
decode, DAG admission, and serving operation — emitted identically by
the discrete-event sim (virtual clock) and the live backends (monotonic
clock), exported as Chrome/Perfetto timelines and canonical byte-stable
``TRACE_summary.json`` artifacts, and reduced to critical-path /
straggler / worker-speed reports by ``python -m repro.obs.report``.

Entry points:

  * :class:`Tracer` — the event ring (pass as ``tracer=`` to
    ``run_job``/``run_dag``/``run_service``/``TrackStore``/
    ``IngestService``/``StoreFrontEnd``, or use ``--trace DIR`` on the
    track workflow CLI);
  * :func:`build_summary` / :func:`summary_from_tracer` — canonical
    ``repro.obs/v1`` summaries;
  * :func:`to_chrome_trace` / :func:`from_chrome_trace` — Perfetto
    export and its inverse;
  * :func:`write_trace_files` — the one-call exporter the workflow and
    bench CLIs use.
"""

from __future__ import annotations

import json
import os

from repro.obs.perfetto import from_chrome_trace, to_chrome_trace
from repro.obs.summary import build_summary, phase_of, summary_from_tracer
from repro.obs.tracer import (
    CATEGORIES, DEFAULT_CAPACITY, EVENT_FIELDS, INSTANT, Tracer)

__all__ = ["Tracer", "INSTANT", "EVENT_FIELDS", "CATEGORIES",
           "DEFAULT_CAPACITY", "build_summary", "summary_from_tracer",
           "phase_of", "to_chrome_trace", "from_chrome_trace",
           "write_trace_files"]


def write_trace_files(tracer: Tracer, out_dir: str, *,
                      label: str = "run") -> dict[str, str]:
    """Export one tracer to ``<out_dir>/trace.json`` (Perfetto) and
    ``<out_dir>/TRACE_summary.json`` (canonical ``repro.obs/v1``
    bytes); returns the paths keyed by artifact kind."""
    from repro.bench.schema import canonical_bytes

    os.makedirs(out_dir, exist_ok=True)
    events = tracer.events
    trace_path = os.path.join(out_dir, "trace.json")
    with open(trace_path, "w") as f:
        json.dump(to_chrome_trace(events, label=label), f)
    summary = build_summary(events, label=label, dropped=tracer.dropped)
    summary_path = os.path.join(out_dir, "TRACE_summary.json")
    with open(summary_path, "wb") as f:
        f.write(canonical_bytes(summary))
    return {"trace": trace_path, "summary": summary_path}
