"""Critical-path / straggler report: ``python -m repro.obs.report``.

Reads either artifact the tracer exports — a Chrome/Perfetto
``trace.json`` (reduced on the fly) or a canonical
``TRACE_summary.json`` — and prints the derived performance story:

  * per-phase critical-path lengths and the fitted cost models;
  * top-k straggler tasks with cost-estimate vs actual residuals;
  * per-worker speed estimates, slowest first (the measured
    ``worker_speed`` input the speculation work consumes);
  * per-manager-shard dispatch-rate timelines (the §V message wall as a
    curve).

``--summary-out`` additionally writes the canonical summary JSON, so a
raw ``trace.json`` can be reduced to the diffable artifact after the
fact.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.bench.schema import OBS_SUMMARY_SCHEMA, canonical_bytes
from repro.obs.perfetto import from_chrome_trace
from repro.obs.summary import build_summary

__all__ = ["load_summary", "render_report", "main"]

_SPARK = " .:-=+*#%@"


def _spark(bins) -> str:
    peak = max(bins) if bins else 0
    if peak <= 0:
        return " " * len(bins)
    return "".join(
        _SPARK[min(int(b * (len(_SPARK) - 1) / peak + 0.5),
                   len(_SPARK) - 1)] for b in bins)


def load_summary(path: str, *, top_k: int = 10) -> dict:
    """Load a summary from either a trace.json or a TRACE_summary.json."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") == OBS_SUMMARY_SCHEMA:
        return doc
    if "traceEvents" in doc:
        label = doc.get("metadata", {}).get("label", "trace")
        return build_summary(from_chrome_trace(doc), label=label,
                             top_k=top_k)
    raise ValueError(
        f"{path}: neither a {OBS_SUMMARY_SCHEMA!r} summary nor a "
        f"Chrome trace (no 'traceEvents')")


def render_report(doc: dict, *, top: int = 10) -> list[str]:
    """Human-readable report lines for one summary document."""
    m = doc["scenario"]["metrics"]
    cfg = doc.get("config", {})
    lines = [
        f"trace: {doc['scenario']['name']}  "
        f"events={cfg.get('n_events', '?')} "
        f"dropped={cfg.get('dropped', 0)}",
        f"makespan {m['makespan_s']:.6g}s  critical path "
        f"{m['critical_path_s']:.6g}s  exec p50/p99 "
        f"{m['exec_p50_s']:.4g}/{m['exec_p99_s']:.4g}s "
        f"(ratio {m['exec_p99_over_p50']:.3g})",
        f"lifecycle: queued={m['n_queued']} assigned={m['n_assigned']} "
        f"done={m['n_done']} failed={m['n_failed']} "
        f"requeued={m['n_requeued']}  exec spans={m['n_exec_spans']} "
        f"workers={m['n_workers_seen']}",
    ]
    phases = doc.get("phases", {})
    if phases:
        lines.append("per-phase critical path:")
        for ph in sorted(phases):
            p = phases[ph]
            cm = p["cost_model"]
            model = (f"linear(a={cm['a_s']:.3g}s, "
                     f"b={cm['b_s_per_byte']:.3g}s/B)"
                     if cm["kind"] == "linear"
                     else f"mean({cm['mean_s']:.3g}s)")
            lines.append(f"  {ph:16s} crit={p['critical_path_s']:10.6g}s"
                         f"  tasks={p['n_tasks']:6d}"
                         f"  busy={p['busy_s']:10.6g}s  cost={model}")
    stragglers = doc.get("stragglers", [])
    if stragglers:
        lines.append(f"top {min(top, len(stragglers))} stragglers "
                     f"(of {m['straggler_count']} beyond the "
                     f"2x-estimate threshold):")
        lines.append(f"  {'task':24s} {'worker':>8s} {'actual':>10s} "
                     f"{'est':>10s} {'residual':>10s} {'ratio':>7s}")
        for s in stragglers[:top]:
            lines.append(f"  {str(s['task_id']):24s} {s['worker']:>8s} "
                         f"{s['actual_s']:10.4g} {s['est_s']:10.4g} "
                         f"{s['residual_s']:10.4g} {s['ratio']:7.2f}")
    workers = {k: v for k, v in doc.get("workers", {}).items()
               if not k.startswith("_")}
    if workers:
        ranked = sorted(workers, key=lambda k: (workers[k]["speed_est"], k))
        lines.append(f"slowest workers (speed = estimated/actual cost; "
                     f"{len(ranked)} listed"
                     + (f", {doc['workers']['_dropped_workers']} dropped)"
                        if "_dropped_workers" in doc.get("workers", {})
                        else ")") + ":")
        for k in ranked[:top]:
            w = workers[k]
            lines.append(f"  {k:>8s}  speed={w['speed_est']:.3f}  "
                         f"tasks={w['n_tasks']:5d}  "
                         f"busy={w['busy_s']:.6g}s")
    shards = doc.get("shards", {})
    if shards:
        lines.append("per-shard dispatch timeline (assigned per bin, "
                     f"bin={next(iter(shards.values()))['bin_s']:.4g}s):")
        for s in sorted(shards):
            d = shards[s]
            lines.append(f"  shard {s:>4s} [{_spark(d['bins'])}] "
                         f"total={d['assigned']}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Derive the critical-path/straggler report from a "
                    "trace.json or TRACE_summary.json.")
    ap.add_argument("path", help="trace.json or TRACE_summary.json")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per table (default 10)")
    ap.add_argument("--summary-out", default=None,
                    help="also write the canonical summary JSON here")
    args = ap.parse_args(argv)
    try:
        doc = load_summary(args.path, top_k=args.top)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    for line in render_report(doc, top=args.top):
        print(line)
    if args.summary_out:
        with open(args.summary_out, "wb") as f:
            f.write(canonical_bytes(doc))
        print(f"wrote {args.summary_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
