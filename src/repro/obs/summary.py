"""Canonical trace summaries: raw event tuples -> ``TRACE_summary.json``.

:func:`build_summary` reduces a :class:`repro.obs.tracer.Tracer` event
stream to the byte-stable ``repro.obs/v1`` artifact the bench compare
tooling diffs:

  * headline ``scenario.metrics`` — critical-path seconds, straggler
    count, exec p99/p50 ratio, makespan — shaped so
    ``repro.bench.compare`` reads them through its single-``scenario``
    path (the smoke-doc shape);
  * per-phase critical paths and fitted cost models;
  * per-worker busy time and *speed estimates* (estimated cost over
    actual cost — the ``worker_speed`` input the ROADMAP's speculation
    tentpole needs, now measured instead of assumed);
  * top-k straggler tasks with cost-estimate vs actual residuals;
  * per-manager-shard dispatch-rate timelines (binned ``assigned``
    counts) that render the paper's §V message wall as a curve.

Determinism: timestamps are normalized to the earliest event, every
reduction iterates in event order or over sorted keys, and no wall-clock
or environment field enters the document — so a sim trace summarizes to
byte-identical JSON across same-seed reruns
(``repro.bench.schema.canonical_bytes`` is the serializer).

Cost model: per phase, a least-squares linear fit of exec duration vs
task ``size_bytes`` when every span carries a size (the sim path), else
the phase mean.  The same fit prices every worker's tasks, so a uniform
fit bias cancels out of the speed-estimate *ranking* — a 4×-slowed
worker lands at the bottom regardless of fit quality.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.bench.schema import OBS_SUMMARY_SCHEMA, SCHEMA_VERSION

__all__ = ["build_summary", "summary_from_tracer", "phase_of",
           "STRAGGLER_RATIO"]

#: A task is a straggler when actual exec time exceeds this multiple of
#: its cost estimate.
STRAGGLER_RATIO = 2.0

#: Floor for cost estimates (keeps actual/estimate ratios finite).
_EST_FLOOR = 1e-12


def phase_of(task_id: Optional[str]) -> str:
    """Phase bucket of a task id: the DAG node prefix when namespaced
    (``radar:t0042`` -> ``radar``), else the catch-all ``all``."""
    if isinstance(task_id, str) and ":" in task_id:
        return task_id.split(":", 1)[0]
    return "all"


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _quantile(sorted_xs: Sequence[float], q: float) -> float:
    """Nearest-rank quantile (same rule as ``RunResult._quantiles``)."""
    i = min(int(q * (len(sorted_xs) - 1) + 0.5), len(sorted_xs) - 1)
    return sorted_xs[i]


def _fit_cost_model(spans: Sequence[tuple]) -> dict:
    """Fit one phase's exec spans -> cost-model doc.

    ``spans`` are event tuples whose ``extra`` slot may carry the task
    size in bytes.  Linear least squares on (size, dur) when every span
    has a numeric size and the fit slope is positive; otherwise the
    phase-mean model.
    """
    durs = [e[1] for e in spans]
    mean = sum(durs) / len(durs)
    sizes = [e[6] for e in spans]
    if len(spans) >= 2 and all(_num(s) for s in sizes):
        n = float(len(spans))
        sx = sum(float(s) for s in sizes)
        sy = sum(durs)
        sxx = sum(float(s) * float(s) for s in sizes)
        sxy = sum(float(s) * d for s, d in zip(sizes, durs))
        denom = n * sxx - sx * sx
        if denom > 0.0:
            b = (n * sxy - sx * sy) / denom
            a = (sy - b * sx) / n
            if b > 0.0:
                return {"kind": "linear", "a_s": a, "b_s_per_byte": b,
                        "mean_s": mean}
    return {"kind": "mean", "mean_s": mean}


def _estimate(model: dict, extra) -> float:
    if model["kind"] == "linear" and _num(extra):
        return max(model["a_s"] + model["b_s_per_byte"] * float(extra),
                   _EST_FLOOR)
    return max(model["mean_s"], _EST_FLOOR)


def build_summary(events: Iterable[tuple], *, label: str = "run",
                  dropped: int = 0, top_k: int = 10,
                  max_workers: int = 64, n_bins: int = 20) -> dict:
    """Reduce raw event tuples to a ``repro.obs/v1`` summary document.

    ``dropped`` records ring-buffer evictions (from
    ``Tracer.dropped``); ``top_k`` bounds the straggler table;
    ``max_workers`` caps the per-worker table (busiest kept, the rest
    counted under ``_dropped_workers``); ``n_bins`` sets the dispatch
    timeline resolution.
    """
    evs = [tuple(e) for e in events]
    t0 = min((e[0] for e in evs), default=0.0)
    t1 = t0
    for e in evs:
        end = e[0] + (e[1] if e[1] >= 0.0 else 0.0)
        if end > t1:
            t1 = end
    makespan = t1 - t0

    name_counts: dict[str, int] = {}
    for e in evs:
        name_counts[e[2]] = name_counts.get(e[2], 0) + 1

    exec_spans = [e for e in evs if e[2] == "exec" and e[1] >= 0.0]

    # -- per-phase cost models + critical paths ---------------------------
    by_phase: dict[str, list[tuple]] = {}
    for e in exec_spans:
        by_phase.setdefault(phase_of(e[5]), []).append(e)
    phases: dict[str, dict] = {}
    models: dict[str, dict] = {}
    critical_path_total = 0.0
    for ph in sorted(by_phase):
        spans = by_phase[ph]
        model = _fit_cost_model(spans)
        models[ph] = model
        worker_busy: dict[str, float] = {}
        busy = 0.0
        for e in spans:
            w = str(e[4])
            worker_busy[w] = worker_busy.get(w, 0.0) + e[1]
            busy += e[1]
        crit = max((worker_busy[w] for w in sorted(worker_busy)),
                   default=0.0)
        critical_path_total += crit
        phases[ph] = {"n_tasks": len(spans), "busy_s": busy,
                      "critical_path_s": crit, "cost_model": model}

    # -- per-task residuals -> stragglers ---------------------------------
    scored = []
    for e in exec_spans:
        ph = phase_of(e[5])
        est = _estimate(models[ph], e[6])
        scored.append((e, ph, est, e[1] - est, e[1] / est))
    straggler_count = sum(1 for s in scored if s[4] > STRAGGLER_RATIO)
    scored.sort(key=lambda s: (-s[3], str(s[0][5]), str(s[0][4])))
    stragglers = [
        {"task_id": s[0][5], "worker": str(s[0][4]), "phase": s[1],
         "actual_s": s[0][1], "est_s": s[2], "residual_s": s[3],
         "ratio": s[4]}
        for s in scored[:top_k]]

    # -- per-worker speed estimates ---------------------------------------
    wk: dict[str, dict] = {}
    for e, _ph, est, _res, _ratio in scored:
        w = wk.setdefault(str(e[4]),
                          {"n_tasks": 0, "busy_s": 0.0, "est_s": 0.0})
        w["n_tasks"] += 1
        w["busy_s"] += e[1]
        w["est_s"] += est
    for w in wk.values():
        w["speed_est"] = (w["est_s"] / w["busy_s"]
                          if w["busy_s"] > 0.0 else 1.0)
    kept = sorted(wk, key=lambda k: (-wk[k]["busy_s"], k))[:max_workers]
    workers: dict[str, dict] = {k: wk[k] for k in kept}
    if len(wk) > len(kept):
        workers["_dropped_workers"] = len(wk) - len(kept)

    # -- per-shard dispatch timelines -------------------------------------
    width = (makespan / n_bins) if makespan > 0.0 else 1.0
    shard_bins: dict[str, list[int]] = {}
    shard_counts: dict[str, int] = {}
    for e in evs:
        if e[2] != "assigned":
            continue
        shard = str(e[6] if e[6] is not None else 0)
        bins = shard_bins.setdefault(shard, [0] * n_bins)
        bins[min(int((e[0] - t0) / width), n_bins - 1)] += 1
        shard_counts[shard] = shard_counts.get(shard, 0) + 1
    shards = {s: {"assigned": shard_counts[s], "bin_s": width,
                  "bins": shard_bins[s]}
              for s in sorted(shard_bins)}

    durs = sorted(e[1] for e in exec_spans)
    p50 = _quantile(durs, 0.50) if durs else 0.0
    p99 = _quantile(durs, 0.99) if durs else 0.0
    metrics = {
        "critical_path_s": critical_path_total,
        "makespan_s": makespan,
        "straggler_count": straggler_count,
        "exec_p50_s": p50,
        "exec_p99_s": p99,
        "exec_p99_over_p50": (p99 / p50) if p50 > 0.0 else 0.0,
        "n_exec_spans": len(exec_spans),
        "n_workers_seen": len(wk),
        "n_queued": name_counts.get("queued", 0),
        "n_assigned": name_counts.get("assigned", 0),
        "n_done": name_counts.get("done", 0),
        "n_failed": name_counts.get("failed", 0),
        "n_requeued": name_counts.get("requeued", 0),
    }
    return {
        "schema": OBS_SUMMARY_SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "config": {"label": label, "n_events": len(evs),
                   "dropped": dropped, "top_k": top_k,
                   "max_workers": max_workers, "n_bins": n_bins},
        "scenario": {"name": label, "status": "ran", "metrics": metrics},
        "phases": phases,
        "workers": workers,
        "stragglers": stragglers,
        "shards": shards,
    }


def summary_from_tracer(tracer, *, label: str = "run", **kw) -> dict:
    """Summarize a live :class:`~repro.obs.tracer.Tracer` in place."""
    return build_summary(tracer.events, label=label,
                         dropped=tracer.dropped, **kw)
