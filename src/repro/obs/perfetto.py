"""Chrome/Perfetto trace export: event tuples <-> ``trace.json``.

:func:`to_chrome_trace` renders a tracer's event ring as the Trace Event
Format JSON that ``chrome://tracing`` and https://ui.perfetto.dev load
directly: each ``(cat, track)`` pair becomes a named thread row, spans
become complete (``"X"``) events, instants become ``"i"`` events, and
timestamps are normalized to the earliest event and scaled to
microseconds.  Virtual-clock sim traces and wall-clock live traces
render identically — the paper's heavy-tail §IV timelines become
something you can scrub.

:func:`from_chrome_trace` is the inverse used by ``repro.obs.report`` so
the CLI accepts either a ``trace.json`` or a ``TRACE_summary.json``.
The round trip preserves event structure exactly; timestamps come back
in (relative) seconds via the µs scaling, so derived *reports* agree
while canonical summary bytes are only guaranteed when built directly
from the tracer.
"""

from __future__ import annotations

from typing import Iterable

from repro.obs.tracer import INSTANT

__all__ = ["to_chrome_trace", "from_chrome_trace"]

_PID = 1
_US = 1e6


def _track_order(events: list[tuple]) -> dict[tuple[str, str], int]:
    """Stable tid assignment: sorted unique (cat, track-name) -> 1..N."""
    keys = sorted({(e[3], str(e[4])) for e in events})
    return {k: i + 1 for i, k in enumerate(keys)}


def to_chrome_trace(events: Iterable[tuple], *, label: str = "run") -> dict:
    """Event tuples -> a Trace Event Format document (JSON-ready dict)."""
    evs = [tuple(e) for e in events]
    t0 = min((e[0] for e in evs), default=0.0)
    tids = _track_order(evs)
    out: list[dict] = []
    for (cat, track), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "pid": _PID, "tid": tid,
                    "name": "thread_name",
                    "args": {"name": f"{cat}:{track}"}})
    for ts, dur, name, cat, track, task_id, extra in evs:
        ev: dict = {"pid": _PID, "tid": tids[(cat, str(track))],
                    "ts": (ts - t0) * _US, "name": name, "cat": cat}
        if dur >= 0.0:
            ev["ph"] = "X"
            ev["dur"] = dur * _US
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        args = {}
        if task_id is not None:
            args["task_id"] = task_id
        if extra is not None:
            args["extra"] = extra
        if args:
            ev["args"] = args
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "metadata": {"label": label, "t0_s": t0,
                         "format": "repro.obs trace"}}


def from_chrome_trace(doc: dict) -> list[tuple]:
    """Trace Event Format document -> event tuples (relative seconds).

    Track identity comes back as the string after ``cat:`` in the thread
    name, so worker tracks that were ints round-trip as strings — every
    downstream reduction keys tracks by ``str(track)`` already.
    """
    raw = doc.get("traceEvents", [])
    names: dict[int, str] = {}
    for ev in raw:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev.get("args", {}).get("name", "")
    events: list[tuple] = []
    for ev in raw:
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            continue
        cat = ev.get("cat", "")
        thread = names.get(ev.get("tid"), "")
        track = (thread.split(":", 1)[1]
                 if thread.startswith(cat + ":") else thread)
        args = ev.get("args", {})
        events.append((ev["ts"] / _US,
                       (ev["dur"] / _US) if ph == "X" else INSTANT,
                       ev.get("name", ""), cat, track,
                       args.get("task_id"), args.get("extra")))
    return events
