"""Post-optimization HLO text parsing: collective bytes per category.

cost_analysis() exposes FLOPs and bytes-accessed but NOT collective
traffic, so we parse ``compiled.as_text()``: build a name -> byte-size
symbol table from every instruction's output shape, then sum operand
sizes for each collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), recording replica-group sizes so the
analysis layer can convert operand bytes into per-chip wire bytes.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g. "all-reduce(", "all-gather-start(", "all-reduce-scatter..." etc.
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape text like
    '(f32[8,128]{1,0}, f32[64]{0})' or 'bf16[2,4096]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_shapes(hlo_text: str) -> dict[str, int]:
    """name -> output bytes for every instruction in the module."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # shape text precedes the opcode: take everything up to the last
        # shape group before an opcode word. Simplest: parse shapes in the
        # prefix before the first '(' that follows the opcode... in
        # practice the output shape(s) lead the RHS.
        opm = re.search(r"[a-z][\w\-]*\(", rhs)
        prefix = rhs[: opm.start()] if opm else rhs
        sizes[name] = _shape_bytes(prefix)
    return sizes


@dataclasses.dataclass
class CollectiveStats:
    """Per-category operand bytes + estimated per-chip wire bytes."""
    operand_bytes: dict[str, float]
    wire_bytes: dict[str, float]

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:                      # replica_groups=[ngroups,group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int = 1) -> CollectiveStats:
    """Sum operand bytes of every collective op in the module.

    Wire-byte model per chip (ring algorithms over a group of size g):
      all-reduce:        2 * (g-1)/g * operand
      all-gather:        (g-1)/g * output          (operand = output/g)
      reduce-scatter:    (g-1)/g * operand
      all-to-all:        (g-1)/g * operand
      collective-permute: operand
    """
    sizes = parse_hlo_shapes(hlo_text)
    op_bytes: dict[str, float] = defaultdict(float)
    wire: dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        cm = _COLL_RE.search(line)
        if not cm or "-done(" in line:   # count start, skip done halves
            continue
        kind = cm.group(1)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        # operands: names inside the call parens
        call = rhs[rhs.index("("):] if "(" in rhs else ""
        ops = [sizes.get(nm, 0) for nm in _OPERAND_RE.findall(call)]
        operand = float(sum(ops))
        out = float(sizes.get(m.group(1), 0))
        g = _group_size(line, n_devices)
        op_bytes[kind] += operand
        if kind == "all-reduce":
            wire[kind] += 2.0 * (g - 1) / g * operand
        elif kind == "all-gather":
            wire[kind] += (g - 1) / g * out
        elif kind in ("reduce-scatter", "all-to-all"):
            wire[kind] += (g - 1) / g * operand
        else:  # collective-permute
            wire[kind] += operand
    return CollectiveStats(dict(op_bytes), dict(wire))
