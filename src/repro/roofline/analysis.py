"""Three-term roofline from dry-run measurements (DESIGN.md §7).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Loop-body composition: XLA's cost_analysis counts a while-loop body once
(verified experimentally), so totals are composed as

    total = full_graph_cost + (n_superblocks - 1) * block_cost

where block_cost is measured by separately lowering one superblock (fwd,
and fwd+bwd for training) under the same mesh/shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                   # PER-CHIP (SPMD cost_analysis is local)
    hbm_bytes: float               # PER-CHIP
    collective_bytes: float        # PER-CHIP wire bytes (ring model)
    model_flops: float             # GLOBAL (6*N*D etc.)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0      # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float = 0.0  # model-flops time / bound

    def finalize(self) -> "RooflineTerms":
        # SPMD cost_analysis + HLO operand shapes are shard-local, so all
        # three numerators here are per-chip; the spec's
        # global/(chips * rate) is identical to per_chip/rate.
        self.compute_s = self.flops / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / (self.flops * self.chips)
                             if self.flops else 0.0)
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        self.roofline_fraction = ideal / bound if bound > 0 else 0.0
        return self


def compute_terms(record: dict) -> RooflineTerms:
    """Build roofline terms from one dry-run JSON record."""
    n_sb = record["n_superblocks"]
    full = record["cost"]
    blk = record.get("block_cost")         # may be None for tiny models
    extra = (n_sb - 1) if blk else 0
    flops = full.get("flops", 0.0) + extra * (blk or {}).get("flops", 0.0)
    hbm = full.get("bytes accessed", 0.0) \
        + extra * (blk or {}).get("bytes accessed", 0.0)
    coll = record["collectives"]["wire_bytes_total"] \
        + extra * record.get("block_collectives", {}).get(
            "wire_bytes_total", 0.0)
    return RooflineTerms(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=record["chips"],
        flops=flops, hbm_bytes=hbm,
        collective_bytes=coll,
        model_flops=record["model_flops"],
    ).finalize()
