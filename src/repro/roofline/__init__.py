"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.hlo_parse import collective_bytes, parse_hlo_shapes
from repro.roofline.analysis import RooflineTerms, compute_terms

__all__ = ["collective_bytes", "parse_hlo_shapes", "RooflineTerms",
           "compute_terms"]
