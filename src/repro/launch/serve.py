"""Serving launcher: batched requests against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --reduced --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import model as M
from repro.serving.server import BatchedServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    if cfg.frontend is not None:
        raise SystemExit("choose a token-input arch for the serve demo")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, slots=args.slots,
                           prompt_len=args.prompt_len, cache_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, args.prompt_len)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    server.serve(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens_out) for r in reqs)
    print(f"arch={cfg.name}: {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
          f"{server.steps} engine steps)")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.tokens_out[:10]}...")


if __name__ == "__main__":
    main()
