import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
backend initialization, and the production meshes need 512 placeholder
host devices. Nothing else in the repo sets this flag — smoke tests and
benchmarks see the real single CPU device.

For every cell this driver:
  1. builds abstract params / optimizer state / caches (eval_shape only);
  2. derives shardings from distribution.sharding rules;
  3. jit(step).lower(...).compile() under the production mesh;
  4. records memory_analysis(), cost_analysis(), and the HLO collective
     traffic (roofline.hlo_parse);
  5. separately lowers ONE superblock (fwd, and fwd+bwd for train) with
     the same shardings — cost_analysis counts while-loop bodies once, so
     roofline totals compose as full + (n_superblocks-1) * block;
  6. writes a JSON record to --out.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch, shapes_for
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.distribution.sharding import (
    batch_shardings, batch_spec, cache_shardings, make_spec,
    opt_state_shardings, param_shardings)
from repro.core.messages import Task
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch import steps
from repro.models import model as M
from repro.roofline.hlo_parse import collective_bytes
from repro.runtime import run_job
from repro.train.optimizer import OptimizerConfig, init_opt_state

F32 = jnp.float32


def _j(obj):
    """JSON-safe."""
    if isinstance(obj, dict):
        return {k: _j(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_j(v) for v in obj]
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    return str(obj)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (serve), N = active params, D = tokens."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch * 1.0      # decode: one token


_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        return {k: float(ca[k]) for k in _COST_KEYS if k in ca}
    except Exception as e:   # pragma: no cover
        return {"error": repr(e)}


def _sharded_bytes(specs, shardings, mesh) -> int:
    """Analytic per-chip bytes for a sharded pytree of ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(specs)
    shs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    total = 0
    for leaf, sh in zip(leaves, shs):
        n = 1
        for d in leaf.shape:
            n *= d
        div = 1
        for axes in sh.spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                div *= mesh.shape[a]
        total += (n // max(div, 1)) * leaf.dtype.itemsize
    return total


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:   # pragma: no cover
        return {"error": repr(e)}


def _block_shardings(cfg: ArchConfig, mesh, params_specs):
    """Shardings for ONE superblock's params (drop the stacked dim)."""
    full = param_shardings(params_specs, mesh)
    blocks_sh = full["blocks"]

    def strip(sh):
        return NamedSharding(mesh, P(*tuple(sh.spec)[1:]))
    return jax.tree_util.tree_map(strip, blocks_sh)


def _one_superblock_specs(params_specs):
    def strip(leaf):
        return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
    return jax.tree_util.tree_map(strip, params_specs["blocks"])


def run_cell(cfg: ArchConfig, shape: ShapeConfig, multi_pod: bool,
             opt_cfg: Optional[OptimizerConfig] = None,
             measure_block: bool = True,
             remat: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict[str, Any] = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind, "ok": False,
        "n_superblocks": cfg.n_superblocks,
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=True),
        "model_flops": model_flops(cfg, shape),
    }
    opt_cfg = opt_cfg or OptimizerConfig(state_dtype="int8")
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["chips"] = mesh.devices.size
    try:
        with mesh_context(mesh):
            pspecs = steps.param_specs(cfg)
            psh = param_shardings(pspecs, mesh)
            batch = steps.input_specs(cfg, shape)
            bsh = batch_shardings(mesh, batch)
            rec["param_bytes_per_chip"] = _sharded_bytes(pspecs, psh, mesh)

            if shape.kind == "train":
                ospecs = jax.eval_shape(
                    functools.partial(init_opt_state, cfg=opt_cfg), pspecs)
                osh = opt_state_shardings(ospecs, pspecs, psh, mesh)
                rec["opt_bytes_per_chip"] = _sharded_bytes(
                    ospecs, osh, mesh)
                fn = steps.make_train_step(cfg, opt_cfg, remat=remat)
                jitted = jax.jit(
                    fn, in_shardings=(psh, osh, bsh),
                    out_shardings=(psh, osh, NamedSharding(mesh, P())))
                lowered = jitted.lower(pspecs, ospecs, batch)
            elif shape.kind == "prefill":
                cspecs = steps.cache_specs(cfg, shape)
                csh = cache_shardings(cspecs, mesh)
                rec["cache_bytes_per_chip"] = _sharded_bytes(
                    cspecs, csh, mesh)
                lsh = NamedSharding(mesh, batch_spec(
                    mesh, shape.global_batch, 2))
                fn = steps.make_prefill_step(cfg, shape.seq_len)
                jitted = jax.jit(fn, in_shardings=(psh, bsh),
                                 out_shardings=(lsh, csh))
                lowered = jitted.lower(pspecs, batch)
            else:  # decode
                cspecs = steps.cache_specs(cfg, shape)
                csh = cache_shardings(cspecs, mesh)
                rec["cache_bytes_per_chip"] = _sharded_bytes(
                    cspecs, csh, mesh)
                lsh = NamedSharding(mesh, batch_spec(
                    mesh, shape.global_batch, 2))
                fn = steps.make_decode_step(cfg)
                jitted = jax.jit(fn, in_shardings=(psh, csh, bsh),
                                 out_shardings=(lsh, csh))
                lowered = jitted.lower(pspecs, cspecs, batch)

            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 1)
            rec["memory"] = _memory_dict(compiled)
            rec["cost"] = _cost_dict(compiled)
            txt = compiled.as_text()
            st = collective_bytes(txt, mesh.devices.size)
            rec["collectives"] = {
                "operand_bytes": st.operand_bytes,
                "wire_bytes": st.wire_bytes,
                "wire_bytes_total": st.total_wire_bytes,
            }
            rec["hlo_bytes"] = len(txt)

            if measure_block and cfg.n_superblocks > 1:
                rec.update(_measure_block(cfg, shape, mesh, pspecs, psh))
            rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def _measure_block(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   pspecs, psh) -> dict:
    """Lower one superblock under the same shardings; compose costs."""
    out: dict[str, Any] = {}
    bspecs = _one_superblock_specs(pspecs)
    bsh = _block_shardings(cfg, mesh, pspecs)
    B = shape.global_batch
    T = shape.seq_len if shape.kind != "decode" else 1
    adt = jnp.dtype(cfg.activation_dtype)
    xspec = jax.ShapeDtypeStruct((B, T, cfg.d_model), adt)
    xsh = NamedSharding(mesh, batch_spec(mesh, B, 2))

    if shape.kind == "decode":
        cspecs_full = steps.cache_specs(cfg, shape)
        csh_full = cache_shardings(cspecs_full, mesh)
        one_cache = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
            cspecs_full)
        one_csh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P(*tuple(s.spec)[1:])), csh_full)

        def blk(bp, cache, x):
            h = x
            ncs = {}
            for i, kind in enumerate(cfg.block_pattern):
                h, nc = M._apply_sublayer_decode(
                    cfg, kind, cfg.is_moe_layer(i), bp[f"s{i}"],
                    cache[f"s{i}"], h)
                ncs[f"s{i}"] = nc
            return h, ncs
        c = jax.jit(blk, in_shardings=(bsh, one_csh, xsh),
                    out_shardings=(xsh, one_csh)) \
            .lower(bspecs, one_cache, xspec).compile()
        out["block_cost"] = _cost_dict(c)
        st = collective_bytes(c.as_text(), mesh.devices.size)
        out["block_collectives"] = {"wire_bytes_total": st.total_wire_bytes}
        return out

    fwd = lambda bp, x: M.superblock_apply(cfg, bp, x)
    c_fwd = jax.jit(fwd, in_shardings=(bsh, xsh), out_shardings=xsh) \
        .lower(bspecs, xspec).compile()
    cost = _cost_dict(c_fwd)
    st = collective_bytes(c_fwd.as_text(), mesh.devices.size)
    wire = st.total_wire_bytes

    if shape.kind == "train":
        def vjp_fn(bp, x, ct):
            y = M.superblock_apply(cfg, bp, x)
            return jnp.sum(y.astype(F32) * ct.astype(F32))
        g = jax.jit(jax.grad(vjp_fn, argnums=(0, 1)),
                    in_shardings=(bsh, xsh, xsh),
                    out_shardings=(bsh, xsh))
        c_bwd = g.lower(bspecs, xspec, xspec).compile()
        bcost = _cost_dict(c_bwd)
        for k in set(cost) | set(bcost):
            if isinstance(cost.get(k, 0.0), float):
                cost[k] = cost.get(k, 0.0) + bcost.get(k, 0.0)
        st2 = collective_bytes(c_bwd.as_text(), mesh.devices.size)
        wire += st2.total_wire_bytes
    out["block_cost"] = cost
    out["block_collectives"] = {"wire_bytes_total": wire}
    return out


def _compile_cell(task: Task, *, opt_cfg: OptimizerConfig,
                  measure_block: bool) -> bool:
    """Worker fn for the self-scheduled cell dispatcher (module-level so
    it pickles under the multiprocessing spawn start method)."""
    a, s, mp, path = task.payload
    print(f"[run ] {task.task_id}", flush=True)
    rec = run_cell(get_arch(a), SHAPES[s], mp, opt_cfg,
                   measure_block=measure_block)
    with open(path, "w") as f:
        json.dump(_j(rec), f, indent=1)
    status = "ok" if rec["ok"] else f"FAIL: {rec.get('error')}"
    print(f"[done] {task.task_id}: {status} ({rec['total_s']}s)",
          flush=True)
    return bool(rec["ok"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=sorted(ARCHS) + [None], nargs="?")
    ap.add_argument("--shape", default=None,
                    choices=sorted(SHAPES) + [None], nargs="?")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-block", action="store_true",
                    help="skip per-superblock roofline measurement")
    ap.add_argument("--opt-state", default="int8",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--jobs", type=int, default=1,
                    help="concurrent compile workers (self-scheduled)")
    ap.add_argument("--exec-backend", default="threads",
                    choices=["threads", "processes"],
                    help="execution backend for the cell dispatcher")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    for a in archs:
        cfg = get_arch(a)
        for sh in shapes_for(cfg):
            if args.shape and sh.name != args.shape:
                continue
            cells.append((a, sh.name))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    opt_cfg = OptimizerConfig(state_dtype=args.opt_state)

    # Each (arch x shape x mesh) cell is one self-scheduled task; sized by
    # param count so largest-first compiles the heavyweight models first.
    cell_tasks: list[Task] = []
    for a, s in cells:
        for mp in meshes:
            tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            cell_tasks.append(Task(
                task_id=tag, size_bytes=get_arch(a).param_count(),
                payload=(a, s, mp, path)))

    if cell_tasks:
        run_job(cell_tasks,
                functools.partial(_compile_cell, opt_cfg=opt_cfg,
                                  measure_block=not args.no_block),
                backend=args.exec_backend, n_workers=args.jobs,
                organization="largest_first", poll_interval=0.05)


if __name__ == "__main__":
    main()
