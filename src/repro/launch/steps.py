"""Step functions + abstract input specs for every (arch x shape) cell.

These are the functions the dry-run lowers and the trainer/server jit:
  * train_step: fwd + bwd + AdamW update (+ optional grad compression)
  * prefill_step: prompt -> logits + primed cache
  * decode_step: one token against a cache

``input_specs`` returns ShapeDtypeStructs only — weak-type-correct,
shardable, no device allocation (the dry-run pattern).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.train.optimizer import OptimizerConfig, apply_updates

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    remat: bool = True):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch, remat=remat))(params)
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ArchConfig, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len=cache_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, batch):
        return M.decode_step(cfg, params, cache, batch)
    return decode_step


# ---------------------------------------------------------------------------
# Abstract specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model inputs for one shape cell (tokens/embeds/labels)."""
    B, S = shape.global_batch, shape.seq_len
    adt = jnp.dtype(cfg.activation_dtype)
    if shape.kind == "train":
        batch: dict[str, Any] = {"labels": sds((B, S), jnp.int32)}
        if cfg.frontend is not None:
            batch["embeds"] = sds((B, S, cfg.d_model), adt)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend is not None:
            return {"embeds": sds((B, S, cfg.d_model), adt)}
        return {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "decode":
        if cfg.frontend is not None:
            return {"embeds": sds((B, 1, cfg.d_model), adt)}
        return {"tokens": sds((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def param_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.key(0))


def opt_state_specs(cfg: ArchConfig, opt_cfg: OptimizerConfig) -> Any:
    from repro.train.optimizer import init_opt_state
    p = param_specs(cfg)
    return jax.eval_shape(
        functools.partial(init_opt_state, cfg=opt_cfg), p)


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(functools.partial(
        M.init_cache, cfg, shape.global_batch, shape.seq_len))
