"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — required because
smoke tests must see 1 CPU device while the dry-run forces 512.

The triples-mode bridge: ``mesh_from_triples`` maps the paper's
(nodes, NPPN, threads) launch triple onto mesh axes (DESIGN.md §2) —
nodes -> pod axis, NPPN -> data axis, threads x chips -> model axis.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.triples import TriplesConfig

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link

V5E_HBM_BYTES = 16e9            # per chip

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (elastic re-mesh after worker loss uses this)."""
    return jax.make_mesh(shape, axes)


def mesh_context(mesh: jax.sharding.Mesh):
    """Version-portable ``with mesh_context(mesh):`` block.

    jax >= 0.5 spells it ``jax.set_mesh(mesh)``; on 0.4.x the Mesh object
    itself is the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_abstract_mesh(shape: tuple[int, ...],
                       axes: tuple[str, ...]):
    """Version-portable AbstractMesh (axis-size/axis-name signature on
    new jax; ((name, size), ...) tuple on 0.4.x)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)            # jax >= 0.5
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))  # jax 0.4.x


def mesh_from_triples(cfg: TriplesConfig, chips_per_node: int = 4,
                      pods: int = 1) -> jax.sharding.Mesh:
    """Map a triples-mode request onto a device mesh.

    nodes x nppn x (threads x chips) must equal the available device
    count; the same exclusive-mode arithmetic from core/triples.py
    validates the request before any devices are touched.
    """
    n_devices = len(jax.devices())
    shape = cfg.mesh_shape(chips_per_node)
    total = int(np.prod(shape)) * pods
    if total != n_devices:
        raise ValueError(
            f"triples {shape} x {pods} pods = {total} devices, "
            f"but {n_devices} are available")
    if pods > 1:
        return jax.make_mesh((pods, *shape[:2], shape[2]),
                             ("pod", "nodes", "data", "model"))
    return jax.make_mesh(shape, ("nodes", "data", "model"))
