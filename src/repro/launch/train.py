"""Training launcher.

Reduced-config CPU run (the end-to-end example driver):
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --reduced --steps 200 --workdir /tmp/run1

On a real fleet the same entrypoint jits against
``make_production_mesh()`` — the dry-run (launch/dryrun.py) proves every
(arch x shape x mesh) cell compiles before any hardware is booked.
"""

from __future__ import annotations

import argparse
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import SelfScheduledLoader, synthetic_token_shards
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def build_loader(cfg, batch_size: int, seq_len: int, workdir: str,
                 n_shards: int = 12, seed: int = 0) -> SelfScheduledLoader:
    shard_dir = os.path.join(workdir, "shards")
    shards = synthetic_token_shards(
        shard_dir, n_shards=n_shards, vocab_size=cfg.vocab_size,
        tokens_per_shard_mean=batch_size * (seq_len + 1) * 8, seed=seed)
    return SelfScheduledLoader(shards, batch_size=batch_size,
                               seq_len=seq_len, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--opt-state", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_train_")
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"workdir={workdir}")

    loader = build_loader(cfg, args.batch_size, args.seq_len, workdir)
    print(f"ingest: {len(loader.job_result.results)} shards in "
          f"{loader.job_result.job_seconds:.2f}s "
          f"({loader.job_result.messages_sent} messages, largest-first)")

    tcfg = TrainerConfig(workdir=workdir, total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         schedule=args.schedule, peak_lr=args.lr)
    trainer = Trainer(cfg, OptimizerConfig(state_dtype=args.opt_state),
                      tcfg)
    if cfg.frontend is not None:
        # stub frontend: swap token batches for embedding batches
        rng = np.random.default_rng(0)
        emb = np.asarray(jax.device_get(trainer.params["embed"]))

        def embed_batches(n):
            for b in loader.batches(n):
                yield {"embeds": emb[b["tokens"]], "labels": b["labels"]}
        log = trainer.run(embed_batches(args.steps), args.steps)
    else:
        log = trainer.run(loader.batches(args.steps), args.steps)
    trainer.close()
    first = np.mean([r["loss"] for r in log[:5]])
    last = np.mean([r["loss"] for r in log[-5:]])
    print(f"loss {first:.4f} -> {last:.4f} over {len(log)} steps; "
          f"stragglers={trainer.straggler_steps}")


if __name__ == "__main__":
    main()
