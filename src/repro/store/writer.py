"""Store ingest: CSV trees / zip archives -> sharded columnar store.

The paper's §III.A zip workaround made the *file count* tractable but
left every run re-parsing CSV text out of zip members.  The writer does
that parse exactly once: it walks an organized CSV tree or a PR-0
archive tree, decodes each aircraft's observations, and packs the
columns (time/lat/lon/alt as contiguous float64 + per-track offsets)
into checksummed shards (:mod:`repro.store.codec`), sized so one shard
is one healthy batch for the PR-3 length-bucketed fused pipeline.

Segment shapes (``seg_knots``/``seg_grid``) are computed at ingest and
recorded in the manifest, so the reader bins segments into buckets from
the index alone.  Planning, shard assignment and encoding are all
deterministic: same inputs -> byte-identical shards and manifest.

Ingest can run standalone (:func:`build_store`, or the CLI below) or as
a self-scheduled ``run_job`` phase: :func:`plan_shards` emits one JSON
task payload per shard and :class:`ShardBuilder` is the picklable worker
fn (see ``tracks/workflow.py``'s ``store-build`` phase).

CLI::

    PYTHONPATH=src python -m repro.store.writer \
        --src experiments/trackwf/archived --out experiments/trackwf/store
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional, Sequence

import numpy as np

from repro.store import codec
from repro.store.format import (
    SHARD_DIR, SHARD_SUFFIX, ShardRecord, StoreManifest, TrackRecord,
    write_atomic)

__all__ = ["DEFAULT_TARGET_POINTS", "EST_BYTES_PER_OBS", "ShardPlan",
           "discover_sources", "plan_shards", "build_shard",
           "ShardBuilder", "commit_shard", "finalize_manifest",
           "finalize_store", "build_store", "main"]

#: Default shard size in observation points.  At ~5-8 s between ADS-B
#: observations this is a few hundred segments per shard — comfortably
#: above the widest fused-pipeline bucket, so every bucket in a shard
#: batch runs near-full rows.
DEFAULT_TARGET_POINTS = 131_072

#: Rough CSV bytes per observation row (scaled OpenSky state vectors);
#: only used to *estimate* points for shard planning before parsing.
EST_BYTES_PER_OBS = 80


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One shard's work order: which source files it ingests."""

    shard_id: str
    sources: tuple[tuple[str, str], ...]    # (track_id, path)

    def dumps(self) -> str:
        return json.dumps({"shard_id": self.shard_id,
                           "sources": [list(s) for s in self.sources]})

    @classmethod
    def loads(cls, s: str) -> "ShardPlan":
        d = json.loads(s)
        return cls(shard_id=d["shard_id"],
                   sources=tuple((t, p) for t, p in d["sources"]))


def discover_sources(src_root: str) -> list[tuple[str, str, int]]:
    """Walk a source tree -> sorted (track_id, path, size_bytes).

    Accepts either a PR-0 archive tree (one ``<icao>.zip`` per aircraft)
    or an organized tree (per-aircraft ``.csv`` leaves).  The track_id is
    the root-relative path — identical to the task ids that
    ``segment_tasks_from_archive_tree`` would produce for the same tree.
    """
    out = []
    for dirpath, _dirs, files in os.walk(src_root):
        for f in files:
            if f.endswith(".zip") or f.endswith(".csv"):
                p = os.path.join(dirpath, f)
                rel = os.path.relpath(p, src_root).replace(os.sep, "/")
                out.append((rel, p, os.path.getsize(p)))
    out.sort(key=lambda s: s[0])
    if not out:
        raise FileNotFoundError(
            f"{src_root}: no .zip/.csv sources to ingest")
    return out


def plan_shards(sources: Sequence[tuple[str, str, int]], *,
                target_points: int = DEFAULT_TARGET_POINTS
                ) -> list[ShardPlan]:
    """Greedy sequential shard assignment from size estimates only.

    Tracks are taken in sorted-id order and a shard is cut when its
    estimated point count reaches ``target_points``; a single oversized
    track still becomes one (oversized) shard rather than being split,
    because the fused pipeline consumes whole tracks.
    """
    plans: list[ShardPlan] = []
    cur: list[tuple[str, str]] = []
    cur_points = 0
    for track_id, path, size_bytes in sources:
        est = max(size_bytes // EST_BYTES_PER_OBS, 1)
        if cur and cur_points + est > target_points:
            plans.append(ShardPlan(f"s{len(plans):05d}", tuple(cur)))
            cur, cur_points = [], 0
        cur.append((track_id, path))
        cur_points += est
    if cur:
        plans.append(ShardPlan(f"s{len(plans):05d}", tuple(cur)))
    return plans


def build_shard(out_root: str, plan: ShardPlan, *,
                compression: str = "zlib"
                ) -> tuple[ShardRecord, list[TrackRecord]]:
    """Parse one plan's sources and write ``shards/<shard_id>.shard``."""
    from repro.tracks.segments import (
        read_observations, segment_shape, split_segments)

    times, lats, lons, alts = [], [], [], []
    icao_codes: list[np.ndarray] = []
    icao_values: list[str] = []
    icao_index: dict[str, int] = {}
    offsets = [0]
    tracks: list[TrackRecord] = []
    for row, (track_id, path) in enumerate(plan.sources):
        obs = read_observations(path)
        if not obs:
            obs = {k: np.zeros(0) for k in ("time", "lat", "lon", "alt")}
            obs["icao24"] = np.zeros(0, dtype="U1")
        n = len(obs["time"])
        times.append(np.asarray(obs["time"], np.float64))
        lats.append(np.asarray(obs["lat"], np.float64))
        lons.append(np.asarray(obs["lon"], np.float64))
        alts.append(np.asarray(obs["alt"], np.float64))
        codes = np.zeros(n, np.uint32)
        names = [str(x) for x in obs["icao24"]]
        for i, name in enumerate(names):
            if name not in icao_index:
                icao_index[name] = len(icao_values)
                icao_values.append(name)
            codes[i] = icao_index[name]
        icao_codes.append(codes)
        offsets.append(offsets[-1] + n)
        segs = split_segments(obs["time"]) if n else []
        shapes = [segment_shape(obs["time"], s) for s in segs]
        tracks.append(TrackRecord(
            track_id=track_id, shard_id=plan.shard_id, row=row,
            n_obs=n, icao24=(names[0] if names else ""),
            seg_knots=tuple(s[0] for s in shapes),
            seg_grid=tuple(s[1] for s in shapes)))

    columns = {
        "time": np.concatenate(times) if times else np.zeros(0),
        "lat": np.concatenate(lats) if lats else np.zeros(0),
        "lon": np.concatenate(lons) if lons else np.zeros(0),
        "alt": np.concatenate(alts) if alts else np.zeros(0),
        "icao_codes": (np.concatenate(icao_codes) if icao_codes
                       else np.zeros(0, np.uint32)),
        "offsets": np.asarray(offsets, np.int64),
    }
    meta = {"shard_id": plan.shard_id,
            "track_ids": [t.track_id for t in tracks],
            "icao_values": icao_values}
    data = codec.encode_shard(columns, meta=meta, compression=compression)
    filename = f"{SHARD_DIR}/{plan.shard_id}{SHARD_SUFFIX}"
    write_atomic(os.path.join(out_root, filename), data)
    rec = ShardRecord(
        shard_id=plan.shard_id, filename=filename,
        n_tracks=len(tracks), n_points=int(offsets[-1]),
        size_bytes=len(data),
        sha256=hashlib.sha256(data).hexdigest())
    return rec, tracks


class ShardBuilder:
    """Picklable ``run_job`` worker fn for the ``store-build`` phase.

    Task payload: ``ShardPlan.dumps()``.  Returns JSON-able record docs
    (the DONE message must survive the process-backend pickle and the
    manager-side merge in :func:`finalize_store`).
    """

    def __init__(self, out_root: str, compression: str = "zlib"):
        self.out_root = out_root
        self.compression = compression

    def __call__(self, task) -> dict:
        plan = ShardPlan.loads(task.payload)
        rec, tracks = build_shard(self.out_root, plan,
                                  compression=self.compression)
        return {"shard": rec.to_doc(),
                "tracks": [t.to_doc() for t in tracks]}


def commit_shard(out_root: str, result: dict, *,
                 compression: str = "zlib",
                 target_points: int = DEFAULT_TARGET_POINTS
                 ) -> ShardRecord:
    """Incrementally append ONE built shard to the store manifest.

    The streaming DAG commits shards as they complete (so downstream
    process tasks can read them immediately) instead of waiting for
    :func:`finalize_store`'s single end-of-phase merge.  ``result`` is a
    :class:`ShardBuilder` return doc.  Idempotent by shard id: a
    re-commit after a kill between manifest append and manager
    checkpoint is a no-op (the shard file itself is deterministic and
    atomically written, so re-running the build task is safe too) — the
    manifest never duplicates or orphans a shard.  Single-writer: only
    the manager calls this, so load-modify-save needs no lock.  Entries
    are kept in the same sorted order as :func:`finalize_store`, so
    after :func:`finalize_manifest` the manifest bytes are identical to
    a barrier build's.
    """
    try:
        manifest = StoreManifest.load(out_root)
    except FileNotFoundError:
        manifest = StoreManifest(compression=compression,
                                 target_points=target_points,
                                 meta={"partial": True})
    rec = ShardRecord.from_doc(result["shard"])
    if any(s.shard_id == rec.shard_id for s in manifest.shards):
        return rec
    manifest.shards = sorted(manifest.shards + [rec],
                             key=lambda s: s.shard_id)
    manifest.tracks = sorted(
        manifest.tracks + [TrackRecord.from_doc(d)
                           for d in result["tracks"]],
        key=lambda t: (t.shard_id, t.row))
    # Every real append advances the generation (re-commits above do
    # not), so readers detect growth by comparing generations alone.
    manifest.generation += 1
    manifest.save(out_root)
    return rec


def finalize_manifest(out_root: str, *,
                      compression: str = "zlib",
                      target_points: int = DEFAULT_TARGET_POINTS,
                      meta: Optional[dict] = None) -> StoreManifest:
    """Seal an incrementally-committed store: replace the provisional
    ``{"partial": True}`` meta and re-save.  The result is byte-identical
    to :func:`finalize_store` over the same shard results."""
    manifest = StoreManifest.load(out_root)
    manifest.compression = compression
    manifest.target_points = target_points
    manifest.meta = meta or {}
    manifest.shards = sorted(manifest.shards, key=lambda s: s.shard_id)
    manifest.tracks = sorted(manifest.tracks,
                             key=lambda t: (t.shard_id, t.row))
    # Normalize so a resumed incremental build (whose re-commits did not
    # bump the counter) seals byte-identically to a batch build.
    manifest.generation = len(manifest.shards)
    manifest.save(out_root)
    return manifest


def finalize_store(out_root: str, results: Sequence[dict], *,
                   compression: str = "zlib",
                   target_points: int = DEFAULT_TARGET_POINTS,
                   meta: Optional[dict] = None) -> StoreManifest:
    """Merge per-shard build results into the saved manifest."""
    shards = sorted((ShardRecord.from_doc(r["shard"]) for r in results),
                    key=lambda s: s.shard_id)
    tracks = sorted(
        (TrackRecord.from_doc(d) for r in results for d in r["tracks"]),
        key=lambda t: (t.shard_id, t.row))
    manifest = StoreManifest(compression=compression,
                             target_points=target_points,
                             generation=len(shards),
                             shards=shards, tracks=tracks,
                             meta=meta or {})
    manifest.save(out_root)
    return manifest


def build_store(src_root: str, out_root: str, *,
                compression: str = "zlib",
                target_points: int = DEFAULT_TARGET_POINTS
                ) -> StoreManifest:
    """One-call ingest: discover -> plan -> build every shard -> manifest."""
    sources = discover_sources(src_root)
    plans = plan_shards(sources, target_points=target_points)
    results = []
    for plan in plans:
        rec, tracks = build_shard(out_root, plan, compression=compression)
        results.append({"shard": rec.to_doc(),
                        "tracks": [t.to_doc() for t in tracks]})
    return finalize_store(out_root, results, compression=compression,
                          target_points=target_points,
                          meta={"source_root": os.path.abspath(src_root)})


def main(argv=None) -> int:
    """CLI: ingest a CSV/zip tree into a columnar track store."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.store.writer",
        description="Ingest an organized CSV tree or zip-archive tree "
                    "into a sharded columnar track store.")
    ap.add_argument("--src", required=True,
                    help="source tree (PR-0 .zip archives or organized "
                         ".csv leaves)")
    ap.add_argument("--out", required=True, help="store root to create")
    ap.add_argument("--compression", default="zlib",
                    choices=list(codec.COMPRESSIONS))
    ap.add_argument("--target-points", type=int,
                    default=DEFAULT_TARGET_POINTS,
                    help="observation points per shard (default "
                         f"{DEFAULT_TARGET_POINTS})")
    args = ap.parse_args(argv)
    manifest = build_store(args.src, args.out,
                           compression=args.compression,
                           target_points=args.target_points)
    n_seg = sum(t.n_segments for t in manifest.tracks)
    print(f"wrote {len(manifest.shards)} shard(s), "
          f"{len(manifest.tracks)} tracks, {n_seg} segments, "
          f"{manifest.n_points} points, {manifest.size_bytes} bytes "
          f"-> {args.out}")
    hist = manifest.bucket_histogram()
    print("bucket histogram (from index): "
          + ", ".join(f"{w}:{c}" for w, c in hist.items()))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
