"""`TrackStore`: index-driven reads + double-buffered async prefetch.

The read side of the store.  A :class:`TrackStore` opens a store root,
loads the manifest index, and serves three access patterns:

  * random access — ``read_track(track_id)`` reconstructs one track's
    observation dict bitwise-identically to what the CSV parse produced
    at ingest;
  * planned batches — ``plan()`` turns the index into per-shard
    :class:`ReadPlan` s (fused-pipeline bucket histograms included,
    computed without touching payload bytes);
  * streaming — ``iter_batches()`` yields :class:`ShardBatch` es whose
    ``items`` are exactly the ``(obs, segs)`` pairs
    ``SegmentProcessor._process_many`` consumes.  With ``prefetch >= 1``
    a background thread reads + decompresses shard N+1 while the caller
    (the fused device pipeline) is busy with shard N, so the host decode
    hides behind device compute instead of serializing with it.

Store URIs name read selections inside ``run_job`` task payloads::

    store://<root>                          # whole store
    store://<root>#track=<track_id>         # one track
    store://<root>#shard=<shard_id>         # one shard (all rows)
    store://<root>#shard=<shard_id>&rows=<a>:<b>   # row range in a shard

They are plain strings, so they survive every execution backend's
message path (threads, pickled process messages, JSON checkpoints).
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
import urllib.parse
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.store import codec
from repro.store.format import ShardRecord, StoreManifest, TrackRecord

__all__ = ["STORE_URI_PREFIX", "is_store_uri", "make_store_uri",
           "parse_store_uri", "ReadPlan", "ShardBatch", "TrackStore"]

STORE_URI_PREFIX = "store://"


def is_store_uri(path: object) -> bool:
    return isinstance(path, str) and path.startswith(STORE_URI_PREFIX)


def make_store_uri(root: str, **selector: str) -> str:
    """``make_store_uri('/d/store', shard='s00001', rows='0:8')``."""
    frag = urllib.parse.urlencode(dict(sorted(selector.items())))
    return STORE_URI_PREFIX + root + ("#" + frag if frag else "")


def parse_store_uri(uri: str) -> tuple[str, dict[str, str]]:
    """-> (store root, selector dict)."""
    if not is_store_uri(uri):
        raise ValueError(f"not a store uri: {uri!r}")
    rest = uri[len(STORE_URI_PREFIX):]
    root, _, frag = rest.partition("#")
    sel = dict(urllib.parse.parse_qsl(frag)) if frag else {}
    unknown = set(sel) - {"track", "shard", "rows"}
    if unknown:
        raise ValueError(f"unknown store selector key(s) {sorted(unknown)} "
                         f"in {uri!r}")
    if "rows" in sel and "shard" not in sel:
        raise ValueError(f"rows= needs shard= in {uri!r}")
    return root, sel


def _parse_rows(spec: str, n: int) -> range:
    a, _, b = spec.partition(":")
    lo = int(a) if a else 0
    hi = int(b) if b else n
    if not (0 <= lo <= hi <= n):
        raise ValueError(f"row range {spec!r} out of bounds for {n} rows")
    return range(lo, hi)


@dataclasses.dataclass(frozen=True)
class ReadPlan:
    """One shard's planned read, derived from the index alone."""

    shard: ShardRecord
    tracks: tuple[TrackRecord, ...]          # rows to materialize
    bucket_histogram: dict[int, int]         # fused bucket width -> segs

    @property
    def n_points(self) -> int:
        return sum(t.n_obs for t in self.tracks)


@dataclasses.dataclass
class ShardBatch:
    """One decoded shard, ready to feed the fused pipeline."""

    shard_id: str
    track_ids: list[str]
    items: list[tuple[dict, list[slice]]]    # _process_many input shape

    @property
    def n_points(self) -> int:
        return sum(len(obs["time"]) for obs, _ in self.items)


class TrackStore:
    """Columnar store reader with an index-driven planner."""

    def __init__(self, root: str, *,
                 manifest: Optional[StoreManifest] = None,
                 prefetch: int = 1,
                 clock=None,
                 tracer=None):
        self.root = root
        self.manifest = manifest or StoreManifest.load(root)
        self.prefetch = prefetch
        #: Optional :class:`repro.obs.Tracer`: shard decodes become
        #: ``store``-category spans (track = shard id), consumer blocking
        #: becomes ``store_wait`` spans, and prefetch handoffs become
        #: instants.  Spans use the *tracer's* clock — not ``clock`` —
        #: so they share one timeline with scheduler/serving events.
        self.tracer = tracer
        #: Monotonic time source for the ``decode_s``/``wait_s`` stats.
        #: Injectable so tests assert exact attribution instead of
        #: flaky wall-time ratios.
        self._clock = clock if clock is not None else time.perf_counter
        #: Optional test/service instrumentation for the prefetch
        #: thread: ``{"queued": fn(kind, shard_id), "blocked": fn(kind)}``
        #: — ``queued`` fires after an event lands in the queue,
        #: ``blocked`` every time a put finds the queue full.  Lets a
        #: deterministic test drive producer/consumer interleavings with
        #: events instead of sleeps.
        self.prefetch_hooks: Optional[dict] = None
        self._reindex()
        self.stats = {"shards_read": 0, "bytes_read": 0,
                      "decode_s": 0.0, "wait_s": 0.0, "stale_drops": 0}

    @classmethod
    def open(cls, root: str, **kw) -> "TrackStore":
        return cls(root, **kw)

    @property
    def generation(self) -> int:
        """The loaded manifest's append generation (invalidation key)."""
        return self.manifest.generation

    def _reindex(self) -> None:
        self._tracks_by_id = {t.track_id: t for t in self.manifest.tracks}
        self._shards_by_id = {s.shard_id: s for s in self.manifest.shards}
        self._rows_by_shard: dict[str, list[TrackRecord]] = {}
        for t in self.manifest.tracks:
            self._rows_by_shard.setdefault(t.shard_id, []).append(t)
        for rows in self._rows_by_shard.values():
            rows.sort(key=lambda t: t.row)

    def reload(self) -> bool:
        """Re-read the manifest and rebuild the index maps.

        A streaming-DAG store grows while it is being read: shards are
        committed to the manifest (:func:`repro.store.writer.commit_shard`)
        while earlier shards are already being processed.  A reader that
        opened the store mid-stream calls this when it misses a
        track/shard that was committed after its manifest snapshot; the
        continuous-ingest service calls it after every commit.  Returns
        True when the manifest generation actually advanced — a live
        ``iter_batches`` iteration observes that through
        :attr:`generation` and invalidates its warm prefetch.
        """
        old_gen = self.manifest.generation
        self.manifest = StoreManifest.load(self.root)
        self._reindex()
        return self.manifest.generation != old_gen

    def __len__(self) -> int:
        return len(self.manifest.tracks)

    # -- planning (index only) -------------------------------------------

    def plan(self, selectors: Optional[Sequence[dict]] = None
             ) -> list[ReadPlan]:
        """Selectors -> per-shard read plans, in manifest shard order.

        Each selector is a ``parse_store_uri`` dict; ``None`` plans the
        whole store.  Tracks from multiple selectors that land in the
        same shard coalesce into one plan (one read, one decode).
        """
        wanted: dict[str, dict[int, TrackRecord]] = {}
        for sel in (selectors if selectors is not None else [{}]):
            for t in self._select(sel):
                wanted.setdefault(t.shard_id, {})[t.row] = t
        plans = []
        for s in self.manifest.shards:
            rows = wanted.get(s.shard_id)
            if not rows:
                continue
            tracks = tuple(rows[r] for r in sorted(rows))
            plans.append(ReadPlan(
                shard=s, tracks=tracks,
                bucket_histogram=self.manifest.bucket_histogram(
                    list(tracks))))
        return plans

    def _select(self, sel: dict[str, str]) -> list[TrackRecord]:
        if "track" in sel:
            return [self._track(sel["track"])]
        if "shard" in sel:
            rows = self._shard_rows(sel["shard"])
            if "rows" in sel:
                rng = _parse_rows(sel["rows"], len(rows))
                rows = [rows[i] for i in rng]
            return list(rows)
        return list(self.manifest.tracks)

    def _track(self, track_id: str) -> TrackRecord:
        try:
            return self._tracks_by_id[track_id]
        except KeyError:
            raise KeyError(f"unknown track {track_id!r} in store "
                           f"{self.root}") from None

    def _shard_rows(self, shard_id: str) -> list[TrackRecord]:
        if shard_id not in self._shards_by_id:
            raise KeyError(f"unknown shard {shard_id!r} in store "
                           f"{self.root}")
        return self._rows_by_shard.get(shard_id, [])

    # -- decoding ---------------------------------------------------------

    def _decode_shard(self, plan: ReadPlan) -> ShardBatch:
        from repro.tracks.segments import split_segments

        rec = plan.shard
        t0 = self._clock()
        tr = self.tracer
        tt0 = tr.now() if tr is not None else 0.0
        path = os.path.join(self.root, rec.filename)
        cols, meta = codec.read_shard(path)
        offsets = cols["offsets"]
        values = meta.get("icao_values", [])
        items: list[tuple[dict, list[slice]]] = []
        track_ids: list[str] = []
        value_arr = (np.asarray(values) if values
                     else np.zeros(0, dtype="U1"))
        for t in plan.tracks:
            lo, hi = int(offsets[t.row]), int(offsets[t.row + 1])
            codes = cols["icao_codes"][lo:hi]
            names = (value_arr[codes] if len(codes)
                     else np.zeros(0, dtype="U1"))
            obs = {
                "time": cols["time"][lo:hi],
                "lat": cols["lat"][lo:hi],
                "lon": cols["lon"][lo:hi],
                "alt": cols["alt"][lo:hi],
                "icao24": names,
            }
            items.append((obs, split_segments(obs["time"])))
            track_ids.append(t.track_id)
        self.stats["shards_read"] += 1
        self.stats["bytes_read"] += rec.size_bytes
        self.stats["decode_s"] += self._clock() - t0
        if tr is not None:
            tr.emit(tt0, tr.now() - tt0, "store_decode", "store",
                    rec.shard_id, extra=rec.size_bytes)
        return ShardBatch(shard_id=rec.shard_id, track_ids=track_ids,
                          items=items)

    # -- access patterns ---------------------------------------------------

    def read_track(self, track_id: str) -> dict[str, np.ndarray]:
        """One track's observation dict (bitwise equal to ingest input)."""
        t = self._track(track_id)
        plan = self.plan([{"track": track_id}])[0]
        batch = self._decode_shard(plan)
        assert batch.track_ids == [t.track_id]
        return batch.items[0][0]

    def read_shard_batch(self, shard_id: str) -> ShardBatch:
        """Decode ONE whole shard into a :class:`ShardBatch` (items in
        row order, so ``items[a:b]`` is the ``rows=a:b`` selection).

        This is the decode a shard-affinity consumer caches: serve every
        row-range task of the shard from one decoded batch, re-decoding
        only when the scheduler moves the worker to another shard.
        """
        rows = self._shard_rows(shard_id)
        if not rows:
            raise KeyError(f"shard {shard_id!r} has no rows in store "
                           f"{self.root}")
        plan = ReadPlan(
            shard=self._shards_by_id[shard_id], tracks=tuple(rows),
            bucket_histogram=self.manifest.bucket_histogram(list(rows)))
        return self._decode_shard(plan)

    def read_selection(self, sel: dict[str, str]
                       ) -> list[tuple[str, dict, list[slice]]]:
        """One selector -> [(track_id, obs, segs)] in plan order."""
        out = []
        for plan in self.plan([sel]):
            batch = self._decode_shard(plan)
            for tid, (obs, segs) in zip(batch.track_ids, batch.items):
                out.append((tid, obs, segs))
        return out

    def iter_batches(self, plans: Optional[Sequence[ReadPlan]] = None, *,
                     prefetch: Optional[int] = None
                     ) -> Iterator[ShardBatch]:
        """Stream decoded shard batches, optionally prefetched.

        ``prefetch=0`` decodes synchronously in the caller's thread.
        ``prefetch=k`` runs a daemon decode thread that stays up to
        ``k`` shards ahead (``k=1`` is classic double buffering: one
        batch in hand, one being decoded).  ``stats['wait_s']``
        accumulates how long the consumer actually blocked — the number
        the storage bench uses to show the decode hiding behind the
        fused pipeline's device time.

        With explicit ``plans`` the selection is pinned: exactly those
        plans stream, in order, regardless of appends.  With
        ``plans=None`` the iteration is *live*: it follows the loaded
        manifest, so when :meth:`reload` advances the generation
        mid-stream (a :func:`~repro.store.writer.commit_shard` append),
        warm in-flight prefetch buffers planned under the old generation
        are dropped (counted in ``stats['stale_drops']``), the remainder
        is re-planned from the fresh index, and newly committed shards
        stream out before the iterator finishes.  Each shard is yielded
        at most once.
        """
        k = self.prefetch if prefetch is None else prefetch
        if plans is not None:
            yield from self._iter_round(plans, k, gen=None)
            return
        delivered: set[str] = set()
        while True:
            gen = self.manifest.generation
            round_plans = [p for p in self.plan()
                           if p.shard.shard_id not in delivered]
            for batch in self._iter_round(round_plans, k, gen=gen):
                delivered.add(batch.shard_id)
                yield batch
            if self.manifest.generation == gen:
                return

    def _iter_round(self, plans: Sequence[ReadPlan], k: int, *,
                    gen: Optional[int]) -> Iterator[ShardBatch]:
        """One streaming pass over ``plans``.  When ``gen`` is given the
        round is generation-pinned: it aborts as soon as the loaded
        manifest's generation moves past ``gen`` — the producer stops
        decoding and the consumer drops (instead of yields) any buffer
        already decoded under the stale generation."""
        if k <= 0:
            for plan in plans:
                if gen is not None and self.manifest.generation != gen:
                    return
                yield self._decode_shard(plan)
            return

        q: queue.Queue = queue.Queue(maxsize=k)
        stop = threading.Event()
        hooks = self.prefetch_hooks or {}

        def put(event: tuple) -> bool:
            """Blocking put that gives up only when the consumer left.
            Every event — including the terminal "err"/"end" — must
            retry indefinitely, or the consumer deadlocks on q.get()."""
            blocked = hooks.get("blocked")
            while not stop.is_set():
                try:
                    q.put(event, timeout=0.1)
                except queue.Full:
                    if blocked is not None:
                        blocked(event[0])
                    continue
                queued = hooks.get("queued")
                if queued is not None:
                    batch = event[1]
                    queued(event[0], getattr(batch, "shard_id", None))
                return True
            return False

        def produce() -> None:
            try:
                for plan in plans:
                    if gen is not None and self.manifest.generation != gen:
                        break               # rest of the round is stale
                    batch = self._decode_shard(plan)
                    if not put(("ok", batch)):
                        return
                    if self.tracer is not None:
                        # Emitted from the prefetch thread; Tracer.emit
                        # is a single deque append, safe cross-thread.
                        self.tracer.emit(self.tracer.now(), -1.0,
                                         "store_prefetch", "store",
                                         batch.shard_id)
                put(("end", None))
            except Exception as e:              # surfaced to the consumer
                put(("err", e))

        worker = threading.Thread(target=produce, daemon=True,
                                  name="trackstore-prefetch")
        worker.start()
        try:
            while True:
                t0 = self._clock()
                tr = self.tracer
                tt0 = tr.now() if tr is not None else 0.0
                kind, val = q.get()
                self.stats["wait_s"] += self._clock() - t0
                if tr is not None:
                    tr.emit(tt0, tr.now() - tt0, "store_wait", "store",
                            "consumer")
                if kind == "end":
                    break
                if kind == "err":
                    raise val
                if gen is not None and self.manifest.generation != gen:
                    # Decoded under a superseded manifest: invalidate.
                    self.stats["stale_drops"] += 1
                    continue
                yield val
        finally:
            stop.set()
            worker.join(timeout=5.0)
