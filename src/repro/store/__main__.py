"""``python -m repro.store`` — the store ingest CLI (writer.main)."""

import sys

from repro.store.writer import main

if __name__ == "__main__":
    sys.exit(main())
