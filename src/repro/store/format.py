"""Store layout: shard/track index records and the on-disk manifest.

A *store* is a directory::

    <root>/store_manifest.json        # StoreManifest (this module)
    <root>/shards/<shard_id>.shard    # codec.py column files

The manifest is the index the read planner works from: per-shard file
facts (sizes, sha256, point counts) and per-track records carrying the
exact segment shapes — ``seg_knots[i]`` raw observations and
``seg_grid[i]`` resampled grid points for the i-th gap-delimited segment
that survives the paper's ten-observation rule.  Those two integers are
all :func:`repro.tracks.segments.bucket_width` needs, so the fused
pipeline's length-bucket binning happens *from the index*, before any
payload byte is read or decompressed.

Like the codec, the manifest serialization is canonical (sorted keys,
compact separators, no timestamps): building the same store twice from
the same inputs produces byte-identical manifests and shard files.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

__all__ = ["STORE_FORMAT", "MANIFEST_NAME", "SHARD_DIR", "SHARD_SUFFIX",
           "TrackRecord", "ShardRecord", "StoreManifest",
           "fsync_dir", "write_atomic"]

STORE_FORMAT = "repro.store/v1"
MANIFEST_NAME = "store_manifest.json"
SHARD_DIR = "shards"
SHARD_SUFFIX = ".shard"


def fsync_dir(path: str) -> None:
    """Best-effort directory fsync (durability of a rename entry)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_atomic(path: str, data: bytes) -> None:
    """THE crash-safe file commit (shards, manifests, archive siblings
    share this one implementation): unique pid-suffixed tmp, data fsync
    BEFORE the atomic rename, directory fsync after — so a power cut
    can lose the whole commit but never leave a committed name with
    torn contents or an unpersisted rename."""
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(parent)


@dataclasses.dataclass(frozen=True)
class TrackRecord:
    """Index entry for one track (one aircraft's observation series)."""

    track_id: str               # stable id (zip-relative path at ingest)
    shard_id: str
    row: int                    # position within the shard's offsets
    n_obs: int                  # raw observations stored
    icao24: str                 # uniform per-track transponder id
    seg_knots: tuple[int, ...]  # per kept segment: raw knots (<= 1024)
    seg_grid: tuple[int, ...]   # per kept segment: 1 Hz grid points

    @property
    def n_segments(self) -> int:
        return len(self.seg_knots)

    def to_doc(self) -> dict:
        d = dataclasses.asdict(self)
        d["seg_knots"] = list(self.seg_knots)
        d["seg_grid"] = list(self.seg_grid)
        return d

    @classmethod
    def from_doc(cls, d: dict) -> "TrackRecord":
        return cls(track_id=d["track_id"], shard_id=d["shard_id"],
                   row=int(d["row"]), n_obs=int(d["n_obs"]),
                   icao24=d["icao24"],
                   seg_knots=tuple(int(x) for x in d["seg_knots"]),
                   seg_grid=tuple(int(x) for x in d["seg_grid"]))


@dataclasses.dataclass(frozen=True)
class ShardRecord:
    """Index entry for one shard file."""

    shard_id: str
    filename: str               # relative to the store root
    n_tracks: int
    n_points: int               # total payload elements across columns' rows
    size_bytes: int             # encoded file size
    sha256: str                 # of the whole shard file

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, d: dict) -> "ShardRecord":
        return cls(shard_id=d["shard_id"], filename=d["filename"],
                   n_tracks=int(d["n_tracks"]),
                   n_points=int(d["n_points"]),
                   size_bytes=int(d["size_bytes"]), sha256=d["sha256"])


@dataclasses.dataclass
class StoreManifest:
    """The store's whole index; everything the read planner needs."""

    compression: str = "zlib"
    target_points: int = 0          # writer's shard-sizing knob, recorded
    #: Monotonic append counter: bumped by every
    #: :func:`repro.store.writer.commit_shard`, normalized to
    #: ``len(shards)`` when the store is sealed — so an incremental
    #: build and a batch build of the same inputs stay byte-identical,
    #: while readers can detect any post-open append by comparing
    #: generations alone.
    generation: int = 0
    shards: list[ShardRecord] = dataclasses.field(default_factory=list)
    tracks: list[TrackRecord] = dataclasses.field(default_factory=list)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- (de)serialization ------------------------------------------------

    def to_doc(self) -> dict:
        return {
            "format": STORE_FORMAT,
            "compression": self.compression,
            "target_points": self.target_points,
            "generation": self.generation,
            "shards": [s.to_doc() for s in self.shards],
            "tracks": [t.to_doc() for t in self.tracks],
            "meta": self.meta,
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "StoreManifest":
        if doc.get("format") != STORE_FORMAT:
            raise ValueError(f"not a {STORE_FORMAT} manifest: "
                             f"{doc.get('format')!r}")
        return cls(
            compression=doc.get("compression", "zlib"),
            target_points=int(doc.get("target_points", 0)),
            generation=int(doc.get("generation", 0)),
            shards=[ShardRecord.from_doc(d) for d in doc["shards"]],
            tracks=[TrackRecord.from_doc(d) for d in doc["tracks"]],
            meta=doc.get("meta", {}))

    def canonical_bytes(self) -> bytes:
        """Deterministic manifest serialization (the saved form)."""
        return json.dumps(self.to_doc(), sort_keys=True,
                          separators=(",", ":")).encode() + b"\n"

    def save(self, root: str) -> str:
        """Atomic manifest write; returns the manifest path."""
        path = os.path.join(root, MANIFEST_NAME)
        write_atomic(path, self.canonical_bytes())
        return path

    @classmethod
    def load(cls, root: str) -> "StoreManifest":
        path = os.path.join(root, MANIFEST_NAME)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"{path}: not a track store (no {MANIFEST_NAME}); "
                f"build one with `python -m repro.store.writer`")
        with open(path) as f:
            return cls.from_doc(json.load(f))

    # -- index queries ----------------------------------------------------

    def shard(self, shard_id: str) -> ShardRecord:
        for s in self.shards:
            if s.shard_id == shard_id:
                return s
        raise KeyError(f"unknown shard {shard_id!r}")

    def tracks_in(self, shard_id: str) -> list[TrackRecord]:
        return sorted((t for t in self.tracks if t.shard_id == shard_id),
                      key=lambda t: t.row)

    def track(self, track_id: str) -> TrackRecord:
        for t in self.tracks:
            if t.track_id == track_id:
                return t
        raise KeyError(f"unknown track {track_id!r}")

    @property
    def n_points(self) -> int:
        return sum(s.n_points for s in self.shards)

    @property
    def size_bytes(self) -> int:
        return sum(s.size_bytes for s in self.shards)

    def row_range_bytes(self, shard_id: str, lo: int = 0,
                        hi: Optional[int] = None) -> int:
        """Encoded-byte estimate for rows ``[lo, hi)`` of a shard,
        computed purely from the index (no payload reads): the shard's
        on-disk size prorated by the range's share of observation
        points.  This is how row-range ``store://`` tasks get the size
        signal that largest-first organization and the cost-aware
        scheduling policies (sized_lpt / adaptive_chunk) key on.
        """
        shard = self.shard(shard_id)
        rows = self.tracks_in(shard_id)
        if hi is None:
            hi = len(rows)
        if not (0 <= lo <= hi <= len(rows)):
            raise ValueError(f"row range {lo}:{hi} out of bounds for "
                             f"{len(rows)} rows in shard {shard_id!r}")
        total = sum(t.n_obs for t in rows)
        if total <= 0:
            return 0
        part = sum(t.n_obs for t in rows[lo:hi])
        return int(round(shard.size_bytes * (part / total)))

    def bucket_histogram(self, tracks: Optional[list[TrackRecord]] = None
                         ) -> dict[int, int]:
        """Segment count per fused-pipeline bucket width, computed purely
        from the index (no payload reads) — the store-side half of the
        PR-3 bucket planner."""
        from repro.tracks.segments import bucket_width
        hist: dict[int, int] = {}
        for t in (self.tracks if tracks is None else tracks):
            for n, m in zip(t.seg_knots, t.seg_grid):
                w = bucket_width(max(n, m))
                hist[w] = hist.get(w, 0) + 1
        return dict(sorted(hist.items()))
