"""``repro.store`` — sharded, chunked columnar track storage.

The fourth subsystem layer: the PR-0 zip workaround made billions of
small files tractable, but still re-parsed CSV text every run; this
package stores *decoded* track columns (time/lat/lon/alt + per-track
offsets) in checksummed, compressed shards with a manifest index that
records per-track segment shapes — so the PR-3 fused pipeline's bucket
planning happens from the index and batches stream in at device speed
through a double-buffered async prefetcher.

    codec.py   — canonical (byte-identical) shard encode/decode + CRCs
    format.py  — shard/track index records, the store manifest
    writer.py  — CSV/zip-tree -> shards ingest (standalone or run_job)
    reader.py  — TrackStore: planner, store:// URIs, async prefetch
"""

from repro.store.codec import (                       # noqa: F401
    ShardChecksumError, ShardFormatError, decode_shard, encode_shard,
    read_shard)
from repro.store.format import (                      # noqa: F401
    MANIFEST_NAME, STORE_FORMAT, ShardRecord, StoreManifest, TrackRecord)
from repro.store.reader import (                      # noqa: F401
    ReadPlan, ShardBatch, TrackStore, is_store_uri, make_store_uri,
    parse_store_uri)
from repro.store.writer import (                      # noqa: F401
    ShardBuilder, ShardPlan, build_shard, build_store, discover_sources,
    finalize_store, plan_shards)

__all__ = [
    "ShardChecksumError", "ShardFormatError", "decode_shard",
    "encode_shard", "read_shard",
    "MANIFEST_NAME", "STORE_FORMAT", "ShardRecord", "StoreManifest",
    "TrackRecord",
    "ReadPlan", "ShardBatch", "TrackStore", "is_store_uri",
    "make_store_uri", "parse_store_uri",
    "ShardBuilder", "ShardPlan", "build_shard", "build_store",
    "discover_sources", "finalize_store", "plan_shards",
]
