"""Columnar shard codec: byte-identical encode, checksummed decode.

One shard file holds a set of named 1-D/2-D numpy columns as contiguous
little-endian blocks, each independently compressed and CRC-checked,
plus a small JSON header describing the blocks and carrying free-form
shard metadata.  Layout::

    [ 0: 8)  magic   b"RPRSTOR1"
    [ 8:12)  u32 LE  format version (CODEC_VERSION)
    [12:20)  u64 LE  header length H
    [20:24)  u32 LE  crc32 of the header bytes
    [24:24+H)        header JSON (sorted keys, compact separators)
    [24+H: )         column payload blocks, back-to-back

The header's ``columns`` list is sorted by column name and records, per
column: dtype string, shape, codec name, compressed/raw byte counts and
the crc32 of the *uncompressed* bytes.  Everything about the encoding is
canonical — sorted column order, sorted-key compact JSON, a fixed zlib
level — so encoding the same columns twice yields byte-identical files
(the reproducibility contract the store's acceptance tests gate on).

Decode verifies magic, version, header crc and every column crc;
corruption raises :class:`ShardChecksumError` (a
:class:`ShardFormatError`) instead of returning silently wrong arrays.
"""

from __future__ import annotations

import json
import zlib
from typing import Any, Optional

import numpy as np

__all__ = ["CODEC_VERSION", "MAGIC", "COMPRESSIONS", "ZLIB_LEVEL",
           "ShardFormatError", "ShardChecksumError",
           "encode_shard", "decode_shard", "read_shard", "peek_meta"]

MAGIC = b"RPRSTOR1"
CODEC_VERSION = 1
ZLIB_LEVEL = 6                      # fixed: part of the canonical encoding
COMPRESSIONS = ("none", "zlib")

_HDR_FIXED = len(MAGIC) + 4 + 8 + 4


class ShardFormatError(ValueError):
    """The byte stream is not a valid shard (bad magic/version/header)."""


class ShardChecksumError(ShardFormatError):
    """A stored checksum does not match the decoded bytes."""


def _canonical_dtype(dt: np.dtype) -> np.dtype:
    """Little-endian is the one true byte order on disk.  ``dt.str``
    resolves native ('=') order, so this also catches native dtypes on
    big-endian hosts — shard bytes must not depend on the writer."""
    if dt.str.startswith(">"):
        return dt.newbyteorder("<")
    return dt


def encode_shard(columns: dict[str, np.ndarray], *,
                 meta: Optional[dict[str, Any]] = None,
                 compression: str = "zlib") -> bytes:
    """Serialize named columns (+ JSON-able ``meta``) into shard bytes."""
    if compression not in COMPRESSIONS:
        raise ValueError(f"unknown compression {compression!r}; "
                         f"choose from {COMPRESSIONS}")
    entries = []
    blocks = []
    for name in sorted(columns):
        arr = np.ascontiguousarray(columns[name])
        arr = arr.astype(_canonical_dtype(arr.dtype), copy=False)
        raw = arr.tobytes()
        enc = zlib.compress(raw, ZLIB_LEVEL) if compression == "zlib" \
            else raw
        # Tiny/incompressible columns: zlib can expand; store whichever
        # is smaller, per column (the header records the choice).
        codec = compression
        if compression == "zlib" and len(enc) >= len(raw):
            enc, codec = raw, "none"
        entries.append({
            "name": name,
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "codec": codec,
            "raw_bytes": len(raw),
            "enc_bytes": len(enc),
            "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
        })
        blocks.append(enc)
    header = {"version": CODEC_VERSION, "columns": entries,
              "meta": meta or {}}
    hdr = json.dumps(header, sort_keys=True,
                     separators=(",", ":")).encode()
    out = bytearray()
    out += MAGIC
    out += CODEC_VERSION.to_bytes(4, "little")
    out += len(hdr).to_bytes(8, "little")
    out += (zlib.crc32(hdr) & 0xFFFFFFFF).to_bytes(4, "little")
    out += hdr
    for b in blocks:
        out += b
    return bytes(out)


def _parse_header(data: bytes) -> tuple[dict, int]:
    if len(data) < _HDR_FIXED:
        raise ShardFormatError("shard truncated before header")
    if data[:len(MAGIC)] != MAGIC:
        raise ShardFormatError(f"bad magic {data[:len(MAGIC)]!r}")
    off = len(MAGIC)
    version = int.from_bytes(data[off:off + 4], "little")
    if version != CODEC_VERSION:
        raise ShardFormatError(f"unsupported shard version {version}")
    off += 4
    hlen = int.from_bytes(data[off:off + 8], "little")
    off += 8
    hcrc = int.from_bytes(data[off:off + 4], "little")
    off += 4
    hdr = data[off:off + hlen]
    if len(hdr) != hlen:
        raise ShardFormatError("shard truncated inside header")
    if (zlib.crc32(hdr) & 0xFFFFFFFF) != hcrc:
        raise ShardChecksumError("header crc mismatch")
    try:
        header = json.loads(hdr.decode())
    except ValueError as e:
        raise ShardFormatError(f"header is not valid JSON: {e}") from e
    return header, off + hlen


def peek_meta(data: bytes) -> dict:
    """Header ``meta`` without touching any payload block."""
    header, _ = _parse_header(data)
    return header.get("meta", {})


def decode_shard(data: bytes, *, columns: Optional[list[str]] = None
                 ) -> tuple[dict[str, np.ndarray], dict]:
    """-> (columns, meta).  ``columns`` restricts which blocks are decoded
    (the others are skipped without decompression); every decoded block's
    crc is verified."""
    header, off = _parse_header(data)
    want = None if columns is None else set(columns)
    out: dict[str, np.ndarray] = {}
    for ent in header["columns"]:
        enc = data[off:off + ent["enc_bytes"]]
        off += ent["enc_bytes"]
        if len(enc) != ent["enc_bytes"]:
            raise ShardFormatError(
                f"shard truncated inside column {ent['name']!r}")
        if want is not None and ent["name"] not in want:
            continue
        if ent["codec"] == "zlib":
            try:
                raw = zlib.decompress(enc)
            except zlib.error as e:
                raise ShardChecksumError(
                    f"column {ent['name']!r} failed to decompress "
                    f"(corrupted shard): {e}") from e
        else:
            raw = enc
        if len(raw) != ent["raw_bytes"] or \
                (zlib.crc32(raw) & 0xFFFFFFFF) != ent["crc32"]:
            raise ShardChecksumError(
                f"column {ent['name']!r} checksum mismatch "
                f"(corrupted shard)")
        arr = np.frombuffer(raw, dtype=np.dtype(ent["dtype"]))
        out[ent["name"]] = arr.reshape(ent["shape"])
    if want is not None and want - set(out):
        raise KeyError(f"shard has no column(s) {sorted(want - set(out))}")
    return out, header.get("meta", {})


def read_shard(path: str, *, columns: Optional[list[str]] = None
               ) -> tuple[dict[str, np.ndarray], dict]:
    """Read + decode one shard file."""
    with open(path, "rb") as f:
        return decode_shard(f.read(), columns=columns)
