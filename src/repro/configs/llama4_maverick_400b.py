"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE interleaved
with dense layers; early-fusion multimodal (frontend stubbed via the
shared vision-embedding path when present).

48L d_model=5120 40H (GQA kv=8, head_dim 128) d_ff=8192/expert
vocab=202048, MoE 128e top-1  [hf:meta-llama/Llama-4-*; unverified]

Llama-4 interleaves MoE and dense FFN layers (interleave step 2); the
shared expert is folded into the dense-layer FFN here (noted in
DESIGN.md §9 as a simplification).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    activation="silu",
    block_pattern=("attn", "attn"),   # period 2 so MoE layout is static
    n_experts=128,
    top_k=1,
    moe_period=2,
    moe_offset=1,
    rope_theta=500_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="llama4-maverick-reduced", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=192, vocab_size=512,
        n_experts=8, top_k=1)
