"""rwkv6-3b [ssm] — RWKV-6 "Finch": attention-free, data-dependent decay.

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536  [arXiv:2404.05892; hf]

O(1) state per layer => long_500k RUNS for this arch.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    rwkv_head_dim=64,
    activation="relu2",    # RWKV channel mix uses squared ReLU
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rwkv6-3b-reduced", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=448, vocab_size=512, rwkv_head_dim=32)
