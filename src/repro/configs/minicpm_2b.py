"""minicpm-2b [dense] — llama-like MHA (kv=36), tied embeddings, WSD LR
schedule (implemented in repro.train.schedules).

40L d_model=2304 36H (kv=36, head_dim 64) d_ff=5760 vocab=122753
[arXiv:2404.06395; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    activation="silu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="minicpm-2b-reduced", n_layers=4, d_model=144,
        n_heads=6, n_kv_heads=6, head_dim=24, d_ff=384, vocab_size=512)
