"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24, head_dim 64) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]

The EnCodec audio frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, T, d_model); the vocabulary is the 2048-entry
codebook. MLP is plain GELU (fairseq-style), not gated.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    activation="gelu",
    gated_mlp=False,
    frontend="audio",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="musicgen-medium-reduced", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=256)
