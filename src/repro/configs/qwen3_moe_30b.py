"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8, fine-grained (d_ff=768
per expert), MoE on every layer.

48L d_model=2048 32H (GQA kv=4, head_dim 128) d_ff=768 vocab=151936,
MoE 128e top-8  [hf:Qwen/Qwen3-30B-A3B; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    activation="silu",
    n_experts=128,
    top_k=8,
    moe_period=1,
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-reduced", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=96, vocab_size=512,
        n_experts=8, top_k=2)
