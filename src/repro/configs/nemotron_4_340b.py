"""nemotron-4-340b [dense] — GQA, squared-ReLU, plain (ungated) MLP.

96L d_model=18432 96H (GQA kv=8, head_dim 192) d_ff=73728 vocab=256000
[arXiv:2402.16819; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    activation="relu2",
    gated_mlp=False,
    rope_theta=10_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="nemotron-4-340b-reduced", n_layers=4, d_model=192,
        n_heads=6, n_kv_heads=2, head_dim=32, d_ff=768, vocab_size=512)
