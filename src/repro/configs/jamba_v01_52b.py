"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e
top-2 on every other layer.

32L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=14336 vocab=65536
[arXiv:2403.19887; hf]

Block pattern (period 8, matching Jamba's published layout): attention at
position 4 of each 8-layer group; MoE on odd layers. Long-context decode
is supported (only 4 of 32 layers keep a KV cache; the Mamba state is
O(1)) — long_500k RUNS for this arch.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    activation="silu",
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    n_experts=16,
    top_k=2,
    moe_period=2,
    moe_offset=1,
    d_state=16,
    d_conv=4,
    mamba_expand=2,
    supports_long_context=True,
    rope_theta=10_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="jamba-v0.1-52b-reduced", n_layers=8, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        n_experts=4, top_k=2, d_state=8)
