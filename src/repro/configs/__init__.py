"""Architecture registry: --arch <id> resolves here."""

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, shapes_for
from repro.configs import (
    granite_34b, jamba_v01_52b, llama4_maverick_400b, minicpm_2b,
    musicgen_medium, nemotron_4_340b, pixtral_12b, qwen3_moe_30b,
    rwkv6_3b, stablelm_12b)

_MODULES = {
    "nemotron-4-340b": nemotron_4_340b,
    "granite-34b": granite_34b,
    "stablelm-12b": stablelm_12b,
    "minicpm-2b": minicpm_2b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "rwkv6-3b": rwkv6_3b,
    "musicgen-medium": musicgen_medium,
    "pixtral-12b": pixtral_12b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b,
}

ARCHS: dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}


def get_arch(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return _MODULES[name].reduced() if reduced else ARCHS[name]


def all_arch_names() -> list[str]:
    return list(_MODULES)


__all__ = ["ArchConfig", "SHAPES", "ShapeConfig", "shapes_for", "ARCHS",
           "get_arch", "all_arch_names"]
