"""Architecture + run configuration schema.

One ``ArchConfig`` per assigned architecture (see configs/<id>.py), plus
``reduced()`` variants for CPU smoke tests. The model stack
(repro.models) consumes only this schema — adding an architecture is a
config file, not a code change.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    # trunk
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "silu"      # silu | gelu | relu2 (squared ReLU)
    gated_mlp: bool = True        # False => plain act(xW1)W2 (nemotron,
                                  # granite, musicgen)
    # block pattern: kind of each layer, repeating with this period.
    # entries: 'attn' | 'mamba' | 'rwkv6'
    block_pattern: tuple[str, ...] = ("attn",)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 0           # every moe_period-th layer is MoE (0=off)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048    # dispatch group (GShard-style)
    # SSM (mamba blocks)
    d_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    # attention details
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # used by hybrid long-context
    # 'xla' keeps attention in stock HLO (faithful cost_analysis for the
    # dry-run); 'flash' uses the Pallas blocked online-softmax kernel
    # (the real-TPU path; interpret mode on CPU).
    attention_impl: str = "xla"
    # modality frontend: None | 'audio' | 'vision' (stubbed: input_specs
    # provides precomputed frame/patch embeddings)
    frontend: Optional[str] = None
    tie_embeddings: bool = False
    # Override for long_500k eligibility (hybrids with few full-attention
    # layers can still decode 500k contexts; see DESIGN.md).
    supports_long_context: Optional[bool] = None
    # norms / numerics
    rms_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_model % self.n_heads:
            raise ValueError("d_model must divide n_heads")
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_kv_heads must divide n_heads")
        if self.n_layers % len(self.block_pattern):
            raise ValueError("n_layers must be a multiple of the pattern")

    # -- derived ----------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // self.pattern_period

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % self.pattern_period]

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe_period:
            return False
        return i % self.moe_period == self.moe_offset

    @property
    def subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM/hybrid)."""
        if self.supports_long_context is not None:
            return self.supports_long_context
        kinds = set(self.block_pattern)
        if kinds <= {"mamba", "rwkv6"}:
            return True
        return "attn" in kinds and self.sliding_window is not None and \
            kinds != {"attn"}

    # -- parameter counting (for roofline MODEL_FLOPS) ---------------------

    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, KV = self.head_dim_, self.n_heads, self.n_kv_heads
        ffn_mats = 3 if self.gated_mlp else 2
        total = V * d                      # embed
        if not self.tie_embeddings:
            total += V * d                 # unembed
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * H * hd + 2 * d * KV * hd + H * hd * d
            elif kind == "mamba":
                di, ds = self.d_inner, self.d_state
                R = max(d // 16, 1)
                total += d * 2 * di + di * self.d_conv \
                    + di * (R + 2 * ds) // 1 + R * di \
                    + di * (ds + 2) + di * d            # projs+conv+ssm+out
            elif kind == "rwkv6":
                total += 5 * d * d                      # wr wk wv wg wo
            total += 2 * d                              # norms
            if kind == "rwkv6":
                total += 2 * d * ff + d * d             # channel mix
            elif self.is_moe_layer(i):
                experts = self.top_k if active_only else self.n_experts
                total += experts * ffn_mats * d * ff \
                    + d * self.n_experts                # router
            else:
                total += ffn_mats * d * ff
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> list[ShapeConfig]:
    """The shape cells that apply to this architecture.

    long_500k needs sub-quadratic attention: skipped for pure
    full-attention archs (recorded in DESIGN.md §Arch-applicability).
    """
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out
