"""granite-34b [dense] — llama-style code model with MQA (kv=1), ungated
GELU MLP (gpt-bigcode lineage).

88L d_model=6144 48H (GQA kv=1, head_dim 128) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    activation="gelu",
    gated_mlp=False,
    rope_theta=10_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="granite-34b-reduced", n_layers=4, d_model=192,
        n_heads=6, n_kv_heads=1, head_dim=32, d_ff=768, vocab_size=512)
