"""stablelm-12b [dense] — GQA, gated SiLU MLP.

40L d_model=5120 32H (GQA kv=8, head_dim 160) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-12b; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,
    activation="silu",
    rope_theta=10_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="stablelm-12b-reduced", n_layers=4, d_model=160,
        n_heads=4, n_kv_heads=2, head_dim=40, d_ff=512, vocab_size=512)
