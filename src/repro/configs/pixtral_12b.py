"""pixtral-12b [vlm] — Pixtral-ViT frontend + Mistral-Nemo-style decoder.

40L d_model=5120 32H (GQA kv=8, head_dim 128) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]

The ViT patch encoder is a STUB: input_specs() provides precomputed patch
embeddings already projected to d_model. head_dim=128 (q projection
5120 -> 4096, Nemo-style).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    activation="silu",
    frontend="vision",
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="pixtral-12b-reduced", n_layers=4, d_model=160,
        n_heads=4, n_kv_heads=2, head_dim=40, d_ff=512, vocab_size=512)
