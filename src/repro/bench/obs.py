"""Observability benchmark matrix: tracing cost, determinism, attribution.

The tracing layer (:mod:`repro.obs`) only earns its keep if it is (a)
cheap enough to leave on, (b) byte-reproducible where the runtime is,
and (c) actually able to find the slow worker.  This module gates all
three as BENCH cells (``BENCH_obs.json``, schema ``repro.bench.obs/v1``):

  * ``overhead`` cells — the heavy-tail sim at fleet scale run traced
    and untraced, interleaved, min-of-N wall-clocks.  The quick tier
    gates ``overhead_ratio <= 1.05`` (the ISSUE-9 ≤5 % budget) *and*
    ``makespan_identical == 1``: the traced run's virtual makespan and
    dispatch digest must equal the untraced run's, i.e. tracing
    observes the schedule without perturbing a single decision.
  * ``determinism`` cells — the same traced sim run twice;
    ``canonical_bytes`` of the two ``repro.obs/v1`` summaries must be
    byte-identical (``summary_identical == 1``).  This cell is also
    the source of the committed reference summary
    (``benchmarks/refs/TRACE_heavy_tail_quick.json``) via the CLI's
    ``--summary-out`` / ``--trace-out`` flags.
  * ``straggler`` cells — heavy tail under ``stragglers_10pct``
    (10 % of workers at 0.25× speed): the summary's per-worker
    ``speed_est`` ranking must place a genuinely-slowed worker at the
    bottom (``straggler_rank_correct == 1``) — the attribution the
    ROADMAP's speculation work will consume.

Every cell reports the traced run's deterministic virtual makespan
(``makespan_seconds``, the compare.py gating metric) and ``n_events``.
Wall-clock ratios live under ``measured`` (they measure the machine),
but the overhead gate is intentionally a measured check: both sides run
interleaved in the same process on the same machine, so the *ratio* is
meaningful where the absolute times are not.

CLI::

    PYTHONPATH=src python -m repro.bench.obs --quick
    PYTHONPATH=src python benchmarks/obs_bench.py \\
        --quick --trace-out trace.json --summary-out TRACE_summary.json
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Optional, Sequence

from repro.bench.scenarios import FAULT_PROFILES, Check
from repro.bench.schema import (
    OBS_BENCH_SCHEMA, SCHEMA_VERSION, canonical_bytes, validate_obs)
from repro.obs import Tracer, summary_from_tracer, to_chrome_trace
from repro.runtime.policies import POLICY_NAMES

__all__ = ["ObsSpec", "ObsScenario", "REF_LABEL", "obs_scenarios",
           "run_obs_scenario", "run_obs_campaign", "reference_run",
           "obs_summary_lines", "main"]

#: Label of the reference trace summary (fixed so the committed ref and
#: a fresh ``--summary-out`` run produce the same scenario name for
#: ``repro.bench.compare`` to match rows on).
REF_LABEL = "heavy_tail_quick"


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """One observability-bench configuration — JSON-able, hashable."""

    kind: str = "overhead"          # overhead | determinism | straggler
    dataset: str = "heavy_tail"
    phase: str = "process"          # cost-model name
    backend: str = "sim"
    n_workers: int = 64
    organization: str = "chronological"
    tasks_per_message: int = 1
    policy: str = "fifo_selfsched"
    fault_profile: str = "deaths_20pct"
    dataset_limit: Optional[int] = 12_000
    repeats: int = 3                # wall-clock repeats (overhead cells)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.kind not in ("overhead", "determinism", "straggler"):
            raise ValueError(f"unknown cell kind {self.kind!r}")
        if self.backend != "sim":
            raise ValueError("obs cells gate on the deterministic sim "
                             "backend")
        if self.policy not in POLICY_NAMES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.fault_profile not in FAULT_PROFILES:
            raise ValueError(
                f"unknown fault profile {self.fault_profile!r}")
        if self.repeats < 1:
            raise ValueError("repeats must be >= 1")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ObsScenario:
    """One named observability-bench cell."""

    name: str
    group: str
    run: ObsSpec
    checks: tuple = ()
    tier: str = "full"
    notes: str = ""

    def matches(self, filters: Sequence[str]) -> bool:
        return (not filters
                or any(f in self.name or f in self.group for f in filters))


# ---------------------------------------------------------------------------
# Cell executors.
# ---------------------------------------------------------------------------

def _run_once(spec: ObsSpec, tracer: Optional[Tracer]):
    """One sim run of the spec's workload, optionally traced."""
    from repro.core.cost_model import PHASES
    from repro.runtime import run_job
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest(spec.dataset, limit=spec.dataset_limit)
    model = PHASES[spec.phase]
    worker_death, worker_speed, _, _ = FAULT_PROFILES[
        spec.fault_profile].materialize(spec.n_workers, spec.seed)
    return run_job(
        tasks, None, backend="sim", n_workers=spec.n_workers,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message, policy=spec.policy,
        cost_model=model, worker_death=worker_death,
        worker_speed=worker_speed, organize_seed=spec.seed,
        raise_on_failure=False, tracer=tracer)


def _execute_overhead(spec: ObsSpec) -> dict:
    """Traced vs untraced, interleaved, min-of-``repeats`` wall-clocks.

    Interleaving (plain, traced, plain, traced, ...) puts both sides
    under the same thermal/frequency regime; min-of-N is the standard
    noise floor for a deterministic workload.  The virtual results
    must be IDENTICAL — tracing is an observer, not a participant.
    """
    plain_walls: list[float] = []
    traced_walls: list[float] = []
    plain = traced = tracer = None
    for _ in range(spec.repeats):
        t0 = time.perf_counter()
        plain = _run_once(spec, None)
        plain_walls.append(time.perf_counter() - t0)
        tracer = Tracer()
        t0 = time.perf_counter()
        traced = _run_once(spec, tracer)
        traced_walls.append(time.perf_counter() - t0)
    identical = int(traced.job_seconds == plain.job_seconds
                    and traced.dispatch_digest == plain.dispatch_digest)
    metrics = {
        "makespan_seconds": traced.job_seconds,
        "n_events": len(tracer.events),
        "events_dropped": tracer.dropped,
        "makespan_identical": identical,
        "tasks_completed": len(traced.completed_ids),
        "messages_sent": traced.messages_sent,
        "dispatch_digest": traced.dispatch_digest,
    }
    measured = {
        "overhead_ratio": min(traced_walls) / min(plain_walls),
        "traced_wall_s": min(traced_walls),
        "untraced_wall_s": min(plain_walls),
    }
    return {"metrics": metrics, "measured": measured}


def _execute_determinism(spec: ObsSpec) -> dict:
    """Two fresh traced runs -> canonical summary bytes must agree."""
    tr1, tr2 = Tracer(), Tracer()
    res = _run_once(spec, tr1)
    _run_once(spec, tr2)
    b1 = canonical_bytes(summary_from_tracer(tr1, label=REF_LABEL))
    b2 = canonical_bytes(summary_from_tracer(tr2, label=REF_LABEL))
    metrics = {
        "makespan_seconds": res.job_seconds,
        "n_events": len(tr1.events),
        "events_dropped": tr1.dropped,
        "summary_identical": int(b1 == b2),
        "n_events_identical": int(len(tr1.events) == len(tr2.events)),
        "summary_bytes": len(b1),
        "tasks_completed": len(res.completed_ids),
    }
    return {"metrics": metrics, "measured": {}}


def _execute_straggler(spec: ObsSpec) -> dict:
    """Does the trace summary's speed ranking find the slowed workers?"""
    _, worker_speed, _, _ = FAULT_PROFILES[spec.fault_profile].materialize(
        spec.n_workers, spec.seed)
    if not worker_speed:
        raise ValueError("straggler cells need a fault profile with "
                         "straggler_frac > 0")
    slow = {str(i) for i, s in enumerate(worker_speed) if s < 1.0}
    tracer = Tracer()
    res = _run_once(spec, tracer)
    summary = summary_from_tracer(tracer, label=spec.dataset,
                                  max_workers=spec.n_workers)
    workers = {w: d for w, d in summary["workers"].items()
               if isinstance(d, dict)}
    # speed_est ascending: the slowest-estimated workers first.
    ranked = sorted(workers, key=lambda w: (workers[w]["speed_est"], w))
    bottom = ranked[:len(slow)]
    metrics = {
        "makespan_seconds": res.job_seconds,
        "n_events": len(tracer.events),
        "events_dropped": tracer.dropped,
        "n_slow_workers": len(slow),
        "straggler_rank_correct": int(bool(ranked) and ranked[0] in slow),
        "bottom_k_hits": sum(1 for w in bottom if w in slow),
        "slowest_speed_est": (workers[ranked[0]]["speed_est"]
                              if ranked else 0.0),
        "straggler_count": summary["scenario"]["metrics"]
                                  ["straggler_count"],
        "tasks_completed": len(res.completed_ids),
    }
    return {"metrics": metrics, "measured": {}}


_EXECUTORS = {"overhead": _execute_overhead,
              "determinism": _execute_determinism,
              "straggler": _execute_straggler}


# ---------------------------------------------------------------------------
# Scenario matrix.
# ---------------------------------------------------------------------------

_BASE = ObsSpec()
#: The determinism cell's spec doubles as the reference-artifact spec
#: (``reference_run`` / ``--summary-out``): 64 workers keeps the whole
#: fleet inside the summary's default per-worker table.
_DETERMINISM_BASE = dataclasses.replace(_BASE, kind="determinism")


def obs_scenarios() -> list[ObsScenario]:
    """The full matrix (the quick tier is the ISSUE-9 acceptance set)."""
    return [
        ObsScenario(
            name="obs_overhead_heavy_tail_w1024",
            group="obs_overhead",
            run=dataclasses.replace(_BASE, kind="overhead",
                                    n_workers=1024),
            checks=(Check("overhead_ratio", "max", 1.05,
                          source="ISSUE 9: tracing enabled costs <= 5% "
                                 "makespan on the heavy_tail sim at "
                                 "1024 workers"),
                    Check("makespan_identical", "min", 1,
                          source="tracing observes the schedule without "
                                 "changing any dispatch decision"),),
            tier="quick", notes="ISSUE-9 overhead acceptance cell"),
        ObsScenario(
            name="obs_determinism_heavy_tail",
            group="obs_determinism",
            run=_DETERMINISM_BASE,
            checks=(Check("summary_identical", "min", 1,
                          source="ISSUE 9: sim trace summaries are "
                                 "byte-identical across same-seed "
                                 "reruns"),
                    Check("n_events_identical", "min", 1,
                          source="same-seed reruns emit the same event "
                                 "stream"),),
            tier="quick", notes="source of TRACE_heavy_tail_quick.json"),
        ObsScenario(
            name="obs_straggler_ranking",
            group="obs_straggler",
            run=dataclasses.replace(_BASE, kind="straggler",
                                    fault_profile="stragglers_10pct"),
            checks=(Check("straggler_rank_correct", "min", 1,
                          source="ISSUE 9: the 0.25x-speed workers rank "
                                 "slowest by measured speed_est"),
                    Check("straggler_count", "min", 1,
                          source="slowed workers produce straggler "
                                 "tasks (actual > 2x estimate)"),),
            tier="quick", notes="ISSUE-9 attribution acceptance cell"),
        # Full tier: the overhead curve at the base fleet size (no
        # gate — documents the small-fleet cost alongside the w1024
        # acceptance point).
        ObsScenario(
            name="obs_overhead_heavy_tail_w64",
            group="obs_overhead",
            run=dataclasses.replace(_BASE, kind="overhead"),
            tier="full", notes="small-fleet overhead curve point"),
    ]


def run_obs_scenario(sc: ObsScenario) -> dict:
    """Execute one scenario into a BENCH record."""
    t0 = time.perf_counter()
    spec_doc = {"run": sc.run.to_dict(), "baseline": None}
    try:
        out = _EXECUTORS[sc.run.kind](sc.run)
    except Exception as e:                 # keep the campaign going
        return {"name": sc.name, "group": sc.group, "tier": sc.tier,
                "status": "error", "spec": spec_doc,
                "metrics": {}, "measured": {}, "checks": [],
                "timing": {"wall_s": time.perf_counter() - t0},
                "error": f"{type(e).__name__}: {e}"}
    metrics, measured = out["metrics"], out["measured"]
    merged = {**measured, **metrics}
    checks = [c.evaluate(merged) for c in sc.checks]
    status = ("ran" if not checks
              else "pass" if all(c["passed"] for c in checks) else "fail")
    return {"name": sc.name, "group": sc.group, "tier": sc.tier,
            "status": status, "spec": spec_doc,
            "metrics": metrics, "measured": measured, "checks": checks,
            "timing": {"wall_s": time.perf_counter() - t0}, "error": None}


def run_obs_campaign(*, quick: bool = False, filters: Sequence[str] = (),
                     seed: Optional[int] = None, progress=None) -> dict:
    """Run the obs matrix into a schema-valid BENCH_obs doc."""
    selected = [sc for sc in obs_scenarios()
                if (not quick or sc.tier == "quick")
                and sc.matches(filters)]
    if not selected:
        raise ValueError("no obs scenarios match the quick/filter "
                         "selection")
    if seed is not None:
        selected = [dataclasses.replace(
            sc, run=dataclasses.replace(sc.run, seed=seed))
            for sc in selected]
    t0 = time.perf_counter()
    records = []
    for sc in selected:
        rec = run_obs_scenario(sc)
        records.append(rec)
        if progress is not None:
            progress(rec)
    counts = {s: 0 for s in ("pass", "fail", "ran", "error")}
    for rec in records:
        counts[rec["status"]] += 1
    doc = {
        "schema": OBS_BENCH_SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "created_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "config": {"quick": quick, "filters": list(filters),
                   "seed": seed, "n_selected": len(selected)},
        "environment": {"python": sys.version.split()[0],
                        "platform": sys.platform},
        "scenarios": records,
        "summary": {"total": len(records), **counts,
                    "checked": sum(1 for r in records if r["checks"])},
        "timing": {"wall_s": time.perf_counter() - t0},
    }
    problems = validate_obs(doc)
    if problems:      # a bug in this module, not in the scenarios
        raise RuntimeError("obs bench produced a schema-invalid "
                           "artifact: " + "; ".join(problems[:5]))
    return doc


def reference_run(seed: Optional[int] = None):
    """-> (tracer, summary doc) of the reference heavy-tail quick run.

    Exactly the determinism cell's workload and label, so
    ``canonical_bytes`` of the returned summary equals the committed
    ``benchmarks/refs/TRACE_heavy_tail_quick.json`` (seed 0).
    """
    spec = (_DETERMINISM_BASE if seed is None
            else dataclasses.replace(_DETERMINISM_BASE, seed=seed))
    tracer = Tracer()
    _run_once(spec, tracer)
    return tracer, summary_from_tracer(tracer, label=REF_LABEL)


def obs_summary_lines(doc: dict) -> list[str]:
    """Human-readable summary for the CLI."""
    s = doc["summary"]
    lines = [f"{s['total']} obs scenarios: {s['pass']} pass, "
             f"{s['fail']} fail, {s['ran']} ran, {s['error']} error "
             f"[{doc['timing']['wall_s']:.1f}s]"]
    for rec in doc["scenarios"]:
        if rec["status"] == "error":
            lines.append(f"  ERROR {rec['name']}: {rec['error']}")
            continue
        m = {**rec["measured"], **rec["metrics"]}
        bits = [f"makespan={m['makespan_seconds']:.3g}s",
                f"events={m['n_events']:.0f}"]
        if "overhead_ratio" in m:
            bits.append(f"overhead={(m['overhead_ratio'] - 1) * 100:+.1f}%")
        if "summary_identical" in m:
            bits.append(f"identical={m['summary_identical']:.0f}")
        if "straggler_rank_correct" in m:
            bits.append(f"rank_ok={m['straggler_rank_correct']:.0f} "
                        f"bottom_k={m['bottom_k_hits']:.0f}"
                        f"/{m['n_slow_workers']:.0f}")
        lines.append(f"  {rec['status']:5s} {rec['name']}: "
                     + " ".join(bits))
        for c in rec["checks"]:
            if not c["passed"]:
                lines.append(f"        FAIL {c['metric']}="
                             f"{c['actual']} vs {c['kind']} {c['expect']}")
    return lines


def main(argv=None) -> int:
    """CLI: ``python -m repro.bench.obs [--quick] [--out PATH]``."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.obs",
        description="Benchmark the tracing layer (overhead, summary "
                    "determinism, straggler attribution); write "
                    "BENCH_obs.json.")
    ap.add_argument("--quick", action="store_true",
                    help="run only the quick tier (the CI acceptance "
                         "cells)")
    ap.add_argument("--filter", action="append", default=[],
                    metavar="SUBSTR")
    ap.add_argument("--out", default="BENCH_obs.json",
                    help="artifact path ('-' for stdout only)")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also write the reference run's Perfetto "
                         "trace.json here")
    ap.add_argument("--summary-out", default=None, metavar="PATH",
                    help="also write the reference run's canonical "
                         "repro.obs/v1 summary here (the bytes of "
                         "benchmarks/refs/TRACE_heavy_tail_quick.json)")
    args = ap.parse_args(argv)

    if args.list:
        for sc in obs_scenarios():
            if sc.matches(args.filter) and (not args.quick
                                            or sc.tier == "quick"):
                print(f"{sc.tier:5s} {sc.group:18s} {sc.name} "
                      f"[{len(sc.checks)} checks]")
        return 0

    def progress(rec):
        print(f"  {rec['status']:5s} {rec['name']} "
              f"({rec['timing']['wall_s']:.2f}s)", flush=True)

    try:
        doc = run_obs_campaign(quick=args.quick, filters=args.filter,
                               seed=args.seed, progress=progress)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if args.trace_out or args.summary_out:
        tracer, summary = reference_run(seed=args.seed)
        if args.trace_out:
            with open(args.trace_out, "w") as f:
                json.dump(to_chrome_trace(tracer.events, label=REF_LABEL),
                          f)
            print(f"wrote {args.trace_out}")
        if args.summary_out:
            with open(args.summary_out, "wb") as f:
                f.write(canonical_bytes(summary))
            print(f"wrote {args.summary_out}")
    for line in obs_summary_lines(doc):
        print(line)
    return 1 if (doc["summary"]["fail"] or doc["summary"]["error"]) else 0


if __name__ == "__main__":
    sys.exit(main())
