"""Scheduling-policy benchmark matrix: policy x dataset x fault x backend.

The campaign benchmarks the protocol against the paper's tables, the
kernels matrix benchmarks the device hot path, the storage matrix the
feed — this module benchmarks the *dispatch decisions* themselves: how
much makespan, worker balance, and prefetch warmth each
:mod:`repro.runtime.policies` policy buys on the workloads where the
companion HPC paper says static chunking falls over (heavy-tailed task
mixes + worker deaths).  Two cell kinds share one artifact
(``BENCH_scheduling.json``, schema ``repro.bench.scheduling/v1``):

  * ``sim`` cells — the discrete-event backend at bench scale: run a
    policy against the heavy-tailed aerodrome manifest under a fault
    profile and record makespan + worker-busy quantiles + simulated
    I/O wait.  Fully deterministic per seed, so everything lands in
    ``metrics`` and regression-gates byte-stably.
  * ``store_feed`` cells — a LIVE threads-backend job over row-range
    ``store://`` tasks of a real (synthetic-content) columnar store,
    with a worker that models the PR-4 prefetch consumer: serving a
    range from its cached shard decode is free, switching shards pays
    a full decode into ``wait_s``.  Wall-clock figures land in
    ``measured``; the quick tier gates the shard_affinity-vs-fifo wait
    *ratio* (both sides measured on the same machine in the same
    process).

  * ``dag_sim`` cells — the streaming phase DAG (ISSUE 6): a
    three-phase 1:1 chain over the dataset on
    :func:`repro.runtime.run_dag` vs the same phases as sequential
    barrier ``run_job`` calls; plus manager-sharding scaling cells that
    gate ``dispatch_rate_gain_x`` where the single coordinator's
    message clock flatlines (paper §V).

The quick tier is the acceptance cell set: on the heavy-tail dataset
with the 20 %-death fault profile in the sim backend, ``adaptive_chunk``
and ``sized_lpt`` each make >= 1.3x lower makespan than ``static`` with
``tasks_per_message=1``; ``shard_affinity`` reduces measured prefetch
``wait_s`` vs ``fifo_selfsched`` on the store-backed feed; the
streaming DAG makes >= 1.5x lower makespan than the barrier sequence;
and 4 manager shards dispatch >= 1.3x faster than one at 1024 workers.

CLI::

    PYTHONPATH=src python -m repro.bench.scheduling --quick
    PYTHONPATH=src python benchmarks/scheduling_bench.py --out BENCH_scheduling.json
"""

from __future__ import annotations

import dataclasses
import json
import sys
import threading
import time
from typing import Optional, Sequence

from repro.bench.scenarios import FAULT_PROFILES, Check
from repro.bench.schema import (
    SCHEDULING_SCHEMA, SCHEMA_VERSION, validate_scheduling)
from repro.runtime.policies import POLICY_NAMES

__all__ = ["SchedulingSpec", "SchedulingScenario", "StoreFeedWorker",
           "scheduling_scenarios", "run_scheduling_scenario",
           "run_scheduling_campaign", "scheduling_summary_lines", "main"]


@dataclasses.dataclass(frozen=True)
class SchedulingSpec:
    """One policy-bench configuration — JSON-able, hashable."""

    policy: str = "static"
    kind: str = "sim"        # sim | store_feed | dag_sim | elastic_panel
    #                        # | elastic_live
    dataset: str = "aerodrome"          # manifest name / feed fixture tag
    phase: str = "process"              # cost-model name (sim cells)
    backend: str = "sim"                # sim | threads
    n_workers: int = 64
    organization: str = "chronological"
    tasks_per_message: int = 1
    fault_profile: str = "none"
    dataset_limit: Optional[int] = 3000
    poll_interval: Optional[float] = None
    failure_timeout: Optional[float] = None
    n_manager_shards: int = 1
    speculative: bool = False
    speculation_max_copies: int = 2
    speed_feedback: bool = False
    elastic: bool = False
    seed: int = 0
    # store_feed fixture knobs (which store, how it is sliced into tasks).
    n_archives: int = 48
    segments_per_archive: int = 8
    target_points: int = 3072
    rows_per_task: int = 2

    def __post_init__(self) -> None:
        if self.policy not in POLICY_NAMES:
            raise ValueError(f"unknown policy {self.policy!r}; choose "
                             f"from {list(POLICY_NAMES)}")
        if self.kind not in ("sim", "store_feed", "dag_sim",
                             "elastic_panel", "elastic_live"):
            raise ValueError(f"unknown cell kind {self.kind!r}")
        if self.fault_profile not in FAULT_PROFILES:
            raise ValueError(
                f"unknown fault profile {self.fault_profile!r}")
        if self.kind in ("sim", "dag_sim", "elastic_panel") \
                and self.backend != "sim":
            raise ValueError(f"{self.kind} cells run on the sim backend")
        if self.n_manager_shards < 1:
            raise ValueError("n_manager_shards must be >= 1")
        if self.kind == "store_feed" and self.backend != "threads":
            raise ValueError("store_feed cells measure a live feed; "
                             "backend must be 'threads'")
        if self.kind == "elastic_live" and self.backend != "threads":
            raise ValueError("elastic_live cells spawn worker threads; "
                             "backend must be 'threads'")
        if self.elastic and self.n_manager_shards > 1:
            raise ValueError("elastic fleets need n_manager_shards=1")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def fixture_key(self) -> tuple:
        return (self.n_archives, self.segments_per_archive,
                self.target_points, self.seed)


@dataclasses.dataclass(frozen=True)
class SchedulingScenario:
    """One named scheduling-bench cell."""

    name: str
    group: str
    run: SchedulingSpec
    baseline: Optional[SchedulingSpec] = None
    checks: tuple[Check, ...] = ()
    tier: str = "full"
    notes: str = ""

    def matches(self, patterns: Sequence[str]) -> bool:
        if not patterns:
            return True
        return any(p in self.name or p in self.group for p in patterns)


# ---------------------------------------------------------------------------
# sim cells.
# ---------------------------------------------------------------------------

def _execute_sim(spec: SchedulingSpec) -> dict:
    from repro.core.cost_model import PHASES
    from repro.runtime import run_job
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest(spec.dataset, limit=spec.dataset_limit)
    model = PHASES[spec.phase]
    worker_death, worker_speed, _, _ = FAULT_PROFILES[
        spec.fault_profile].materialize(spec.n_workers, spec.seed)
    kwargs: dict = {}
    if spec.poll_interval is not None:
        kwargs["poll_interval"] = spec.poll_interval
    if spec.failure_timeout is not None:
        kwargs["failure_timeout"] = spec.failure_timeout
    result = run_job(
        tasks, None, backend="sim", n_workers=spec.n_workers,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message,
        policy=spec.policy, cost_model=model,
        n_manager_shards=spec.n_manager_shards,
        worker_death=worker_death, worker_speed=worker_speed,
        speculative=spec.speculative,
        speculation_max_copies=spec.speculation_max_copies,
        speed_feedback=spec.speed_feedback, elastic=spec.elastic,
        organize_seed=spec.seed, raise_on_failure=False, **kwargs)
    bq = result.busy_quantiles()
    # Everything the sim reports is deterministic for a fixed spec+seed.
    metrics = {
        "n_tasks": len(tasks),
        "tasks_completed": len(result.completed_ids),
        "messages_sent": result.messages_sent,
        "n_batches": len(result.batches),
        "reassigned_tasks": result.reassigned_tasks,
        "makespan_seconds": result.job_seconds,
        "busy_p50_s": bq["p50"],
        "busy_p90_s": bq["p90"],
        "busy_p99_s": bq["p99"],
        "busy_total_s": sum(result.worker_busy),
        "wait_total_s": sum(result.worker_wait),
        "dispatch_digest": result.dispatch_digest,
        "dispatch_rate_msgs_per_s": result.dispatch_rate_msgs_per_s,
        "speculated": result.speculated,
        "extra_messages": result.extra_messages,
        "wasted_duplicate_s": result.wasted_seconds,
    }
    if result.workers_added or result.workers_retired:
        metrics["workers_added"] = result.workers_added
        metrics["workers_retired"] = result.workers_retired
    if result.shard_messages:
        metrics["n_manager_shards"] = len(result.shard_messages)
        metrics["shard_messages"] = list(result.shard_messages)
        metrics["shard_dispatch_rates_msgs_per_s"] = (
            result.shard_dispatch_rates_msgs_per_s)
    return {"metrics": metrics, "measured": {}}


def _execute_dag_sim(spec: SchedulingSpec) -> dict:
    """Streaming-DAG cell: a three-phase 1:1 chain over the dataset on
    :func:`repro.runtime.run_dag`, against the barrier baseline (the
    same three phases as sequential ``run_job`` calls, each waiting for
    the previous one's slowest task).  Both sides share the cost model,
    fault profile, policy, and manager-shard count, so the speedup
    isolates the barrier removal itself.

    The workload mirrors the paper's pipeline shape: the source phase
    streams the dataset's bytes through the phase model's SHARED
    bandwidth hierarchy (at fleet scale the global Lustre term binds,
    so the fleet idles waiting on I/O), while the two downstream
    phases carry the dataset's heavy-tailed CPU costs on otherwise
    idle cores.  A barrier sequence pays T_io + T_cpu + T_cpu; the
    streaming DAG hides the CPU phases inside the I/O phase's
    bandwidth shadow — a speedup no intra-phase policy can reach."""
    from repro.core.cost_model import PHASES
    from repro.core.messages import Task
    from repro.runtime import run_job
    from repro.runtime.dag import StreamingDAG, run_dag
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest(spec.dataset, limit=spec.dataset_limit)
    model = PHASES[spec.phase]
    worker_death, worker_speed, _, _ = FAULT_PROFILES[
        spec.fault_profile].materialize(spec.n_workers, spec.seed)
    common = dict(
        n_workers=spec.n_workers, organization=spec.organization,
        tasks_per_message=spec.tasks_per_message, policy=spec.policy,
        cost_model=model, n_manager_shards=spec.n_manager_shards,
        worker_death=worker_death, worker_speed=worker_speed,
        organize_seed=spec.seed, raise_on_failure=False)

    # p0 carries the manifest's BYTES (I/O-bound under the phase
    # model's shared bandwidth hierarchy at this fleet size); p1/p2
    # carry the manifest's heavy-tailed CPU-cost hints on negligible
    # bytes, with the per-item rank reshuffled per phase (the big raw
    # file is not the slow track to process), so no single item chains
    # all three giants through the DAG's critical path.
    import random
    phase_hints: list[dict[str, float]] = []
    for phase in (1, 2):
        hints = [t.cpu_cost_hint or 0.0 for t in tasks]
        random.Random(spec.seed * 7919 + phase).shuffle(hints)
        phase_hints.append({t.task_id: h for t, h in zip(tasks, hints)})

    def cpu_tasks(phase: int) -> list[Task]:
        return [Task(task_id=t.task_id, size_bytes=1, timestamp=t.timestamp,
                     cpu_cost_hint=phase_hints[phase - 1][t.task_id])
                for t in tasks]

    def relabel(phase: int):
        def expand(task: Task, _result) -> list[Task]:
            # 1:1 expansion at the next phase's cost for this item;
            # namespacing keeps the ids distinct on the wire.
            return [Task(task_id=task.task_id, size_bytes=1,
                         timestamp=task.timestamp,
                         cpu_cost_hint=phase_hints[phase - 1][task.task_id])]
        return expand

    dag = StreamingDAG()
    dag.add_node("p0", tasks=list(tasks))
    dag.add_node("p1")
    dag.add_node("p2")
    dag.add_edge("p0", "p1", expand=relabel(1))
    dag.add_edge("p1", "p2", expand=relabel(2))
    dres = run_dag(dag, backend="sim", **common)
    pipelined = dres.run

    barrier_makespan = 0.0
    barrier_messages = 0
    barrier_completed = 0
    for phase_tasks in (list(tasks), cpu_tasks(1), cpu_tasks(2)):
        r = run_job(phase_tasks, None, backend="sim", **common)
        barrier_makespan += r.job_seconds
        barrier_messages += r.messages_sent
        barrier_completed += len(r.completed_ids)

    completed = sum(len(c) for c in dres.node_completed.values())
    metrics = {
        "n_tasks": 3 * len(tasks),
        "tasks_completed": completed,
        "messages_sent": pipelined.messages_sent,
        "makespan_seconds": pipelined.job_seconds,
        "barrier_makespan_seconds": barrier_makespan,
        "barrier_messages_sent": barrier_messages,
        "barrier_tasks_completed": barrier_completed,
        "makespan_speedup_x": (barrier_makespan / pipelined.job_seconds
                               if pipelined.job_seconds else 0.0),
        "dispatch_rate_msgs_per_s": pipelined.dispatch_rate_msgs_per_s,
        "dispatch_digest": pipelined.dispatch_digest,
    }
    if pipelined.shard_messages:
        metrics["n_manager_shards"] = len(pipelined.shard_messages)
        metrics["shard_messages"] = list(pipelined.shard_messages)
    return {"metrics": metrics, "measured": {}}


# ---------------------------------------------------------------------------
# elastic cells (ISSUE 10).
# ---------------------------------------------------------------------------

#: Every static-fleet policy the elastic stack must beat — the panel
#: runs ALL of them under the identical fault regime, so the acceptance
#: gate compares against the best static cell, not a cherry-picked one.
_STATIC_PANEL_POLICIES = ("static", "fifo_selfsched", "sized_lpt",
                          "adaptive_chunk")


def _execute_elastic_panel(spec: SchedulingSpec) -> dict:
    """ISSUE-10 acceptance cell: the full elastic stack (speculation +
    speed-fed sizing + threshold autoscaler) against every static-fleet
    policy under the same deaths+stragglers storm.  The headline metric
    ``makespan_speedup_vs_best_static_x`` divides the BEST static
    makespan by the elastic one; the gate is >= 1.2x.  Deaths shrink a
    static fleet permanently while the controller re-grows capacity,
    and speculation cuts the 4x-slow straggler tail — all decisions on
    the virtual clock, so the whole panel is deterministic per seed."""
    elastic_spec = dataclasses.replace(
        spec, kind="sim", speculative=True, speed_feedback=True,
        elastic=True)
    elastic = _execute_sim(elastic_spec)
    em = elastic["metrics"]
    static_makespans: dict[str, float] = {}
    static_completed: dict[str, int] = {}
    for policy in _STATIC_PANEL_POLICIES:
        srun = _execute_sim(dataclasses.replace(
            spec, kind="sim", policy=policy, speculative=False,
            speed_feedback=False, elastic=False))
        static_makespans[policy] = srun["metrics"]["makespan_seconds"]
        static_completed[policy] = srun["metrics"]["tasks_completed"]
    best_policy = min(static_makespans, key=static_makespans.get)
    best = static_makespans[best_policy]
    metrics = dict(em)
    metrics.update({
        "static_makespans": static_makespans,
        "best_static_policy": best_policy,
        "best_static_makespan_seconds": best,
        "makespan_speedup_vs_best_static_x": (
            best / em["makespan_seconds"] if em["makespan_seconds"]
            else 0.0),
        "static_tasks_completed_min": min(static_completed.values()),
    })
    return {"metrics": metrics, "measured": {}}


class _SleepTaskWorker:
    """Fixed-cost live worker for the elastic threads cell: every task
    sleeps ``base_s``, so straggling comes only from the injected
    ``worker_slow_factor`` — the thing the cell measures."""

    def __init__(self, base_s: float = 0.02):
        self.base_s = base_s

    def __call__(self, task) -> str:
        time.sleep(self.base_s)
        return task.task_id


def _execute_elastic_live(spec: SchedulingSpec) -> dict:
    """Live threads cell: a real 4x-slow worker (``live_slow4`` ->
    ``worker_slow_factor``), real speculation, and a real autoscaler
    spawning/retiring worker threads mid-run.  Wall-clock numbers land
    in ``measured``; the exactly-once counters stay in ``metrics``."""
    from repro.runtime import FleetController, run_job
    from repro.tracks.datasets import get_manifest

    tasks = get_manifest(spec.dataset, limit=spec.dataset_limit)
    _, _, worker_fail_after, worker_slow_factor = FAULT_PROFILES[
        spec.fault_profile].materialize(spec.n_workers, spec.seed)
    # A live control loop needs sub-second ticks on a seconds-long job
    # (run_job's default controller paces for simulated hours).
    fleet = None
    if spec.elastic:
        fleet = FleetController(
            min_workers=1, max_workers=2 * spec.n_workers,
            interval_s=0.1, cooldown_s=0.2, queue_high_per_worker=2.0)
    result = run_job(
        tasks, _SleepTaskWorker(), backend="threads",
        n_workers=spec.n_workers,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message,
        policy=spec.policy,
        speculative=spec.speculative,
        speculation_max_copies=spec.speculation_max_copies,
        speed_feedback=spec.speed_feedback,
        fleet=fleet,
        worker_fail_after=worker_fail_after,
        worker_slow_factor=worker_slow_factor,
        organize_seed=spec.seed,
        poll_interval=(spec.poll_interval if spec.poll_interval is not None
                       else 0.002))
    metrics = {
        "n_tasks": len(tasks),
        "tasks_completed": len(result.completed_ids),
        "n_results": len(result.results),
        "messages_sent": result.messages_sent,
        "n_batches": len(result.batches),
    }
    measured = {
        "makespan_seconds": result.job_seconds,
        "speculated": float(result.speculated),
        "extra_messages": float(result.extra_messages),
        "wasted_duplicate_s": result.wasted_seconds,
        "workers_added": float(result.workers_added),
        "workers_retired": float(result.workers_retired),
    }
    return {"metrics": metrics, "measured": measured}


# ---------------------------------------------------------------------------
# store_feed cells.
# ---------------------------------------------------------------------------

class StoreFeedWorker:
    """run_job worker fn modelling the store-backed prefetch consumer.

    Each task is a ``store://...#shard=<id>&rows=a:b`` payload.  The
    worker keeps ONE decoded shard per thread/process (exactly what the
    double-buffered prefetcher keeps warm): a task on the cached shard
    serves from memory; a task on a different shard pays the full
    read+decode, accumulated as feed wait.  ``take_wait_s()`` hands the
    wait to the runtime after every DONE batch, so it surfaces in
    ``RunResult`` per worker — the number the shard_affinity acceptance
    cell gates on.
    """

    def __init__(self, store_root: str):
        self.store_root = store_root
        self._local = threading.local()

    # One-shard-cache state is per thread (threads backend) and rebuilt
    # per process after pickling (processes backend).
    def __getstate__(self) -> dict:
        return {"store_root": self.store_root}

    def __setstate__(self, state: dict) -> None:
        self.__init__(state["store_root"])

    def _state(self):
        loc = self._local
        if not hasattr(loc, "store"):
            from repro.store.reader import TrackStore
            loc.store = TrackStore(self.store_root, prefetch=0)
            loc.shard_id = None
            loc.batch = None
            loc.wait_s = 0.0
            loc.decodes = 0
        return loc

    def __call__(self, task) -> dict:
        from repro.store.reader import parse_store_uri

        loc = self._state()
        _root, sel = parse_store_uri(task.payload)
        shard_id = sel["shard"]
        decoded = 0
        if loc.shard_id != shard_id:
            t0 = time.perf_counter()
            loc.batch = loc.store.read_shard_batch(shard_id)
            loc.wait_s += time.perf_counter() - t0
            loc.decodes += 1
            loc.shard_id = shard_id
            decoded = 1
        a, _, b = sel.get("rows", "").partition(":")
        lo = int(a) if a else 0
        hi = int(b) if b else len(loc.batch.items)
        items = loc.batch.items[lo:hi]
        return {"n_rows": len(items),
                "n_points": sum(len(obs["time"]) for obs, _ in items),
                "decoded": decoded}

    def take_wait_s(self) -> float:
        """Return-and-reset this thread's accumulated decode wait (the
        runtime calls it after each DONE batch — see worker_loop)."""
        loc = self._state()
        w, loc.wait_s = loc.wait_s, 0.0
        return w


def _feed_fixture(spec: SchedulingSpec) -> dict:
    """A real columnar store on disk (cached via the storage bench's
    fixture machinery, which also cleans it up at exit)."""
    from repro.bench.storage import StorageSpec, _fixture

    return _fixture(StorageSpec(
        source="store", phase="warm", workload="heavy_tail",
        n_archives=spec.n_archives,
        segments_per_archive=spec.segments_per_archive,
        target_points=spec.target_points, seed=spec.seed))


def _feed_tasks(store_root: str, spec: SchedulingSpec) -> list:
    """Row-range tasks over every shard, timestamped so chronological
    order interleaves shards round-robin — the worst case for a
    locality-blind policy (consecutive FIFO tasks almost always switch
    shards) and precisely what shard_affinity is meant to undo."""
    from repro.store.reader import parse_store_uri
    from repro.tracks.segments import segment_tasks_from_store

    tasks = segment_tasks_from_store(store_root, granularity="rows",
                                     rows_per_task=spec.rows_per_task)
    by_shard: dict[str, list] = {}
    for t in tasks:
        _root, sel = parse_store_uri(t.payload)
        by_shard.setdefault(sel["shard"], []).append(t)
    n_shards = len(by_shard)
    for si, sid in enumerate(sorted(by_shard)):
        for ri, t in enumerate(sorted(by_shard[sid],
                                      key=lambda t: t.task_id)):
            t.timestamp = float(ri * n_shards + si)
    return tasks


def _batch_locality(batches: list, tasks: list) -> float:
    """Fraction of MULTI-task ASSIGNs whose ids share one shard (1.0 =
    every such batch is single-shard, the shard_affinity invariant).
    Single-task batches are trivially single-shard and are excluded so
    the metric cannot go vacuously true; 0.0 when the job produced no
    multi-task batch at all (the acceptance cell runs at
    tasks_per_message=2 precisely so this measures something)."""
    from repro.store.reader import parse_store_uri

    shard_of = {}
    for t in tasks:
        _root, sel = parse_store_uri(t.payload)
        shard_of[t.task_id] = sel["shard"]
    multi = [b for b in batches if len(b) > 1]
    if not multi:
        return 0.0
    ok = sum(1 for b in multi
             if len({shard_of[tid] for tid in b}) == 1)
    return ok / len(multi)


def _execute_store_feed(spec: SchedulingSpec) -> dict:
    from repro.runtime import run_job

    from repro.store.reader import parse_store_uri

    fx = _feed_fixture(spec)
    tasks = _feed_tasks(fx["store_root"], spec)
    fn = StoreFeedWorker(fx["store_root"])
    # Warm-up decode of every shard once (page cache + lazy imports) so
    # the measured cells compare decode *scheduling*, not first-touch
    # costs that only the first cell of the process would pay.
    warm = StoreFeedWorker(fx["store_root"])._state().store
    for sid in sorted({parse_store_uri(t.payload)[1]["shard"]
                       for t in tasks}):
        warm.read_shard_batch(sid)
    result = run_job(
        tasks, fn, backend="threads", n_workers=spec.n_workers,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message,
        policy=spec.policy,
        poll_interval=(spec.poll_interval if spec.poll_interval is not None
                       else 0.002))
    metrics = {
        "n_tasks": len(tasks),
        "n_shards": fx["n_shards"],
        "tasks_completed": len(result.completed_ids),
        "messages_sent": result.messages_sent,
        "n_batches": len(result.batches),
        "batch_locality": _batch_locality(result.batches, tasks),
    }
    measured = {
        "makespan_seconds": result.job_seconds,
        "prefetch_wait_s": sum(result.worker_wait),
        "shard_decodes": float(sum(
            r.get("decoded", 0) for r in result.results.values())),
        "worker_breakdown": result.worker_breakdown(),
    }
    return {"metrics": metrics, "measured": measured}


# ---------------------------------------------------------------------------
# Record assembly.
# ---------------------------------------------------------------------------

def _execute(spec: SchedulingSpec,
             cache: Optional[dict] = None) -> dict:
    """Run one spec; ``cache`` (keyed on the frozen spec) lets a
    campaign reuse shared baselines — the quick tier alone would
    otherwise simulate the identical static cell once per scenario."""
    if cache is not None and spec in cache:
        return cache[spec]
    out = (_execute_sim(spec) if spec.kind == "sim"
           else _execute_dag_sim(spec) if spec.kind == "dag_sim"
           else _execute_elastic_panel(spec) if spec.kind == "elastic_panel"
           else _execute_elastic_live(spec) if spec.kind == "elastic_live"
           else _execute_store_feed(spec))
    if cache is not None:
        cache[spec] = out
    return out


def run_scheduling_scenario(sc: SchedulingScenario,
                            cache: Optional[dict] = None) -> dict:
    """Execute one scenario (plus baseline) into a BENCH record."""
    t0 = time.perf_counter()
    spec_doc = {"run": sc.run.to_dict(),
                "baseline": sc.baseline.to_dict() if sc.baseline else None}
    try:
        run = _execute(sc.run, cache)
        base = _execute(sc.baseline, cache) if sc.baseline else None
    except Exception as e:                 # keep the campaign going
        return {"name": sc.name, "group": sc.group, "tier": sc.tier,
                "status": "error", "spec": spec_doc,
                "metrics": {}, "measured": {}, "checks": [],
                "timing": {"wall_s": time.perf_counter() - t0},
                "error": f"{type(e).__name__}: {e}"}

    metrics = dict(run["metrics"])
    measured = dict(run["measured"])
    if base is not None:
        bm, bw = base["metrics"], base["measured"]
        if "makespan_seconds" in bm:          # sim vs sim: deterministic
            metrics["baseline_makespan_seconds"] = bm["makespan_seconds"]
            if metrics.get("makespan_seconds"):
                metrics["makespan_speedup_x"] = (
                    bm["makespan_seconds"] / metrics["makespan_seconds"])
            if bm.get("busy_p90_s"):
                metrics["busy_p90_delta_pct"] = (
                    metrics["busy_p90_s"] / bm["busy_p90_s"] - 1.0) * 100.0
        if (bm.get("dispatch_rate_msgs_per_s")
                and metrics.get("dispatch_rate_msgs_per_s")):
            # Manager-sharding cells: how much dispatch throughput the
            # extra coordinator clocks buy over the single manager.
            metrics["dispatch_rate_gain_x"] = (
                metrics["dispatch_rate_msgs_per_s"]
                / bm["dispatch_rate_msgs_per_s"])
        if "makespan_seconds" in bw:          # live vs live: wall clock
            measured["baseline_makespan_seconds"] = bw["makespan_seconds"]
            if bw.get("prefetch_wait_s") is not None:
                measured["baseline_prefetch_wait_s"] = bw["prefetch_wait_s"]
                w = measured.get("prefetch_wait_s") or 0.0
                measured["prefetch_wait_reduction_x"] = (
                    bw["prefetch_wait_s"] / w if w > 0 else float("inf"))
            if bw.get("shard_decodes"):
                measured["baseline_shard_decodes"] = bw["shard_decodes"]

    merged = {**measured, **metrics}
    checks = [c.evaluate(merged) for c in sc.checks]
    status = ("ran" if not checks
              else "pass" if all(c["passed"] for c in checks) else "fail")
    return {"name": sc.name, "group": sc.group, "tier": sc.tier,
            "status": status, "spec": spec_doc,
            "metrics": metrics, "measured": measured, "checks": checks,
            "timing": {"wall_s": time.perf_counter() - t0}, "error": None}


# ---------------------------------------------------------------------------
# The declared matrix.
# ---------------------------------------------------------------------------

#: The ISSUE-5 sim acceptance cell base: the heavy-tail dataset (many
#: small tasks under a Pareto tail with the largest near total/P — see
#: repro.tracks.datasets.heavy_tail_manifest), naive arrival order, one
#: task per message, 20 % of the fleet dying mid-job — the regime where
#: the 2020 HPC companion paper shows static chunking collapsing behind
#: stragglers, and where the paper's own §V needed tasks-per-message to
#: stop the manager serializing.
_SIM_BASE = SchedulingSpec(kind="sim", dataset="heavy_tail",
                           phase="radar", backend="sim", n_workers=64,
                           organization="chronological",
                           tasks_per_message=1,
                           fault_profile="deaths_20pct",
                           dataset_limit=12_000)

_FEED_BASE = SchedulingSpec(kind="store_feed", dataset="store_heavy_tail",
                            backend="threads", n_workers=3,
                            organization="chronological",
                            tasks_per_message=1, dataset_limit=None)


def scheduling_scenarios() -> list[SchedulingScenario]:
    """policy x dataset x fault-profile x backend.

    Quick tier = the ISSUE-5 acceptance cells; full tier sweeps every
    policy over fault profiles and adds the radar-like tiny-task regime
    (where adaptive chunking pays through message-overhead amortization
    rather than tail behavior).
    """
    static_base = dataclasses.replace(_SIM_BASE, policy="static")
    fifo_feed = dataclasses.replace(_FEED_BASE, policy="fifo_selfsched")
    out = [
        SchedulingScenario(
            name="sched_heavy_tail_deaths20_adaptive_chunk",
            group="sched_makespan",
            run=dataclasses.replace(_SIM_BASE, policy="adaptive_chunk"),
            baseline=static_base,
            checks=(Check("makespan_speedup_x", "min", 1.3,
                          source="ISSUE 5: adaptive_chunk >= 1.3x vs "
                                 "static @ k=1, heavy tail, 20% deaths"),
                    Check("tasks_completed", "min", 12_000,
                          source="exactly-once under deaths")),
            tier="quick", notes="ISSUE-5 acceptance cell"),
        SchedulingScenario(
            name="sched_heavy_tail_deaths20_sized_lpt",
            group="sched_makespan",
            run=dataclasses.replace(_SIM_BASE, policy="sized_lpt"),
            baseline=static_base,
            checks=(Check("makespan_speedup_x", "min", 1.3,
                          source="ISSUE 5: sized_lpt >= 1.3x vs static "
                                 "@ k=1, heavy tail, 20% deaths"),
                    Check("tasks_completed", "min", 12_000,
                          source="exactly-once under deaths")),
            tier="quick", notes="ISSUE-5 acceptance cell"),
        SchedulingScenario(
            name="sched_store_affinity_prefetch_wait",
            group="sched_locality",
            # k=2 so the run emits real multi-task ASSIGNs — that is
            # what makes the batch_locality gate falsifiable (a k=1 run
            # is single-shard per batch by construction).
            run=dataclasses.replace(_FEED_BASE, policy="shard_affinity",
                                    tasks_per_message=2),
            baseline=fifo_feed,
            checks=(Check("prefetch_wait_reduction_x", "min", 1.2,
                          source="ISSUE 5: shard_affinity cuts measured "
                                 "prefetch wait_s vs fifo_selfsched"),
                    Check("batch_locality", "min", 1.0,
                          source="every multi-task affinity ASSIGN is "
                                 "single-shard"),),
            tier="quick", notes="ISSUE-5 acceptance cell (live feed)"),
        # ISSUE-6 pipelined acceptance cell: the streaming DAG vs the
        # barrier sequence, same heavy-tail tasks / deaths / policy.
        SchedulingScenario(
            name="sched_dag_stream_vs_barrier_heavy_tail",
            group="sched_dag",
            # phase="organize" at 1024 workers puts p0 behind the
            # shared Lustre bandwidth cap (the paper's I/O wall), so
            # the barrier fleet idles there while the DAG overlaps the
            # CPU phases into that shadow.  fault_profile="none": the
            # deaths_20pct profile kills a FIXED worker set at absolute
            # sim times, which the barrier baseline dodges by
            # restarting the fleet at every phase boundary while the
            # single long DAG run pays permanently — that asymmetry
            # measures fleet attrition, not barrier removal.  Fault
            # handling is gated by the exactly-once cells/tests.
            run=dataclasses.replace(_SIM_BASE, kind="dag_sim",
                                    policy="fifo_selfsched",
                                    phase="organize", n_workers=1024,
                                    fault_profile="none"),
            checks=(Check("makespan_speedup_x", "min", 1.5,
                          source="ISSUE 6: streaming DAG >= 1.5x vs "
                                 "barrier phases on heavy tail"),
                    Check("tasks_completed", "min", 36_000,
                          source="exactly-once across streamed phases "
                                 "under 20% deaths")),
            tier="quick", notes="ISSUE-6 acceptance cell (3-phase chain)"),
    ]
    # ISSUE-10 acceptance cell: the full elastic stack (speculation +
    # speed-fed sizing + autoscaler) vs EVERY static-fleet policy under
    # the combined deaths+stragglers storm — the gate compares against
    # whichever static policy does best.
    out.append(SchedulingScenario(
        name="sched_elastic_vs_static_panel",
        group="sched_elastic",
        run=dataclasses.replace(
            _SIM_BASE, kind="elastic_panel", policy="adaptive_chunk",
            fault_profile="deaths20_stragglers10"),
        checks=(Check("makespan_speedup_vs_best_static_x", "min", 1.2,
                      source="ISSUE 10: elastic+speculative+speed-fed "
                             ">= 1.2x vs the best static cell under 20% "
                             "deaths + 4x stragglers"),
                Check("tasks_completed", "min", 12_000,
                      source="exactly-once under deaths, stragglers, "
                             "speculation, and scaling"),
                Check("workers_added", "min", 1,
                      source="the controller actually grew the fleet")),
        tier="quick", notes="ISSUE-10 acceptance cell (elastic panel)"))
    # ISSUE-10 live cell: real worker threads, a real 4x-slow straggler
    # (worker_slow_factor), real speculation and thread spawn/retire.
    # Wall-clock lands in measured; the gated metric is exactly-once.
    out.append(SchedulingScenario(
        name="sched_elastic_live_slow4_speculative",
        group="sched_elastic",
        run=dataclasses.replace(
            _SIM_BASE, kind="elastic_live", backend="threads",
            dataset="tiny", dataset_limit=80, n_workers=4,
            policy="fifo_selfsched", fault_profile="live_slow4",
            speculative=True, speed_feedback=True, elastic=True),
        checks=(Check("tasks_completed", "min", 80,
                      source="ISSUE 10: exactly-once on live threads "
                             "under a 4x straggler with speculation + "
                             "elastic scaling"),
                Check("n_results", "min", 80,
                      source="every result delivered exactly once")),
        tier="quick", notes="ISSUE-10 live cell (threads autoscaler)"))
    # ISSUE-6 manager-sharding scaling curve: tiny radar-like tasks at
    # one task per message drive the §V message wall; the single manager
    # flatlines at 1/msg_overhead dispatches per second while four shard
    # clocks keep scaling.  stragglers_10pct wires worker_speed
    # heterogeneity through the same cells.
    msgwall = dataclasses.replace(_SIM_BASE, dataset="tiny",
                                  dataset_limit=20_000, phase="radar",
                                  policy="fifo_selfsched",
                                  fault_profile="stragglers_10pct")
    for n_workers, tier, checks in (
            (256, "quick", ()),
            (1024, "quick",
             (Check("dispatch_rate_gain_x", "min", 1.3,
                    source="ISSUE 6: 4 manager shards >= 1.3x dispatch "
                           "throughput where one manager flatlines"),)),):
        out.append(SchedulingScenario(
            name=f"sched_msgwall_shards4_w{n_workers}",
            group="sched_msgwall",
            run=dataclasses.replace(msgwall, n_workers=n_workers,
                                    n_manager_shards=4),
            baseline=dataclasses.replace(msgwall, n_workers=n_workers),
            checks=checks, tier=tier,
            notes="sharded-manager dispatch-throughput scaling"))
    # Full tier: the whole policy sweep on the acceptance regime plus a
    # fault-free control (policies must not cost anything when nothing
    # goes wrong) and the tiny-task message-overhead regime.
    for policy in POLICY_NAMES:
        out.append(SchedulingScenario(
            name=f"sched_sweep_deaths20_{policy}",
            group="sched_sweep",
            run=dataclasses.replace(_SIM_BASE, policy=policy),
            baseline=(static_base if policy != "static" else None)))
        out.append(SchedulingScenario(
            name=f"sched_sweep_faultfree_{policy}",
            group="sched_sweep",
            run=dataclasses.replace(_SIM_BASE, policy=policy,
                                    fault_profile="none"),
            baseline=(dataclasses.replace(static_base,
                                          fault_profile="none")
                      if policy != "static" else None)))
    tiny = dataclasses.replace(_SIM_BASE, dataset="tiny", phase="radar",
                               dataset_limit=20_000,
                               fault_profile="none")
    out.append(SchedulingScenario(
        name="sched_tiny_msg_overhead_adaptive_chunk",
        group="sched_tiny",
        run=dataclasses.replace(tiny, policy="adaptive_chunk"),
        baseline=dataclasses.replace(tiny, policy="static"),
        notes="radar regime: chunking amortizes the serial manager"))
    out.append(SchedulingScenario(
        name="sched_store_static_vs_fifo",
        group="sched_locality",
        run=dataclasses.replace(_FEED_BASE, policy="static",
                                tasks_per_message=2),
        baseline=fifo_feed))
    return out


def run_scheduling_campaign(*, quick: bool = False,
                            filters: Sequence[str] = (),
                            seed: Optional[int] = None,
                            progress=None) -> dict:
    """Run the policy matrix into a schema-valid BENCH_scheduling doc."""
    selected = [sc for sc in scheduling_scenarios()
                if (not quick or sc.tier == "quick")
                and sc.matches(filters)]
    if not selected:
        raise ValueError("no scheduling scenarios match the quick/filter "
                         "selection")
    if seed is not None:
        selected = [dataclasses.replace(
            sc, run=dataclasses.replace(sc.run, seed=seed),
            baseline=(dataclasses.replace(sc.baseline, seed=seed)
                      if sc.baseline else None))
            for sc in selected]
    t0 = time.perf_counter()
    records = []
    cache: dict = {}     # one execution per distinct spec per campaign
    for sc in selected:
        rec = run_scheduling_scenario(sc, cache)
        records.append(rec)
        if progress is not None:
            progress(rec)
    counts = {s: 0 for s in ("pass", "fail", "ran", "error")}
    for rec in records:
        counts[rec["status"]] += 1
    doc = {
        "schema": SCHEDULING_SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "created_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "config": {"quick": quick, "filters": list(filters),
                   "seed": seed, "n_selected": len(selected)},
        "environment": {"python": sys.version.split()[0],
                        "platform": sys.platform},
        "scenarios": records,
        "summary": {"total": len(records), **counts,
                    "checked": sum(1 for r in records if r["checks"])},
        "timing": {"wall_s": time.perf_counter() - t0},
    }
    problems = validate_scheduling(doc)
    if problems:      # a bug in this module, not in the scenarios
        raise RuntimeError("scheduling bench produced a schema-invalid "
                           "artifact: " + "; ".join(problems[:5]))
    return doc


def scheduling_summary_lines(doc: dict) -> list[str]:
    """Human-readable summary for the CLI."""
    s = doc["summary"]
    lines = [f"{s['total']} scheduling scenarios: {s['pass']} pass, "
             f"{s['fail']} fail, {s['ran']} ran, {s['error']} error "
             f"[{doc['timing']['wall_s']:.1f}s]"]
    for rec in doc["scenarios"]:
        if rec["status"] == "error":
            lines.append(f"  ERROR {rec['name']}: {rec['error']}")
            continue
        m = {**rec["measured"], **rec["metrics"]}
        bits = [f"makespan={m['makespan_seconds']:.3g}s"]
        if "makespan_speedup_x" in m:
            bits.append(f"speedup={m['makespan_speedup_x']:.2f}x")
        if "busy_p90_s" in m:
            bits.append(f"busy_p90={m['busy_p90_s']:.3g}s")
        if "dispatch_rate_gain_x" in m:
            bits.append(f"dispatch_gain={m['dispatch_rate_gain_x']:.2f}x")
        if "prefetch_wait_s" in m:
            bits.append(f"wait={m['prefetch_wait_s'] * 1e3:.1f}ms")
        if "prefetch_wait_reduction_x" in m:
            bits.append(f"wait_cut={m['prefetch_wait_reduction_x']:.2f}x")
        if "shard_decodes" in m:
            bits.append(f"decodes={m['shard_decodes']:.0f}")
        lines.append(f"  {rec['status']:5s} {rec['name']}: "
                     + " ".join(bits))
        for c in rec["checks"]:
            if not c["passed"]:
                lines.append(f"        FAIL {c['metric']}="
                             f"{c['actual']} vs {c['kind']} {c['expect']}")
    return lines


def main(argv=None) -> int:
    """CLI: ``python -m repro.bench.scheduling [--quick] [--out PATH]``."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.scheduling",
        description="Benchmark the scheduling-policy matrix (makespan, "
                    "busy quantiles, prefetch wait); write "
                    "BENCH_scheduling.json.")
    ap.add_argument("--quick", action="store_true",
                    help="run only the quick tier (the CI acceptance "
                         "cells)")
    ap.add_argument("--filter", action="append", default=[],
                    metavar="SUBSTR")
    ap.add_argument("--out", default="BENCH_scheduling.json",
                    help="artifact path ('-' for stdout only)")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for sc in scheduling_scenarios():
            if sc.matches(args.filter) and (not args.quick
                                            or sc.tier == "quick"):
                print(f"{sc.tier:5s} {sc.group:18s} {sc.name} "
                      f"[{len(sc.checks)} checks]")
        return 0

    if not any(sc.matches(args.filter) and (not args.quick
                                            or sc.tier == "quick")
               for sc in scheduling_scenarios()):
        print("no scheduling scenarios match", file=sys.stderr)
        return 1

    def progress(rec):
        print(f"  {rec['status']:5s} {rec['name']} "
              f"({rec['timing']['wall_s']:.2f}s)", flush=True)

    doc = run_scheduling_campaign(quick=args.quick, filters=args.filter,
                                  seed=args.seed, progress=progress)
    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    for line in scheduling_summary_lines(doc):
        print(line)
    return 1 if (doc["summary"]["fail"] or doc["summary"]["error"]) else 0


if __name__ == "__main__":
    sys.exit(main())
