"""Beyond-paper scenarios — the paper's own declared future work.

§VI: "Additional benchmarking is possible future work, as we did not vary
the number of threads" — plus the knobs the paper fixed on LLSC advice
(0.3 s poll) or abandoned after one data point (tasks/message), and the
failure/heterogeneity story the paper doesn't have at all.  These used to
be bespoke loops in ``benchmarks/beyond_paper.py``; they are now plain
matrix declarations over the campaign engine.
"""

from __future__ import annotations

import dataclasses

from repro.bench.scenarios import Check, RunSpec, Scenario, expand

__all__ = ["beyond_scenarios"]


def beyond_scenarios() -> list[Scenario]:
    scens: list[Scenario] = []

    # Threads-per-process: more threads at fixed total cores means fewer
    # processes sharing the node's I/O path (lower effective NPPN) but
    # fewer concurrent workers; per-task CPU scales as threads**0.7
    # (imperfect intra-task scaling).
    for threads in (1, 2, 4):
        scens.append(Scenario(
            name=f"beyond_threads_{threads}", group="beyond_threads",
            run=RunSpec(dataset="monday", phase="organize",
                        n_workers=1024 // threads - 1, nodes=64,
                        nppn=max(16 // threads, 1),
                        organization="largest_first",
                        cpu_rate_scale=threads ** 0.7),
            notes=f"{threads} threads/process at 1024 fixed cores"))

    # The 0.3 s poll was an LLSC recommendation, never benchmarked.
    scens.extend(expand(
        "beyond_poll", dataset="monday", phase="organize",
        n_workers=511, nodes=64, nppn=8, organization="largest_first",
        poll_interval=[0.05, 0.3, 2.0, 10.0]))

    # tasks/message x task-size regime: a load-balancing tax on big-task
    # jobs, a manager-serialization rescue on tiny-task jobs (why §V
    # needed 300 tasks/message).
    scens.extend(expand(
        "beyond_batch_bigtasks", dataset="monday", phase="organize",
        n_workers=511, nodes=64, nppn=8, organization="largest_first",
        tasks_per_message=[1, 8]))
    scens.extend(expand(
        "beyond_batch_tinytasks", dataset="tiny", phase="radar",
        n_workers=1023, nodes=128, nppn=8, organization="random",
        tasks_per_message=[1, 30, 300]))

    # Worker deaths at increasing rates: self-scheduling re-queues the
    # lost work; makespan grows ~linearly with lost capacity, no cliff.
    scens.extend(expand(
        "beyond_failures", dataset="monday", phase="organize",
        n_workers=511, nodes=64, nppn=8, organization="largest_first",
        failure_timeout=30.0,
        fault_profile=["none", "deaths_5pct", "deaths_20pct"]))

    # Persistent 4x-slow stragglers: the quantitative version of the
    # paper's central qualitative claim — static distribution is hostage
    # to its slowest assignee, self-scheduling routes around it.
    straggler = RunSpec(dataset="monday", phase="organize",
                        n_workers=511, nodes=64, nppn=8,
                        organization="largest_first",
                        fault_profile="stragglers_10pct")
    scens.append(Scenario(
        name="beyond_stragglers10_selfsched_vs_static",
        group="beyond_stragglers",
        run=straggler,
        baseline=dataclasses.replace(
            straggler, mode="static", policy="cyclic",
            organization="chronological"),
        checks=(Check("job_seconds_reduction_pct", "min", 0.0,
                      source="self-scheduling routes around stragglers"),)))
    scens.append(Scenario(
        name="beyond_stragglers10_speculative",
        group="beyond_stragglers",
        run=dataclasses.replace(straggler, speculative=True),
        baseline=straggler,
        notes="MapReduce-style backup tasks on top of self-scheduling"))
    return scens
