"""Campaign CLI: ``python -m repro.bench.campaign``.

Runs the declared scenario matrix (paper reproductions + live smokes +
beyond-paper sweeps) and writes one structured ``BENCH_campaign.json``
artifact.  Exit codes: 0 — every check passed; 1 — at least one scenario
failed a reference check, errored, or regressed against ``--baseline``.

Examples::

    # CI quick tier -> BENCH_campaign.json, non-zero on any failed check
    python -m repro.bench.campaign --quick

    # one group, custom output path
    python -m repro.bench.campaign --filter table1 --out /tmp/t1.json

    # regression-gate against a previous artifact
    python -m repro.bench.campaign --quick --baseline old.json --threshold 0.1
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.bench.beyond import beyond_scenarios
from repro.bench.engine import run_campaign, summary_lines
from repro.bench.paper import paper_scenarios, smoke_scenarios
from repro.bench.scenarios import Scenario

__all__ = ["all_scenarios", "main"]

DEFAULT_OUT = "BENCH_campaign.json"


def all_scenarios() -> list[Scenario]:
    """The full declared matrix, in campaign order."""
    return paper_scenarios() + smoke_scenarios() + beyond_scenarios()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.campaign",
        description="Run the scenario-matrix benchmark campaign and write "
                    "a structured BENCH_campaign.json artifact.")
    ap.add_argument("--quick", action="store_true",
                    help="run only the quick tier (CI: paper table cells, "
                         "headline claims, threads smoke)")
    ap.add_argument("--filter", action="append", default=[],
                    metavar="SUBSTR",
                    help="keep scenarios whose name/group contains SUBSTR "
                         "(repeatable; OR)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"artifact path (default {DEFAULT_OUT}; '-' for "
                         f"stdout only)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override every scenario's organize/fault seed")
    ap.add_argument("--list", action="store_true",
                    help="list matching scenarios and exit")
    ap.add_argument("--baseline", default=None, metavar="JSON",
                    help="previous BENCH_campaign.json to regression-gate "
                         "against")
    ap.add_argument("--kernels", action="store_true",
                    help="also run the kernel-level matrix "
                         "(repro.bench.kernels) and write a second "
                         "artifact next to --out")
    ap.add_argument("--kernels-out", default="BENCH_kernels.json",
                    metavar="JSON",
                    help="artifact path for --kernels "
                         "(default BENCH_kernels.json)")
    ap.add_argument("--storage", action="store_true",
                    help="also run the storage-layer matrix "
                         "(repro.bench.storage: columnar store vs "
                         "CSV-zip) and write a third artifact")
    ap.add_argument("--storage-out", default="BENCH_storage.json",
                    metavar="JSON",
                    help="artifact path for --storage "
                         "(default BENCH_storage.json)")
    ap.add_argument("--scheduling", action="store_true",
                    help="also run the scheduling-policy matrix "
                         "(repro.bench.scheduling: makespan + prefetch "
                         "wait per policy) and write another artifact")
    ap.add_argument("--scheduling-out", default="BENCH_scheduling.json",
                    metavar="JSON",
                    help="artifact path for --scheduling "
                         "(default BENCH_scheduling.json)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max allowed relative job_seconds regression vs "
                         "--baseline (default 0.10)")
    args = ap.parse_args(argv)

    scenarios = [sc for sc in all_scenarios()
                 if (not args.quick or sc.tier == "quick")
                 and sc.matches(args.filter)]
    if args.list:
        for sc in scenarios:
            marks = f" [{len(sc.checks)} checks]" if sc.checks else ""
            print(f"{sc.tier:5s} {sc.group:18s} {sc.name}{marks}")
        print(f"{len(scenarios)} scenarios")
        return 0
    if not scenarios:
        print("no scenarios match", file=sys.stderr)
        return 1

    def progress(rec):
        print(f"  {rec['status']:5s} {rec['name']} "
              f"({rec['timing']['wall_s']:.2f}s)", flush=True)

    doc = run_campaign(scenarios, quick=args.quick, filters=args.filter,
                       seed=args.seed, progress=progress)
    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    for line in summary_lines(doc):
        print(line)

    rc = 0
    if doc["summary"]["fail"] or doc["summary"]["error"]:
        rc = 1
    if args.kernels:
        from repro.bench.kernels import (
            kernel_scenarios, kernel_summary_lines, run_kernel_campaign)
        if not any(sc.matches(args.filter)
                   and (not args.quick or sc.tier == "quick")
                   for sc in kernel_scenarios()):
            # campaign-group filters legitimately may not name any
            # kernel cell; skip rather than fail the whole run
            print("no kernel scenarios match --filter; skipping "
                  "--kernels artifact")
        else:
            kdoc = run_kernel_campaign(quick=args.quick,
                                       filters=args.filter,
                                       seed=args.seed, progress=progress)
            if args.kernels_out != "-":
                with open(args.kernels_out, "w") as f:
                    json.dump(kdoc, f, indent=1, sort_keys=True)
                    f.write("\n")
                print(f"wrote {args.kernels_out}")
            for line in kernel_summary_lines(kdoc):
                print(line)
            if kdoc["summary"]["fail"] or kdoc["summary"]["error"]:
                rc = 1
    if args.storage:
        from repro.bench.storage import (
            run_storage_campaign, storage_scenarios,
            storage_summary_lines)
        if not any(sc.matches(args.filter)
                   and (not args.quick or sc.tier == "quick")
                   for sc in storage_scenarios()):
            print("no storage scenarios match --filter; skipping "
                  "--storage artifact")
        else:
            sdoc = run_storage_campaign(quick=args.quick,
                                        filters=args.filter,
                                        seed=args.seed,
                                        progress=progress)
            if args.storage_out != "-":
                with open(args.storage_out, "w") as f:
                    json.dump(sdoc, f, indent=1, sort_keys=True)
                    f.write("\n")
                print(f"wrote {args.storage_out}")
            for line in storage_summary_lines(sdoc):
                print(line)
            if sdoc["summary"]["fail"] or sdoc["summary"]["error"]:
                rc = 1
    if args.scheduling:
        from repro.bench.scheduling import (
            run_scheduling_campaign, scheduling_scenarios,
            scheduling_summary_lines)
        if not any(sc.matches(args.filter)
                   and (not args.quick or sc.tier == "quick")
                   for sc in scheduling_scenarios()):
            print("no scheduling scenarios match --filter; skipping "
                  "--scheduling artifact")
        else:
            pdoc = run_scheduling_campaign(quick=args.quick,
                                           filters=args.filter,
                                           seed=args.seed,
                                           progress=progress)
            if args.scheduling_out != "-":
                with open(args.scheduling_out, "w") as f:
                    json.dump(pdoc, f, indent=1, sort_keys=True)
                    f.write("\n")
                print(f"wrote {args.scheduling_out}")
            for line in scheduling_summary_lines(pdoc):
                print(line)
            if pdoc["summary"]["fail"] or pdoc["summary"]["error"]:
                rc = 1
    if args.baseline:
        from repro.bench.compare import compare_docs, render_rows
        with open(args.baseline) as f:
            old = json.load(f)
        rows, regressions = compare_docs(old, doc,
                                         threshold=args.threshold)
        for line in render_rows(rows):
            print(line)
        if regressions:
            print(f"{len(regressions)} scenario(s) regressed beyond "
                  f"{args.threshold:.0%}: "
                  + ", ".join(r["name"] for r in regressions))
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
