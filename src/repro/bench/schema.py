"""The BENCH artifact schema: validation + canonical serialization.

Three artifact kinds share the scenario-record shape:

  * ``BENCH_campaign.json`` (``repro.bench.campaign/v1``) — one record per
    scenario plus a campaign summary;
  * ``BENCH_smoke.json`` (``repro.bench.smoke/v1``) — a single record
    emitted by ``benchmarks/run.py --backend ...``;
  * ``BENCH_kernels.json`` (``repro.bench.kernels/v1``) — kernel-level
    records from ``benchmarks/kernel_bench.py``: fused vs unfused
    segment-pipeline throughput, padded-element fraction, intermediate
    host<->device transfer counts, and per-bucket compile cache hits.
    Kernel records use a different ``spec.run`` shape (workload x
    pipeline x backend instead of dataset x triple x backend) and their
    own required metrics.
  * ``BENCH_storage.json`` (``repro.bench.storage/v1``) — storage-layer
    records from ``benchmarks/storage_bench.py``: columnar-store vs
    CSV-zip batch-feed throughput, bytes per point, prefetch wait
    fraction, bitwise feed equality and rebuild determinism.  Storage
    records use a source x phase x prefetch x consume ``spec.run``
    shape.
  * ``BENCH_scheduling.json`` (``repro.bench.scheduling/v1``) —
    scheduling-policy records from ``benchmarks/scheduling_bench.py``:
    makespan + worker-busy quantiles per policy x dataset x
    fault-profile x backend, and prefetch-wait attribution for the
    store-backed shard-affinity cells.  Scheduling records use a
    policy x dataset x fault-profile x backend ``spec.run`` shape.
  * ``BENCH_serving.json`` (``repro.bench.serving/v1``) — continuous-
    ingest serving records from ``benchmarks/serving_bench.py``:
    snapshot byte-identity of the live-appended store vs a batch
    build, tiny-query p50/p99 latency idle vs under concurrent
    ingest, and maximum accepted-but-uncommitted ingest backlog.
    Serving records use a mode x feed-shape x shard-target
    ``spec.run`` shape.
  * ``BENCH_encounters.json`` (``repro.bench.encounters/v1``) —
    encounter-screening records from ``benchmarks/encounters_bench.py``:
    spatial-hash + fused-kernel candidate exactness vs the brute-force
    all-pairs reference, kernel speedup at aerodrome density, and
    scheduling-policy makespan on the genuinely quadratic per-cell
    cost skew.  Encounter records use a kind x dataset x backend x
    policy ``spec.run`` shape; the deterministic gating metric is
    ``screen_seconds_per_candidate`` (modeled screen cost per emitted
    candidate).

Scenario record layout::

    {
      "name": str, "group": str, "tier": str, "status": str,
      "spec":     {"run": {...RunSpec...}, "baseline": {...}|null},
      "metrics":  {...},   # deterministic for a fixed spec + seed
      "measured": {...},   # wall-clock measurements (live backends)
      "checks":   [{metric, kind, expect, tol, source, actual, passed}],
      "timing":   {"wall_s": float},
      "error":    str|null
    }

Determinism contract: for a fixed seed, ``canonical_bytes`` of two runs of
the same campaign are byte-identical.  Everything nondeterministic lives
under the ``NONDETERMINISTIC_KEYS`` (per record: ``measured``/``timing``;
per campaign: ``created_at``/``environment``/``timing``), which canonical
serialization drops.  The validator is hand-rolled (no jsonschema
dependency in the container).
"""

from __future__ import annotations

import json
from typing import Any

__all__ = ["CAMPAIGN_SCHEMA", "SMOKE_SCHEMA", "KERNELS_SCHEMA",
           "STORAGE_SCHEMA", "SCHEDULING_SCHEMA", "SERVING_SCHEMA",
           "ENCOUNTERS_SCHEMA", "OBS_SUMMARY_SCHEMA", "OBS_BENCH_SCHEMA",
           "SCHEMA_VERSION",
           "NONDETERMINISTIC_RECORD_KEYS", "NONDETERMINISTIC_DOC_KEYS",
           "validate_record", "validate_campaign", "validate_smoke",
           "validate_kernels", "validate_storage", "validate_scheduling",
           "validate_serving", "validate_encounters", "validate_obs",
           "validate_obs_summary", "canonical_bytes"]

SCHEMA_VERSION = 1
CAMPAIGN_SCHEMA = "repro.bench.campaign/v1"
SMOKE_SCHEMA = "repro.bench.smoke/v1"
KERNELS_SCHEMA = "repro.bench.kernels/v1"
STORAGE_SCHEMA = "repro.bench.storage/v1"
SCHEDULING_SCHEMA = "repro.bench.scheduling/v1"
SERVING_SCHEMA = "repro.bench.serving/v1"
ENCOUNTERS_SCHEMA = "repro.bench.encounters/v1"
#: Canonical trace summary (``TRACE_summary.json``) emitted by
#: :mod:`repro.obs.summary` — a single-scenario document shaped for
#: ``compare.py``'s smoke-doc path.
OBS_SUMMARY_SCHEMA = "repro.obs/v1"
#: Observability bench matrix (``BENCH_obs.json``) from
#: ``benchmarks/obs_bench.py``: tracing overhead / determinism /
#: straggler-attribution cells.
OBS_BENCH_SCHEMA = "repro.bench.obs/v1"

NONDETERMINISTIC_RECORD_KEYS = ("measured", "timing")
NONDETERMINISTIC_DOC_KEYS = ("created_at", "environment", "timing")

_STATUSES = ("pass", "fail", "ran", "error")
_CHECK_KEYS = ("metric", "kind", "expect", "tol", "source", "actual",
               "passed")
_RECORD_KEYS = ("name", "group", "tier", "status", "spec", "metrics",
                "measured", "checks", "timing", "error")
_SPEC_REQUIRED = ("dataset", "phase", "backend", "mode", "n_workers",
                  "organization", "tasks_per_message", "fault_profile",
                  "seed")
_METRICS_REQUIRED = ("tasks_completed", "messages_sent")
# Kernel-bench records describe a synthetic workload, not a run_job spec.
_KERNEL_SPEC_REQUIRED = ("workload", "pipeline", "backend", "n_archives",
                         "seed")
_KERNEL_METRICS_REQUIRED = ("n_segments", "padded_fraction",
                            "intermediate_transfers")
# Storage-bench records describe a feed path, not a run_job spec.
_STORAGE_SPEC_REQUIRED = ("source", "phase", "prefetch", "consume",
                          "workload", "n_archives", "seed")
_STORAGE_METRICS_REQUIRED = ("n_tracks", "n_points", "bytes_on_disk")
# Scheduling-bench records describe a policy cell: policy x dataset x
# fault profile x backend.  makespan_seconds lives under ``metrics`` on
# the sim backend (deterministic) and ``measured`` on live backends;
# the validator merges both, so one requirement covers both kinds.
_SCHEDULING_SPEC_REQUIRED = ("policy", "dataset", "backend", "n_workers",
                             "organization", "tasks_per_message",
                             "fault_profile", "seed")
_SCHEDULING_METRICS_REQUIRED = ("tasks_completed", "messages_sent",
                                "makespan_seconds")
# Serving-bench records describe a continuous-ingest cell: mode x feed
# shape x shard target.  Latency quantiles live under ``measured``
# (wall-clock); the required metrics are the deterministic counters plus
# the byte-identity flag the acceptance gate reads.
_SERVING_SPEC_REQUIRED = ("mode", "n_files", "obs_per_file",
                          "feed_batch", "target_points", "tiny_queries",
                          "seed")
_SERVING_METRICS_REQUIRED = ("shards_committed", "points_ingested",
                             "snapshot_identical")
# Encounter-bench records describe either a live screen cell (spatial
# hash + fused kernel vs brute force) or a scheduling-policy sim cell
# over screen tasks.  The shared requirement is the deterministic cell
# count; the gating ``screen_seconds_per_candidate`` metric only exists
# on screen-kind records (compare.py skips records without it).
_ENCOUNTERS_SPEC_REQUIRED = ("kind", "dataset", "backend", "policy",
                             "n_workers", "fault_profile", "seed")
_ENCOUNTERS_METRICS_REQUIRED = ("cells",)
# Obs-bench records describe a tracing cell: kind (overhead /
# determinism / straggler attribution) x dataset x backend x fleet x
# fault profile.  Every cell reports the virtual makespan of its traced
# run (the deterministic gating metric).
_OBS_SPEC_REQUIRED = ("kind", "dataset", "backend", "n_workers",
                      "fault_profile", "seed")
_OBS_METRICS_REQUIRED = ("makespan_seconds", "n_events")
# Required headline metrics of a repro.obs/v1 trace summary (the
# ``scenario.metrics`` block compare.py diffs).
_OBS_SUMMARY_METRICS_REQUIRED = ("critical_path_s", "makespan_s",
                                 "straggler_count", "exec_p99_over_p50",
                                 "n_exec_spans")


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_record(rec: Any, where: str = "record",
                    spec_required: tuple = _SPEC_REQUIRED,
                    required_metrics: tuple = _METRICS_REQUIRED
                    ) -> list[str]:
    """Structural validation of one scenario record; returns problems."""
    errs: list[str] = []
    if not isinstance(rec, dict):
        return [f"{where}: not an object"]
    for key in _RECORD_KEYS:
        if key not in rec:
            errs.append(f"{where}: missing key {key!r}")
    for key in ("name", "group", "tier"):
        if key in rec and not isinstance(rec[key], str):
            errs.append(f"{where}.{key}: not a string")
    if rec.get("status") not in _STATUSES:
        errs.append(f"{where}.status: {rec.get('status')!r} not in "
                    f"{_STATUSES}")
    spec = rec.get("spec")
    if not isinstance(spec, dict) or "run" not in spec:
        errs.append(f"{where}.spec: missing 'run' object")
    else:
        run = spec["run"]
        if not isinstance(run, dict):
            errs.append(f"{where}.spec.run: not an object")
        else:
            for key in spec_required:
                if key not in run:
                    errs.append(f"{where}.spec.run: missing key {key!r}")
        base = spec.get("baseline")
        if base is not None and not isinstance(base, dict):
            errs.append(f"{where}.spec.baseline: not an object or null")
    for key in ("metrics", "measured"):
        if key in rec and not isinstance(rec[key], dict):
            errs.append(f"{where}.{key}: not an object")
    if rec.get("status") in ("pass", "fail", "ran"):
        merged = {}
        for key in ("metrics", "measured"):
            if isinstance(rec.get(key), dict):
                merged.update(rec[key])
        for key in required_metrics:
            if not _num(merged.get(key)):
                errs.append(f"{where}: metric {key!r} missing/non-numeric")
    checks = rec.get("checks")
    if not isinstance(checks, list):
        errs.append(f"{where}.checks: not a list")
    else:
        for i, c in enumerate(checks):
            if not isinstance(c, dict):
                errs.append(f"{where}.checks[{i}]: not an object")
                continue
            for key in _CHECK_KEYS:
                if key not in c:
                    errs.append(f"{where}.checks[{i}]: missing {key!r}")
            if not isinstance(c.get("passed"), bool):
                errs.append(f"{where}.checks[{i}].passed: not a bool")
    timing = rec.get("timing")
    if not isinstance(timing, dict) or not _num(timing.get("wall_s")):
        errs.append(f"{where}.timing.wall_s: missing/non-numeric")
    if rec.get("status") == "error" and not isinstance(rec.get("error"), str):
        errs.append(f"{where}.error: status=error needs an error string")
    return errs


def validate_campaign(doc: Any) -> list[str]:
    """Structural validation of a whole campaign artifact."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["campaign: not an object"]
    if doc.get("schema") != CAMPAIGN_SCHEMA:
        errs.append(f"campaign.schema: {doc.get('schema')!r} != "
                    f"{CAMPAIGN_SCHEMA!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append("campaign.schema_version: missing/mismatched")
    if not isinstance(doc.get("config"), dict):
        errs.append("campaign.config: not an object")
    scenarios = doc.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        errs.append("campaign.scenarios: missing/empty list")
        scenarios = []
    names = set()
    for i, rec in enumerate(scenarios):
        where = (f"scenarios[{i}]({rec.get('name', '?')})"
                 if isinstance(rec, dict) else f"scenarios[{i}]")
        errs.extend(validate_record(rec, where))
        if isinstance(rec, dict):
            if rec.get("name") in names:
                errs.append(f"{where}: duplicate scenario name")
            names.add(rec.get("name"))
    summary = doc.get("summary")
    if not isinstance(summary, dict):
        errs.append("campaign.summary: not an object")
    else:
        for key in ("total", "pass", "fail", "ran", "error"):
            if not isinstance(summary.get(key), int):
                errs.append(f"campaign.summary.{key}: missing/non-int")
        if isinstance(doc.get("scenarios"), list) and \
                summary.get("total") != len(doc["scenarios"]):
            errs.append("campaign.summary.total != len(scenarios)")
    return errs


def _validate_matrix_doc(doc: Any, *, label: str, schema: str,
                         spec_required: tuple,
                         required_metrics: tuple) -> list[str]:
    """Shared shape check for the scenario-matrix artifacts (kernels,
    storage): schema/version stamp, config, uniquely-named records with
    the matrix's own spec/metric requirements, and a summary."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return [f"{label}: not an object"]
    if doc.get("schema") != schema:
        errs.append(f"{label}.schema: {doc.get('schema')!r} != "
                    f"{schema!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"{label}.schema_version: missing/mismatched")
    if not isinstance(doc.get("config"), dict):
        errs.append(f"{label}.config: not an object")
    scenarios = doc.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        errs.append(f"{label}.scenarios: missing/empty list")
        scenarios = []
    names = set()
    for i, rec in enumerate(scenarios):
        where = (f"scenarios[{i}]({rec.get('name', '?')})"
                 if isinstance(rec, dict) else f"scenarios[{i}]")
        errs.extend(validate_record(
            rec, where, spec_required=spec_required,
            required_metrics=required_metrics))
        if isinstance(rec, dict):
            if rec.get("name") in names:
                errs.append(f"{where}: duplicate scenario name")
            names.add(rec.get("name"))
    summary = doc.get("summary")
    if not isinstance(summary, dict):
        errs.append(f"{label}.summary: not an object")
    else:
        for key in ("total", "pass", "fail", "ran", "error"):
            if not isinstance(summary.get(key), int):
                errs.append(f"{label}.summary.{key}: missing/non-int")
    return errs


def validate_kernels(doc: Any) -> list[str]:
    """Structural validation of a BENCH_kernels.json artifact."""
    return _validate_matrix_doc(
        doc, label="kernels", schema=KERNELS_SCHEMA,
        spec_required=_KERNEL_SPEC_REQUIRED,
        required_metrics=_KERNEL_METRICS_REQUIRED)


def validate_storage(doc: Any) -> list[str]:
    """Structural validation of a BENCH_storage.json artifact."""
    return _validate_matrix_doc(
        doc, label="storage", schema=STORAGE_SCHEMA,
        spec_required=_STORAGE_SPEC_REQUIRED,
        required_metrics=_STORAGE_METRICS_REQUIRED)


def validate_scheduling(doc: Any) -> list[str]:
    """Structural validation of a BENCH_scheduling.json artifact."""
    return _validate_matrix_doc(
        doc, label="scheduling", schema=SCHEDULING_SCHEMA,
        spec_required=_SCHEDULING_SPEC_REQUIRED,
        required_metrics=_SCHEDULING_METRICS_REQUIRED)


def validate_serving(doc: Any) -> list[str]:
    """Structural validation of a BENCH_serving.json artifact."""
    return _validate_matrix_doc(
        doc, label="serving", schema=SERVING_SCHEMA,
        spec_required=_SERVING_SPEC_REQUIRED,
        required_metrics=_SERVING_METRICS_REQUIRED)


def validate_encounters(doc: Any) -> list[str]:
    """Structural validation of a BENCH_encounters.json artifact."""
    return _validate_matrix_doc(
        doc, label="encounters", schema=ENCOUNTERS_SCHEMA,
        spec_required=_ENCOUNTERS_SPEC_REQUIRED,
        required_metrics=_ENCOUNTERS_METRICS_REQUIRED)


def validate_obs(doc: Any) -> list[str]:
    """Structural validation of a BENCH_obs.json artifact."""
    return _validate_matrix_doc(
        doc, label="obs", schema=OBS_BENCH_SCHEMA,
        spec_required=_OBS_SPEC_REQUIRED,
        required_metrics=_OBS_METRICS_REQUIRED)


def validate_obs_summary(doc: Any) -> list[str]:
    """Structural validation of a TRACE_summary.json (repro.obs/v1).

    A trace summary is not a bench record — it carries no spec/checks/
    timing — so it gets its own shape check: schema stamp, a
    single-``scenario`` metrics block (the compare.py contract), and
    the derived phase/worker/straggler/shard tables.
    """
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["obs_summary: not an object"]
    if doc.get("schema") != OBS_SUMMARY_SCHEMA:
        errs.append(f"obs_summary.schema: {doc.get('schema')!r} != "
                    f"{OBS_SUMMARY_SCHEMA!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append("obs_summary.schema_version: missing/mismatched")
    if not isinstance(doc.get("config"), dict):
        errs.append("obs_summary.config: not an object")
    sc = doc.get("scenario")
    if not isinstance(sc, dict):
        errs.append("obs_summary.scenario: not an object")
    else:
        if not isinstance(sc.get("name"), str):
            errs.append("obs_summary.scenario.name: not a string")
        metrics = sc.get("metrics")
        if not isinstance(metrics, dict):
            errs.append("obs_summary.scenario.metrics: not an object")
        else:
            for key in _OBS_SUMMARY_METRICS_REQUIRED:
                if not _num(metrics.get(key)):
                    errs.append(f"obs_summary.scenario.metrics: "
                                f"{key!r} missing/non-numeric")
    for key in ("phases", "workers", "shards"):
        if not isinstance(doc.get(key), dict):
            errs.append(f"obs_summary.{key}: not an object")
    if not isinstance(doc.get("stragglers"), list):
        errs.append("obs_summary.stragglers: not a list")
    return errs


def validate_smoke(doc: Any) -> list[str]:
    """Structural validation of a BENCH_smoke.json artifact."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["smoke: not an object"]
    if doc.get("schema") != SMOKE_SCHEMA:
        errs.append(f"smoke.schema: {doc.get('schema')!r} != "
                    f"{SMOKE_SCHEMA!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append("smoke.schema_version: missing/mismatched")
    errs.extend(validate_record(doc.get("scenario"), "smoke.scenario"))
    return errs


def canonical_bytes(doc: dict) -> bytes:
    """Deterministic serialization: drop nondeterministic keys, sort keys.

    Two campaigns over the same scenarios with the same seed must agree
    byte-for-byte here (the acceptance gate for reproducible BENCH
    artifacts); wall-clock fields are excluded by construction.
    """
    def strip_record(rec: dict) -> dict:
        return {k: v for k, v in rec.items()
                if k not in NONDETERMINISTIC_RECORD_KEYS}

    out: dict[str, Any] = {k: v for k, v in doc.items()
                           if k not in NONDETERMINISTIC_DOC_KEYS}
    if isinstance(out.get("scenarios"), list):
        out["scenarios"] = [strip_record(r) if isinstance(r, dict) else r
                            for r in out["scenarios"]]
    return json.dumps(out, sort_keys=True,
                      separators=(",", ":")).encode() + b"\n"
