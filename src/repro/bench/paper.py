"""Paper-reference scenarios: Tables I/II, Figs 4-9, §IV.A-C, §V.

These declarations replace the bespoke loops that used to live in
``benchmarks/paper_tables.py`` — each published cell/claim is now one
:class:`~repro.bench.scenarios.Scenario` with explicit reference checks,
so the campaign artifact records the delta against the paper for every
run.

``TABLE_TOLERANCE`` is the documented reproduction tolerance for the
Table I/II job-time cells: the calibrated simulator lands within ~10 % of
most cells (see core/cost_model.py's calibration story); 20 % is the gate
so that cost-model recalibration can't silently drift a cell further than
the tier-1 suite (tests/test_simulator_paper.py) allows.
"""

from __future__ import annotations

import dataclasses

from repro.bench.scenarios import Check, RunSpec, Scenario, expand
from repro.core.cost_model import LEGACY_LAUNCH_PENALTY
from repro.core.triples import feasible_table_cells

__all__ = ["PAPER_TABLE1", "PAPER_TABLE2", "TABLE_TOLERANCE",
           "paper_scenarios", "smoke_scenarios"]

# Job seconds, Tables I & II (chronological / largest-first organization).
PAPER_TABLE1 = {(2048, 32): 5640, (1024, 32): 5944, (512, 32): 7493,
                (256, 32): 11944, (1024, 16): 5963, (512, 16): 7157,
                (256, 16): 11860, (512, 8): 6989, (256, 8): 11860}
PAPER_TABLE2 = {(2048, 32): 5456, (1024, 32): 5704, (512, 32): 6608,
                (256, 32): 11015, (1024, 16): 5568, (512, 16): 6330,
                (256, 16): 10428, (512, 8): 6171, (256, 8): 10428}

TABLE_TOLERANCE = 0.20


def _table_scenarios() -> list[Scenario]:
    out = []
    for group, organization, table, src in (
            ("table1", "chronological", PAPER_TABLE1, "Table I"),
            ("table2", "largest_first", PAPER_TABLE2, "Table II")):
        for cores, nppn in feasible_table_cells():
            out.append(Scenario(
                name=f"{group}_c{cores}_n{nppn}", group=group, tier="quick",
                run=RunSpec.from_table_cell(cores, nppn, organization),
                checks=(Check("job_seconds", "within_rel",
                              table[(cores, nppn)], TABLE_TOLERANCE,
                              f"{src} ({cores} cores, NPPN {nppn})"),)))
    return out


def paper_scenarios() -> list[Scenario]:
    """Every published cell/claim the simulator reproduces."""
    scens = _table_scenarios()

    # Fig 4 headline: 1024 cores/NPPN=16/size-order beats 2048
    # cores/NPPN=32/chronological => same perf from 50 % fewer nodes.
    scens.append(Scenario(
        name="fig4_1024c16_size_beats_2048c32_chrono", group="fig4",
        tier="quick",
        run=RunSpec.from_table_cell(1024, 16, "largest_first"),
        baseline=RunSpec.from_table_cell(2048, 32, "chronological"),
        checks=(Check("job_seconds_reduction_pct", "min", 0.0,
                      source="Fig 4 (half the nodes, same performance)"),)))

    # Figs 5-6: worker-time distribution shift/shape (observational; the
    # shape assertions live in tests/test_simulator_paper.py).
    scens.extend(expand(
        "fig56", dataset="monday", phase="organize",
        n_workers=255, nodes=32, nppn=8,
        organization=["chronological", "largest_first"]))

    # Fig 7: job time degrades as tasks-per-message grows (dataset #1).
    scens.extend(expand(
        "fig7", dataset="monday", phase="organize",
        n_workers=511, nodes=64, nppn=8, organization="largest_first",
        tasks_per_message=[1, 2, 4, 8, 16]))

    # §IV.A: median worker time -14 % vs the legacy batch/block launcher.
    scens.append(Scenario(
        name="sec4a_median_worker_vs_legacy", group="sec4a", tier="quick",
        run=RunSpec(dataset="monday", phase="organize",
                    n_workers=255, nodes=32, nppn=8,
                    organization="largest_first"),
        baseline=RunSpec(dataset="monday", phase="organize", mode="static",
                         policy="block", n_workers=255, nodes=32, nppn=8,
                         organization="chronological",
                         legacy_launch_penalty=LEGACY_LAUNCH_PENALTY),
        checks=(Check("median_busy_delta_pct", "within_abs", -14.0, 4.0,
                      "§IV.A (median worker time -14%)"),)))

    # §IV.B: block -> cyclic archive distribution cuts job time >90 %.
    scens.append(Scenario(
        name="sec4b_archive_block_to_cyclic", group="sec4b", tier="quick",
        run=RunSpec(dataset="archive", phase="archive", mode="static",
                    policy="cyclic", n_workers=1023, nodes=64, nppn=16),
        baseline=RunSpec(dataset="archive", phase="archive", mode="static",
                         policy="block", n_workers=1023, nodes=64, nppn=16),
        checks=(Check("job_seconds_reduction_pct", "min", 90.0,
                      source="§IV.B (>90% reduction)"),)))

    # §IV.C / Fig 8: processing worker-time distribution.
    scens.append(Scenario(
        name="fig8_processing", group="fig8",
        run=RunSpec(dataset="processing", phase="process",
                    n_workers=1023, nodes=64, nppn=16,
                    organization="random"),
        checks=(Check("median_busy_hours", "within_rel", 13.1, 0.10,
                      "§IV.C (median 13.1 h)"),
                Check("max_busy_hours", "max", 32.0,
                      source="§IV.C (all done within 29.6 h)"))))
    scens.append(Scenario(
        name="fig8_legacy_batch_block", group="fig8",
        run=RunSpec(dataset="processing", phase="process", mode="static",
                    policy="block", n_workers=1023, nodes=32, nppn=32,
                    organization="filename",
                    legacy_launch_penalty=LEGACY_LAUNCH_PENALTY),
        checks=(Check("job_seconds", "min", 7 * 86400.0,
                      source="§IV.C (legacy batch/block needed >7 days)"),)))

    # §V / Fig 9: radar dataset, 300 tasks/message, tight span.
    scens.append(Scenario(
        name="fig9_radar", group="fig9", tier="quick",
        run=RunSpec(dataset="radar_messages", phase="radar",
                    n_workers=1023, nodes=128, nppn=8,
                    organization="random"),
        checks=(Check("median_busy_hours", "within_rel", 24.34, 0.05,
                      "§V (median worker busy 24.34 h)"),
                Check("span_hours", "max", 2.5,
                      "§V (worker span 1.12 h; tight by construction)"))))
    return scens


def smoke_scenarios() -> list[Scenario]:
    """Scaled live-backend smokes: the same protocol on real workers.

    The threads smoke is quick-tier (CI runs it on every push); the
    processes smoke and the fault-injected variant stay full-tier.
    """

    def completes_all(_cell: dict) -> tuple[Check, ...]:
        return (Check("tasks_completed", "within_abs", 200.0, 0.0,
                      "engine invariant (exactly-once completion)"),)

    scens = expand(
        "smoke", dataset="smoke", phase="organize",
        backend=["threads", "processes"],
        n_workers=7, nppn=8, nodes=1, tasks_per_message=5,
        checks=completes_all)
    for i, sc in enumerate(scens):
        if sc.run.backend == "threads":
            scens[i] = dataclasses.replace(sc, tier="quick")
    scens.extend(expand(
        "smoke_faults", dataset="smoke", phase="organize",
        backend=["threads"], n_workers=4, tasks_per_message=2,
        fault_profile="live_one_death", failure_timeout=5.0,
        checks=completes_all))
    return scens
