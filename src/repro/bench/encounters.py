"""Encounter-screening benchmark matrix: density x backend x policy.

The kernels matrix benchmarks the segment hot path; this module
benchmarks the *screening* stage built on top of it (ISSUE 8): the
spatial-hash binning (:mod:`repro.geometry.gridhash`) plus the fused
pairwise miss-distance kernel (:mod:`repro.kernels.encounter_screen`)
against the brute-force all-pairs reference, and the scheduling
policies against the genuinely *quadratic* per-cell cost skew the
screening workload produces.  Two cell kinds share one artifact
(``BENCH_encounters.json``, schema ``repro.bench.encounters/v1``):

  * ``screen`` cells — LIVE screening of synthetic density trails
    (:func:`repro.tracks.datasets.screen_density_trails`): bin, batch,
    screen, then brute-force the same rows and require the candidate
    sets to be *exactly* equal (ids and values — the halo-padded hash
    guarantees no pair inside the thresholds can be missed).  The
    deterministic gating metric is ``screen_seconds_per_candidate``
    (modeled SCREEN_PHASE cost over the screened cells per emitted
    candidate); the live ``kernel_speedup_x`` (brute wall / grid wall)
    lands in ``measured`` and is gated by the scenario check, not by
    ``bench.compare``.
  * ``policy_sim`` cells — the discrete-event backend over the
    ``aerodrome_dense`` screen-cell manifest, whose
    ``cpu_cost_hint = cell_cost(occupancy)`` is quadratic in
    occupancy: a handful of hotspot cells dominate total cost, which
    is precisely the skew ``sized_lpt`` / ``adaptive_chunk`` exist to
    handle.  Deterministic per seed, so everything gates byte-stably.

The quick tier is the acceptance cell set: candidate-set exactness on
the tiny manifests (jit AND pallas backends), >= 5x fused-kernel
speedup over the numpy brute force at aerodrome density, sparse cells
skipping the kernel, and ``sized_lpt``/``adaptive_chunk`` each >= 1.3x
lower makespan than ``static`` on the quadratic skew.

Note on backends: ``jit`` (the chunked trace XLA-compiled over the
batch) is the production CPU path; ``pallas`` runs in interpret mode
on CPU and is a *correctness* surface for the TPU kernel, not a CPU
perf path — so the speedup cell runs ``jit`` and the pallas cell only
gates exactness.

CLI::

    PYTHONPATH=src python -m repro.bench.encounters --quick
    PYTHONPATH=src python benchmarks/encounters_bench.py --out BENCH_encounters.json
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Optional, Sequence

import numpy as np

from repro.bench.scenarios import FAULT_PROFILES, Check
from repro.bench.schema import (
    ENCOUNTERS_SCHEMA, SCHEMA_VERSION, validate_encounters)
from repro.runtime.policies import POLICY_NAMES

__all__ = ["EncounterSpec", "EncounterScenario", "encounter_scenarios",
           "run_encounter_scenario", "run_encounter_campaign",
           "encounter_summary_lines", "main"]


@dataclasses.dataclass(frozen=True)
class EncounterSpec:
    """One encounter-bench configuration — JSON-able, hashable."""

    kind: str = "screen"            # screen | policy_sim
    dataset: str = "dense"          # trail kind (screen) / manifest name
    n_aircraft: int = 3000          # screen cells: trail population
    backend: str = "jit"            # pallas | jit | ref (screen); sim
    policy: str = "static"
    phase: str = "screen"
    n_workers: int = 32
    organization: str = "chronological"
    tasks_per_message: int = 1
    fault_profile: str = "none"
    h_thresh_m: float = 926.0
    v_thresh_m: float = 152.4
    cell_deg: float = 0.25
    cell_t_s: float = 300.0
    seed: int = 11

    def __post_init__(self) -> None:
        if self.kind not in ("screen", "policy_sim"):
            raise ValueError(f"unknown cell kind {self.kind!r}")
        if self.kind == "screen":
            if self.backend not in ("pallas", "jit", "ref"):
                raise ValueError(f"screen cells need a kernel backend, "
                                 f"not {self.backend!r}")
            if self.dataset not in ("dense", "sparse"):
                raise ValueError(f"unknown trail kind {self.dataset!r}")
        else:
            if self.backend != "sim":
                raise ValueError("policy_sim cells run on the sim backend")
            if self.policy not in POLICY_NAMES:
                raise ValueError(f"unknown policy {self.policy!r}")
        if self.fault_profile not in FAULT_PROFILES:
            raise ValueError(f"unknown fault profile "
                             f"{self.fault_profile!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EncounterScenario:
    """One named encounter-bench cell."""

    name: str
    group: str
    run: EncounterSpec
    baseline: Optional[EncounterSpec] = None
    checks: tuple[Check, ...] = ()
    tier: str = "full"
    notes: str = ""

    def matches(self, patterns: Sequence[str]) -> bool:
        if not patterns:
            return True
        return any(p in self.name or p in self.group for p in patterns)


# ---------------------------------------------------------------------------
# screen cells.
# ---------------------------------------------------------------------------

def _screen_rows(spec: EncounterSpec) -> list:
    """Density trails -> ScreenRows (one per aircraft)."""
    from repro.kernels.encounter_screen import ScreenRow
    from repro.tracks.datasets import (
        SCREEN_TRAIL_DT_S, screen_density_trails)

    rows = []
    for aid, ts, la, lo, al in screen_density_trails(
            spec.dataset, spec.n_aircraft, spec.seed):
        rows.append(ScreenRow(
            row_id=f"{aid}#s000", group=aid, t0=float(ts[0]),
            lat=np.asarray(la, np.float32),
            lon=np.asarray(lo, np.float32),
            alt=np.asarray(al, np.float32),
            dt_s=SCREEN_TRAIL_DT_S))
    return rows


def _pair_key(c: dict) -> tuple:
    return (c["a"], c["b"])


def _execute_screen(spec: EncounterSpec) -> dict:
    from repro.core.cost_model import SCREEN_PHASE
    from repro.geometry.gridhash import GridSpec, cell_cost
    from repro.kernels.encounter_screen import (
        ScreenConfig, bin_screen_rows, brute_force_screen,
        get_screen_stats, reset_screen_stats, screen_rows_grid)
    from repro.tracks.datasets import SCREEN_ROW_BYTES, SCREEN_TRAIL_DT_S

    rows = _screen_rows(spec)
    # The 4-D hash prunes along TIME as much as space: density trails
    # span ~2 min inside a 30-min feed, so an hour-scale window (the
    # workflow default, sized for hourly track files) would co-bin
    # pairs that never temporally overlap.  Exactness is window-
    # independent — every co-cell pair is screened over its rows' FULL
    # joint span (see ``_pack_cell``), the window only selects which
    # pairs meet — so the bench grid matches the window to the feed.
    grid = GridSpec(cell_deg=spec.cell_deg, cell_t_s=spec.cell_t_s)
    config = ScreenConfig(h_thresh_m=spec.h_thresh_m,
                          v_thresh_m=spec.v_thresh_m,
                          dt_s=SCREEN_TRAIL_DT_S, backend=spec.backend)

    # Warm-up pass compiles every bucket shape, so the measured pass
    # times steady-state screening, not XLA compilation.
    screen_rows_grid(rows, grid=grid, config=config)
    reset_screen_stats()
    t0 = time.perf_counter()
    cands, stats = screen_rows_grid(rows, grid=grid, config=config)
    grid_wall = time.perf_counter() - t0
    kstats = get_screen_stats()

    t0 = time.perf_counter()
    brute = brute_force_screen(rows, config=config)
    brute_wall = time.perf_counter() - t0

    set_equal = int([_pair_key(c) for c in cands]
                    == [_pair_key(c) for c in brute])
    # Minima may differ by float32 ULPs (XLA fuses the distance trace
    # differently from numpy); anything beyond centimetres is a bug.
    values_equal = int(set_equal and all(
        g["t_s"] == b["t_s"] and abs(g["h_m"] - b["h_m"]) <= 1e-2
        and abs(g["v_m"] - b["v_m"]) <= 1e-2
        for g, b in zip(cands, brute)))

    # Modeled (deterministic) screen cost: the SCREEN_PHASE estimate of
    # every multi-row cell at its quadratic cpu_cost_hint — the same
    # numbers the workflow's screen tasks carry.
    bins = bin_screen_rows(rows, grid=grid, config=config)
    occs = [len(ids) for ids in bins.values() if len(ids) >= 2]
    modeled = sum(SCREEN_PHASE.task_seconds(occ * SCREEN_ROW_BYTES,
                                            cpu_cost_hint=cell_cost(occ))
                  for occ in occs)
    metrics = {
        "n_rows": len(rows),
        "cells": stats["cells"],
        "cells_screened": stats["cells_screened"],
        "cells_skipped": stats["cells_skipped"],
        "pairs_screened": stats["pairs_screened"],
        "max_cell_occupancy": stats["max_occupancy"],
        "candidates": stats["candidates"],
        "candidates_raw": stats["candidates_raw"],
        "candidate_set_equal": set_equal,
        "candidate_values_equal": values_equal,
        "kernel_calls": kstats["kernel_calls"],
        "modeled_screen_seconds": modeled,
        "screen_seconds_per_candidate": (
            modeled / max(stats["candidates"], 1)),
    }
    measured = {
        "grid_wall_s": grid_wall,
        "brute_wall_s": brute_wall,
        "kernel_speedup_x": (brute_wall / grid_wall if grid_wall > 0
                             else 0.0),
    }
    return {"metrics": metrics, "measured": measured}


# ---------------------------------------------------------------------------
# policy_sim cells.
# ---------------------------------------------------------------------------

def _execute_policy_sim(spec: EncounterSpec) -> dict:
    from repro.core.cost_model import PHASES
    from repro.runtime import run_job
    from repro.tracks.datasets import SCREEN_ROW_BYTES, get_manifest

    tasks = get_manifest(spec.dataset)
    model = PHASES[spec.phase]
    worker_death, worker_speed, _, _ = FAULT_PROFILES[
        spec.fault_profile].materialize(spec.n_workers, spec.seed)
    result = run_job(
        tasks, None, backend="sim", n_workers=spec.n_workers,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message,
        policy=spec.policy, cost_model=model,
        worker_death=worker_death, worker_speed=worker_speed,
        organize_seed=spec.seed, raise_on_failure=False)
    bq = result.busy_quantiles()
    metrics = {
        "cells": len(tasks),
        "max_cell_occupancy": max(
            t.size_bytes // SCREEN_ROW_BYTES for t in tasks),
        "tasks_completed": len(result.completed_ids),
        "messages_sent": result.messages_sent,
        "makespan_seconds": result.job_seconds,
        "busy_p50_s": bq["p50"],
        "busy_p90_s": bq["p90"],
        "busy_total_s": sum(result.worker_busy),
        "dispatch_digest": result.dispatch_digest,
    }
    return {"metrics": metrics, "measured": {}}


# ---------------------------------------------------------------------------
# Record assembly.
# ---------------------------------------------------------------------------

def _execute(spec: EncounterSpec, cache: Optional[dict] = None) -> dict:
    if cache is not None and spec in cache:
        return cache[spec]
    out = (_execute_screen(spec) if spec.kind == "screen"
           else _execute_policy_sim(spec))
    if cache is not None:
        cache[spec] = out
    return out


def run_encounter_scenario(sc: EncounterScenario,
                           cache: Optional[dict] = None) -> dict:
    """Execute one scenario (plus baseline) into a BENCH record."""
    t0 = time.perf_counter()
    spec_doc = {"run": sc.run.to_dict(),
                "baseline": sc.baseline.to_dict() if sc.baseline else None}
    try:
        run = _execute(sc.run, cache)
        base = _execute(sc.baseline, cache) if sc.baseline else None
    except Exception as e:                 # keep the campaign going
        return {"name": sc.name, "group": sc.group, "tier": sc.tier,
                "status": "error", "spec": spec_doc,
                "metrics": {}, "measured": {}, "checks": [],
                "timing": {"wall_s": time.perf_counter() - t0},
                "error": f"{type(e).__name__}: {e}"}

    metrics = dict(run["metrics"])
    measured = dict(run["measured"])
    if base is not None:
        bm = base["metrics"]
        if "makespan_seconds" in bm:          # sim vs sim: deterministic
            metrics["baseline_makespan_seconds"] = bm["makespan_seconds"]
            if metrics.get("makespan_seconds"):
                metrics["makespan_speedup_x"] = (
                    bm["makespan_seconds"] / metrics["makespan_seconds"])

    merged = {**measured, **metrics}
    checks = [c.evaluate(merged) for c in sc.checks]
    status = ("ran" if not checks
              else "pass" if all(c["passed"] for c in checks) else "fail")
    return {"name": sc.name, "group": sc.group, "tier": sc.tier,
            "status": status, "spec": spec_doc,
            "metrics": metrics, "measured": measured, "checks": checks,
            "timing": {"wall_s": time.perf_counter() - t0}, "error": None}


# ---------------------------------------------------------------------------
# The declared matrix.
# ---------------------------------------------------------------------------

#: ISSUE-8 policy acceptance regime: the aerodrome-dense screen-cell
#: manifest (quadratic cpu_cost_hint skew: max cell ~7 s of a ~90 s
#: total over 585 cells) on 32 fault-free sim workers — enough fleet
#: that the giant hotspot cells dominate the static-chunk makespan,
#: not so much that any order saturates.
_POLICY_BASE = EncounterSpec(kind="policy_sim", dataset="aerodrome_dense",
                             n_aircraft=3000, backend="sim",
                             phase="screen", n_workers=32,
                             organization="chronological",
                             tasks_per_message=1, fault_profile="none")

_TINY = EncounterSpec(kind="screen", dataset="dense", n_aircraft=500,
                      backend="jit")


def encounter_scenarios() -> list[EncounterScenario]:
    """screen exactness/speedup cells + policy cells on quadratic skew."""
    static_base = dataclasses.replace(_POLICY_BASE, policy="static")
    exact_checks = (
        Check("candidate_set_equal", "min", 1,
              source="ISSUE 8: grid+kernel candidates exactly equal "
                     "brute-force all-pairs"),
        Check("candidate_values_equal", "min", 1,
              source="pair minima/time bitwise equal to brute force"),
        Check("cells_skipped", "min", 1,
              source="empty/singleton cells never reach the kernel"),
    )
    out = [
        EncounterScenario(
            name="enc_exact_tiny_dense_jit",
            group="enc_exact",
            run=_TINY,
            checks=exact_checks + (
                Check("candidates", "min", 1,
                      source="tiny dense manifest produces a non-empty "
                             "candidate set (the equality gate is not "
                             "vacuous)"),),
            tier="quick", notes="ISSUE-8 exactness cell (jit backend)"),
        EncounterScenario(
            name="enc_exact_tiny_dense_pallas",
            group="enc_exact",
            run=dataclasses.replace(_TINY, n_aircraft=150,
                                    backend="pallas"),
            checks=exact_checks,
            tier="quick",
            notes="pallas kernel (interpret mode on CPU) exactness — "
                  "correctness surface for the TPU path"),
        EncounterScenario(
            name="enc_dense_kernel_speedup",
            group="enc_speedup",
            run=dataclasses.replace(_TINY, n_aircraft=3000),
            checks=exact_checks + (
                Check("candidates", "min", 100,
                      source="full aerodrome density yields a dense "
                             "candidate set"),
                Check("kernel_speedup_x", "min", 5.0,
                      source="ISSUE 8: fused within-cell screen >= 5x "
                             "over numpy brute force at aerodrome "
                             "density"),),
            tier="quick",
            notes="jit backend (the production CPU path) at the full "
                  "aerodrome-dense population; warm-up pass excludes "
                  "compilation from the measured wall"),
        EncounterScenario(
            name="enc_sparse_density",
            group="enc_density",
            run=dataclasses.replace(_TINY, dataset="sparse",
                                    n_aircraft=900, seed=12),
            checks=(
                Check("candidate_set_equal", "min", 1,
                      source="exactness holds on the sparse regime too"),
                Check("max_cell_occupancy", "max", 8,
                      source="en-route-sparse cells stay an order of "
                             "magnitude below aerodrome density"),
                Check("cells_skipped", "min", 1,
                      source="sparse binning is dominated by "
                             "singleton cells"),),
            tier="quick", notes="paper dataset #1 regime"),
    ]
    for policy in ("sized_lpt", "adaptive_chunk"):
        out.append(EncounterScenario(
            name=f"enc_policy_quadratic_{policy}",
            group="enc_policy",
            run=dataclasses.replace(_POLICY_BASE, policy=policy),
            baseline=static_base,
            checks=(
                Check("makespan_speedup_x", "min", 1.3,
                      source=f"ISSUE 8: {policy} >= 1.3x vs static on "
                             f"quadratic-skew screen cells"),
                Check("tasks_completed", "min", 585,
                      source="every screen cell completes"),),
            tier="quick", notes="ISSUE-8 policy acceptance cell"))
    # Full tier: the whole policy sweep plus the sparse policy control
    # (near-uniform tiny cells: policies must not lose to static).
    for policy in POLICY_NAMES:
        if policy in ("sized_lpt", "adaptive_chunk"):
            continue
        out.append(EncounterScenario(
            name=f"enc_policy_sweep_{policy}",
            group="enc_policy",
            run=dataclasses.replace(_POLICY_BASE, policy=policy),
            baseline=(static_base if policy != "static" else None)))
    out.append(EncounterScenario(
        name="enc_policy_sparse_control_sized_lpt",
        group="enc_policy",
        run=dataclasses.replace(_POLICY_BASE, dataset="enroute_sparse",
                                policy="sized_lpt"),
        baseline=dataclasses.replace(static_base,
                                     dataset="enroute_sparse"),
        notes="near-uniform cells: nothing for LPT to exploit"))
    out.append(EncounterScenario(
        name="enc_dense_mid_scale",
        group="enc_speedup",
        run=dataclasses.replace(_TINY, n_aircraft=2000),
        checks=exact_checks,
        notes="mid-density point on the scaling curve"))
    return out


def run_encounter_campaign(*, quick: bool = False,
                           filters: Sequence[str] = (),
                           seed: Optional[int] = None,
                           progress=None) -> dict:
    """Run the screening matrix into a schema-valid BENCH doc."""
    selected = [sc for sc in encounter_scenarios()
                if (not quick or sc.tier == "quick")
                and sc.matches(filters)]
    if not selected:
        raise ValueError("no encounter scenarios match the quick/filter "
                         "selection")
    if seed is not None:
        selected = [dataclasses.replace(
            sc, run=dataclasses.replace(sc.run, seed=seed),
            baseline=(dataclasses.replace(sc.baseline, seed=seed)
                      if sc.baseline else None))
            for sc in selected]
    t0 = time.perf_counter()
    records = []
    cache: dict = {}     # one execution per distinct spec per campaign
    for sc in selected:
        rec = run_encounter_scenario(sc, cache)
        records.append(rec)
        if progress is not None:
            progress(rec)
    counts = {s: 0 for s in ("pass", "fail", "ran", "error")}
    for rec in records:
        counts[rec["status"]] += 1
    doc = {
        "schema": ENCOUNTERS_SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "created_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "config": {"quick": quick, "filters": list(filters),
                   "seed": seed, "n_selected": len(selected)},
        "environment": {"python": sys.version.split()[0],
                        "platform": sys.platform},
        "scenarios": records,
        "summary": {"total": len(records), **counts,
                    "checked": sum(1 for r in records if r["checks"])},
        "timing": {"wall_s": time.perf_counter() - t0},
    }
    problems = validate_encounters(doc)
    if problems:      # a bug in this module, not in the scenarios
        raise RuntimeError("encounters bench produced a schema-invalid "
                           "artifact: " + "; ".join(problems[:5]))
    return doc


def encounter_summary_lines(doc: dict) -> list[str]:
    """Human-readable summary for the CLI."""
    s = doc["summary"]
    lines = [f"{s['total']} encounter scenarios: {s['pass']} pass, "
             f"{s['fail']} fail, {s['ran']} ran, {s['error']} error "
             f"[{doc['timing']['wall_s']:.1f}s]"]
    for rec in doc["scenarios"]:
        if rec["status"] == "error":
            lines.append(f"  ERROR {rec['name']}: {rec['error']}")
            continue
        m = {**rec["measured"], **rec["metrics"]}
        bits = []
        if "candidates" in m:
            bits.append(f"cells={m['cells']}")
            bits.append(f"occ_max={m['max_cell_occupancy']}")
            bits.append(f"cands={m['candidates']}")
            bits.append(f"exact={m['candidate_set_equal']}")
        if "kernel_speedup_x" in m:
            bits.append(f"kernel={m['kernel_speedup_x']:.1f}x")
        if "makespan_seconds" in m:
            bits.append(f"makespan={m['makespan_seconds']:.3g}s")
        if "makespan_speedup_x" in m:
            bits.append(f"speedup={m['makespan_speedup_x']:.2f}x")
        lines.append(f"  {rec['status']:5s} {rec['name']}: "
                     + " ".join(bits))
        for c in rec["checks"]:
            if not c["passed"]:
                lines.append(f"        FAIL {c['metric']}="
                             f"{c['actual']} vs {c['kind']} {c['expect']}")
    return lines


def main(argv=None) -> int:
    """CLI: ``python -m repro.bench.encounters [--quick] [--out PATH]``."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.encounters",
        description="Benchmark the encounter-screening matrix (candidate "
                    "exactness, kernel speedup, policy makespan on "
                    "quadratic skew); write BENCH_encounters.json.")
    ap.add_argument("--quick", action="store_true",
                    help="run only the quick tier (the CI acceptance "
                         "cells)")
    ap.add_argument("--filter", action="append", default=[],
                    metavar="SUBSTR")
    ap.add_argument("--out", default="BENCH_encounters.json",
                    help="artifact path ('-' for stdout only)")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for sc in encounter_scenarios():
            if sc.matches(args.filter) and (not args.quick
                                            or sc.tier == "quick"):
                print(f"{sc.tier:5s} {sc.group:14s} {sc.name} "
                      f"[{len(sc.checks)} checks]")
        return 0

    if not any(sc.matches(args.filter) and (not args.quick
                                            or sc.tier == "quick")
               for sc in encounter_scenarios()):
        print("no encounter scenarios match", file=sys.stderr)
        return 1

    def progress(rec):
        print(f"  {rec['status']:5s} {rec['name']} "
              f"({rec['timing']['wall_s']:.2f}s)", flush=True)

    doc = run_encounter_campaign(quick=args.quick, filters=args.filter,
                                 seed=args.seed, progress=progress)
    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    for line in encounter_summary_lines(doc):
        print(line)
    return 1 if (doc["summary"]["fail"] or doc["summary"]["error"]) else 0


if __name__ == "__main__":
    sys.exit(main())
