"""Campaign engine: expand scenarios into runs, collect BENCH records.

Every scenario funnels through the unified runtime entry point
(:func:`repro.runtime.run_job`) — full-scale sweeps on the ``sim``
backend, scaled smoke workloads on ``threads``/``processes`` — except
``mode='static'`` baselines, which use the discrete-event
``simulate_static`` (there is no live static distribution to run).

Record shape and the deterministic/measured split are documented in
:mod:`repro.bench.schema`.  The split rule:

  * sim backend — the engine is a deterministic discrete-event machine,
    so *every* metric (including fault-injected runs) goes in ``metrics``;
  * live backend, fault-free — counts and the dispatch digest are decided
    by the shared SchedulerCore and stay deterministic; wall-clock times
    and busy-time quantiles go in ``measured``;
  * live backend with faults — re-queue accounting depends on real
    timing, so only the completion count stays in ``metrics``.
"""

from __future__ import annotations

import dataclasses
import sys
import time
from typing import Optional, Sequence

from repro.bench.scenarios import FAULT_PROFILES, RunSpec, Scenario
from repro.bench.schema import (
    CAMPAIGN_SCHEMA, SCHEMA_VERSION, validate_campaign)
from repro.core.cost_model import PHASES
from repro.runtime import run_job
from repro.runtime.api import default_topology
from repro.runtime.result import RunResult
from repro.tracks.datasets import get_manifest

__all__ = ["execute_spec", "run_scenario", "run_campaign", "csv_rows",
           "summary_lines"]

# Live smoke scenarios poll fast; the paper's 0.3 s default would dominate
# a 200-task smoke job.
LIVE_POLL_DEFAULT = 0.002

# Deterministic keys of RunResult.to_record() on a fault-free live run
# (all decided by the shared SchedulerCore, not by wall clocks).
_LIVE_DET_KEYS = ("backend", "tasks_completed", "n_results",
                  "messages_sent", "n_batches", "dispatch_digest",
                  "reassigned_tasks", "failed_workers", "n_task_failures",
                  "n_workers")
_LIVE_FAULT_DET_KEYS = ("backend", "tasks_completed", "n_task_failures")


def _smoke_fn(task):
    """Per-task worker fn for live smoke scenarios (picklable)."""
    return task.size_bytes


def execute_spec(spec: RunSpec, *,
                 tracer=None) -> tuple[RunResult, int]:
    """Run one RunSpec; returns (result, n_tasks).

    ``tracer`` attaches a :class:`repro.obs.Tracer` to the run (ignored
    by ``mode='static'`` baselines — there is no per-task dispatch to
    trace in a static distribution)."""
    tasks = get_manifest(spec.dataset, limit=spec.dataset_limit)
    model = PHASES[spec.phase]
    if spec.cpu_rate_scale != 1.0:
        model = dataclasses.replace(
            model, cpu_rate=model.cpu_rate * spec.cpu_rate_scale)
    profile = FAULT_PROFILES[spec.fault_profile]
    (worker_death, worker_speed, worker_fail_after,
     worker_slow_factor) = profile.materialize(spec.n_workers, spec.seed)

    if spec.mode == "static":
        from repro.runtime.sim import simulate_static
        default_nodes, default_nppn = default_topology(spec.n_workers)
        result = simulate_static(
            tasks, n_workers=spec.n_workers,
            nodes=spec.nodes if spec.nodes is not None else default_nodes,
            nppn=spec.nppn if spec.nppn is not None else default_nppn,
            model=model, policy=spec.policy,
            organization=spec.organization,
            **({"poll_interval": spec.poll_interval}
               if spec.poll_interval is not None else {}),
            worker_death=worker_death,
            **({"failure_timeout": spec.failure_timeout}
               if spec.failure_timeout is not None else {}),
            legacy_launch_penalty=spec.legacy_launch_penalty,
            worker_speed=worker_speed)
        return result, len(tasks)

    kwargs: dict = {}
    if spec.backend == "sim":
        kwargs.update(cost_model=model, worker_death=worker_death,
                      worker_speed=worker_speed,
                      legacy_launch_penalty=spec.legacy_launch_penalty)
        fn = None
        poll = (spec.poll_interval if spec.poll_interval is not None
                else None)
    else:
        kwargs.update(worker_fail_after=worker_fail_after,
                      worker_slow_factor=worker_slow_factor)
        fn = _smoke_fn
        poll = (spec.poll_interval if spec.poll_interval is not None
                else LIVE_POLL_DEFAULT)
    # Speculation / speed feedback / elastic fleets are policy concerns
    # shared by every backend (run_job validates elastic's backend
    # restrictions at declaration level via RunSpec.__post_init__).
    kwargs.update(speculative=spec.speculative,
                  speculation_max_copies=spec.speculation_max_copies,
                  speed_feedback=spec.speed_feedback,
                  elastic=spec.elastic)
    if poll is not None:
        kwargs["poll_interval"] = poll
    if spec.failure_timeout is not None:
        kwargs["failure_timeout"] = spec.failure_timeout
    result = run_job(
        tasks, fn, backend=spec.backend, n_workers=spec.n_workers,
        nodes=spec.nodes, nppn=spec.nppn,
        organization=spec.organization,
        tasks_per_message=spec.tasks_per_message,
        policy=spec.sched_policy,
        organize_seed=spec.seed, raise_on_failure=False,
        tracer=tracer, **kwargs)
    return result, len(tasks)


def _sim_derived(rec: dict) -> dict:
    """Headline figures the paper reports in hours."""
    return {
        "median_busy_hours": rec["median_worker_busy_s"] / 3600.0,
        "max_busy_hours":
            rec["worker_busy_quantiles_s"]["p100"] / 3600.0,
        "span_hours": rec["worker_time_span_s"] / 3600.0,
    }


def _baseline_derived(rec: dict, base: dict) -> dict:
    out = {"baseline_job_seconds": base["job_seconds"]}
    if base["job_seconds"] > 0:
        out["job_seconds_reduction_pct"] = \
            (1.0 - rec["job_seconds"] / base["job_seconds"]) * 100.0
        out["speedup_x"] = base["job_seconds"] / rec["job_seconds"] \
            if rec["job_seconds"] > 0 else float("inf")
    if base["median_worker_busy_s"] > 0:
        out["median_busy_delta_pct"] = \
            (rec["median_worker_busy_s"] / base["median_worker_busy_s"]
             - 1.0) * 100.0
    return out


def run_scenario(sc: Scenario, *, trace: bool = False) -> dict:
    """Execute one scenario (plus baseline) into a BENCH record.

    ``trace=True`` runs the scenario (not its baseline) with a
    :class:`repro.obs.Tracer` attached and adds an ``obs`` key to the
    record — the trace-summary headline metrics (critical path,
    straggler count, exec-time tails).  Default runs carry no ``obs``
    key, so existing artifacts stay byte-identical."""
    t0 = time.perf_counter()
    spec_doc = {"run": sc.run.to_dict(),
                "baseline": sc.baseline.to_dict() if sc.baseline else None}
    base_rec: Optional[dict] = None
    tracer = None
    if trace:
        from repro.obs import Tracer
        tracer = Tracer()
    try:
        result, n_tasks = execute_spec(sc.run, tracer=tracer)
        if sc.baseline is not None:
            base_result, _ = execute_spec(sc.baseline)
            base_rec = base_result.to_record()
    except Exception as e:                 # keep the campaign going
        return {"name": sc.name, "group": sc.group, "tier": sc.tier,
                "status": "error", "spec": spec_doc,
                "metrics": {}, "measured": {}, "checks": [],
                "timing": {"wall_s": time.perf_counter() - t0},
                "error": f"{type(e).__name__}: {e}"}
    wall_s = time.perf_counter() - t0

    rec = result.to_record()
    rec["n_tasks"] = n_tasks
    if sc.run.backend == "sim":
        rec.update(_sim_derived(rec))
        if base_rec is not None:
            rec.update(_baseline_derived(rec, base_rec))
        metrics, measured = rec, {}
    else:
        det_keys = (_LIVE_DET_KEYS if FAULT_PROFILES[
            sc.run.fault_profile].is_none else _LIVE_FAULT_DET_KEYS)
        metrics = {k: rec[k] for k in det_keys}
        metrics["n_tasks"] = n_tasks
        measured = {k: v for k, v in rec.items()
                    if k not in metrics}
        if base_rec is not None:
            measured.update(_baseline_derived(rec, base_rec))

    merged = {**measured, **metrics}
    checks = [c.evaluate(merged) for c in sc.checks]
    if not checks:
        status = "ran"
    else:
        status = "pass" if all(c["passed"] for c in checks) else "fail"
    out = {"name": sc.name, "group": sc.group, "tier": sc.tier,
           "status": status, "spec": spec_doc,
           "metrics": metrics, "measured": measured, "checks": checks,
           "timing": {"wall_s": wall_s}, "error": None}
    if tracer is not None:
        from repro.obs import summary_from_tracer
        obs = summary_from_tracer(tracer, label=sc.name)
        out["obs"] = {"metrics": obs["scenario"]["metrics"],
                      "dropped": tracer.dropped}
    return out


def run_campaign(scenarios: Sequence[Scenario], *, quick: bool = False,
                 filters: Sequence[str] = (), seed: Optional[int] = None,
                 progress=None) -> dict:
    """Run a scenario set into a schema-valid campaign artifact (dict).

    ``quick`` keeps only tier='quick' scenarios; ``filters`` are OR'd
    substring matches on scenario name/group; ``seed`` overrides every
    spec's organize/fault seed (the campaign-level reproducibility knob).
    """
    selected = [sc for sc in scenarios
                if (not quick or sc.tier == "quick") and sc.matches(filters)]
    if seed is not None:
        selected = [dataclasses.replace(
            sc, run=dataclasses.replace(sc.run, seed=seed),
            baseline=(dataclasses.replace(sc.baseline, seed=seed)
                      if sc.baseline else None))
            for sc in selected]
    t0 = time.perf_counter()
    records = []
    for sc in selected:
        rec = run_scenario(sc)
        records.append(rec)
        if progress is not None:
            progress(rec)
    counts = {s: 0 for s in ("pass", "fail", "ran", "error")}
    for rec in records:
        counts[rec["status"]] += 1
    doc = {
        "schema": CAMPAIGN_SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "created_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "config": {"quick": quick, "filters": list(filters),
                   "seed": seed, "n_selected": len(selected)},
        "environment": {"python": sys.version.split()[0],
                        "platform": sys.platform},
        "scenarios": records,
        "summary": {"total": len(records), **counts,
                    "checked": sum(1 for r in records if r["checks"])},
        "timing": {"wall_s": time.perf_counter() - t0},
    }
    problems = validate_campaign(doc)
    if problems:      # a bug in the engine, not in the scenarios
        raise RuntimeError("engine produced a schema-invalid campaign: "
                           + "; ".join(problems[:5]))
    return doc


# ---------------------------------------------------------------------------
# Back-compat adapters for the benchmarks/ CSV harness.
# ---------------------------------------------------------------------------

def _fmt(x) -> str:
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def csv_rows(records: Sequence[dict]) -> list[str]:
    """Render records as the historical ``name,us_per_call,derived`` rows."""
    rows = []
    for rec in records:
        us = rec["timing"]["wall_s"] * 1e6
        if rec["status"] == "error":
            derived = "ERROR_" + rec["error"].split(":")[0]
        elif rec["checks"]:
            parts = []
            for c in rec["checks"]:
                tag = "ok" if c["passed"] else "FAIL"
                parts.append(f"{c['metric']}={_fmt(c['actual'])}"
                             f"_ref{_fmt(c['expect'])}_{tag}")
            derived = "_".join(parts)
        else:
            merged = {**rec["measured"], **rec["metrics"]}
            derived = f"job_seconds={_fmt(merged.get('job_seconds'))}"
            if "job_seconds_reduction_pct" in merged:
                derived += (f"_reduction={merged['job_seconds_reduction_pct']:.1f}pct")
        rows.append(f"{rec['name']},{us:.0f},{derived}")
    return rows


def summary_lines(doc: dict) -> list[str]:
    """Human-readable campaign summary for the CLI."""
    s = doc["summary"]
    lines = [f"{doc['summary']['total']} scenarios: "
             f"{s['pass']} pass, {s['fail']} fail, {s['ran']} ran "
             f"(unchecked), {s['error']} error "
             f"[{doc['timing']['wall_s']:.1f}s]"]
    for rec in doc["scenarios"]:
        if rec["status"] in ("fail", "error"):
            detail = rec["error"] or "; ".join(
                f"{c['metric']}={_fmt(c['actual'])} vs {c['kind']} "
                f"{_fmt(c['expect'])} (tol {c['tol']}) [{c['source']}]"
                for c in rec["checks"] if not c["passed"])
            lines.append(f"  {rec['status'].upper()} {rec['name']}: {detail}")
    return lines
