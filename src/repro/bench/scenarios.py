"""Declarative benchmark scenarios — the campaign engine's input language.

A :class:`Scenario` names one benchmarkable configuration: a dataset
manifest x resource triple (workers/nodes/NPPN) x task organization x
tasks-per-message x fault/heterogeneity profile x execution backend,
optionally paired with a ``baseline`` run (for the paper's comparative
claims: block vs cyclic, self-scheduling vs legacy batch) and a tuple of
:class:`Check` s against published reference values.

Scenarios are pure data — no clocks, no execution.  The engine
(:mod:`repro.bench.engine`) expands each one into
:func:`repro.runtime.run_job` / ``simulate_static`` invocations and
serializes the outcome into BENCH records (:mod:`repro.bench.schema`).

:func:`expand` is the matrix helper: any :class:`RunSpec` field given as a
list/tuple becomes a swept axis, and the cartesian product becomes one
scenario per cell — that is how the Table I/II grids and the beyond-paper
sweeps are declared in a few lines each.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence, Union

__all__ = ["Check", "FaultProfile", "FAULT_PROFILES", "RunSpec", "Scenario",
           "expand"]


# ---------------------------------------------------------------------------
# Fault / heterogeneity profiles (one matrix axis).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Deterministic fault/heterogeneity injection for one scenario.

    Sim backends: ``death_frac`` of the workers die at staggered sim times
    (``death_at_s + i * death_stride_s``); ``straggler_frac`` run at
    ``straggler_speed`` x nominal.  Live backends: the first worker exits
    without a DONE after ``live_fail_after`` completed tasks, and
    ``live_slow_factor`` makes the first worker run that many times
    slower (the threads mirror of the sim's straggler injection —
    see ``worker_slow_factor`` in :func:`repro.runtime.run_job`).
    """

    death_frac: float = 0.0
    death_at_s: float = 1000.0
    death_stride_s: float = 7.0
    straggler_frac: float = 0.0
    straggler_speed: float = 0.25
    live_fail_after: Optional[int] = None
    live_slow_factor: Optional[float] = None

    @property
    def is_none(self) -> bool:
        return (self.death_frac == 0.0 and self.straggler_frac == 0.0
                and self.live_fail_after is None
                and self.live_slow_factor is None)

    def materialize(self, n_workers: int, seed: int):
        """-> (worker_death, worker_speed, worker_fail_after,
        worker_slow_factor), all seeded."""
        worker_death = None
        if self.death_frac > 0.0:
            worker_death = {i: self.death_at_s + self.death_stride_s * i
                            for i in range(int(n_workers * self.death_frac))}
        worker_speed = None
        if self.straggler_frac > 0.0:
            import numpy as np
            rng = np.random.default_rng(seed)
            speed = np.ones(n_workers)
            slow = rng.choice(n_workers, int(n_workers * self.straggler_frac),
                              replace=False)
            speed[slow] = self.straggler_speed
            worker_speed = speed.tolist()
        worker_fail_after = None
        if self.live_fail_after is not None:
            worker_fail_after = {"w0": self.live_fail_after}
        worker_slow_factor = None
        if self.live_slow_factor is not None:
            worker_slow_factor = {"w0": float(self.live_slow_factor)}
        return (worker_death, worker_speed, worker_fail_after,
                worker_slow_factor)


FAULT_PROFILES: dict[str, FaultProfile] = {
    "none": FaultProfile(),
    "deaths_5pct": FaultProfile(death_frac=0.05),
    "deaths_20pct": FaultProfile(death_frac=0.20),
    "stragglers_10pct": FaultProfile(straggler_frac=0.10),
    # The ISSUE-10 acceptance regime: a fifth of the fleet dies AND a
    # tenth of the survivors-by-lottery run 4x slow — the combined
    # attrition+heterogeneity storm the elastic/speculative stack is
    # gated against.
    "deaths20_stragglers10": FaultProfile(death_frac=0.20,
                                          straggler_frac=0.10,
                                          straggler_speed=0.25),
    "live_one_death": FaultProfile(live_fail_after=3),
    # Live straggler: worker w0 runs 4x slow on the threads backend.
    "live_slow4": FaultProfile(live_slow_factor=4.0),
}


# ---------------------------------------------------------------------------
# One execution configuration.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything needed to launch one job — JSON-able, hashable.

    ``mode='self_sched'`` runs through :func:`repro.runtime.run_job` on the
    chosen ``backend``; ``mode='static'`` runs the LLMapReduce-style
    pre-assigned distribution through ``simulate_static`` (sim only).
    ``nodes``/``nppn`` default to run_job's triples derivation when None.
    """

    dataset: str
    phase: str = "organize"             # cost-model name (core.PHASES)
    backend: str = "sim"                # sim | threads | processes
    mode: str = "self_sched"            # self_sched | static
    policy: str = "cyclic"              # static mode only: block | cyclic
    sched_policy: str = "static"        # self_sched: runtime.policies name
    n_workers: int = 4
    nodes: Optional[int] = None
    nppn: Optional[int] = None
    organization: str = "largest_first"
    tasks_per_message: int = 1
    poll_interval: Optional[float] = None
    failure_timeout: Optional[float] = None
    legacy_launch_penalty: float = 1.0
    cpu_rate_scale: float = 1.0         # threads-per-process modelling
    fault_profile: str = "none"
    speculative: bool = False
    speculation_max_copies: int = 2
    speed_feedback: bool = False
    elastic: bool = False
    dataset_limit: Optional[int] = None
    seed: int = 0                       # organize_seed + fault seeding

    def __post_init__(self) -> None:
        if self.mode not in ("self_sched", "static"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == "static" and self.backend != "sim":
            raise ValueError("static distribution is sim-only")
        from repro.runtime.policies import POLICY_NAMES
        if self.sched_policy not in POLICY_NAMES:
            raise ValueError(
                f"unknown scheduling policy {self.sched_policy!r}; "
                f"choose from {list(POLICY_NAMES)}")
        if self.mode == "static" and self.sched_policy != "static":
            raise ValueError("mode='static' pre-assigns all tasks; "
                             "sched_policy applies to self_sched only")
        if self.fault_profile not in FAULT_PROFILES:
            raise ValueError(f"unknown fault profile {self.fault_profile!r}; "
                             f"choose from {sorted(FAULT_PROFILES)}")
        # A fault profile whose knobs the chosen backend cannot honor must
        # be rejected at declaration time — otherwise the scenario would
        # run fault-free while claiming to measure fault recovery.
        profile = FAULT_PROFILES[self.fault_profile]
        if self.backend == "sim":
            if profile.live_fail_after is not None \
                    or profile.live_slow_factor is not None:
                raise ValueError(
                    f"fault profile {self.fault_profile!r} "
                    f"(live_fail_after/live_slow_factor) needs a live "
                    f"backend")
        elif profile.death_frac > 0.0 or profile.straggler_frac > 0.0:
            raise ValueError(
                f"fault profile {self.fault_profile!r} (timed deaths/"
                f"stragglers) needs the sim backend")
        if self.elastic:
            if self.mode != "self_sched":
                raise ValueError("elastic fleets need mode='self_sched'")
            if self.backend == "processes":
                raise ValueError("elastic fleets run on the sim and "
                                 "threads backends only")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        return cls(**d)

    @classmethod
    def from_table_cell(cls, cores: int, nppn: int, organization: str,
                        **kw) -> "RunSpec":
        """A Tables I/II cell: 'Allocated Compute Cores' counts worker
        processes (2 slots each); one process is the manager."""
        return cls(dataset="monday", phase="organize",
                   n_workers=cores - 1, nodes=cores // nppn, nppn=nppn,
                   organization=organization, **kw)


# ---------------------------------------------------------------------------
# Reference checks against published values.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Check:
    """One assertion against a scenario metric.

    kinds: ``within_rel`` (|actual/expect - 1| <= tol), ``within_abs``
    (|actual - expect| <= tol), ``min`` (actual >= expect), ``max``
    (actual <= expect).
    """

    metric: str
    kind: str
    expect: float
    tol: float = 0.0
    source: str = ""

    _KINDS = ("within_rel", "within_abs", "min", "max")

    def __post_init__(self) -> None:
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown check kind {self.kind!r}")

    def evaluate(self, metrics: dict) -> dict:
        actual = metrics.get(self.metric)
        if actual is None:
            passed = False
        elif self.kind == "within_rel":
            passed = bool(self.expect != 0
                          and abs(actual / self.expect - 1.0) <= self.tol)
        elif self.kind == "within_abs":
            passed = bool(abs(actual - self.expect) <= self.tol)
        elif self.kind == "min":
            passed = bool(actual >= self.expect)
        else:                                     # "max"
            passed = bool(actual <= self.expect)
        delta_pct = ((actual / self.expect - 1.0) * 100.0
                     if actual is not None and self.expect else None)
        return {"metric": self.metric, "kind": self.kind,
                "expect": self.expect, "tol": self.tol,
                "source": self.source, "actual": actual,
                "delta_pct": delta_pct, "passed": passed}


# ---------------------------------------------------------------------------
# Scenario + matrix expansion.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named cell of the campaign matrix."""

    name: str
    group: str
    run: RunSpec
    baseline: Optional[RunSpec] = None
    checks: tuple[Check, ...] = ()
    tier: str = "full"                  # "quick" scenarios also run in CI
    notes: str = ""

    def matches(self, patterns: Sequence[str]) -> bool:
        """Substring filter over name/group (OR across patterns)."""
        if not patterns:
            return True
        return any(p in self.name or p in self.group for p in patterns)


ChecksFor = Callable[[dict], tuple[Check, ...]]

# Swept-axis abbreviations used in expanded scenario names.
_ABBREV = {"tasks_per_message": "k", "poll_interval": "poll",
           "organization": "org", "fault_profile": "", "backend": "",
           "n_workers": "w", "cpu_rate_scale": "cpu", "dataset": "",
           "sched_policy": ""}


def expand(group: str, *, tier: str = "full",
           checks: Union[tuple[Check, ...], ChecksFor] = (),
           baseline: Optional[Callable[[dict], Optional[RunSpec]]] = None,
           notes: str = "", **axes) -> list[Scenario]:
    """Expand a scenario matrix: list-valued RunSpec fields are swept.

    ``checks`` (and ``baseline``) may be callables receiving the swept-axis
    dict of each cell, so reference values can vary across the grid::

        expand("beyond_poll", dataset="monday", n_workers=511,
               nodes=64, nppn=8, poll_interval=[0.05, 0.3, 2.0, 10.0])

    Scenario names are ``{group}_{axis}{value}...`` over the swept axes,
    in declaration order.
    """
    swept = {k: v for k, v in axes.items()
             if isinstance(v, (list, tuple))}
    fixed = {k: v for k, v in axes.items() if k not in swept}
    out: list[Scenario] = []
    for combo in itertools.product(*swept.values()) if swept else [()]:
        cell = dict(zip(swept.keys(), combo))
        spec = RunSpec(**fixed, **cell)
        suffix = "".join(f"_{_ABBREV.get(k, k)}{v}"
                         for k, v in cell.items())
        cell_checks = checks(cell) if callable(checks) else tuple(checks)
        cell_base = baseline(cell) if baseline is not None else None
        out.append(Scenario(
            name=f"{group}{suffix}" if suffix else group,
            group=group, run=spec, baseline=cell_base,
            checks=cell_checks, tier=tier, notes=notes))
    return out
